"""Random forest / extra-trees training on top of the histogram CART trainer.

Inference semantics mirror sklearn's soft voting: each tree emits a class
distribution, the ensemble averages them (paper Sec. II-A).  That average is
exactly what InTreeger converts to fixed point at codegen time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.trees.cart import TreeArrays, _quantile_bins, train_tree


@dataclass
class RandomForestClassifier:
    n_estimators: int = 10
    max_depth: int = 6
    min_samples_leaf: int = 1
    min_samples_split: int = 2
    max_features: Optional[str] = "sqrt"  # "sqrt" | None (all)
    bootstrap: bool = True
    extra_random: bool = False  # True -> ExtraTrees-style random splits
    n_bins: int = 64
    seed: int = 0

    trees_: List[TreeArrays] = field(default_factory=list)
    n_classes_: int = 0
    n_features_: int = 0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForestClassifier":
        X = np.asarray(X, np.float32)
        y = np.asarray(y)
        classes = np.unique(y)
        self.n_classes_ = int(classes.max()) + 1
        self.n_features_ = X.shape[1]
        rng = np.random.default_rng(self.seed)
        binned = _quantile_bins(X, self.n_bins, rng)
        if self.max_features == "sqrt":
            mf = max(1, int(np.sqrt(X.shape[1])))
        else:
            mf = None
        self.trees_ = []
        n = X.shape[0]
        for _ in range(self.n_estimators):
            if self.bootstrap:
                idx = rng.integers(0, n, size=n)
            else:
                idx = np.arange(n)
            codes, edges = binned
            tree = train_tree(
                X[idx],
                y[idx],
                self.n_classes_,
                max_depth=self.max_depth,
                min_samples_leaf=self.min_samples_leaf,
                min_samples_split=self.min_samples_split,
                max_features=mf,
                n_bins=self.n_bins,
                extra_random=self.extra_random,
                rng=rng,
                _binned=(codes[idx], edges),
            )
            self.trees_.append(tree)
        return self

    # float64 oracle — the "standard floating-point implementation" baseline
    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        acc = np.zeros((X.shape[0], self.n_classes_), np.float64)
        for t in self.trees_:
            acc += t.predict_proba(X)
        return acc / len(self.trees_)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.predict_proba(X).argmax(axis=1)

    @property
    def max_tree_depth(self) -> int:
        return max(t.depth for t in self.trees_)

"""Serving engines.

``LMEngine``: batched prefill + greedy/temperature decode for the LM archs
(jitted prefill and decode steps, KV/state cache carried on device).

``TreeEngine``: the paper's serving path — a thin shape-bucketing wrapper
over any registered :class:`~repro.backends.TreeBackend` (reference jnp,
Pallas kernel, or either emitted-C flavor compiled into a shared library),
mirroring InTreeger's "one model, any hardware" deployment story.  The engine
is also where the ForestIR pipeline (IR -> layout -> backend) is resolved: it
materializes the layout the backend prefers (or the caller pins) before
constructing it, so callers hand over a ForestIR or any artifact and never
deal in layouts unless they want to.  It is the execution
layer behind the gateway (``repro.serve.gateway``): for backends that compile
per shape, incoming batches are padded up to a small set of power-of-two row
buckets so each (model, mode, backend, bucket) compiles exactly once, no
matter how ragged the request stream is.  Tree traversal is row-independent,
so padding rows never perturb real rows — bucketed outputs are bit-identical
to unbucketed ones.  Shape-oblivious backends (native C) skip padding
entirely; the engine consults ``backend.capabilities`` for both decisions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


class LMEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(lambda p, b: tfm.prefill(cfg, p, b, max_seq=max_seq))
        self._decode = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))

    def generate(self, batch: dict, n_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Greedy (T=0) or sampled decode.  Returns (B, n_tokens) int32."""
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        toks = []
        b = logits.shape[0]
        for i in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32).reshape(b, 1)
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(toks, axis=1)


def bucket_rows(b: int, *, max_bucket: int = 4096) -> int:
    """Padded row count for a batch of ``b`` rows: the next power of two,
    capped at ``max_bucket``; beyond the cap, the next ``max_bucket``
    multiple (so huge batches still see a bounded shape vocabulary)."""
    if b <= 0:
        raise ValueError("batch must have at least one row")
    if b >= max_bucket:
        return -(-b // max_bucket) * max_bucket
    return 1 << (b - 1).bit_length()


class TreeEngine:
    """Shape-bucketing wrapper over one :class:`~repro.backends.TreeBackend`.

    ``packed`` is a :class:`~repro.ir.ForestIR` or any materialized layout
    artifact; ``backend`` is either a registered backend name
    (``"reference"``, ``"pallas"``, ``"native_c"``, ``"native_c_table"``) or
    an already-constructed backend instance (then ``packed``/``mode`` are
    taken from it).  ``layout`` pins a ForestIR layout; by default the
    backend's declared ``preferred_layout`` is materialized (resolution goes
    through the artifact's IR back-reference, so a ``pack_forest`` output can
    feed a ragged-only backend without re-quantizing).  ``predict``/
    ``predict_scores`` accept any row count; for shape-compiling backends the
    batch is padded to a :func:`bucket_rows` bucket so each bucket compiles
    once (tracked in ``compiled_buckets``).  ``max_bucket`` defaults to the
    backend's ``preferred_block_rows`` hint so padded shapes line up with its
    internal tiling.
    """

    def __init__(self, packed=None, *, mode: str = "integer",
                 backend="reference", backend_kwargs: Optional[dict] = None,
                 max_bucket: Optional[int] = None, layout: Optional[str] = None):
        from repro.backends import backend_class, create_backend
        from repro.ir import resolve_artifact

        if isinstance(backend, str):
            caps = backend_class(backend).capabilities
            wanted = layout or caps.preferred_layout
            caps.require_layout(wanted, backend)
            self.backend = create_backend(
                backend, resolve_artifact(packed, wanted), mode=mode,
                **(backend_kwargs or {})
            )
        else:
            if layout is not None and getattr(backend, "layout", "padded") != layout:
                raise ValueError(
                    f"layout {layout!r} conflicts with the constructed "
                    f"backend's artifact (layout {backend.layout!r}); "
                    "materialize the backend on the wanted layout instead"
                )
            self.backend = backend
        self.packed = self.backend.packed
        self.mode = self.backend.mode
        caps = self.backend.capabilities
        self.max_bucket = max_bucket or caps.preferred_block_rows or 4096
        self.compiled_buckets: set[int] = set()

    @property
    def backend_name(self) -> str:
        return self.backend.name

    @property
    def layout(self) -> str:
        """The ForestIR layout the backend is walking."""
        return self.backend.layout

    @property
    def deterministic(self) -> bool:
        """True when outputs are bit-exact integer scores (cacheable)."""
        return self.backend.deterministic

    def warm(self, max_rows: int) -> None:
        """Pre-compile every bucket any batch of 1..``max_rows`` rows can map
        to: the power-of-two buckets below ``max_bucket``, plus the
        ``max_bucket``-multiple shapes used once batches reach the cap.  For
        shape-oblivious backends one call builds the artifact (e.g. compiles
        the native library) and no further shapes exist."""
        zeros = lambda nb: np.zeros((nb, self.packed.n_features), np.float32)
        if not self.backend.capabilities.compiles_per_shape:
            self.predict(zeros(1))
            return
        # `top` is the bucket the largest batch rounds UP to — walking only to
        # max_rows would leave the covering bucket cold (e.g. 20 rows -> 32)
        top = bucket_rows(max_rows, max_bucket=self.max_bucket)
        nb = 1
        while nb <= top and nb < self.max_bucket:
            self.predict(zeros(nb))
            nb *= 2
        if top >= self.max_bucket:
            for m in range(self.max_bucket, top + 1, self.max_bucket):
                self.predict(zeros(m))

    def padded_rows(self, b: int) -> int:
        """Rows actually executed for a ``b``-row batch: the bucket shape
        for compiling backends, ``b`` itself for shape-oblivious ones."""
        if not self.backend.capabilities.compiles_per_shape:
            return b
        return bucket_rows(b, max_bucket=self.max_bucket)

    def _run(self, X):
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected (B, F) features, got shape {X.shape}")
        b = X.shape[0]
        nb = self.padded_rows(b)
        if nb != b:
            X = np.concatenate([X, np.zeros((nb - b, X.shape[1]), np.float32)])
        scores, preds = self.backend.predict_scores(X)
        if self.backend.capabilities.compiles_per_shape:
            # only a predict that actually returned has compiled its bucket
            self.compiled_buckets.add(nb)
        return np.asarray(scores)[:b], np.asarray(preds)[:b]

    def predict(self, X) -> np.ndarray:
        _, preds = self._run(X)
        return preds

    def predict_scores(self, X):
        return self._run(X)

# One-step entry points for the repo's standard workflows.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast conformance check bench bench-smoke ci \
	serve-trees serve-gateway

# tier-1 verify (see ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# tier-1 minus the long end-to-end drivers (the `slow` marker) — what the
# CI tier-1 job runs; `make check` still runs everything
test-fast:
	$(PY) -m pytest -q -m "not slow"

# cross-(backend, layout, variant) bit-identity suite: reference / pallas
# (gather + leaf_major linear scan) / native_c / native_c_table (block_rows
# 1/4/8) x padded / ragged / leaf_major
conformance:
	$(PY) -m pytest -q tests/test_backends.py

# the full gate: tier-1 tests, then the conformance suite standalone
check: test conformance

bench:
	$(PY) benchmarks/run.py

# tiny-forest bench pass: proves every backend executes and produces the
# benchmarks/artifacts/bench_results.json artifact CI uploads
bench-smoke:
	REPRO_BENCH_TINY=1 $(PY) benchmarks/run.py backend_matrix memory_footprint

# exactly what .github/workflows/ci.yml runs, as one local target
ci: test-fast conformance bench-smoke

serve-trees:
	$(PY) -m repro.launch.serve --trees

serve-gateway:
	$(PY) -m repro.launch.serve --trees --gateway

"""QuickScorer bitvector C: sorted threshold streams compiled as static data.

The emitted scorer is the sequential form the bitvector layout is built for
(the jnp backend evaluates the same tables data-parallel instead):

    for each feature f:
      for each entry e in f's ASCENDING threshold list:
        if (x[f] <= key[e]) break;        /* every later test is true too */
        v[tree[e]] &= mask[e];            /* clear the false node's left leaves */
    for each tree: exit leaf = lowest set bit of v[tree]

No per-row pointer chasing: the hot loop is a linear stream over sorted keys
with one well-predicted break per feature, and the per-tree state is
``words`` uint64 accumulators (multi-word for trees beyond 64 leaves).  The
lowest-set-bit scan uses ``__builtin_ctzll`` under GCC/Clang and a portable
shift loop otherwise — build with ``-DREPRO_NO_BUILTINS`` to force the
portable path (the CI degradation job does exactly that).

At batch, the per-row scorer is memory-bound: every row re-streams the whole
threshold table (~24 B/entry — hundreds of KB per row on large forests).  So
``predict_batch`` walks blocks of 8 rows through one shared pass over the
stream, amortizing every table load 8x.  The block keeps the early exit —
ascending keys make ``x > key`` monotone decreasing per row, so an 8-bit
``act`` bitset recomputed per entry only ever loses bits and ``act == 0``
ends the feature for the whole block — and applies masks branch-free:
``m[k] | (((uint64_t)((act >> r) & 1)) - 1)`` is the mask when row ``r`` is
active and all-ones (a no-op AND) when it is not.  Live-leaf state is
row-minor (``v[(t*words + k)*8 + r]``) so one (tree, word) touch lands the
whole block's lane on a single cache line.

``interleave=K`` is the v-QuickScorer multi-tree blocking knob (Lucchese et
al.; Koschel/Buschjäger/Lucchese for the ARM line): each feature's stream is
padded to a multiple of K with inert entries (key = INT32_MAX never tests
true; mask = all-ones is a no-op AND) and emitted as K-entry *groups*.  At
large tree counts consecutive ascending-key entries belong to K different
trees, so a group is K independent mask applies with no store-to-load chain
between them — the emitter unrolls them — and the block's early-exit test
collapses from one per entry to one per group: the group's FIRST key is its
smallest, so no row exceeding it means no row exceeds any later key in the
feature either.  One broadcast feature load now feeds K mask applies.

The blocked apply is lifted to SIMD with the same runtime-cpuid dispatch and
``simd_isa()`` export as the table-walk unit, but variant-named: the
dispatcher reports the emitted variant that will actually run
(``avx512-k8`` / ``avx2-k8`` / ``neon-k8`` / ``scalar``), never a
compile-time capability.  AVX2: one broadcast compare per entry yields the
8-row active set, sign-extension widens it to 64-bit lane masks, and
``v &= mk | ~act`` folds to two ``andnot`` ops per half-block per word.
AVX-512 (F+VL): the compare writes a ``__mmask8`` directly and the whole
apply is ONE ``_mm512_mask_and_epi64`` on the full 8-row lane — the mask
registers collapse the sign-extend/andnot dance entirely.  NEON: two
``vcgtq_s32`` halves widened by self-``vzip``, apply as two ``vbic`` ops per
row pair.  The x86 variants also vectorize the leaf-accumulate tail
(per-row ``maskload``/``add_epi32`` accumulators — same per-tree add order,
so partials stay bit-identical).  The scalar 8-lane block remains in every
TU as the mandatory fallback (``-DREPRO_NO_SIMD`` / non-GNU builds).

Integer translation unit only: like the other deterministic C backends, both
flint and integer modes run the uint32-partials unit and diverge only in the
shared numpy finalize, so the emitter refuses anything else.  The scalar
paths need only <stdint.h>.
"""
from __future__ import annotations

import numpy as np

from repro.codegen.table_emitter import _array_lines, _i32, _simd_prelude

_CTZ64 = [
    "static int ctz64(uint64_t x) {",
    "#if defined(__GNUC__) && !defined(REPRO_NO_BUILTINS)",
    "  return __builtin_ctzll(x);",
    "#else",
    "  int n = 0;",
    "  while (!(x & 1u)) { x >>= 1; ++n; }",
    "  return n;",
    "#endif",
    "}",
]


def _u64(v: int) -> str:
    return f"0x{int(v) & 0xFFFFFFFFFFFFFFFF:016x}ull"


def _i64(v: int) -> str:
    return f"{int(v)}ll"


_BLOCK_ROWS = 8  # rows sharing one pass over the threshold stream


def _interleaved_stream(bv, k: int):
    """The K-group-padded threshold stream: ``(feat_off, key, tree, mask)``.

    Each feature's ascending slice is padded to a multiple of ``k`` with
    inert entries — key INT32_MAX (``x > key`` is never true, and the
    per-row scalar scorer's ``x <= key`` break fires exactly as it would at
    the real end of the stream), tree 0, mask all-ones (a no-op AND even if
    applied) — so every emitted group loop runs whole K-entry groups with
    no runtime remainder handling.  ``k == 1`` returns the layout's arrays
    unchanged.
    """
    if k <= 1:
        return bv.feat_offsets, bv.thr_key, bv.thr_tree, bv.thr_mask
    ones = np.full(bv.words, np.uint64(0xFFFFFFFFFFFFFFFF), np.uint64)
    keys, trees, masks = [], [], []
    off = np.zeros(bv.n_features + 1, np.int64)
    for f in range(bv.n_features):
        a, b = int(bv.feat_offsets[f]), int(bv.feat_offsets[f + 1])
        keys.append(bv.thr_key[a:b])
        trees.append(bv.thr_tree[a:b])
        masks.append(bv.thr_mask[a:b])
        pad = (-(b - a)) % k
        if pad:
            keys.append(np.full(pad, np.int32(2**31 - 1), np.int32))
            trees.append(np.zeros(pad, np.int32))
            masks.append(np.broadcast_to(ones, (pad, bv.words)))
        off[f + 1] = off[f] + (b - a) + pad
    return (
        off,
        np.concatenate(keys) if keys else bv.thr_key,
        np.concatenate(trees) if trees else bv.thr_tree,
        (np.concatenate(masks).reshape(-1, bv.words)
         if masks else bv.thr_mask),
    )


def _scalar_block(t, c, f, w, r, k, tail) -> list:
    """The mandatory scalar 8-row block, K-entry group loop."""
    lines = [
        f"static void predict_block{r}(const int32_t* data, uint32_t* scores) {{",
        "  /* row-minor state, cache-line aligned: one (tree, word) touch",
        f"     lands the whole block's lane on one line — v[(t*{w} + k)*{r} + rr] */",
        f"  uint64_t v[{t * w * r}] __attribute__((aligned(64)));",
        f"  for (int i = 0; i < {t * w}; ++i) {{",
        "    const uint64_t iv = init_mask[i];",
        f"    for (int rr = 0; rr < {r}; ++rr) v[i * {r} + rr] = iv;",
        "  }",
        f"  for (int f = 0; f < {f}; ++f) {{",
        f"    int32_t xf[{r}];",
        f"    for (int rr = 0; rr < {r}; ++rr) xf[rr] = data[rr * {f} + f];",
        f"    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; e += {k}) {{",
        "      uint32_t act0 = 0;",
        "      {",
        "        const int32_t key = thr_key[e];",
        f"        for (int rr = 0; rr < {r}; ++rr)",
        "          act0 |= (uint32_t)(xf[rr] > key) << rr;",
        "      }",
        "      if (!act0) break;  /* group's smallest key: rest false too */",
        f"      for (int64_t ej = e; ej < e + {k}; ++ej) {{",
        "        uint32_t act = act0;",
        f"        if (ej != e) {{",
        "          const int32_t key = thr_key[ej];",
        "          act = 0;",
        f"          for (int rr = 0; rr < {r}; ++rr)",
        "            act |= (uint32_t)(xf[rr] > key) << rr;",
        "        }",
        f"        uint64_t* vt = v + (int64_t)thr_tree[ej] * {w * r};",
        f"        const uint64_t* m = thr_mask + ej * {w};",
        f"        for (int kk = 0; kk < {w}; ++kk) {{",
        "          const uint64_t mk = m[kk];",
        f"          uint64_t* vp = vt + kk * {r};",
        f"          for (int rr = 0; rr < {r}; ++rr)",
        "            vp[rr] &= mk | (((uint64_t)((act >> rr) & 1u)) - 1u);",
        "        }",
        "      }",
        "    }",
        "  }",
    ]
    return lines + tail


def _x86_vector_tail(t, c, w, r) -> list:
    """Leaf extraction + class adds with per-row __m256i accumulators.

    Row-outer / tree-inner, trees ascending — exactly the scalar tail's
    per-row add order, so the uint32 lane sums are bit-identical.  Classes
    load/store via ``maskload``/``maskstore`` (8-lane chunks, tail chunk
    masked) so no read ever crosses the leaf table's end.
    """
    nacc = -(-c // 8)
    lines = []
    for a in range(nacc):
        rem = min(8, c - a * 8)
        setr = ", ".join("-1" if i < rem else "0" for i in range(8))
        lines.append(
            f"  const __m256i cmask{a} = _mm256_setr_epi32({setr});")
    lines.append(f"  for (int rr = 0; rr < {r}; ++rr) {{")
    for a in range(nacc):
        lines.append(f"    __m256i acc{a} = _mm256_setzero_si256();")
    lines += [
        f"    for (int t = 0; t < {t}; ++t) {{",
        "      int leaf = 0;",
        f"      for (int k = 0; k < {w}; ++k) {{",
        f"        const uint64_t word = v[(t * {w} + k) * {r} + rr];",
        "        if (word) { leaf = k * 64 + ctz64(word); break; }",
        "      }",
        "      const int32_t* lf = (const int32_t*)(leaf_fixed"
        f" + (leaf_off[t] + leaf) * {c});",
    ]
    for a in range(nacc):
        lines.append(
            f"      acc{a} = _mm256_add_epi32(acc{a}, "
            f"_mm256_maskload_epi32(lf + {a * 8}, cmask{a}));")
    lines.append("    }")
    lines.append(f"    int32_t* out = (int32_t*)(scores + rr * {c});")
    for a in range(nacc):
        lines.append(
            f"    _mm256_maskstore_epi32(out + {a * 8}, cmask{a}, acc{a});")
    lines += ["  }", "}"]
    return lines


def _avx2_block(t, c, f, w, r, k, tail) -> list:
    """AVX2 8-row block: broadcast compare + double-andnot apply, K-unrolled."""

    def apply(ej: str, cmp: str) -> list:
        body = [
            f"        const __m256i alo = _mm256_cvtepi32_epi64("
            f"_mm256_castsi256_si128({cmp}));",
            f"        const __m256i ahi = _mm256_cvtepi32_epi64("
            f"_mm256_extracti128_si256({cmp}, 1));",
            f"        uint64_t* vt = v + (int64_t)thr_tree[{ej}] * {w * r};",
            f"        const uint64_t* m = thr_mask + ({ej}) * {w};",
            f"        for (int kk = 0; kk < {w}; ++kk) {{",
            "          const __m256i mk = _mm256_set1_epi64x((long long)m[kk]);",
            f"          uint64_t* vp = vt + kk * {r};",
            "          __m256i lo = _mm256_loadu_si256((const __m256i*)vp);",
            "          __m256i hi = _mm256_loadu_si256((const __m256i*)(vp + 4));",
            "          lo = _mm256_andnot_si256(_mm256_andnot_si256(mk, alo), lo);",
            "          hi = _mm256_andnot_si256(_mm256_andnot_si256(mk, ahi), hi);",
            "          _mm256_storeu_si256((__m256i*)vp, lo);",
            "          _mm256_storeu_si256((__m256i*)(vp + 4), hi);",
            "        }",
        ]
        return ["      {"] + body + ["      }"]

    lines = [
        '__attribute__((target("avx2")))',
        f"static void predict_block{r}_avx2(const int32_t* data, uint32_t* scores) {{",
        f"  uint64_t v[{t * w * r}] __attribute__((aligned(64)));",
        f"  for (int i = 0; i < {t * w}; ++i) {{",
        "    const __m256i iv = _mm256_set1_epi64x((long long)init_mask[i]);",
        f"    _mm256_storeu_si256((__m256i*)(v + i * {r}), iv);",
        f"    _mm256_storeu_si256((__m256i*)(v + i * {r} + 4), iv);",
        "  }",
        "  const __m256i vstride = _mm256_setr_epi32("
        + ", ".join(str(rr * f) for rr in range(r)) + ");",
        f"  for (int f = 0; f < {f}; ++f) {{",
        "    const __m256i xv = _mm256_i32gather_epi32(data + f, vstride, 4);",
        f"    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; e += {k}) {{",
        "      const __m256i cmp0 = _mm256_cmpgt_epi32(",
        "          xv, _mm256_set1_epi32(thr_key[e]));",
        "      if (!_mm256_movemask_epi8(cmp0)) break;  /* group min key */",
    ]
    lines += apply("e", "cmp0")
    for j in range(1, k):
        lines += [
            "      {",
            f"      const __m256i cmp{j} = _mm256_cmpgt_epi32(",
            f"          xv, _mm256_set1_epi32(thr_key[e + {j}]));",
        ]
        lines += apply(f"e + {j}", f"cmp{j}")
        lines.append("      }")
    lines += ["    }", "  }"]
    return lines + tail


def _avx512_block(t, c, f, w, r, k, tail) -> list:
    """AVX-512 (F+VL) 8-row block: the compare writes a ``__mmask8`` and the
    whole mask apply is one ``_mm512_mask_and_epi64`` over the 8-row lane."""

    def apply(ej: str, act: str) -> list:
        return [
            "      {",
            f"        uint64_t* vt = v + (int64_t)thr_tree[{ej}] * {w * r};",
            f"        const uint64_t* m = thr_mask + ({ej}) * {w};",
            f"        for (int kk = 0; kk < {w}; ++kk) {{",
            f"          uint64_t* vp = vt + kk * {r};",
            "          __m512i vv = _mm512_loadu_si512((const void*)vp);",
            f"          vv = _mm512_mask_and_epi64(vv, {act}, vv,",
            "              _mm512_set1_epi64((long long)m[kk]));",
            "          _mm512_storeu_si512((void*)vp, vv);",
            "        }",
            "      }",
        ]

    lines = [
        '__attribute__((target("avx2,avx512f,avx512vl")))',
        f"static void predict_block{r}_avx512(const int32_t* data, uint32_t* scores) {{",
        "  /* 64-byte alignment: every 8-row lane is exactly one full",
        "     512-bit register and never splits a cache line */",
        f"  uint64_t v[{t * w * r}] __attribute__((aligned(64)));",
        f"  for (int i = 0; i < {t * w}; ++i)",
        f"    _mm512_storeu_si512((void*)(v + i * {r}),",
        "        _mm512_set1_epi64((long long)init_mask[i]));",
        "  const __m256i vstride = _mm256_setr_epi32("
        + ", ".join(str(rr * f) for rr in range(r)) + ");",
        f"  for (int f = 0; f < {f}; ++f) {{",
        "    const __m256i xv = _mm256_i32gather_epi32(data + f, vstride, 4);",
        f"    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; e += {k}) {{",
        "      const __mmask8 act0 = _mm256_cmpgt_epi32_mask(",
        "          xv, _mm256_set1_epi32(thr_key[e]));",
        "      if (!act0) break;  /* group min key */",
    ]
    lines += apply("e", "act0")
    for j in range(1, k):
        lines += [
            "      {",
            f"      const __mmask8 act{j} = _mm256_cmpgt_epi32_mask(",
            f"          xv, _mm256_set1_epi32(thr_key[e + {j}]));",
        ]
        lines += apply(f"e + {j}", f"act{j}")
        lines.append("      }")
    lines += ["    }", "  }"]
    return lines + tail


def _neon_block(t, c, f, w, r, k, tail) -> list:
    """NEON 8-row block: two vcgtq halves, self-zip widen, vbic apply."""

    def apply(ej: str, clo: str, chi: str) -> list:
        return [
            "      {",
            f"        const uint64x2_t a01 = vreinterpretq_u64_u32("
            f"vzip1q_u32({clo}, {clo}));",
            f"        const uint64x2_t a23 = vreinterpretq_u64_u32("
            f"vzip2q_u32({clo}, {clo}));",
            f"        const uint64x2_t a45 = vreinterpretq_u64_u32("
            f"vzip1q_u32({chi}, {chi}));",
            f"        const uint64x2_t a67 = vreinterpretq_u64_u32("
            f"vzip2q_u32({chi}, {chi}));",
            f"        uint64_t* vt = v + (int64_t)thr_tree[{ej}] * {w * r};",
            f"        const uint64_t* m = thr_mask + ({ej}) * {w};",
            f"        for (int kk = 0; kk < {w}; ++kk) {{",
            "          const uint64x2_t mk = vdupq_n_u64(m[kk]);",
            f"          uint64_t* vp = vt + kk * {r};",
            "          /* v &= mk | ~a  ==  vbic(v, vbic(a, mk)) */",
            "          vst1q_u64(vp + 0, vbicq_u64(vld1q_u64(vp + 0),"
            " vbicq_u64(a01, mk)));",
            "          vst1q_u64(vp + 2, vbicq_u64(vld1q_u64(vp + 2),"
            " vbicq_u64(a23, mk)));",
            "          vst1q_u64(vp + 4, vbicq_u64(vld1q_u64(vp + 4),"
            " vbicq_u64(a45, mk)));",
            "          vst1q_u64(vp + 6, vbicq_u64(vld1q_u64(vp + 6),"
            " vbicq_u64(a67, mk)));",
            "        }",
            "      }",
        ]

    lines = [
        f"static void predict_block{r}_neon(const int32_t* data, uint32_t* scores) {{",
        f"  uint64_t v[{t * w * r}] __attribute__((aligned(64)));",
        f"  for (int i = 0; i < {t * w}; ++i) {{",
        "    const uint64x2_t iv = vdupq_n_u64(init_mask[i]);",
        f"    vst1q_u64(v + i * {r} + 0, iv);",
        f"    vst1q_u64(v + i * {r} + 2, iv);",
        f"    vst1q_u64(v + i * {r} + 4, iv);",
        f"    vst1q_u64(v + i * {r} + 6, iv);",
        "  }",
        f"  for (int f = 0; f < {f}; ++f) {{",
        f"    int32_t xf[{r}];",
        f"    for (int rr = 0; rr < {r}; ++rr) xf[rr] = data[rr * {f} + f];",
        "    const int32x4_t xlo = vld1q_s32(xf);",
        "    const int32x4_t xhi = vld1q_s32(xf + 4);",
        f"    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; e += {k}) {{",
        "      const int32x4_t key0 = vdupq_n_s32(thr_key[e]);",
        "      const uint32x4_t c0lo = vcgtq_s32(xlo, key0);",
        "      const uint32x4_t c0hi = vcgtq_s32(xhi, key0);",
        "      if (!vmaxvq_u32(vorrq_u32(c0lo, c0hi))) break;  /* group min */",
    ]
    lines += apply("e", "c0lo", "c0hi")
    for j in range(1, k):
        lines += [
            "      {",
            f"      const int32x4_t key{j} = vdupq_n_s32(thr_key[e + {j}]);",
            f"      const uint32x4_t c{j}lo = vcgtq_s32(xlo, key{j});",
            f"      const uint32x4_t c{j}hi = vcgtq_s32(xhi, key{j});",
        ]
        lines += apply(f"e + {j}", f"c{j}lo", f"c{j}hi")
        lines.append("      }")
    lines += ["    }", "  }"]
    return lines + tail


def emit_bitvector_c(bv, mode: str = "integer", interleave: int = 1) -> str:
    """Emit the standalone bitvector scorer for a ``BitvectorEnsemble``.

    Single-row ``predict(data, result)`` over FlInt int32 keys filling uint32
    partials (the block tail path, and the contract every other emitter
    shares), the row-blocked ``predict_block8`` family (scalar always;
    AVX2/AVX-512/NEON under the arch gates), the shared ``predict_class``,
    and a ``predict_batch`` entry that runs full blocks through the
    dispatched blocked scorer and the remainder through ``predict`` — a
    complete translation unit; nothing from ``c_emitter`` needs appending.

    ``interleave=K`` pads each feature's stream to K-entry groups and
    restructures every block variant around them (see module docstring).
    ``K=1`` emits the ungrouped stream with per-entry early exits.
    """
    assert mode == "integer", (
        "the bitvector scorer is emitted once as the integer translation "
        "unit; flint reuses it and diverges only in the shared finalize"
    )
    k = int(interleave)
    if k < 1:
        raise ValueError(f"interleave must be >= 1, got {interleave}")
    from repro.codegen.c_emitter import emit_predict_class

    t, c, f, w = bv.n_trees, bv.n_classes, bv.n_features, bv.words
    feat_off, thr_key, thr_tree, thr_mask = _interleaved_stream(bv, k)
    lines = ["#include <stdint.h>", ""]
    lines += _simd_prelude()
    lines.append("")
    lines.append(
        f"/* InTreeger bitvector (QuickScorer-family) ensemble: per-feature\n"
        f"   ascending threshold streams + false-node leaf masks. trees={t}\n"
        f"   classes={c} entries={len(thr_key)} ({bv.total_entries} real) "
        f"words={w} scale={bv.scale} interleave={k} */"
    )
    lines += _array_lines("feat_off", "int64_t", feat_off, _i64)
    lines += _array_lines("thr_key", "int32_t", thr_key, _i32)
    lines += _array_lines("thr_tree", "int32_t", thr_tree, _i32)
    lines += _array_lines("thr_mask", "uint64_t", thr_mask.reshape(-1), _u64)
    lines += _array_lines("init_mask", "uint64_t", bv.init_mask.reshape(-1), _u64)
    lines += _array_lines("leaf_off", "int64_t", bv.leaf_offsets[:-1], _i64)
    lines += _array_lines(
        "leaf_fixed", "uint32_t", bv.leaf_fixed.reshape(-1),
        lambda v: f"{int(v)}u",
    )
    lines.append("")
    lines += _CTZ64
    lines += [
        "",
        "void predict(const int32_t* data, uint32_t* result) {",
        f"  uint64_t v[{t * w}];",
        f"  for (int i = 0; i < {t * w}; ++i) v[i] = init_mask[i];",
        f"  for (int f = 0; f < {f}; ++f) {{",
        "    const int32_t xf = data[f];",
        "    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; ++e) {",
        "      if (xf <= thr_key[e]) break;  /* ascending: rest true too */",
        f"      uint64_t* vt = v + (int64_t)thr_tree[e] * {w};",
        f"      const uint64_t* m = thr_mask + e * {w};",
        f"      for (int k = 0; k < {w}; ++k) vt[k] &= m[k];",
        "    }",
        "  }",
        f"  for (int i = 0; i < {c}; ++i) result[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int leaf = 0;",
        f"    for (int k = 0; k < {w}; ++k) {{",
        f"      const uint64_t word = v[t * {w} + k];",
        "      if (word) { leaf = k * 64 + ctz64(word); break; }",
        "    }",
        f"    const uint32_t* lf = leaf_fixed + (leaf_off[t] + leaf) * {c};",
        f"    for (int i = 0; i < {c}; ++i) result[i] += lf[i];",
        "  }",
        "}",
        "",
    ]
    lines += emit_predict_class(c, "uint32_t", "int32_t")
    r = _BLOCK_ROWS
    # leaf extraction + class adds shared by the scalar and NEON blocks; the
    # x86 variants run the same adds in the same order through vector
    # accumulators (identical order -> bit-identical partials everywhere)
    block_tail = [
        f"  for (long i = 0; i < {r * c}; ++i) scores[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        f"    for (int rr = 0; rr < {r}; ++rr) {{",
        "      int leaf = 0;",
        f"      for (int k = 0; k < {w}; ++k) {{",
        f"        const uint64_t word = v[(t * {w} + k) * {r} + rr];",
        "        if (word) { leaf = k * 64 + ctz64(word); break; }",
        "      }",
        f"      const uint32_t* lf = leaf_fixed + (leaf_off[t] + leaf) * {c};",
        f"      uint32_t* out = scores + rr * {c};",
        f"      for (int i = 0; i < {c}; ++i) out[i] += lf[i];",
        "    }",
        "  }",
        "}",
    ]
    vec_tail = _x86_vector_tail(t, c, w, r)
    lines += [
        "",
        f"/* {r} rows share ONE pass over the threshold stream (the per-row",
        "   scorer re-streams the whole table per row and is memory-bound at",
        "   batch).  act = the block's still-active rows for this entry,",
        "   recomputed branch-free each entry: ascending keys make x > key",
        "   monotone decreasing, so act only loses bits; the early-exit test",
        f"   runs once per {k}-entry group against the group's smallest key.",
        "   Inactive rows AND with all-ones. */",
    ]
    lines += _scalar_block(t, c, f, w, r, k, block_tail)
    lines += [
        "",
        "#if defined(REPRO_HAVE_AVX2)",
    ]
    lines += _avx2_block(t, c, f, w, r, k, vec_tail)
    lines += [
        "",
    ]
    lines += _avx512_block(t, c, f, w, r, k, vec_tail)
    lines += [
        "#endif  /* REPRO_HAVE_AVX2 */",
        "",
        "#if defined(REPRO_HAVE_NEON)",
    ]
    lines += _neon_block(t, c, f, w, r, k, block_tail)
    lines += [
        "#endif  /* REPRO_HAVE_NEON */",
        "",
        "/* runtime dispatch mirrors the table-walk unit but is",
        "   variant-named: simd_isa() reports the block variant",
        "   predict_batch will actually run, never a compile-time",
        "   capability. */",
        "static const char* g_simd_isa = 0;",
        "",
        "static void pick_simd(void) {",
        "#if defined(REPRO_HAVE_AVX2)",
        '  if (__builtin_cpu_supports("avx512f") &&',
        '      __builtin_cpu_supports("avx512vl")) {',
        f'    g_simd_isa = "avx512-k{k}"; return;',
        "  }",
        f'  if (__builtin_cpu_supports("avx2")) {{'
        f' g_simd_isa = "avx2-k{k}"; return; }}',
        "#endif",
        "#if defined(REPRO_HAVE_NEON)",
        f'  g_simd_isa = "neon-k{k}"; return;',
        "#endif",
        '  g_simd_isa = "scalar";',
        "}",
        "",
        "const char* simd_isa(void) {",
        "  if (!g_simd_isa) pick_simd();",
        "  return g_simd_isa;",
        "}",
        "",
        "void predict_batch(const int32_t* data, long n_rows,",
        "                   uint32_t* scores, int32_t* preds) {",
        "  if (!g_simd_isa) pick_simd();",
        "  long r0 = 0;",
        "#if defined(REPRO_HAVE_AVX2)",
        "  if (g_simd_isa[0] == 'a' && g_simd_isa[3] == '5')",
        f"    for (; r0 + {r} <= n_rows; r0 += {r})",
        f"      predict_block{r}_avx512(data + r0 * {f}, scores + r0 * {c});",
        "  if (g_simd_isa[0] == 'a' && g_simd_isa[3] == '2')",
        f"    for (; r0 + {r} <= n_rows; r0 += {r})",
        f"      predict_block{r}_avx2(data + r0 * {f}, scores + r0 * {c});",
        "#endif",
        "#if defined(REPRO_HAVE_NEON)",
        "  if (g_simd_isa[0] == 'n')",
        f"    for (; r0 + {r} <= n_rows; r0 += {r})",
        f"      predict_block{r}_neon(data + r0 * {f}, scores + r0 * {c});",
        "#endif",
        f"  for (; r0 + {r} <= n_rows; r0 += {r})",
        f"    predict_block{r}(data + r0 * {f}, scores + r0 * {c});",
        "  for (; r0 < n_rows; ++r0)",
        f"    predict(data + r0 * {f}, scores + r0 * {c});",
        "  for (long rr = 0; rr < n_rows; ++rr) {",
        f"    const uint32_t* out = scores + rr * {c};",
        "    int best = 0;",
        f"    for (int i = 1; i < {c}; ++i) if (out[i] > out[best]) best = i;",
        "    preds[rr] = best;",
        "  }",
        "}",
        "",
    ]
    return "\n".join(lines)

"""starcoder2-3b [dense]: GQA + RoPE.  [arXiv:2402.19173; hf]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    act="gelu",
    microbatches=8,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
)

"""Observability: request tracing, histogram telemetry, exposition.

The serving stack's measurement layer, threaded through the whole execution
path — ``Gateway`` admission → cache probe → micro-batch queue wait → engine
bucket/pad → ``ExecutionPlan`` dispatch → per-shard ``predict_partials`` →
merge → finalize → response stitch:

  * :mod:`repro.obs.trace` — staged spans: nested, thread-safe, sampled,
    near-zero cost when disabled (``NULL_SPAN`` propagation).
  * :mod:`repro.obs.histogram` — fixed log-scale bucket histograms: O(1)
    record, exact counters, mergeable across shards and models.
  * :mod:`repro.obs.export` — JSONL trace export, flame-style summaries,
    Prometheus-text + strict-JSON metric snapshots.

Attach a tracer with ``Gateway(..., tracer=Tracer())`` (or ``--gw-trace`` /
``--gw-trace-out`` on ``repro.launch.serve``); stage histograms are always
on — they cost one ``perf_counter_ns`` pair per stage — and surface as the
``queue_ms`` / ``pad_ms`` / ``shard_ms`` / ``finalize_ms`` columns in
``MetricsRegistry.stats()``.
"""
from repro.obs.export import (render_flame, render_prometheus, request_trees,
                              snapshot_json, spans_to_jsonl, write_jsonl)
from repro.obs.histogram import LogHistogram
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Span, Tracer

__all__ = [
    "LogHistogram",
    "NULL_SPAN",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "render_flame",
    "render_prometheus",
    "request_trees",
    "snapshot_json",
    "spans_to_jsonl",
    "write_jsonl",
]

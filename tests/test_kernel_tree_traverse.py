"""Pallas tree-traversal kernel vs the pure-jnp oracle: shape/dtype sweeps,
both gather strategies, padding paths — bit-identical uint32 scores."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.flint import float_to_key
from repro.core.packing import pack_forest
from repro.kernels.ops import packed_predict_integer, pick_blocks, tree_predict_integer
from repro.kernels.ref import tree_predict_integer_ref
from repro.trees.forest import RandomForestClassifier


def _forest(n_trees, depth, n_features, n_classes, seed=0, n=1500):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, n_features)).astype(np.float32)
    y = rng.integers(0, n_classes, n)
    # inject signal so trees are non-trivial
    y = np.where(X[:, 0] > 0.5, (y + 1) % n_classes, y)
    rf = RandomForestClassifier(n_estimators=n_trees, max_depth=depth, seed=seed).fit(X, y)
    return pack_forest(rf), X


def _args(packed):
    return (
        jnp.asarray(packed.feature),
        jnp.asarray(packed.threshold_key),
        jnp.asarray(packed.left),
        jnp.asarray(packed.right),
        jnp.asarray(packed.leaf_fixed),
    )


@pytest.mark.parametrize("impl", ["gather", "onehot"])
@pytest.mark.parametrize(
    "n_trees,depth,n_features,n_classes",
    [(3, 3, 4, 2), (7, 5, 7, 7), (12, 6, 11, 3), (5, 4, 87, 2)],
)
def test_kernel_matches_ref_sweep(impl, n_trees, depth, n_features, n_classes):
    packed, X = _forest(n_trees, depth, n_features, n_classes)
    keys = float_to_key(jnp.asarray(X[:300]))
    feature, tkey, left, right, leaf = _args(packed)
    ref = tree_predict_integer_ref(keys, feature, tkey, left, right, leaf, packed.max_depth)
    out = tree_predict_integer(
        keys, feature, tkey, left, right, leaf,
        depth=packed.max_depth, block_b=64, impl=impl,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.dtype == jnp.uint32


@given(
    bb=st.sampled_from([16, 64, 128]),
    bt=st.integers(min_value=1, max_value=7),
    rows=st.integers(min_value=1, max_value=200),
)
@settings(max_examples=12, deadline=None)
def test_kernel_block_shapes_property(bb, bt, rows):
    """Any (block_b, block_t, n_rows) combination is bit-identical to ref."""
    packed, X = _forest(7, 4, 5, 3, seed=2)
    keys = float_to_key(jnp.asarray(X[:rows]))
    feature, tkey, left, right, leaf = _args(packed)
    ref = tree_predict_integer_ref(keys, feature, tkey, left, right, leaf, packed.max_depth)
    out = tree_predict_integer(
        keys, feature, tkey, left, right, leaf,
        depth=packed.max_depth, block_b=bb, block_t=min(bt, packed.n_trees),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_packed_entry_point(small_packed, shuttle_small):
    from repro.core.ensemble import predict_integer

    _, _, Xte, _ = shuttle_small
    acc_ref, pred_ref = predict_integer(small_packed, Xte[:200])
    acc_k, pred_k = packed_predict_integer(small_packed, Xte[:200], block_b=32)
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_ref))
    np.testing.assert_array_equal(np.asarray(pred_k), np.asarray(pred_ref))


def test_vmem_budget_picker():
    bb, bt = pick_blocks(b=4096, t=128, n=2047, f=87, c=8)
    words = bb * 87 + bt * 2047 * 4 + bt * 2047 * 8 + bb * 8
    assert words * 4 <= 8 * 1024 * 1024
    assert bb >= 1 and bt >= 1


def test_vmem_budget_picker_wide_leaf_tables():
    """Regression: with c large relative to n the ``block_b * c`` output
    block alone can bust the budget at ``block_t == 1`` — the picker used to
    return it unchecked.  The row block must shrink until the whole
    leaf-major working set (incl. the internal-counts vector) fits."""
    from repro.kernels.ops import _VMEM_BUDGET_BYTES, _block_words

    cases = [
        dict(b=4096, t=4, n=31, f=16, c=16384),   # output block dominates
        dict(b=4096, t=2, n=3, f=8, c=400000),    # degenerate: even bt=1 huge
        dict(b=4096, t=128, n=2047, f=87, c=8),   # the historical case
    ]
    for kw in cases:
        bb, bt = pick_blocks(**kw)
        assert bb >= 1 and bt >= 1
        words = _block_words(bb, bt, kw["n"], kw["f"], kw["c"])
        if _block_words(1, 1, kw["n"], kw["f"], kw["c"]) * 4 <= _VMEM_BUDGET_BYTES:
            assert words * 4 <= _VMEM_BUDGET_BYTES, kw


@pytest.mark.parametrize(
    "n_trees,depth,n_features,n_classes",
    [(3, 3, 4, 2), (7, 5, 7, 7), (12, 6, 11, 3)],
)
def test_leaf_major_scan_matches_ref_sweep(n_trees, depth, n_features, n_classes):
    """The linear-scan kernel over leaf_major tables == the jnp oracle over
    the padded tables, across forest shapes and with row/tree padding."""
    packed, X = _forest(n_trees, depth, n_features, n_classes)
    keys = float_to_key(jnp.asarray(X[:217]))  # odd rows: padding path
    feature, tkey, left, right, leaf = _args(packed)
    ref = tree_predict_integer_ref(keys, feature, tkey, left, right, leaf, packed.max_depth)
    lm = packed.to_ir().materialize("leaf_major")
    out = tree_predict_integer(
        keys,
        jnp.asarray(lm.feature), jnp.asarray(lm.threshold_key),
        jnp.asarray(lm.left), jnp.asarray(lm.right), jnp.asarray(lm.leaf_fixed),
        depth=lm.max_depth, block_b=64, block_t=2,
        impl="leaf_major", internal_counts=lm.internal_counts,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.dtype == jnp.uint32


def test_leaf_major_impl_requires_internal_counts():
    packed, X = _forest(3, 3, 4, 2)
    keys = float_to_key(jnp.asarray(X[:8]))
    feature, tkey, left, right, leaf = _args(packed)
    with pytest.raises(ValueError, match="internal_counts"):
        tree_predict_integer(
            keys, feature, tkey, left, right, leaf,
            depth=packed.max_depth, impl="leaf_major",
        )


def test_packed_entry_point_auto_impl(small_packed, shuttle_small):
    """``impl="auto"`` resolves per layout and stays bit-identical; pinning
    ``impl="leaf_major"`` on a padded artifact re-materializes via the IR."""
    from repro.core.ensemble import predict_integer

    _, _, Xte, _ = shuttle_small
    acc_ref, pred_ref = predict_integer(small_packed, Xte[:150])
    lm = small_packed.to_ir().materialize("leaf_major")
    for packed, kw in (
        (lm, {}),                            # auto on leaf_major -> scan
        (small_packed, {}),                  # auto on padded -> gather
        (small_packed, {"impl": "leaf_major"}),  # pinned: re-materializes
    ):
        acc, pred = packed_predict_integer(packed, Xte[:150], block_b=32, **kw)
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc_ref))
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_ref))

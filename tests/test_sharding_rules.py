"""Sharding-rule unit tests (no multi-device needed: specs are pure data)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.launch.shapes import SHAPES, applicable_shapes, cell_applicable
from repro.models import transformer as tfm


class FakeMesh:
    """Duck-typed mesh: rules only touch .shape."""

    def __init__(self, **axes):
        self.shape = axes


from repro.sharding import rules


def test_divisibility_fallback():
    mesh = FakeMesh(data=16, model=16)
    # granite-3-2b vocab 49155 is not divisible by 16 -> replicated dim
    spec = rules.spec_for(
        (jax.tree_util.DictKey("embed"),), (49155, 2048), mesh
    )
    assert spec == P(None, "data")
    spec = rules.spec_for((jax.tree_util.DictKey("embed"),), (262144, 5376), mesh)
    assert spec == P("model", "data")


def test_stacked_block_params_get_leading_none():
    mesh = FakeMesh(data=16, model=16)
    spec = rules.spec_for((jax.tree_util.DictKey("wq"),), (48, 6144, 6144), mesh)
    assert spec == P(None, "data", "model")


def test_all_archs_have_consistent_specs():
    """Every param leaf of every full-size arch gets a legal spec."""
    mesh = FakeMesh(data=16, model=16, pod=2)
    for arch in ("gemma3-27b", "qwen3-moe-30b-a3b", "zamba2-2.7b", "hubert-xlarge",
                 "granite-34b", "mamba2-370m"):
        cfg = get_config(arch)
        shapes = tfm.param_shapes(cfg)
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            spec = rules.spec_for(path, leaf.shape, mesh)
            assert len(spec) <= len(leaf.shape)
            for dim, ax in zip(leaf.shape, list(spec)):
                if ax is not None:
                    assert dim % mesh.shape[ax] == 0, (arch, path, leaf.shape, spec)


def test_batch_pspec():
    mesh = FakeMesh(data=16, model=16, pod=2)
    assert rules.batch_pspec(mesh, 256) == P(("pod", "data"))
    assert rules.batch_pspec(mesh, 1) == P()
    # batch 16: pod*data=32 doesn't divide; pod alone (2) does
    assert rules.batch_pspec(mesh, 16) == P(("pod",)) or rules.batch_pspec(mesh, 16) == P(("pod", "data"))


def test_cell_applicability_matrix():
    """The skip rules documented in DESIGN.md §4."""
    runnable = {}
    for arch in ("zamba2-2.7b", "olmoe-1b-7b", "qwen3-moe-30b-a3b", "mamba2-370m",
                 "llava-next-34b", "starcoder2-3b", "granite-3-2b", "gemma3-27b",
                 "granite-34b", "hubert-xlarge"):
        runnable[arch] = applicable_shapes(get_config(arch))
    assert "long_500k" in runnable["zamba2-2.7b"]
    assert "long_500k" in runnable["mamba2-370m"]
    assert "long_500k" in runnable["gemma3-27b"]  # 5:1 local:global
    for a in ("olmoe-1b-7b", "qwen3-moe-30b-a3b", "llava-next-34b",
              "starcoder2-3b", "granite-3-2b", "granite-34b"):
        assert "long_500k" not in runnable[a]
    assert runnable["hubert-xlarge"] == ["train_4k", "prefill_32k"]
    total = sum(len(v) for v in runnable.values())
    assert total == 32  # 40 assigned cells - 6 long skips - 2 encoder decode skips


def test_param_count_sanity():
    """Analytic param counts land near the published model sizes."""
    approx = {
        "granite-34b": 34e9,
        "gemma3-27b": 27e9,
        "starcoder2-3b": 3e9,
        "mamba2-370m": 0.37e9,
        "olmoe-1b-7b": 6.9e9,
        "qwen3-moe-30b-a3b": 30.5e9,
    }
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * expect < n < 1.6 * expect, (arch, n, expect)
    # MoE active params are much smaller than total
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()

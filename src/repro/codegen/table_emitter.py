"""Vectorizable table-walk C: the ragged layout compiled data-as-arrays.

The paper's deliverable (``c_emitter.emit_c``) encodes the forest *in the
instruction stream* — one if-else cascade per tree, FlInt keys and fixed-point
leaves as immediates.  That is ideal for MCU-class single-row inference but
branchy at batch: every row takes a data-dependent path through thousands of
conditional jumps.  This emitter is the other point in the design space the
paper's architecture discussion motivates: the forest as *static data* (the
``ragged`` ForestIR layout — CSR node arrays with per-tree roots and global
child indices) plus one generic walk loop

    node = root[t];
    while (feature[node] >= 0)
      node = (data[feature[node]] <= key[node]) ? left[node] : right[node];

whose code footprint is O(1) in forest size instead of O(total_nodes).

``block_rows=R`` selects the row-blocked variant (the memory-layout/blocking
optimization line of Koschel et al. and FLInt): node records are emitted
*interleaved* — one ``(feature, key, left, right)`` quad per node, so a walk
step touches one cache line instead of four arrays — and ``predict_batch``
walks R rows through each tree in lockstep.  The R walk states live in
registers (the emitter unrolls the row loop; a runtime-bounded loop would
spill the state to the stack every step), every child select is an
arithmetic mask — branchless, so the data-dependent 50%-mispredict branch
of the scalar walk disappears — and one well-predicted test per level exits
as soon as all R rows sit on leaves.  The R independent dependent-load
chains give the memory-level parallelism a single row's serial walk cannot,
and tree-major order keeps each tree's nodes cache-hot across the rows in
flight.

Modes mirror the deterministic pair: ``integer`` (int32 FlInt compares,
uint32 fixed-point adds — bit-identical to every other backend) and ``flint``
(int32 compares, float32 adds in the same per-tree order plus the same
precomputed-reciprocal ensemble average the reference path lowers to).
Blocking never reorders any single row's accumulation, so scores stay
bit-identical at every block size.  The emitted file needs only <stdint.h>.
"""
from __future__ import annotations

import numpy as np

from repro.codegen.c_emitter import _c_float, emit_predict_class

_VALS_PER_LINE = 12


def _i32(v: int) -> str:
    v = int(v)
    # INT32_MIN has no negatable literal form in C; every other value is fine
    return "(-2147483647-1)" if v == -(1 << 31) else str(v)


def _array_lines(name: str, ctype: str, values, fmt) -> list:
    lines = [f"static const {ctype} {name}[{len(values)}] = {{"]
    for i in range(0, len(values), _VALS_PER_LINE):
        chunk = ", ".join(fmt(v) for v in values[i:i + _VALS_PER_LINE])
        lines.append(f"  {chunk},")
    lines.append("};")
    return lines


def emit_table_walk_c(ragged, mode: str = "integer", block_rows: int = None) -> str:
    """Emit a standalone table-walk C file for a ragged ensemble.

    Same entry-point contract as ``c_emitter.emit_c`` — ``predict(data,
    result)`` over FlInt int32 keys plus a comparison-only ``predict_class`` —
    so the shared batch entry (``emit_batch_entry``) and the test harness
    compose with it unchanged.

    ``block_rows=R`` switches the node storage to interleaved quads and
    additionally emits the row-blocked ``predict_batch`` (see module
    docstring): R register-resident walk states per tree, branch-free
    arithmetic child selects, an all-leaves early exit per level, and a
    scalar-``predict`` tail for the final partial block.
    """
    assert mode in ("integer", "flint"), (
        "the table walk serves the deterministic integer-compare modes; "
        "float thresholds would reintroduce the FPU the paper removes"
    )
    t, c = ragged.n_trees, ragged.n_classes
    total = ragged.total_nodes
    acc_t = "uint32_t" if mode == "integer" else "float"
    lines = ["#include <stdint.h>", ""]
    lines.append(
        f"/* InTreeger table-walk ensemble ({mode} mode): ragged ForestIR layout\n"
        f"   as static data. trees={t} classes={c} nodes={total}"
        + (f" scale={ragged.scale}" if mode == "integer" else "")
        + (f" block_rows={int(block_rows)}" if block_rows is not None else "")
        + " */"
    )
    if block_rows is None:
        lines += _array_lines("node_feature", "int32_t", ragged.feature, _i32)
        lines += _array_lines("node_key", "int32_t", ragged.threshold_key, _i32)
        lines += _array_lines("node_left", "int32_t", ragged.left, _i32)
        lines += _array_lines("node_right", "int32_t", ragged.right, _i32)
        feat = "node_feature[{n}]"
        key = "node_key[{n}]"
        left = "node_left[{n}]"
        right = "node_right[{n}]"
    else:
        # interleaved (feature, key, left, right) records: one walk step
        # touches one 16-byte quad instead of four distinct arrays
        quad = np.stack(
            [ragged.feature, ragged.threshold_key, ragged.left, ragged.right],
            axis=1,
        ).reshape(-1)
        lines += _array_lines("node_quad", "int32_t", quad, _i32)
        feat = "node_quad[4 * (long)({n})]"
        key = "node_quad[4 * (long)({n}) + 1]"
        left = "node_quad[4 * (long)({n}) + 2]"
        right = "node_quad[4 * (long)({n}) + 3]"
    if mode == "integer":
        leaf_vals = ragged.leaf_fixed.reshape(-1)
        lines += _array_lines(
            "node_leaf", "uint32_t", leaf_vals, lambda v: f"{int(v)}u"
        )
    else:
        leaf_vals = ragged.leaf_probs.reshape(-1)
        lines += _array_lines("node_leaf", "float", leaf_vals, _c_float)
    lines += _array_lines("tree_root", "int32_t", ragged.roots, _i32)
    lines += [
        "",
        f"void predict(const int32_t* data, {acc_t}* result) {{",
        f"  for (int i = 0; i < {c}; ++i) result[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int32_t node = tree_root[t];",
        f"    int32_t f = {feat.format(n='node')};",
        "    while (f >= 0) {",
        f"      node = (data[f] <= {key.format(n='node')}) ? "
        f"{left.format(n='node')} : {right.format(n='node')};",
        f"      f = {feat.format(n='node')};",
        "    }",
        f"    const {acc_t}* leaf = node_leaf + (long)node * {c};",
        f"    for (int i = 0; i < {c}; ++i) result[i] += leaf[i];",
        "  }",
    ]
    if mode == "flint":
        # same precomputed float32 reciprocal the reference path's `acc / n`
        # lowers to, applied in the same place -> bit-identical averages
        rcp = np.float32(1.0) / np.float32(t)
        lines.append(f"  for (int i = 0; i < {c}; ++i) result[i] *= {_c_float(rcp)};")
    lines += ["}", ""]
    lines += emit_predict_class(c, acc_t, "int32_t")
    if block_rows is not None:
        lines += _emit_blocked_batch(ragged, mode, acc_t, int(block_rows))
    return "\n".join(lines)


def _emit_blocked_batch(ragged, mode: str, acc_t: str, block_rows: int) -> list:
    """The row-blocked ``predict_batch``: R walk chains per tree in registers.

    The emitter unrolls the row dimension so each chain is a named local —
    gcc keeps them in registers and the R dependent-load chains issue
    independently.  Per level it preloads every chain's node feature, takes
    one well-predicted exit branch when their AND is negative (all leaves:
    ``feature == -1`` is all-ones, and only an all-negative set keeps the
    sign bit through AND), and advances each chain with a branch-free
    arithmetic select.  The depth bound is a backstop: leaves self-loop, so
    extra levels are inert and the early exit usually fires first.
    """
    assert block_rows >= 1
    t, c, f = ragged.n_trees, ragged.n_classes, ragged.n_features
    depth, r = ragged.max_depth, block_rows
    chains = range(r)
    lines = [
        f"/* row-blocked walk: {r} register walk chains per tree, early exit",
        "   when every chain sits on a leaf (see table_emitter docstring). */",
        f"static void walk_block_full(const int32_t* data, {acc_t}* scores) {{",
        f"  for (long i = 0; i < {r} * {c}; ++i) scores[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    const int32_t root = tree_root[t];",
        "    " + " ".join(f"int32_t n{k} = root;" for k in chains),
    ]
    if depth > 0:
        lines.append(f"    for (int d = 0; d < {depth}; ++d) {{")
        for k in chains:
            lines.append(
                f"      const int32_t f{k} = node_quad[4 * (long)n{k}];"
            )
        all_leaves = " & ".join(f"f{k}" for k in chains)
        lines.append(f"      if (({all_leaves}) < 0) break;")
        for k in chains:
            lines += [
                f"      {{ const int32_t* q{k} = node_quad + 4 * (long)n{k};",
                f"        const int32_t fi{k} = f{k} & ~(f{k} >> 31);",
                f"        const int32_t go{k} = -(data[{k} * {f} + fi{k}] <= q{k}[1]);",
                f"        n{k} = (q{k}[2] & go{k}) | (q{k}[3] & ~go{k}); }}",
            ]
        lines.append("    }")
    lines.append(
        "    " + "const int32_t node[] = {"
        + ", ".join(f"n{k}" for k in chains) + "};"
    )
    lines += [
        f"    for (long w = 0; w < {r}; ++w) {{",
        f"      const {acc_t}* leaf = node_leaf + (long)node[w] * {c};",
        f"      for (int i = 0; i < {c}; ++i) scores[w * {c} + i] += leaf[i];",
        "    }",
        "  }",
    ]
    if mode == "flint":
        rcp = np.float32(1.0) / np.float32(t)
        lines.append(
            f"  for (long i = 0; i < {r} * {c}; ++i) scores[i] *= {_c_float(rcp)};"
        )
    lines += [
        "}",
        "",
        f"void predict_batch(const int32_t* data, long n_rows,",
        f"                   {acc_t}* scores, int32_t* preds) {{",
        "  long r0 = 0;",
        f"  for (; r0 + {r} <= n_rows; r0 += {r})",
        f"    walk_block_full(data + r0 * {f}, scores + r0 * {c});",
        "  for (; r0 < n_rows; ++r0)",
        f"    predict(data + r0 * {f}, scores + r0 * {c});",
        "  for (long w = 0; w < n_rows; ++w) {",
        f"    const {acc_t}* out = scores + w * {c};",
        "    int best = 0;",
        f"    for (int i = 1; i < {c}; ++i) if (out[i] > out[best]) best = i;",
        "    preds[w] = best;",
        "  }",
        "}",
        "",
    ]
    return lines

"""Distributed tree-ensemble serving step (the paper's arch at pod scale).

Same math as ``repro.kernels.ref`` (bit-identical — tested).  Batched tree
inference is embarrassingly row-parallel, but GSPMD does not see that: the
loop-carried node-index vector gets replicated and every per-level gather
emits a (rows,) all-reduce — measured 5.37 GB/device/step on serve_1m; adding
with_sharding_constraint inside the loop body made it *worse* (10.7 GB of
all-gather on top).  EXPERIMENTS.md §Perf (tree cell) logs both iterations.

The fix is manual SPMD: ``shard_map`` over every mesh axis with replicated
node tables — all compute is local by construction, collectives drop to
exactly zero.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.ref import tree_predict_integer_ref
from repro.sharding.ops import compat_shard_map, current_mesh


def _local_predict(tables: dict, x_keys, depth: int):
    acc = tree_predict_integer_ref(
        x_keys,
        tables["feature"],
        tables["threshold_key"],
        tables["left"],
        tables["right"],
        tables["leaf_fixed"],
        depth,
    )
    return acc, jnp.argmax(acc, axis=1).astype(jnp.int32)


def tree_serve_step(tables: dict, x_keys, depth: int):
    """tables: feature/threshold_key/left/right (T,N) + leaf_fixed (T,N,C).
    x_keys: (B, F) int32.  Returns (scores (B,C) uint32, preds (B,) int32).

    Inside a ``use_mesh`` context the rows are shard_map'ed over every mesh
    axis (tables replicated); otherwise runs locally (CPU tests).
    """
    mesh = current_mesh()
    if mesh is None:
        return _local_predict(tables, x_keys, depth)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    fn = compat_shard_map(
        lambda t, x: _local_predict(t, x, depth),
        mesh=mesh,
        in_specs=(P(), P(axes, None)),
        out_specs=(P(axes, None), P(axes)),
    )
    return fn(tables, x_keys)

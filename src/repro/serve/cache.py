"""Exact-match response cache keyed on FlInt-quantized int32 feature keys.

The FlInt transform (``float_to_key``) maps every float32 feature vector to a
canonical int32 vector: two requests whose features quantize to the same key
vector are guaranteed — for the ``flint``/``integer`` modes, whose outputs
are bit-deterministic integers — to produce byte-identical scores.  That
makes an exact-match response cache *semantically safe*: a hit returns
exactly what the engine would have computed.  The float mode gives no such
guarantee (float accumulation order), so the gateway only enables the cache
for deterministic engines.

Keys are ``(model_id, version, mode, row_key_bytes)`` so a hot-swap to a new
model version naturally orphans stale entries (LRU evicts them).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core.flint import float_to_key_np


def row_keys(X) -> list:
    """Per-row cache key material: FlInt int32 key vector bytes."""
    keys = float_to_key_np(np.ascontiguousarray(X, np.float32))
    return [keys[i].tobytes() for i in range(keys.shape[0])]


class QuantizedKeyCache:
    """LRU cache of per-row (scores, pred) results."""

    def __init__(self, capacity_rows: int = 65536):
        self.capacity_rows = capacity_rows
        self._od: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key_for(model_id: str, version: int, mode: str, row_key: bytes) -> tuple:
        return (model_id, version, mode, row_key)

    def get(self, key) -> Optional[Tuple[np.ndarray, int]]:
        hit = self._od.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._od.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key, scores_row: np.ndarray, pred: int) -> None:
        if self.capacity_rows <= 0:
            return
        if key in self._od:
            self._od.move_to_end(key)
        self._od[key] = (np.asarray(scores_row).copy(), int(pred))
        while len(self._od) > self.capacity_rows:
            self._od.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._od)

    def stats(self) -> dict:
        probed = self.hits + self.misses
        return {
            "rows": len(self._od),
            "capacity_rows": self.capacity_rows,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hits / probed if probed else 0.0,
        }

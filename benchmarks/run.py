"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's headline
quantity).  CPU-backend wall times are used for *relative* comparisons
(float vs FlInt vs integer), mirroring the paper's relative-cycles axis;
absolute TPU projections live in the roofline table (§Roofline).

  Fig. 2  -> accuracy_identity        (pred identity + prob-delta magnitude)
  Fig. 3  -> perf_float_flint_integer (3 impls x 2 datasets x n_trees)
  IV-C    -> instruction_count_proxy  (HLO op counts per impl)
  IV-E    -> memory_footprint         (artifact bytes, MCU-style)
  IV-F    -> energy_model             (paper's E_saved formula)
  kernels -> kernel_identity          (Pallas kernel == oracle, us/row)
  plans   -> plan_scaling             (ns/row vs shard count, tree/row-parallel)
  §Roofline -> roofline_table         (from dry-run artifacts)
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ROWS = []

# REPRO_BENCH_TINY=1 (the CI bench-smoke job) shrinks datasets/forests so the
# full pipeline runs in seconds: numbers are still *reported* but only prove
# every backend executes — perf conclusions need the full-size run.
TINY = bool(int(os.environ.get("REPRO_BENCH_TINY", "0") or "0"))

# REPRO_BENCH_DEVICES=N forces N XLA host-platform devices *before* jax is
# first imported (all jax imports in this harness are lazy), so the
# plan_scaling section can exercise real shard_map tree-parallel execution
# on a CPU-only host — the same trick the CI conformance job uses.
_N_DEV = os.environ.get("REPRO_BENCH_DEVICES")
if _N_DEV and "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={int(_N_DEV)}"
    ).strip()


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def host_info() -> dict:
    """The machine the numbers were taken on: every perf row in the JSON is
    meaningless without the CPU, its SIMD capabilities, the core count, and
    the compiler that built the C backends."""
    import platform
    import shutil
    import subprocess

    info = {
        "machine": platform.machine(),
        "cores": os.cpu_count(),
        "python": platform.python_version(),
    }
    cpu_model, flags = None, ""
    try:  # /proc/cpuinfo: "model name" on x86, "Features"/"flags" lists ISA
        with open("/proc/cpuinfo") as fh:
            for line in fh:
                low = line.lower()
                if cpu_model is None and low.startswith("model name"):
                    cpu_model = line.split(":", 1)[1].strip()
                elif low.startswith(("flags", "features")):
                    flags = line.split(":", 1)[1]
    except OSError:
        pass
    info["cpu"] = cpu_model or platform.processor() or "unknown"
    fl = set(flags.split())
    info["avx2"] = "avx2" in fl
    info["neon"] = bool({"neon", "asimd"} & fl)
    info["gcc"] = None
    if shutil.which("gcc"):
        try:
            out = subprocess.run(["gcc", "--version"], capture_output=True,
                                 text=True, timeout=10).stdout
            info["gcc"] = out.splitlines()[0] if out else None
        except (OSError, subprocess.SubprocessError):
            pass
    return info


def _isa_of(eng) -> str:
    """The SIMD ISA an engine's backend dispatches to ('-' for non-C
    backends and fused plans with no per-shard backend objects)."""
    fn = getattr(eng.backend, "simd_isa", None)
    return (fn() or "-") if fn is not None else "-"


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    elif isinstance(out, tuple) and hasattr(out[0], "block_until_ready"):
        out[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _datasets():
    from repro.data.tabular import make_esa_like, make_shuttle_like, train_test_split

    n = 2500 if TINY else 20000
    shuttle = train_test_split(*make_shuttle_like(n=n, seed=0), seed=0)
    esa = train_test_split(*make_esa_like(n=n, seed=0), seed=0)
    return {"shuttle": shuttle, "esa": esa}


def _forest(data, n_trees, depth=7, seed=0):
    from repro.core.packing import pack_forest
    from repro.trees.forest import RandomForestClassifier

    Xtr, ytr, Xte, yte = data
    rf = RandomForestClassifier(n_estimators=n_trees, max_depth=depth, seed=seed).fit(Xtr, ytr)
    return rf, pack_forest(rf), Xte, yte


def accuracy_identity():
    """Fig. 2: integer vs float predictions identical; prob deltas ~n/2^32."""
    from repro.core.ensemble import predict_float, predict_integer
    from repro.core.fixedpoint import fixed_to_prob_np

    for dname, data in _datasets().items():
        for n_trees in (1, 10, 50, 100):
            t0 = time.perf_counter()
            rf, packed, Xte, yte = _forest(data, n_trees, depth=6)
            _, predf = predict_float(packed, Xte)
            acc, predi = predict_integer(packed, Xte)
            identical = bool((np.asarray(predf) == np.asarray(predi)).all())
            oracle = rf.predict_proba(Xte)
            delta = np.abs(
                fixed_to_prob_np(np.asarray(acc), n_trees) - oracle
            ).max()
            us = (time.perf_counter() - t0) * 1e6
            emit(
                f"fig2_identity_{dname}_t{n_trees}",
                us,
                f"identical={identical};max_prob_delta={delta:.3e}",
            )
            assert identical


def perf_float_flint_integer():
    """Fig. 3: relative runtime of float / flint / integer paths."""
    from repro.core.ensemble import make_predict_fn

    for dname, data in _datasets().items():
        for n_trees in (10, 50):
            rf, packed, Xte, yte = _forest(data, n_trees, depth=7)
            Xte = Xte[:4096]
            times = {}
            for mode in ("float", "flint", "integer"):
                fn = make_predict_fn(packed, mode)
                times[mode] = _time(fn, Xte)
            speedup = times["float"] / times["integer"]
            emit(
                f"fig3_perf_{dname}_t{n_trees}_float", times["float"] / len(Xte),
                f"us_per_row",
            )
            emit(
                f"fig3_perf_{dname}_t{n_trees}_flint", times["flint"] / len(Xte),
                f"rel={times['float']/times['flint']:.3f}x",
            )
            emit(
                f"fig3_perf_{dname}_t{n_trees}_integer", times["integer"] / len(Xte),
                f"speedup_vs_float={speedup:.3f}x",
            )


def gbt_identity():
    """GBT support (paper Sec. II-B): integer-only signed-margin
    accumulation agrees with the float GBT on argmax."""
    from repro.trees.gbt import GradientBoostedClassifier, pack_gbt, predict_gbt_integer

    data = _datasets()["shuttle"]
    Xtr, ytr, Xte, yte = data
    t0 = time.perf_counter()
    gbt = GradientBoostedClassifier(n_estimators=12, max_depth=4, seed=0).fit(
        Xtr[:8000], ytr[:8000]
    )
    packed = pack_gbt(gbt)
    pred_f = gbt.predict(Xte[:2000])
    pred_i = predict_gbt_integer(packed, Xte[:2000])
    agree = (pred_f == pred_i).mean()
    acc = (pred_i == yte[:2000]).mean()
    emit(
        "gbt_identity", (time.perf_counter() - t0) * 1e6,
        f"agree={agree:.4f};acc={acc:.4f};scale={packed.scale:.3e}",
    )
    assert agree >= 0.999


def perf_native_c():
    """Fig. 3, faithfully: the emitted if-else C compiled -O3 and timed on
    this host's x86 core — float vs FlInt vs InTreeger, both datasets.
    (The paper's ARM/RISC-V columns need those ISAs; noted in EXPERIMENTS.)"""
    import shutil

    if shutil.which("gcc") is None:
        emit("fig3_native_c", 0, "gcc unavailable; skipped")
        return
    from repro.codegen.native_bench import compile_and_time

    for dname, data in _datasets().items():
        for n_trees in (10, 50):
            rf, packed, Xte, yte = _forest(data, n_trees, depth=7)
            X = Xte[:4096]
            res = {m: compile_and_time(packed, X, m) for m in ("float", "flint", "integer")}
            # all three must agree on every argmax (checksum = sum of classes)
            assert res["float"]["checksum"] == res["integer"]["checksum"] == res["flint"]["checksum"]
            f, fl, i = (res[m]["ns_per_row"] / 1e3 for m in ("float", "flint", "integer"))
            emit(f"fig3c_{dname}_t{n_trees}_float", f, "us_per_row")
            emit(f"fig3c_{dname}_t{n_trees}_flint", fl, f"rel={f/fl:.3f}x")
            emit(
                f"fig3c_{dname}_t{n_trees}_integer", i,
                f"speedup_vs_float={f/i:.3f}x;binary_bytes={res['integer']['binary_bytes']}",
            )


def instruction_count_proxy():
    """IV-C analog: compiled op counts per implementation (no ISA on TPU —
    HLO instruction count is the portable analogue)."""
    import jax
    import jax.numpy as jnp
    from repro.core.ensemble import ensemble_device_arrays, _predict
    from repro.core.flint import float_to_key

    data = _datasets()["shuttle"]
    rf, packed, Xte, yte = _forest(data, 20, depth=6)
    x = jnp.asarray(Xte[:512], jnp.float32)
    counts = {}
    for mode, acc_dtype in (("float", jnp.float32), ("integer", jnp.uint32)):
        arrays = ensemble_device_arrays(packed, mode)
        xx = x if mode == "float" else float_to_key(x)
        lowered = jax.jit(
            lambda a, v: _predict(a, v, packed.max_depth, acc_dtype)
        ).lower(arrays, xx)
        txt = lowered.compile().as_text()
        counts[mode] = sum(1 for l in txt.splitlines() if "=" in l and "%" in l)
    emit(
        "ivc_hlo_ops_float", counts["float"],
        f"integer={counts['integer']};ratio={counts['integer']/counts['float']:.3f}",
    )


def memory_footprint():
    """IV-E analog: deployable artifact size (the MCU had 43.5 kB total),
    now broken out per ForestIR layout — padded tables pay O(T * max_nodes)
    while ragged pays O(sum(nodes)), so the gap widens with depth skew."""
    from repro.codegen.c_emitter import emit_c

    data = _datasets()["shuttle"]
    rf, packed, Xte, _ = _forest(data, 30, depth=5)  # the paper's MCU config
    int_bytes = packed.nbytes_integer()
    float_bytes = packed.nbytes_float()
    c_src = len(emit_c(packed, mode="integer").encode())
    emit(
        "ive_artifact_bytes", int_bytes,
        f"float_bytes={float_bytes};ratio={int_bytes/float_bytes:.3f};c_source={c_src}",
    )
    per_layout = packed.ir.nbytes_by_layout(mode="integer")
    emit(
        "ive_bytes_per_layout", per_layout["padded"],
        ";".join(f"{name}={nb}" for name, nb in sorted(per_layout.items()))
        + f";ragged_saving={1 - per_layout['ragged']/per_layout['padded']:.3f}",
    )


def energy_model():
    """IV-F: the paper's E_saved formula with measured runtime ratio.

    The paper measured T_float=19.36s, T_int=7.79s, P_high=2.81W,
    P_low=1.81W -> 21.3% saved.  We plug OUR measured runtimes into the SAME
    formula with the paper's power constants (no power meter in container).
    """
    import shutil

    data = _datasets()["shuttle"]
    rf, packed, Xte, yte = _forest(data, 50, depth=7)  # paper's energy config
    Xte = Xte[:4096]
    if shutil.which("gcc"):
        # the faithful measurement: emitted if-else C at -O3 (paper IV-F)
        from repro.codegen.native_bench import compile_and_time

        t_float = compile_and_time(packed, Xte, "float")["ns_per_row"]
        t_int = compile_and_time(packed, Xte, "integer")["ns_per_row"]
    else:
        from repro.core.ensemble import make_predict_fn

        t_float = _time(make_predict_fn(packed, "float"), Xte)
        t_int = _time(make_predict_fn(packed, "integer"), Xte)
    p_high, p_low = 2.81, 1.81
    e_saved = 1 - (t_int * p_high + (t_float - t_int) * p_low) / (t_float * p_high)
    emit(
        "ivf_energy_saved", t_int,
        f"t_float={t_float:.1f};t_int={t_int:.1f};E_saved={e_saved*100:.1f}%"
        f";paper=21.3%",
    )
    # paper's own constants reproduce the paper's number (formula check)
    e_paper = 1 - (7.79 * 2.81 + (19.36 - 7.79) * 1.81) / (19.36 * 2.81)
    assert abs(e_paper - 0.213) < 0.005


def kernel_identity():
    """Pallas kernel (interpret mode) == jnp oracle; per-row cost of the jnp
    deployment path (interpret-mode kernel timing is not meaningful)."""
    from repro.core.ensemble import make_predict_fn
    from repro.kernels.ops import packed_predict_integer

    data = _datasets()["shuttle"]
    rf, packed, Xte, _ = _forest(data, 16, depth=6)
    Xte = Xte[:1024]
    fn = make_predict_fn(packed, "integer")
    scores_ref, _ = fn(Xte)
    scores_k, _ = packed_predict_integer(packed, Xte, block_b=256)
    same = bool((np.asarray(scores_ref) == np.asarray(scores_k)).all())
    us = _time(fn, Xte, reps=3)
    emit("kernel_identity", us / len(Xte), f"bit_identical={same}")
    assert same


def gateway_vs_naive():
    """Gateway throughput vs naive per-request predict on a single-row
    request stream (the motivating workload: millions of independent rows).
    The gateway coalesces the stream into block-shaped batches and serves
    repeated quantized keys from cache; the naive baseline dispatches the
    engine once per request.  Low rates are arrival-bound (wall time is
    dominated by Poisson pacing); ``rate=inf`` is a burst and measures pure
    serving capacity, the apples-to-apples comparison with the closed-loop
    naive baseline."""
    import asyncio

    from repro.launch.serve import run_gateway_workload
    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    data = _datasets()["shuttle"]
    rf, packed, Xte, _ = _forest(data, 16, depth=6)
    reg = ModelRegistry()
    mv = reg.register_packed("shuttle", packed)
    eng = mv.engine("integer")
    eng.warm(64)  # compile shape buckets so jit doesn't skew either side

    # reference: the bare engine loop (no server at all), one call per row
    t0 = time.perf_counter()
    for i in range(200):
        eng.predict(Xte[i:i + 1])
    bare_rows_per_s = 200 / (time.perf_counter() - t0)

    def run_server(rate, batched: bool):
        # naive = same async server, but no coalescing and no cache
        gw = Gateway(reg, mode="integer",
                     max_batch_rows=64 if batched else 1,
                     max_delay_ms=4.0 if batched else 0.0,
                     max_queue_rows=8192,
                     cache_rows=65536 if batched else 0)
        t0 = time.perf_counter()
        results, rejected = asyncio.run(run_gateway_workload(
            gw, {"shuttle": Xte}, n_requests=400, rate_hz=rate,
            seed=17, row_choices=(1,),
        ))
        dt = time.perf_counter() - t0
        st = gw.stats()["per_model"]["shuttle"]
        asyncio.run(gw.close())
        rows = sum(len(X) for _, X, _ in results)
        return rows, dt, st, rejected

    def stage_cols(st):
        # always-on per-stage attribution (mean wall ms per sample); NaN
        # (no samples for a stage) renders as the literal "nan", fine in CSV
        return (f"queue_ms={st['queue_ms']:.3f};pad_ms={st['pad_ms']:.3f};"
                f"shard_ms={st['shard_ms']:.3f};finalize_ms={st['finalize_ms']:.3f}")

    for rate in (500.0, 2000.0, float("inf")):
        rows, gw_dt, st, rejected = run_server(rate, batched=True)
        n_rows, n_dt, n_st, n_rej = run_server(rate, batched=False)
        tag = "inf" if rate == float("inf") else str(int(rate))
        emit(
            f"gateway_rate{tag}", gw_dt / max(rows, 1) * 1e6,
            f"rows_per_s={rows/gw_dt:.0f};naive_rows_per_s={n_rows/n_dt:.0f};"
            f"speedup_vs_naive={(n_dt/n_rows)/(gw_dt/rows):.2f}x;"
            f"bare_loop_rows_per_s={bare_rows_per_s:.0f};"
            f"occupancy={st['batch_occupancy']:.1f};hit_rate={st['cache_hit_rate']:.2f};"
            f"p95_ms={st['p95_ms']:.2f}(naive={n_st['p95_ms']:.2f});"
            f"rejected={rejected}(naive={n_rej});" + stage_cols(st),
        )


def gateway_stage_breakdown():
    """Where a traced request's wall time goes, from actual span trees: one
    fully-traced burst through the gateway, stage totals aggregated from the
    per-request spans (queue wait, cache probe, pad, shard execute, merge,
    finalize, stitch).  Runs separately from ``gateway_vs_naive`` so the
    timed comparison rows stay untraced."""
    import asyncio

    from repro.launch.serve import run_gateway_workload
    from repro.obs import Tracer, request_trees
    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    data = _datasets()["shuttle"]
    rf, packed, Xte, _ = _forest(data, 16, depth=6)
    reg = ModelRegistry()
    mv = reg.register_packed("shuttle", packed)
    mv.engine("integer").warm(64)

    tracer = Tracer(sample=1.0)
    gw = Gateway(reg, mode="integer", max_batch_rows=64, max_delay_ms=4.0,
                 max_queue_rows=8192, tracer=tracer)
    t0 = time.perf_counter()
    results, _ = asyncio.run(run_gateway_workload(
        gw, {"shuttle": Xte}, n_requests=200, rate_hz=float("inf"),
        seed=17, row_choices=(1,),
    ))
    dt = time.perf_counter() - t0
    asyncio.run(gw.close())

    trees = request_trees(tracer.spans())

    def fold(node, acc):
        # batch children are shared across riders; folding per tree counts
        # each request's view of its stages, which is the per-request story
        key = node["name"].split(":")[0]  # shard:s0[...] -> shard
        acc[key] = acc.get(key, 0.0) + node["dur_ms"]
        for c in node["children"]:
            fold(c, acc)
        return acc

    totals: dict = {}
    for t in trees:
        fold(t, totals)
    n = max(len(trees), 1)
    req_ms = totals.pop("request", 0.0)
    stages = ";".join(f"{k}_ms={v / n:.3f}" for k, v in sorted(totals.items()))
    emit(
        "gateway_stage_breakdown", dt / max(len(results), 1) * 1e6,
        f"traced_requests={len(trees)};request_ms={req_ms / n:.3f};" + stages,
    )


def backend_matrix():
    """Backend axis: one model served through every registered backend *and
    execution variant* at several batch sizes, per-backend ns/row.

    ``reference`` and ``pallas`` are jitted JAX on the host backend (pallas
    runs in interpret mode on CPU, so its absolute time is not meaningful —
    identity is the point; the gather-vs-linear-scan comparison is about op
    structure).  The pallas rows cover both walk strategies: per-depth
    gathers over ``padded`` tables vs the leaf_major linear scan.
    ``native_c`` is the paper's emitted if-else C and ``native_c_table`` the
    ragged-layout table-walk C, benchmarked scalar (``block_rows=1``) vs
    row-blocked (``block_rows=8``), all compiled -O2 into shared libraries
    and driven through ctypes.  All integer scores must be bit-identical
    across every route (the conformance property the IR/backend layers are
    anchored on).

    The shuttle forest is small enough to live in cache, which flatters the
    speculative scalar walk; the ``deep`` rows rerun blocked-vs-scalar on a
    deeper, harder forest (the regime the row-blocking literature targets),
    where the blocked walk's branch-free lockstep chains win.
    """
    from repro.backends import have_c_toolchain
    from repro.serve.engine import TreeEngine

    ds = _datasets()
    rf, packed, Xte, _ = _forest(ds["shuttle"], 4 if TINY else 16,
                                 depth=4 if TINY else 6)
    have_gcc = have_c_toolchain()
    # (route tag, backend, engine kwargs)
    routes = [
        ("reference", "reference", {}),
        ("pallas[gather]", "pallas",
         {"layout": "padded", "backend_kwargs": {"impl": "gather"}}),
        ("pallas[leaf_major]", "pallas",
         {"layout": "leaf_major", "backend_kwargs": {"impl": "leaf_major"}}),
    ]
    if have_gcc:
        routes += [
            ("native_c", "native_c", {}),
            ("native_c_table[block_rows=1]", "native_c_table",
             {"backend_kwargs": {"block_rows": 1}}),
            ("native_c_table[block_rows=8]", "native_c_table",
             {"backend_kwargs": {"block_rows": 8}}),
        ]
    else:
        emit("backend_matrix_native_c", 0,
             "gcc unavailable; native_c + native_c_table skipped")

    batches = (32, 64) if TINY else (64, 256, 1024)
    probe = Xte[: batches[-1]]
    ref_scores = None
    ns_row = {}
    for tag, name, kwargs in routes:
        eng = TreeEngine(packed, mode="integer", backend=name, **kwargs)
        scores, _ = eng.predict_scores(probe)
        if ref_scores is None:
            ref_scores = scores
        else:
            assert (scores == ref_scores).all(), f"{tag} diverged from reference"
        for batch in batches:
            X = Xte[:batch]
            us = _time(eng.predict_scores, X, reps=3)
            ns_row[(tag, batch)] = us * 1e3 / batch
            emit(
                f"backend_{tag}_b{batch}", us,
                f"ns_per_row={us * 1e3 / batch:.1f};layout={eng.layout};"
                f"isa={_isa_of(eng)};buckets={sorted(eng.compiled_buckets)}",
            )

    # small-batch guard for the tiny-batch Pallas fix: pick_blocks shrinks
    # block_t (and the leaf_major wrapper falls back to the gather walk)
    # below _SMALL_BATCH_GATHER_ROWS, so the smallest batch's ns/row must
    # stay within 3x of the next batch up.  BENCH_7 measured a 3.4x cliff
    # (131us/row at b32 vs 38us at b64) before the fix; interpret-mode
    # per-call overhead alone accounts for ~2x at half the rows.
    for tag in ("pallas[gather]", "pallas[leaf_major]"):
        small, nxt = ns_row[(tag, batches[0])], ns_row[(tag, batches[1])]
        assert small <= 3.0 * nxt, (
            f"{tag} small-batch cliff: b{batches[0]}={small:.0f}ns/row vs "
            f"b{batches[1]}={nxt:.0f}ns/row (> 3x)")

    # autotuned rows next to the static defaults: the warm-time measured
    # winner must never lose to the default it was picked against
    # (min-of-rounds interleaved timing; 10% allowance for shared-host
    # noise, 15% for interpret-mode pallas).
    tuned_routes = [("pallas[leaf_major]", "pallas", 1.15,
                     {"layout": "leaf_major",
                      "backend_kwargs": {"impl": "leaf_major"}})]
    if have_gcc:
        tuned_routes.insert(0, ("native_c_table", "native_c_table", 1.10, {}))
    for tag, name, tol, kwargs in tuned_routes:
        tuned = TreeEngine(packed, mode="integer", backend=name,
                           autotune=True, **kwargs)
        tuned.warm(batches[-1])
        static = TreeEngine(packed, mode="integer", backend=name, **kwargs)
        scores, _ = tuned.predict_scores(probe)
        assert (scores == ref_scores).all(), f"tuned {tag} diverged"
        for batch in batches:
            X = Xte[:batch]
            t_tuned = t_static = float("inf")
            for _ in range(3):
                t_tuned = min(t_tuned, _time(tuned.predict_scores, X, reps=3))
                t_static = min(t_static,
                               _time(static.predict_scores, X, reps=3))
            emit(
                f"backend_tuned_{tag}_b{batch}", t_tuned,
                f"ns_per_row={t_tuned * 1e3 / batch:.1f};"
                f"tuned={tuned.tuned_config or '-'};"
                f"static_ns_per_row={t_static * 1e3 / batch:.1f};"
                f"isa={_isa_of(tuned)}",
            )
            assert t_tuned <= t_static * tol, (
                f"tuned {tag} b{batch} slower than static default: "
                f"{t_tuned:.1f}us vs {t_static:.1f}us")

    if have_gcc:
        # blocked-vs-scalar where row blocking actually bites: a deep forest
        # whose walks defeat branch prediction and exceed the fast caches.
        # Three table-walk builds of the same artifact: the scalar per-row
        # while loop, the blocked walk with SIMD pinned off, and the full
        # blocked walk (runtime-dispatched AVX2/NEON) — the last pair is the
        # simd-vs-scalar comparison the interleaved gather walker must win.
        # even TINY keeps this forest genuinely deep.  Trained on structured
        # data the trees come out imbalanced — most paths terminate well
        # short of max_depth and the gather walker has little latency to
        # hide — so the deep rows train on featureless gaussian data, which
        # fills the depth budget with balanced trees: every walk is
        # max_depth dependent loads, the regime the SIMD interleave targets.
        from repro.core.packing import pack_forest
        from repro.trees.forest import RandomForestClassifier
        drng = np.random.default_rng(3)
        n_df = 16
        dXtr = drng.standard_normal((4000, n_df)).astype(np.float32)
        dytr = drng.integers(0, 5, 4000)
        drf = RandomForestClassifier(
            n_estimators=24 if TINY else 60, max_depth=10 if TINY else 12,
            seed=3).fit(dXtr, dytr)
        dpacked = pack_forest(drf)
        dXte = drng.standard_normal((1024, n_df)).astype(np.float32)
        engs = {
            "rows": TreeEngine(dpacked, mode="integer",
                               backend="native_c_table",
                               backend_kwargs={"block_rows": 1}),
            "scalar": TreeEngine(dpacked, mode="integer",
                                 backend="native_c_table",
                                 backend_kwargs={"simd": False}),
            "simd": TreeEngine(dpacked, mode="integer",
                               backend="native_c_table"),
        }
        outs = {k: e.predict_scores(dXte[:64])[0] for k, e in engs.items()}
        for k in ("scalar", "simd"):
            assert (outs[k] == outs["rows"]).all(), \
                f"{k} table walk diverged from the per-row walk"
        # compiled C pays no per-shape XLA compile, so even the TINY smoke
        # run can measure at the batch sizes the simd-vs-scalar claim is
        # made for (>= 256 rows; tiny batches are timer noise on CI hosts)
        dbatches = (256, 1024) if TINY else (64, 256, 1024)
        for batch in dbatches:
            X = dXte
            while len(X) < batch:
                X = np.concatenate([X, dXte])
            X = X[:batch]
            t_rows = _time(engs["rows"].predict_scores, X, reps=10)
            t_scalar = _time(engs["scalar"].predict_scores, X, reps=10)
            t_simd = _time(engs["simd"].predict_scores, X, reps=10)
            emit(
                f"backend_deep_table_simd_b{batch}", t_simd,
                f"ns_per_row={t_simd * 1e3 / batch:.1f};"
                f"isa={_isa_of(engs['simd'])};"
                f"scalar_blocked_ns_per_row={t_scalar * 1e3 / batch:.1f};"
                f"per_row_ns_per_row={t_rows * 1e3 / batch:.1f};"
                f"simd_speedup_vs_scalar_blocked={t_scalar / t_simd:.2f}x;"
                f"blocked_speedup_vs_per_row={t_rows / t_scalar:.2f}x",
            )


def backend_bitvector():
    """QuickScorer crossover: the bitvector backends against every node-walk
    backend in the regime the QuickScorer line of work targets — many trees,
    shallow depth, large batches.  There the per-row tree walk pays T root
    dispatches and mispredicted branches per row, while the bitvector scorer
    streams sorted threshold tables shared by the whole 8-row block.  The
    forest is wide enough that the if-else translation unit also falls out
    of the instruction cache — the regime where data-as-arrays must win.

    Every route is asserted bit-identical before timing, and the summary row
    reports whether the best bitvector backend beat every other backend on
    this host (the crossover claim, checked live).
    """
    from repro.backends import have_c_toolchain
    from repro.serve.engine import TreeEngine

    data = _datasets()["shuttle"]
    # the crossover regime needs real width even in the smoke pass — depth-3
    # trees train in seconds, and batch >= 1024 is where the claim lives
    # (the TINY test split is smaller than the batch, so rows are tiled;
    # prediction cost does not care about row uniqueness)
    # T=1200 even in TINY: at T=600 the if-else C's translation unit still
    # fits the instruction cache and sits within host-noise distance of the
    # bitvector scorer; doubling the forest pushes it out (and widens the
    # margin over the table walk), so the crossover verdict is stable on a
    # noisy shared CI core.  Depth-3 trees keep the training cost ~seconds.
    n_trees, depth = 1200, 3
    batch = 1024 if TINY else 2048
    rf, packed, Xte, _ = _forest(data, n_trees, depth=depth)
    X = np.tile(Xte, (batch // len(Xte) + 1, 1))[:batch] \
        if len(Xte) < batch else Xte[:batch]
    routes = [("reference", "reference", {}),
              ("bitvector", "bitvector", {})]
    if have_c_toolchain():
        routes += [("native_c", "native_c", {}),
                   ("native_c_table", "native_c_table", {}),
                   ("native_c_bitvector", "native_c_bitvector", {})]
    else:
        emit("bitvector_native_c", 0, "gcc unavailable; C routes skipped")
    engines, builds, ref_scores = {}, {}, None
    for tag, name, kwargs in routes:
        t0 = time.perf_counter()
        eng = TreeEngine(packed, mode="integer", backend=name, **kwargs)
        scores, _ = eng.predict_scores(X[:64])
        builds[tag] = time.perf_counter() - t0
        if ref_scores is None:
            ref_scores = scores
        else:
            assert (scores == ref_scores).all(), f"{tag} diverged"
        engines[tag] = eng
    if have_c_toolchain():
        # autotuned twins of the two tunable C routes: warm() measures the
        # candidate grid (block_rows for the table walk, the v-QuickScorer
        # interleave width K for the bitvector scorer) and pins the winner,
        # so the tuned_* rows make the autotune win a diffable number in
        # BENCH_8.json next to the static-default rows
        for tag, name in (("tuned_native_c_table", "native_c_table"),
                          ("tuned_native_c_bitvector", "native_c_bitvector")):
            t0 = time.perf_counter()
            eng = TreeEngine(packed, mode="integer", backend=name,
                             autotune=True)
            eng.warm(batch)
            scores, _ = eng.predict_scores(X[:64])
            builds[tag] = time.perf_counter() - t0
            assert (scores == ref_scores).all(), f"{tag} diverged"
            engines[tag] = eng
    # interleaved min-of-rounds timing: on a noisy shared host a transient
    # slowdown (CPU steal, frequency dip) lasting one measurement would land
    # entirely on whichever engine happened to be under the timer, flipping
    # the crossover verdict run to run.  Cycling the engines per round and
    # keeping each engine's best round measures the machine's capability,
    # not its worst moment.
    times = {tag: float("inf") for tag in engines}
    for _ in range(3):
        for tag, eng in engines.items():
            times[tag] = min(times[tag], _time(eng.predict_scores, X, reps=3))
    for tag, us in times.items():
        extra = ""
        if tag.startswith("tuned_"):
            extra = f";tuned={engines[tag].tuned_config or '-'}"
        emit(
            f"bitvector_{tag}_t{n_trees}d{depth}_b{batch}", us,
            f"ns_per_row={us * 1e3 / batch:.1f};isa={_isa_of(engines[tag])};"
            f"build_s={builds[tag]:.1f}" + extra,
        )
    # the measured winner must never lose to the static default it was
    # picked against (same min-of-rounds interleaved timing; 10% noise
    # allowance on shared hosts)
    for tag in [t for t in times if t.startswith("tuned_")]:
        base = tag[len("tuned_"):]
        assert times[tag] <= times[base] * 1.10, (
            f"{tag} slower than static {base}: "
            f"{times[tag]:.1f}us vs {times[base]:.1f}us")
    # the crossover verdict stays a static-defaults comparison (the row
    # BENCH_7/BENCH_8 are diffed on); tuned_* rows ride alongside
    static_times = {t: u for t, u in times.items()
                    if not t.startswith("tuned_")}
    bv_routes = {t for t in static_times if "bitvector" in t}
    others = {t: u for t, u in static_times.items() if t not in bv_routes}
    if others:
        best_bv = min(bv_routes, key=times.get)
        best_other = min(others, key=others.get)
        emit(
            f"bitvector_crossover_t{n_trees}d{depth}_b{batch}",
            times[best_bv],
            f"winner={best_bv if times[best_bv] < others[best_other] else best_other};"
            f"best_bitvector={best_bv}:{times[best_bv] * 1e3 / batch:.1f}ns;"
            f"best_other={best_other}:{others[best_other] * 1e3 / batch:.1f}ns;"
            f"bitvector_wins={times[best_bv] < others[best_other]}",
        )


def plan_scaling():
    """Execution-plan axis: ns/row vs shard count, tree- and row-parallel.

    Tree-parallel shards a *wide* forest (the tree scan dominates, so carving
    it across devices is the win the paper's associative integer sum makes
    lossless); with ``REPRO_BENCH_DEVICES=8`` the reference shards run as one
    ``shard_map`` over forced host devices (the CI configuration), otherwise
    as a thread pool of sub-forest backends.  Row-parallel shards the batch
    on the same model.  Every plan's scores are asserted bit-identical to
    the single-shard baseline before timing — the conformance property, live
    in the bench.
    """
    import jax

    from repro.serve.engine import TreeEngine

    data = _datasets()["shuttle"]
    # wide & shallow: many trees, small per-tree walk — the tree-parallel
    # regime (depth keeps the padded tables tiny so S copies stay cheap)
    rf, packed, Xte, _ = _forest(data, 24 if TINY else 96, depth=4 if TINY else 6)
    batch = 256 if TINY else 2048
    X = Xte[:batch]

    single = TreeEngine(packed, mode="integer")
    single.warm(batch)
    s_ref, _ = single.predict_scores(X)
    t_single = _time(single.predict_scores, X, reps=3)
    emit(
        f"plan_single_b{batch}", t_single,
        f"ns_per_row={t_single * 1e3 / batch:.1f};shards=1;"
        f"devices={len(jax.devices())}",
    )

    for plan, shard_counts in (("tree_parallel", (2, 4, 8)),
                               ("row_parallel", (2, 4))):
        for shards in shard_counts:
            eng = TreeEngine(packed, mode="integer", plan=plan, shards=shards)
            eng.warm(batch)
            s, _ = eng.predict_scores(X)
            assert (np.asarray(s) == np.asarray(s_ref)).all(), \
                f"{plan}({shards}) diverged from single-shard"
            us = _time(eng.predict_scores, X, reps=3)
            fused = bool(getattr(eng.plan, "fused", False))
            emit(
                f"plan_{plan}_s{shards}_b{batch}", us,
                f"ns_per_row={us * 1e3 / batch:.1f};"
                f"speedup_vs_single={t_single / us:.2f}x;"
                f"fused={fused};shards={eng.n_shards}",
            )


def remote_scaleout():
    """Scale-out axis: rows/s vs loopback worker *process* count.

    The remote_tree_parallel plan ships tree shards to worker processes over
    the ITRG wire protocol and merges their uint32 partials at the gateway —
    the paper's associative integer sum across machine boundaries.  Before
    timing, every worker count's merged output is asserted bit-identical to
    the single-process walk; a final pass re-asserts it for flint AND
    integer *after a forced worker kill mid-request* (straggler re-dispatch
    to the survivor).
    """
    import threading

    from repro.serve.engine import TreeEngine
    from repro.serve.worker import spawn_local_workers

    data = _datasets()["shuttle"]
    rf, packed, Xte, _ = _forest(data, 24 if TINY else 96,
                                 depth=4 if TINY else 6)
    batch = 256 if TINY else 2048
    X = Xte[:batch]
    batch = len(X)

    single = TreeEngine(packed, "integer")
    single.warm(batch)
    s_ref, p_ref = single.predict_scores(X)
    t_single = _time(single.predict_scores, X, reps=3)
    emit(
        f"remote_single_b{batch}", t_single,
        f"ns_per_row={t_single * 1e3 / batch:.1f};workers=0",
    )

    for n in (1, 2, 4):
        eng = TreeEngine(
            packed, f"integer:reference+remote_tree_parallel:{n}",
            plan_kwargs={"workers": n, "model_id": "bench", "version": 1},
        )
        eng.warm(batch)
        s, p = eng.predict_scores(X)
        assert (np.asarray(s) == np.asarray(s_ref)).all() \
            and (np.asarray(p) == np.asarray(p_ref)).all(), \
            f"remote({n} workers) diverged from single-process"
        us = _time(eng.predict_scores, X, reps=3)
        eng.close()
        emit(
            f"remote_scaleout_w{n}_b{batch}", us,
            f"ns_per_row={us * 1e3 / batch:.1f};"
            f"rows_per_s={batch / (us / 1e6):.0f};workers={n};"
            f"speedup_vs_single={t_single / us:.2f}x",
        )

    # conformance under failure: one worker stalls and is killed mid-request;
    # its shard re-dispatches to the survivor, output must not change by a bit
    Xk = X[:min(128, batch)]
    for mode in ("flint", "integer"):
        ref = TreeEngine(packed, mode).predict_scores(Xk)
        procs, addrs = spawn_local_workers(2, delays=[2000, 0])
        try:
            eng = TreeEngine(
                packed, f"{mode}:reference+remote_tree_parallel:2",
                plan_kwargs={"workers": addrs, "model_id": "bench",
                             "version": 1},
            )
            killer = threading.Timer(0.3, procs[0].kill)
            killer.start()
            try:
                s, p = eng.predict_scores(Xk)
            finally:
                killer.cancel()
            identical = bool((np.asarray(s) == np.asarray(ref[0])).all()
                             and (np.asarray(p) == np.asarray(ref[1])).all())
            assert identical, f"{mode}: kill/re-dispatch changed the output"
            emit(
                f"remote_kill_redispatch_{mode}", 0.0,
                f"identical={identical};redispatches={eng.plan.redispatches}",
            )
            eng.close()
        finally:
            for p_ in procs:
                if p_.poll() is None:
                    p_.kill()
                if p_.stdout is not None:
                    p_.stdout.close()


def coldstart_swap():
    """Artifact axis: registry cold-start and hot-swap through the ITRF
    binary artifact vs the JSON boundary.

    ``register_json`` pays JSON parse + requantization on every load;
    ``register_artifact`` is an mmap + header parse — the arrays are
    zero-copy views over page cache, materialized per backend layout only
    when an engine is built.  Cold-start is min-of-5 on *fresh* registries
    (no artifact cache); hot-swap re-registers the same already-mapped path
    and must reuse the mapped ForestIR outright.  Serving identity and the
    packed_leaf < bitvector byte claim are asserted live, so BENCH_10-style
    snapshots can be diffed on all three headline numbers.
    """
    from repro.ir import ForestIR
    from repro.serve.registry import ModelRegistry
    from repro.trees.io import forest_to_json

    data = _datasets()["shuttle"]
    # even TINY keeps T=32: JSON parse cost scales with node count while the
    # mmap load is O(header), so a wider forest keeps the >= 5x claim far
    # from timer noise on shared CI cores
    n_trees, depth = (32, 9) if TINY else (120, 10)
    rf, packed, Xte, _ = _forest(data, n_trees, depth=depth)
    js = forest_to_json(rf)
    ir = ForestIR.from_forest(rf)
    ART.mkdir(parents=True, exist_ok=True)
    path = str(ART / "coldstart.itrf")
    info = ir.to_itrf(path)

    # warm both boundaries once so neither pays first-import costs under
    # the timer, then min-of-5 cold loads on fresh registries
    warm = ModelRegistry()
    warm.register_json("warm", js)
    warm.register_artifact("warm", path)
    t_json = t_art = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        ModelRegistry().register_json("m", js)
        t_json = min(t_json, time.perf_counter() - t0)
        t0 = time.perf_counter()
        ModelRegistry().register_artifact("m", path)
        t_art = min(t_art, time.perf_counter() - t0)
    ratio = t_json / t_art
    emit("coldstart_register_json", t_json * 1e6,
         f"load_ms={t_json * 1e3:.3f};json_bytes={len(js)}")
    emit("coldstart_register_artifact", t_art * 1e6,
         f"load_ms={t_art * 1e3:.3f};file_bytes={info['file_bytes']};"
         f"speedup_vs_json={ratio:.1f}x")
    assert ratio >= 5.0, (
        f"register_artifact only {ratio:.1f}x faster than register_json "
        f"({t_art * 1e3:.3f} ms vs {t_json * 1e3:.3f} ms)")

    # hot-swap: a new version of an already-mapped artifact must reuse the
    # mapped ForestIR (page-cache pages), and the swap cost lands in the
    # engine's compile/warm ledger under the "load" bucket
    reg = ModelRegistry()
    mv1 = reg.register_artifact("m", path)
    t0 = time.perf_counter()
    mv2 = reg.register_artifact("m", path)
    t_swap = time.perf_counter() - t0
    reused = mv2.packed is mv1.packed
    eng = mv2.engine("integer")
    buckets = dict(eng.drain_compile_timings())
    emit("coldstart_hot_swap", t_swap * 1e6,
         f"swap_ms={t_swap * 1e3:.3f};mapped_ir_reused={reused};"
         f"load_bucket_ms={buckets.get('load', 0.0):.3f}")
    assert reused, "hot-swap of an already-mapped artifact re-read the file"
    assert "load" in buckets, "swap latency missing from the engine ledger"

    # serving identity across the boundary: artifact engine == json engine
    X = Xte[:256]
    mv_j = ModelRegistry().register_json("j", js)
    same = bool(np.array_equal(np.asarray(eng.predict(X)),
                               np.asarray(mv_j.engine("integer").predict(X))))
    assert same, "artifact-loaded engine diverged from JSON-loaded engine"

    # IV-E continued: bytes per materialized layout on the bench forest —
    # the packed_leaf group/dictionary codec must beat the bitvector layout
    per_layout = ir.nbytes_by_layout(mode="integer")
    pl, bv = per_layout["packed_leaf"], per_layout["bitvector"]
    emit("coldstart_bytes_per_layout", pl,
         ";".join(f"{k}={v}" for k, v in sorted(per_layout.items()))
         + f";itrf_file={info['file_bytes']};identity={same};"
         f"packed_leaf_saving_vs_bitvector={1 - pl / bv:.3f}")
    assert pl < bv, f"packed_leaf {pl} B not below bitvector {bv} B"


def roofline_table():
    """§Roofline: summarize every dry-run artifact (see EXPERIMENTS.md)."""
    dd = ART / "dryrun"
    if not dd.exists():
        emit("roofline_table", 0, "no dryrun artifacts; run repro.launch.dryrun --all")
        return
    recs = [json.loads(p.read_text()) for p in sorted(dd.glob("*.json"))]
    ok = [r for r in recs if r.get("ok")]
    for r in ok:
        t = r["roofline"]
        emit(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            t["step_time_lb_s"] * 1e6,
            f"dom={t['dominant']};compute_s={t['compute_s']:.3e};"
            f"memory_s={t['memory_s']:.3e};collective_s={t['collective_s']:.3e};"
            f"useful={t['useful_ratio']:.2f};mfu_bound={t['mfu_bound']:.3f}",
        )
    emit("roofline_cells_ok", len(ok), f"total={len(recs)}")


BENCHES = (
    accuracy_identity,
    gbt_identity,
    perf_float_flint_integer,
    perf_native_c,
    instruction_count_proxy,
    memory_footprint,
    energy_model,
    kernel_identity,
    backend_matrix,
    backend_bitvector,
    plan_scaling,
    remote_scaleout,
    gateway_vs_naive,
    gateway_stage_breakdown,
    coldstart_swap,
    roofline_table,
)


def main(argv=None) -> None:
    """Run all benches, or only the ones named on the command line
    (e.g. ``python benchmarks/run.py backend_matrix``)."""
    import sys

    names = list(sys.argv[1:] if argv is None else argv)
    by_name = {fn.__name__: fn for fn in BENCHES}
    unknown = [n for n in names if n not in by_name]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; have {sorted(by_name)}")
    for fn in [by_name[n] for n in names] or BENCHES:
        fn()
    ART.mkdir(parents=True, exist_ok=True)
    out = ART / "bench_results.csv"
    out.write_text("name,us_per_call,derived\n" + "\n".join(ROWS) + "\n")
    # machine-readable mirror: the CI bench-smoke job uploads this artifact
    records = []
    for row in ROWS:
        name, us, derived = row.split(",", 2)
        records.append({"name": name, "us_per_call": float(us), "derived": derived})
    payload = {"tiny": TINY, "host": host_info(), "results": records}
    out_json = ART / "bench_results.json"
    out_json.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out} and {out_json}")
    # REPRO_BENCH_SNAPSHOT=<path>: a repo-root snapshot (``make bench-smoke``
    # writes BENCH_8.json) — the host block plus one ns/row entry per bench
    # row that reports one, so perf regressions diff as plain JSON
    snap_path = os.environ.get("REPRO_BENCH_SNAPSHOT")
    if snap_path:
        ns_rows = {}
        for rec in records:
            for part in rec["derived"].split(";"):
                if part.startswith("ns_per_row="):
                    ns_rows[rec["name"]] = float(part.split("=", 1)[1])
        # coldstart_* rows carry ms/bytes headlines, not ns/row — snapshot
        # their derived strings whole so artifact-load regressions diff too
        cold = {rec["name"]: rec["derived"] for rec in records
                if rec["name"].startswith("coldstart_")}
        snap_payload = {"tiny": TINY, "host": payload["host"],
                        "ns_per_row": ns_rows}
        if cold:
            snap_payload["coldstart"] = cold
        snap = pathlib.Path(snap_path)
        snap.write_text(json.dumps(snap_payload, indent=2) + "\n")
        print(f"# wrote {snap}")


if __name__ == "__main__":
    main()

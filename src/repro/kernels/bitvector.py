"""jnp reference path for the QuickScorer ``bitvector`` layout.

The C bitvector scorer (``codegen/bitvector_emitter``) streams each feature's
ascending threshold list and breaks at the first true compare — a sequential
early-exit that XLA has no use for.  This path exploits the same
order-independence the early exit rests on: the set of masks a row applies is
exactly ``{e : x[feat_e] > key_e}`` (every false node), regardless of the
order they are ANDed in.  So the kernel evaluates ALL entries data-parallel —
a tree-major padded view of the layout's entries, one fori_loop step per
entry slot, each step vectorized over (batch, trees) — and the bitvector
algebra (AND of clearing masks == AND-NOT of an OR of cleared-bit sets)
turns the reduction into a plain commutative OR accumulator.

uint64 is unavailable under JAX's default x64-disabled config, so bitvectors
run as pairs of uint32 words: ``mask.view(np.uint32)`` on the layout's
little-endian uint64 words yields words low-to-high, i.e. uint32 word
``b // 32`` holds leaf bit ``b`` — the leaf-order scan below only needs that.

The exit leaf (lowest surviving bit) is branch-free: first nonzero uint32
word via ``argmax(v != 0)``, lowest set bit via the two's-complement isolate
``w & (~w + 1)`` and ``population_count(lsb - 1)``.  Partials are the same
uint32 fixed-point sums as every other backend — the per-tree uint32 adds
commute mod 2^32, so summing in tree order is bit-identical to the reference
scan — and finalize stays the one shared numpy step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flint import float_to_key

_NEVER_KEY = np.int32(0x7FFFFFFF)  # int32 max: ``key > this`` is unsatisfiable


def bitvector_device_arrays(bv) -> dict:
    """Build the tree-major padded entry view the jitted kernel consumes.

    The layout stores entries feature-major (the C stream order); the jnp
    kernel wants one (T, M) slot grid — M = max entries per tree — so each
    fori_loop step gathers a (B, T) compare and ORs a (B, T, W32) clear set.
    Padding slots get ``_NEVER_KEY`` *and* an all-zero clear set, so they are
    inert twice over.  Pure numpy, run once per backend build.
    """
    T, F = bv.n_trees, bv.n_features
    W32 = 2 * bv.words
    E = bv.total_entries
    # per-entry feature ids back out of the feature-major CSR
    feat_of_entry = np.repeat(
        np.arange(F, dtype=np.int32), np.diff(bv.feat_offsets).astype(np.int64)
    )
    counts = (np.bincount(bv.thr_tree, minlength=T) if E
              else np.zeros(T, np.int64))
    M = int(counts.max()) if E else 0
    entry_feat = np.zeros((T, M), np.int32)
    entry_key = np.full((T, M), _NEVER_KEY, np.int32)
    # ~mask = the bits this false node CLEARS; all-zero rows clear nothing
    inv_mask = np.zeros((T, M, W32), np.uint32)
    inv_all = (~bv.thr_mask).view(np.uint32).reshape(E, W32)
    slot = np.zeros(T, np.int64)
    for e in range(E):
        t = int(bv.thr_tree[e])
        j = slot[t]
        entry_feat[t, j] = feat_of_entry[e]
        entry_key[t, j] = bv.thr_key[e]
        inv_mask[t, j] = inv_all[e]
        slot[t] = j + 1
    return dict(
        entry_feat=jnp.asarray(entry_feat),
        entry_key=jnp.asarray(entry_key),
        inv_mask=jnp.asarray(inv_mask),
        init_mask=jnp.asarray(bv.init_mask.view(np.uint32).reshape(T, W32)),
        leaf_off=jnp.asarray(bv.leaf_offsets[:-1].astype(np.int32)),
        leaf_fixed=jnp.asarray(bv.leaf_fixed),
        n_entry_slots=M,
    )


@partial(jax.jit, static_argnames=("n_slots",))
def _bitvector_partials(arrays, keys, n_slots: int):
    """(B, F) int32 FlInt keys -> (B, C) uint32 partial accumulators."""
    entry_feat = arrays["entry_feat"]   # (T, M) int32
    entry_key = arrays["entry_key"]     # (T, M) int32
    inv_mask = arrays["inv_mask"]       # (T, M, W32) uint32 cleared-bit sets
    init = arrays["init_mask"]          # (T, W32) uint32
    b = keys.shape[0]
    t, w32 = init.shape

    def apply_slot(j, cleared):
        kv = keys[:, entry_feat[:, j]]                      # (B, T)
        applied = kv > entry_key[None, :, j]                # false nodes
        clr = jnp.where(applied[:, :, None], inv_mask[None, :, j, :],
                        jnp.uint32(0))
        return cleared | clr

    cleared = jnp.zeros((b, t, w32), jnp.uint32)
    if n_slots:  # static; all-stump forests have no internal nodes at all
        cleared = jax.lax.fori_loop(0, n_slots, apply_slot, cleared)
    v = init[None] & ~cleared                               # live-leaf vectors
    # lowest surviving bit: first nonzero word, then isolate its lowest bit
    w_idx = jnp.argmax(v != 0, axis=-1)                     # (B, T)
    word = jnp.take_along_axis(v, w_idx[..., None], axis=-1)[..., 0]
    lsb = word & (~word + jnp.uint32(1))
    bit = jax.lax.population_count(lsb - jnp.uint32(1)).astype(jnp.int32)
    leaf = w_idx.astype(jnp.int32) * 32 + bit               # (B, T)
    rows = arrays["leaf_off"][None, :] + leaf               # (B, T) leaf rows
    contrib = arrays["leaf_fixed"][rows]                    # (B, T, C) uint32
    return jnp.sum(contrib, axis=1, dtype=jnp.uint32)


def make_bitvector_partials_fn(bv):
    """Close over the device tables; return jitted ``X -> uint32 partials``."""
    arrays = bitvector_device_arrays(bv)
    n_slots = arrays.pop("n_entry_slots")

    def fn(x):
        keys = float_to_key(jnp.asarray(x, jnp.float32))
        return _bitvector_partials(arrays, keys, n_slots)

    return jax.jit(fn)

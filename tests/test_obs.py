"""Observability layer: log-scale histograms, the span tracer, end-to-end
trace integrity through the gateway, and the exposition renderers.

The trace-integrity tests pin the span contract the serving stack promises:
per-request spans nest inside the request interval, every stage the request
paid for (queue wait, pad, shard execute ×N, merge, finalize) appears in its
tree, the per-request *direct* children never sum past the request's wall
time, and a gateway with tracing disabled pays nothing measurable.
"""
import asyncio
import json
import math

import numpy as np
import pytest

from repro.obs import (
    NULL_SPAN,
    NULL_TRACER,
    LogHistogram,
    Tracer,
    render_flame,
    render_prometheus,
    request_trees,
    snapshot_json,
    spans_to_jsonl,
    write_jsonl,
)
from repro.serve.gateway import Gateway
from repro.serve.metrics import MetricsRegistry, ModelMetrics
from repro.serve.registry import ModelRegistry


# ----------------------------------------------------------------- histogram

def test_histogram_percentiles_vs_numpy():
    """p50/p95/p99 land within one log bucket (factor 2**(1/sub)) of the
    exact sample percentiles — the accuracy contract that let the histogram
    replace the unbounded reservoir."""
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=1.0, sigma=1.2, size=5000)
    h = LogHistogram()
    for v in samples:
        h.record(v)
    width = 2 ** (1 / h.sub)
    for q in (50, 95, 99):
        exact = float(np.percentile(samples, q))
        est = h.percentile(q)
        assert exact / width <= est <= exact * width, (q, exact, est)
    assert h.count == len(samples)
    assert h.total == pytest.approx(samples.sum(), rel=1e-9)
    assert h.mean == pytest.approx(samples.mean(), rel=1e-9)


def test_histogram_merge_equals_combined():
    rng = np.random.default_rng(1)
    a, b = rng.exponential(3.0, 800), rng.exponential(0.2, 800)
    ha, hb, hc = LogHistogram(), LogHistogram(), LogHistogram()
    for v in a:
        ha.record(v)
        hc.record(v)
    for v in b:
        hb.record(v)
        hc.record(v)
    ha.merge(hb)
    assert ha.count == hc.count and ha.total == pytest.approx(hc.total)
    for q in (50, 95, 99):
        assert ha.percentile(q) == pytest.approx(hc.percentile(q))
    snap = ha.snapshot()
    assert snap["count"] == 1600
    assert sum(c for _, c in snap["buckets"]) == 1600


def test_histogram_under_overflow_and_empty():
    h = LogHistogram(lo=1.0, hi=100.0)
    h.record(1e-9)   # underflow bucket
    h.record(1e9)    # overflow bucket
    h.record(0.0)    # non-positive -> underflow, must not blow up log2
    assert h.count == 3
    snap = h.snapshot()
    assert snap["buckets"][-1][0] is None  # +Inf edge
    # percentile stays clamped to observed extremes
    assert h.percentile(99) <= h.vmax
    empty = LogHistogram()
    assert math.isnan(empty.percentile(50))
    assert math.isnan(empty.snapshot()["p50"])
    with pytest.raises(ValueError):
        LogHistogram(lo=1.0, hi=100.0).merge(LogHistogram(lo=2.0, hi=100.0))


# -------------------------------------------------------------------- tracer

def test_disabled_tracer_hands_out_null_spans():
    t = Tracer(enabled=False)
    s = t.request_span("request")
    assert s is NULL_SPAN and not s
    assert s.child("x") is NULL_SPAN
    s.end()
    assert t.spans() == [] and t.started == 0
    # null parent -> null child, record under null parent is a no-op
    assert t.child(None, "x") is NULL_SPAN
    t.record("x", 0, 1, parent=NULL_SPAN)
    assert NULL_TRACER.request_span("request") is NULL_SPAN


def test_disabled_tracer_overhead_guard():
    """The disabled path must cost no more than a few microseconds per
    request worth of span calls (falsy checks, no allocations)."""
    import time

    t = Tracer(enabled=False)
    n = 20000
    t0 = time.perf_counter()
    for _ in range(n):
        s = t.request_span("request", rows=1)
        c = t.child(s, "batch")
        t.record("stage", 0, 1, parent=c)
        s.end()
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 20e-6, f"{per_call * 1e6:.2f}us per disabled request"


def test_deterministic_sampling():
    t = Tracer(sample=0.5)
    roots = [t.request_span("request") for _ in range(100)]
    live = [s for s in roots if s]
    assert len(live) == 50  # accumulator sampling: exactly half, no RNG
    for s in live:
        s.end()
    assert len(t.spans()) == 50


def test_span_nesting_and_ring_bound():
    t = Tracer(capacity=8)
    with t.request_span("request") as root:
        with root.child("inner") as c:
            c.annotate(k=1)
    spans = t.spans()
    by_name = {s.name: s for s in spans}
    inner, req = by_name["inner"], by_name["request"]
    assert inner.parent_id == req.span_id and inner.trace_id == req.trace_id
    assert req.t0 <= inner.t0 and inner.t1 <= req.t1
    assert inner.attrs == {"k": 1}
    for _ in range(50):
        t.request_span("request").end()
    assert len(t.spans()) <= 8 and t.dropped > 0


# ------------------------------------------------------- metrics regressions

def test_rejected_requests_advance_throughput_span():
    """Satellite fix: rejections must touch t_first/t_last.  A gateway that
    only shed load for a while used to freeze its clock, inflating
    rows_per_s over the real serving span."""
    import time

    mm = ModelMetrics()
    mm.record_request(10, 1.0)
    time.sleep(0.02)
    mm.record_rejected()
    span = mm.t_last - mm.t_first
    assert span >= 0.015, "rejection did not extend the throughput span"
    st = mm.stats()
    assert st["rejected"] == 1
    # 10 rows over >=15ms, not over the ~0ms request-only span
    assert st["rows_per_s"] <= 10 / 0.015


def test_render_table_columns_and_nan():
    reg = MetricsRegistry()
    mm = reg.model("m1")
    mm.record_request(4, 2.0)
    mm.hit_requests += 1
    table = reg.render_table()
    head = table.splitlines()[0]
    for col in ("hit_req", "shards", "queue_ms", "pad_ms", "shard_ms"):
        assert col in head, f"missing column {col!r}"
    # no stage samples yet -> those cells render '-', never a bare 'nan'
    assert "nan" not in table
    assert "-" in table.splitlines()[2]


def test_registry_aggregate_merges_histograms():
    reg = MetricsRegistry()
    reg.model("a").record_request(1, 1.0)
    reg.model("b").record_request(1, 100.0)
    reg.model("a").record_stage("queue", 0.5)
    reg.model("b").record_stage("queue", 5.0)
    agg = reg.aggregate()
    assert agg["models"] == 2 and agg["requests"] == 2
    assert agg["latency"]["count"] == 2
    assert agg["stages"]["queue"]["count"] == 2
    # the merged p99 reflects the slow model, not either alone
    assert agg["latency"]["p99"] > 50


# ----------------------------------------------------- gateway trace integrity

def _run_traced_gateway(small_forest, Xte, *, tracer, plan=None, shards=None,
                        n_requests=6):
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", max_delay_ms=1.0, plan=plan,
                 shards=shards, tracer=tracer)

    async def run():
        outs = []
        for i in range(n_requests):
            outs.append(await gw.submit("m", Xte[i * 4:(i + 1) * 4]))
        await gw.close()
        return outs

    outs = asyncio.run(run())
    return gw, outs


def _assert_trace_integrity(spans, *, expect_shards=None):
    by_id = {s.span_id: s for s in spans}
    roots = [s for s in spans if s.name == "request"]
    assert roots, "no request spans recorded"
    for s in spans:
        assert s.t1 is not None and s.t1 >= s.t0
        if s.parent_id and s.parent_id in by_id:
            p = by_id[s.parent_id]
            assert p.t0 <= s.t0 and s.t1 <= p.t1, (
                f"{s.name} [{s.t0},{s.t1}] escapes parent "
                f"{p.name} [{p.t0},{p.t1}]"
            )
    # per-request DIRECT children must not sum past the request wall time
    # (parallel shard spans under the batch may overlap — that's the point)
    for r in roots:
        direct = [s for s in spans if s.parent_id == r.span_id]
        assert sum(s.t1 - s.t0 for s in direct) <= (r.t1 - r.t0)
    trees = request_trees(spans)
    assert len(trees) == len(roots)

    def names(node, acc):
        acc.append(node["name"])
        for c in node["children"]:
            names(c, acc)
        return acc

    shard_counts = []
    saw_stages = set()
    for t in trees:
        ns = names(t, [])
        saw_stages.update(n.split(":")[0] for n in ns)
        shard_counts.append(sum(1 for n in ns if n.startswith("shard:")))
    for stage in ("request", "cache_probe", "queue", "batch", "pad",
                  "shard", "finalize", "stitch"):
        assert stage in saw_stages, f"stage {stage!r} missing from traces"
    if expect_shards is not None:
        assert max(shard_counts) >= expect_shards, (
            f"expected >= {expect_shards} shard spans per batch, "
            f"got {shard_counts}"
        )
    return trees


def test_gateway_trace_single_plan(small_forest, shuttle_small):
    _, _, Xte, _ = shuttle_small
    tracer = Tracer()
    gw, _ = _run_traced_gateway(small_forest, Xte, tracer=tracer)
    _assert_trace_integrity(tracer.spans(), expect_shards=1)
    # the always-on stage columns got fed regardless of tracing
    st = gw.stats()["per_model"]["m"]
    for stage in ("queue", "pad", "shard", "finalize"):
        assert st["stages"][stage]["count"] > 0
        assert np.isfinite(st[f"{stage}_ms"])


def test_gateway_trace_tree_parallel(small_forest, shuttle_small):
    """Threaded tree-parallel: one shard span per sub-forest plus an explicit
    merge span, all inside the batch span."""
    _, _, Xte, _ = shuttle_small
    tracer = Tracer()
    from repro.plan import thread_shard_cap

    gw, _ = _run_traced_gateway(small_forest, Xte, tracer=tracer,
                                plan="tree_parallel", shards=3)
    n = min(3, thread_shard_cap())  # threaded fan-out is core-capped
    trees = _assert_trace_integrity(tracer.spans(), expect_shards=n)
    flat = []

    def walk(n):
        flat.append(n["name"])
        for c in n["children"]:
            walk(c)

    for t in trees:
        walk(t)
    assert any(n == "merge" for n in flat)
    st = gw.stats()["per_model"]["m"]
    assert st["stages"]["merge"]["count"] > 0
    assert len(st["shards"]) == n


def test_gateway_trace_row_parallel(small_forest, shuttle_small):
    _, _, Xte, _ = shuttle_small
    tracer = Tracer()
    gw, _ = _run_traced_gateway(small_forest, Xte, tracer=tracer,
                                plan="row_parallel", shards=2)
    _assert_trace_integrity(tracer.spans(), expect_shards=1)
    st = gw.stats()["per_model"]["m"]
    assert st["stages"]["merge"]["count"] > 0


def test_engine_fused_or_threaded_shard_spans(small_packed, shuttle_small):
    """Direct engine attach (no gateway): the shard spans reflect the
    execution strategy — ``shard:fused:*`` for the shard_map path, one span
    per shard backend otherwise."""
    from repro.serve.engine import TreeEngine

    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", plan="tree_parallel",
                     shards=2)
    tracer = Tracer()
    root = tracer.request_span("request")
    eng.attach_trace(tracer, root)
    try:
        eng.predict_scores(Xte[:8])
    finally:
        eng.detach_trace()
    root.end()
    shard_spans = [s for s in tracer.spans() if s.name.startswith("shard:")]
    if eng.plan.fused:
        assert len(shard_spans) == 1 and "fused" in shard_spans[0].name
    else:
        assert len(shard_spans) == eng.n_shards
    # compile/warm cost of the bucket this batch hit was tracked
    assert 8 in eng.drain_compile_timings()


def test_gateway_batch_riders_grafted(small_forest, shuttle_small):
    """Coalesced requests share one batch span; the export layer grafts the
    batch subtree under every rider request."""
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    tracer = Tracer()
    gw = Gateway(reg, mode="integer", max_delay_ms=20.0, cache_rows=0,
                 tracer=tracer)

    async def run():
        await asyncio.gather(*[gw.submit("m", Xte[i:i + 1]) for i in range(4)])
        await gw.close()

    asyncio.run(run())
    spans = tracer.spans()
    batches = [s for s in spans if s.name == "batch"]
    assert batches
    coalesced = max(batches, key=lambda s: len(s.attrs.get("riders", [])))
    riders = coalesced.attrs["riders"]
    assert len(riders) >= 2, "batcher did not coalesce under a 20ms deadline"
    trees = request_trees(spans)
    with_batch = [t for t in trees
                  if any(c["name"] == "batch" for c in t["children"])]
    assert len(with_batch) >= len(riders)


def test_gateway_disabled_tracing_collects_nothing(small_forest, shuttle_small):
    _, _, Xte, _ = shuttle_small
    gw, _ = _run_traced_gateway(small_forest, Xte, tracer=None, n_requests=3)
    assert gw.tracer is NULL_TRACER and len(gw.tracer.spans()) == 0
    # stage metrics still flow (they are always-on, tracing is opt-in)
    st = gw.stats()["per_model"]["m"]
    assert st["stages"]["pad"]["count"] > 0


# ---------------------------------------------------------------- exposition

def _sample_stats():
    reg = MetricsRegistry()
    mm = reg.model("m")
    mm.record_request(4, 2.5)
    mm.record_request(4, 7.5)
    mm.record_batch(8, 8)
    mm.record_cache(2, 6)
    mm.record_stage("queue", 0.3)
    mm.record_shards({"s0:reference[0:5]": (1.5, 1)})
    mm.record_compiles({8: 12.0})
    return reg.stats()


def test_render_prometheus_format():
    text = render_prometheus(_sample_stats())
    assert '# TYPE repro_requests_total counter' in text
    assert 'repro_requests_total{model="m"} 2' in text
    assert '# TYPE repro_request_latency_ms histogram' in text
    assert 'le="+Inf"' in text
    assert 'repro_request_latency_ms_count{model="m"} 2' in text
    assert 'repro_stage_ms_bucket{model="m",stage="queue"' in text
    assert 'repro_shard_ms_total{model="m",shard="s0:reference[0:5]"} 1.5' in text
    assert 'repro_bucket_compile_ms{model="m",bucket="8"} 12.0' in text
    # cumulative: the +Inf bucket equals the count
    lat = [l for l in text.splitlines()
           if l.startswith('repro_request_latency_ms_bucket') and '+Inf' in l]
    assert lat[0].rsplit(" ", 1)[1] == "2"


def test_snapshot_json_strict():
    stats = _sample_stats()
    stats["m"]["broken"] = float("nan")  # must sanitize, not crash
    out = snapshot_json(stats, run="test")
    doc = json.loads(out)  # strict parse: would fail on NaN tokens
    assert doc["run"] == "test"
    assert doc["stats"]["m"]["broken"] is None
    assert doc["stats"]["m"]["requests"] == 2


def test_jsonl_roundtrip_and_flame(tmp_path):
    tracer = Tracer()
    with tracer.request_span("request", rows=2) as root:
        with root.child("batch") as b:
            tracer.record("shard:s0", b.t0, b.t0 + 1000, parent=b)
    spans = tracer.spans()
    text = spans_to_jsonl(spans)
    lines = [json.loads(l) for l in text.splitlines()]
    assert len(lines) == len(spans) == 3
    assert {l["name"] for l in lines} == {"request", "batch", "shard:s0"}
    path = tmp_path / "trace.jsonl"
    assert write_jsonl(spans, path) == 3
    assert len(path.read_text().splitlines()) == 3
    flame = render_flame(spans)
    assert "request" in flame and "shard:s0" in flame

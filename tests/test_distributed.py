"""Distribution-layer tests that need >1 device run in subprocesses so the
main pytest process keeps a single CPU device (jax locks device count at
first init)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.mesh import elastic_mesh_shape


def _run(py: str, devices: int = 8, timeout: int = 560) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        # version-agnostic mesh construction, available to every script
        # (importing it does not initialize the jax backend)
        "from repro.launch.mesh import compat_make_mesh\n"
        "from repro.sharding.ops import compat_shard_map\n"
        + textwrap.dedent(py)
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        # JAX_PLATFORMS pinned: these children force host-platform devices
        # via XLA_FLAGS, so a bundled libtpu must never probe the cloud
        # metadata service for a TPU (minutes of retry when it blackholes).
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_integer_allreduce_matches_float_psum():
    """The paper-math integer all-reduce: deterministic and within bound."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro.train.intreeger_allreduce import integer_psum, quantization_error_bound
        mesh = compat_make_mesh((8,), ("data",))
        x = np.random.default_rng(0).normal(size=(8, 1024)).astype(np.float32)
        def f(xs):
            return integer_psum(xs, "data", 8)
        y = compat_shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check=True)(x)
        y = np.asarray(y).reshape(8, -1)[0]
        exact = x.sum(axis=0)
        bound = quantization_error_bound(8, float(np.abs(x).max()))
        print(json.dumps({"max_err": float(np.abs(y - exact).max()), "bound": bound}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["max_err"] <= res["bound"] * 1.01
    assert res["max_err"] < 1e-4


def test_sharded_train_step_matches_single_device():
    """Same batch, same seed: 2x4 mesh loss == single-device loss."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs.base import smoke_config
        from repro.models import transformer as tfm
        from repro.sharding import rules
        from repro.sharding.ops import use_mesh
        from repro.train import optimizer as opt
        from repro.train.step import make_train_step
        from repro.data.tokens import pipeline_for

        cfg = smoke_config("granite-3-2b")
        pipe = pipeline_for(cfg, 8, 64)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        ostate = opt.init_opt_state(params)
        step = make_train_step(cfg, opt.AdamWConfig(lr=1e-3))

        # single device
        p1, o1, m1 = jax.jit(step)(params, ostate, batch)

        # 2x4 mesh
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        with mesh, use_mesh(mesh):
            sh = rules.params_shardings(params, mesh)
            pp = jax.tree.map(jax.device_put, params, sh)
            oo = opt.init_opt_state(pp)
            bsh = rules.batch_shardings(mesh, batch)
            bb = jax.tree.map(jax.device_put, batch, bsh)
            p2, o2, m2 = jax.jit(step)(pp, oo, bb)
        print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert abs(res["l1"] - res["l2"]) < 5e-2, res


def test_dryrun_entry_on_small_mesh():
    """run_cell machinery end-to-end on a small config x 8-device mesh."""
    out = _run("""
        import jax, json
        import jax.numpy as jnp
        from repro.configs.base import smoke_config
        from repro.launch import jaxpr_cost
        from repro.launch.hlo_analysis import collective_bytes
        from repro.launch.specs import params_specs
        from repro.models import transformer as tfm
        from repro.sharding import rules
        from repro.sharding.ops import use_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = smoke_config("olmoe-1b-7b")
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        with mesh, use_mesh(mesh):
            shapes = tfm.param_shapes(cfg)
            sh = rules.params_shardings(shapes, mesh)
            params = jax.tree.map(lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h), shapes, sh)
            batch = {
                "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32, sharding=NamedSharding(mesh, P("data", None))),
                "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32, sharding=NamedSharding(mesh, P("data", None))),
            }
            fn = lambda p, b: tfm.loss_fn(cfg, p, b)[0]
            jc = jaxpr_cost.analyze(fn, params, batch)
            compiled = jax.jit(fn).lower(params, batch).compile()
            cb = collective_bytes(compiled.as_text())
            ma = compiled.memory_analysis()
        print(json.dumps({"flops": jc["flops"], "coll": cb["total"],
                          "temp": ma.temp_size_in_bytes}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["flops"] > 1e6
    assert res["coll"] > 0  # sharded program must contain collectives
    assert res["temp"] > 0


def test_trip_count_awareness():
    """jaxpr cost scales with scan length; XLA's aggregate does not."""
    out = _run("""
        import jax, jax.numpy as jnp, json
        from repro.launch import jaxpr_cost
        def make(n):
            def f(x, w):
                def body(c, _):
                    return c @ w, None
                y, _ = jax.lax.scan(body, x, None, length=n)
                return y
            return f
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c1 = jaxpr_cost.analyze(make(1), a, a)
        c10 = jaxpr_cost.analyze(make(10), a, a)
        print(json.dumps({"r": c10["flops"] / c1["flops"]}))
    """, devices=1)
    res = json.loads(out.strip().splitlines()[-1])
    assert 9.0 < res["r"] < 11.0


def test_integer_dp_training_converges():
    """End-to-end: the paper-math integer all-reduce trains as well as the
    exact float path over 25 steps on 8 data shards."""
    out = _run("""
        import jax, jax.numpy as jnp, json
        from repro.configs.base import smoke_config
        from repro.data.tokens import pipeline_for
        from repro.models import transformer as tfm
        from repro.train import optimizer as opt
        from repro.train.step import make_integer_dp_train_step, make_train_step

        cfg = smoke_config("granite-3-2b")
        mesh = compat_make_mesh((8,), ("data",))
        pipe = pipeline_for(cfg, 16, 64)
        ocfg = opt.AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=25)

        def run(step_fn):
            params = tfm.init_params(cfg, jax.random.PRNGKey(0))
            ostate = opt.init_opt_state(params)
            jstep = jax.jit(step_fn, donate_argnums=(0, 1))
            losses = []
            for s in range(25):
                batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
                params, ostate, m = jstep(params, ostate, batch)
                losses.append(float(m["loss"]))
            return losses

        exact = run(make_train_step(cfg, ocfg))
        with mesh:
            integer = run(make_integer_dp_train_step(cfg, mesh, ocfg))
        print(json.dumps({"exact": exact[-1], "integer": integer[-1],
                          "e0": exact[0], "i0": integer[0]}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["integer"] < res["i0"] - 0.2  # clearly descending
    assert abs(res["integer"] - res["exact"]) < 0.15  # tracks the exact path


def test_distributed_attention_matches_local():
    """shard_map attention == local attention across the three layouts."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.models.layers import _attn_core
        from repro.sharding.ops import use_mesh
        rng = np.random.default_rng(0)
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        errs = {}
        # (name, q_shape, kv_shape, kwargs)
        cases = {
          "train_gqa": ((4, 32, 4, 2, 16), (4, 32, 4, 16), dict(causal=True, window=0, q_chunk=8)),
          "decode_mqa_seqshard": ((4, 1, 1, 8, 16), (4, 64, 1, 16),
                                  dict(causal=True, window=0, q_chunk=8, q_offset=40, kv_len=41)),
          "decode_long_batch1": ((1, 1, 4, 2, 16), (1, 128, 4, 16),
                                 dict(causal=True, window=24, q_chunk=8, q_offset=100, kv_len=101)),
        }
        for name, (qs, ks, kw) in cases.items():
            q = jnp.asarray(rng.normal(size=qs), jnp.bfloat16)
            k = jnp.asarray(rng.normal(size=ks), jnp.bfloat16)
            v = jnp.asarray(rng.normal(size=ks), jnp.bfloat16)
            ref = _attn_core(q, k, v, **kw)
            with mesh, use_mesh(mesh):
                got = jax.jit(lambda a,b,c: _attn_core(a,b,c, mesh=mesh, **kw))(q,k,v)
            errs[name] = float(np.abs(np.asarray(ref,np.float32)-np.asarray(got,np.float32)).max())
        print(json.dumps(errs))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    for name, err in res.items():
        assert err < 0.02, (name, err)


def test_tree_serve_step_sharded_matches_local():
    """The pod-scale serving step is bit-identical to the oracle and
    lowers with ZERO collectives (embarrassingly row-parallel)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core.serving import tree_serve_step
        from repro.core.packing import pack_forest
        from repro.core.flint import float_to_key
        from repro.data.tabular import make_shuttle_like
        from repro.trees.forest import RandomForestClassifier
        from repro.sharding.ops import use_mesh
        from repro.launch.hlo_analysis import collective_bytes

        X, y = make_shuttle_like(n=3000, seed=1)
        rf = RandomForestClassifier(n_estimators=8, max_depth=5, seed=0).fit(X, y)
        packed = pack_forest(rf)
        tables = {k: jnp.asarray(getattr(packed, k)) for k in
                  ("feature", "threshold_key", "left", "right", "leaf_fixed")}
        keys = float_to_key(jnp.asarray(X[:1024]))
        acc_ref, preds_ref = tree_serve_step(tables, keys, packed.max_depth)
        mesh = compat_make_mesh((2, 4), ("data", "model"))
        with mesh, use_mesh(mesh):
            fn = jax.jit(lambda t, x: tree_serve_step(t, x, packed.max_depth))
            acc, preds = fn(tables, keys)
            coll = collective_bytes(fn.lower(tables, keys).compile().as_text())
        same = bool((np.asarray(acc) == np.asarray(acc_ref)).all())
        print(json.dumps({"same": same, "coll": coll["total"]}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["same"]
    assert res["coll"] == 0


def test_elastic_mesh_planner():
    assert elastic_mesh_shape(512) == ((2, 16, 16), ("pod", "data", "model"))
    assert elastic_mesh_shape(256) == ((2, 8, 16), ("pod", "data", "model"))
    # degraded: 480 devices (one host of 32 lost from 512)
    shape, axes = elastic_mesh_shape(480)
    assert np.prod(shape) == 480 and shape[-1] == 16
    # tiny fallback
    shape, axes = elastic_mesh_shape(6, model=16)
    assert np.prod(shape) == 6

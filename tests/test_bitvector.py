"""Property tests for the QuickScorer ``bitvector`` layout and its backends.

The layout's correctness rests on three claims, each checked directly here
(the conformance matrices in ``test_backends.py``/``test_plans.py`` cover the
end-to-end scores):

  1. *Round trip*: the per-feature ascending threshold streams encode exactly
     the same (tree, feature, key) comparisons as the ragged CSR's internal
     nodes — nothing dropped, nothing invented — and each feature's segment
     really is sorted ascending (what the C early exit relies on).
  2. *Mask algebra*: ANDing the masks of exactly the false nodes
     (``x > key``) into the init mask leaves the ragged walk's exit leaf as
     the lowest set bit — including >64-leaf trees, where the bitvector
     spans multiple uint64 words.
  3. *Degradation*: the emitted C stays bit-identical with the GCC builtins
     and the SIMD dispatcher compiled out (``-DREPRO_NO_BUILTINS`` /
     ``-DREPRO_NO_SIMD`` / ``-mno-avx2``), and ``simd_isa()`` reports what
     actually dispatches.

Randomization is seed-parametrized (deterministic per run) rather than
hypothesis-driven: the suite must exercise the properties even where
hypothesis is not installed (see the conftest shim).
"""
import numpy as np
import pytest

from forest_cases import DEGENERATE_FORESTS, chain_tree, forest_from_trees, stump
from repro.backends import create_backend
from repro.ir import ForestIR

SEEDS = [0, 1, 2, 3, 4]


def _random_forest(seed, n_trees=10, depth=6, n_classes=5, n_features=7):
    from repro.trees.forest import RandomForestClassifier

    rng = np.random.default_rng(seed)
    Xtr = rng.standard_normal((1500, n_features)).astype(np.float32)
    ytr = rng.integers(0, n_classes, 1500)
    return RandomForestClassifier(
        n_estimators=n_trees, max_depth=depth, seed=seed
    ).fit(Xtr, ytr)


def _multiword_forest():
    """One 71-leaf chain (needs two uint64 words) plus small companions."""
    return forest_from_trees(
        [chain_tree(70, 3), chain_tree(5, 3), stump([0.2, 0.3, 0.5])], 3, 4
    )


def _all_case_irs(seed):
    yield f"random{seed}", ForestIR.from_forest(_random_forest(seed))
    for name, mk in DEGENERATE_FORESTS.items():
        yield name, ForestIR.from_forest(mk())
    yield "multiword", ForestIR.from_forest(_multiword_forest())


def _entry_features(bv):
    """Per-entry feature ids recovered from the feature-major CSR."""
    return np.repeat(
        np.arange(bv.n_features, dtype=np.int32),
        np.diff(bv.feat_offsets).astype(np.int64),
    )


def _ragged_walk_leaf(ragged, t, keys):
    """Reference traversal of tree ``t``: global exit-node index."""
    n = int(ragged.roots[t])
    while ragged.feature[n] >= 0:
        if keys[ragged.feature[n]] > ragged.threshold_key[n]:
            n = int(ragged.right[n])
        else:
            n = int(ragged.left[n])
    return n


# ------------------------------------------------------------- property 1

@pytest.mark.parametrize("seed", SEEDS)
def test_threshold_streams_round_trip_ragged_comparisons(seed):
    """The sorted streams hold exactly the ragged internal nodes'
    (tree, feature, key) triples, ascending by key within each feature."""
    for name, ir in _all_case_irs(seed):
        bv = ir.materialize("bitvector")
        ragged = ir.materialize("ragged")
        feat = _entry_features(bv)
        got = sorted(zip(bv.thr_tree.tolist(), feat.tolist(),
                         bv.thr_key.tolist()))
        internal = np.flatnonzero(ragged.feature >= 0)
        tree_of = np.searchsorted(ragged.node_offsets[1:], internal,
                                  side="right")
        want = sorted(zip(tree_of.tolist(),
                          ragged.feature[internal].tolist(),
                          ragged.threshold_key[internal].tolist()))
        assert got == want, f"comparison multiset mismatch ({name})"
        for f in range(bv.n_features):
            seg = bv.thr_key[bv.feat_offsets[f]:bv.feat_offsets[f + 1]]
            assert (np.diff(seg) >= 0).all(), f"stream not ascending ({name})"


# ------------------------------------------------------------- property 2

@pytest.mark.parametrize("seed", SEEDS)
def test_mask_algebra_reproduces_ragged_exit_leaf(seed):
    """numpy re-derivation of the scorer: AND the false nodes' masks in
    *arbitrary* (table) order, take the lowest surviving bit, and compare the
    leaf's class contributions against the ragged walk's exit node."""
    from repro.core.flint import float_to_key_np

    rng = np.random.default_rng(seed + 100)
    for name, ir in _all_case_irs(seed):
        bv = ir.materialize("bitvector")
        ragged = ir.materialize("ragged")
        feat = _entry_features(bv)
        X = rng.normal(0.0, 4.0, (17, ir.n_features)).astype(np.float32)
        K = float_to_key_np(X)
        for keys in K:
            v = bv.init_mask.copy()  # (T, words)
            false_e = np.flatnonzero(keys[feat] > bv.thr_key)
            for e in false_e:
                v[bv.thr_tree[e]] &= bv.thr_mask[e]
            for t in range(bv.n_trees):
                assert v[t].any(), f"no surviving leaf ({name}, tree {t})"
                words = v[t]
                k = int(np.flatnonzero(words)[0])
                w = int(words[k])
                leaf = 64 * k + (w & -w).bit_length() - 1
                got = bv.leaf_fixed[bv.leaf_offsets[t] + leaf]
                node = _ragged_walk_leaf(ragged, t, keys)
                np.testing.assert_array_equal(
                    got, ragged.leaf_fixed[node],
                    err_msg=f"exit leaf mismatch ({name}, tree {t})")


def test_multiword_layout_shape():
    """>64-leaf trees widen the bitvector: words == 2, init masks populate
    exactly n_leaves bits, and the wide tree's bits spill into word 1."""
    ir = ForestIR.from_forest(_multiword_forest())
    bv = ir.materialize("bitvector")
    assert bv.words == 2
    assert int(bv.n_leaves.max()) == 71
    for t in range(bv.n_trees):
        pop = sum(int(w).bit_count() for w in bv.init_mask[t].tolist())
        assert pop == int(bv.n_leaves[t])
    wide = int(np.argmax(bv.n_leaves))
    assert bv.init_mask[wide, 1] != 0  # leaves 64..70 live in word 1


# ------------------------------------------------------------- property 3

@pytest.mark.requires_gcc
@pytest.mark.parametrize("flags,forces_scalar", [
    ("-DREPRO_NO_BUILTINS", False),   # portable ctz; SIMD dispatch untouched
    ("-mno-avx2 -DREPRO_NO_BUILTINS", True),
])
def test_degraded_builds_stay_bit_identical(monkeypatch, flags, forces_scalar):
    """The portable ctz loop and the SIMD-less build produce the same bits
    as the full build — the CI degradation job's in-process mirror."""
    ir = ForestIR.from_forest(_multiword_forest())
    rows = np.random.default_rng(9).normal(0, 4, (41, 4)).astype(np.float32)
    ref = create_backend("reference", ir.materialize("padded"),
                         mode="integer")
    want = np.asarray(ref.predict_partials(rows))
    monkeypatch.setenv("REPRO_CC_EXTRA_FLAGS", flags)
    for backend, layout in [("native_c_bitvector", "bitvector"),
                            ("native_c_table", "ragged")]:
        b = create_backend(backend, ir.materialize(layout), mode="integer")
        np.testing.assert_array_equal(
            np.asarray(b.predict_partials(rows)), want,
            err_msg=f"{backend} under {flags}")
        if forces_scalar:
            assert b.simd_isa() == "scalar"


@pytest.mark.requires_gcc
def test_simd_isa_surface(small_packed):
    """simd_isa() reports the *dispatched* variant, not compile-time
    capability: a plain ISA for the blocked table walk, an ISA + interleave
    width (e.g. "avx512-k8", "avx2-k4", "neon-k8") for the bitvector unit,
    scalar for dispatcher-less TUs and pinned-scalar builds."""
    ir = small_packed.to_ir()
    ragged = ir.materialize("ragged")
    blocked = create_backend("native_c_table", ragged, mode="integer")
    assert blocked.simd_isa() in ("avx2", "neon", "scalar")
    pinned = create_backend("native_c_table", ragged, mode="integer",
                            simd=False)
    assert pinned.simd_isa() == "scalar"
    # TUs without a runtime dispatcher are scalar by construction
    assert create_backend("native_c", small_packed,
                          mode="integer").simd_isa() == "scalar"
    # the bitvector unit names the variant it dispatches: ISA prefix plus
    # the emitted interleave width
    bv = ir.materialize("bitvector")
    isa = create_backend("native_c_bitvector", bv, mode="integer").simd_isa()
    assert isa == "scalar" or \
        isa in tuple(f"{p}-k8" for p in ("avx512", "avx2", "neon"))
    isa4 = create_backend("native_c_bitvector", bv, mode="integer",
                          interleave=4).simd_isa()
    assert isa4 == "scalar" or isa4.endswith("-k4")
    # simd=False pins the scalar blocked path for this build only
    assert create_backend("native_c_bitvector", bv, mode="integer",
                          simd=False).simd_isa() == "scalar"


@pytest.mark.requires_gcc
@pytest.mark.parametrize("simd", [True, False], ids=["simd", "scalar"])
@pytest.mark.parametrize("interleave", [1, 4, 8])
def test_interleave_widths_every_dispatch_bit_identical(interleave, simd):
    """K-wide comparison groups x {host SIMD dispatch, pinned scalar}: the
    grouping transform is pure padding + unrolling, so every (width,
    dispatch) pair matches the reference bits — including the multi-word
    (>64-leaf) case, where the K applies each touch several mask words."""
    rng = np.random.default_rng(interleave * 10 + simd)
    for name, ir in (("random2", ForestIR.from_forest(_random_forest(2))),
                     ("multiword", ForestIR.from_forest(_multiword_forest()))):
        rows = rng.normal(0, 4, (23, ir.n_features)).astype(np.float32)
        want = np.asarray(
            create_backend("reference", ir.materialize("padded"),
                           mode="integer").predict_partials(rows))
        b = create_backend("native_c_bitvector", ir.materialize("bitvector"),
                           mode="integer", interleave=interleave, simd=simd)
        np.testing.assert_array_equal(
            np.asarray(b.predict_partials(rows)), want,
            err_msg=f"{name} k={interleave} simd={simd}")
        isa = b.simd_isa()
        if simd:
            assert isa == "scalar" or isa.endswith(f"-k{interleave}")
        else:
            assert isa == "scalar"


@pytest.mark.requires_gcc
@pytest.mark.parametrize("n_rows", [1, 7, 8, 9, 16, 41])
def test_blocked_bitvector_c_every_tail_shape(n_rows):
    """predict_batch mixes 8-row blocks with a scalar tail; every split of
    full blocks + remainder must match the reference bit-for-bit."""
    ir = ForestIR.from_forest(_random_forest(11, n_trees=6, depth=5))
    rows = np.random.default_rng(n_rows).normal(
        0, 3, (n_rows, ir.n_features)).astype(np.float32)
    ref = create_backend("reference", ir.materialize("padded"),
                         mode="integer")
    cbv = create_backend("native_c_bitvector", ir.materialize("bitvector"),
                         mode="integer")
    np.testing.assert_array_equal(
        np.asarray(cbv.predict_partials(rows)),
        np.asarray(ref.predict_partials(rows)))

"""Staged request tracing: nested spans through the serving path.

One request through the gateway touches half a dozen subsystems — admission,
cache probe, micro-batch queue, bucket padding, per-shard execution, partial
merge, finalize, response stitch — and a latency percentile alone cannot say
which of them a slow request paid for.  The tracer records that path as a
tree of **spans**: ``(trace_id, span_id, parent_id, name, t0_ns, t1_ns,
attrs)``, timed with ``perf_counter_ns`` and kept in a bounded thread-safe
ring buffer, exported as JSONL or a flame-style summary (``repro.obs.
export``).

Design constraints, in order:

  * **Near-zero cost when disabled.**  A disabled tracer answers every
    ``request_span``/``child`` call with the module-level :data:`NULL_SPAN`
    singleton — falsy, allocation-free, and every method a no-op — so the
    serving hot path can call the span API unconditionally.  Children of a
    null span are null, so one root-level check gates an entire request's
    tracing.
  * **Sampling at the root.**  ``sample=0.25`` traces every 4th request via
    a deterministic accumulator (no RNG in the hot path); an unsampled
    request's whole span tree collapses to null spans.
  * **Cross-thread spans.**  Spans carry no thread-local magic: the parent
    is passed explicitly, so a span started on the event loop can parent
    spans recorded from the batcher worker, the plan's shard pool, or a
    ctypes call — :meth:`Tracer.record` takes explicit ``t0_ns``/``t1_ns``
    for stages measured where the tracer isn't reachable.
  * **Batch fan-in.**  A micro-batched execute serves many requests at
    once; the batch span is parented to its first sampled rider and lists
    every rider span id in ``attrs["riders"]``, so the export layer can
    graft the shared execution subtree under *each* request that rode it.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

__all__ = ["Span", "Tracer", "NULL_SPAN", "NULL_TRACER"]


class Span:
    """One timed, named node of a trace tree.  Created by a :class:`Tracer`;
    call :meth:`end` (or use as a context manager) to stamp the end time and
    commit it to the tracer's ring buffer."""

    __slots__ = ("_tracer", "name", "trace_id", "span_id", "parent_id",
                 "t0", "t1", "attrs")

    def __init__(self, tracer: "Tracer", name: str, trace_id: int,
                 span_id: int, parent_id: int, t0_ns: int, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0_ns
        self.t1: Optional[int] = None
        self.attrs = attrs

    # ------------------------------------------------------------ lifecycle
    def child(self, name: str, **attrs) -> "Span":
        return self._tracer.child(self, name, **attrs)

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self, **attrs) -> None:
        """Stamp the end time and commit; idempotent (first end wins)."""
        if self.t1 is None:
            self.t1 = time.perf_counter_ns()
            if attrs:
                self.attrs.update(attrs)
            self._tracer._push(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False

    # -------------------------------------------------------------- reading
    @property
    def duration_ms(self) -> float:
        return ((self.t1 if self.t1 is not None else time.perf_counter_ns())
                - self.t0) / 1e6

    def to_dict(self) -> dict:
        return {
            "trace": self.trace_id,
            "span": self.span_id,
            "parent": self.parent_id,
            "name": self.name,
            "t0_us": self.t0 / 1e3,
            "dur_us": (((self.t1 or self.t0) - self.t0) / 1e3),
            "attrs": self.attrs,
        }

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"span={self.span_id}, parent={self.parent_id})")


class _NullSpan:
    """The falsy do-nothing span: what a disabled/unsampled trace hands out.
    Every operation is a no-op returning null, so a whole request's span
    tree costs a few method calls and zero allocations."""

    __slots__ = ()
    name = "null"
    trace_id = span_id = parent_id = 0
    t0 = t1 = 0
    attrs: dict = {}
    duration_ms = 0.0

    def __bool__(self) -> bool:
        return False

    def child(self, name: str, **attrs) -> "_NullSpan":
        return self

    def annotate(self, **attrs) -> None:
        pass

    def end(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring buffer of completed spans.

    ``capacity`` bounds memory (oldest spans are dropped — ``dropped``
    counts them); ``sample`` in [0, 1] picks which *requests* are traced
    (children inherit the decision through null-span propagation);
    ``enabled=False`` turns the whole tracer into null-span handouts.
    """

    def __init__(self, *, capacity: int = 16384, sample: float = 1.0,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = bool(enabled)
        self.sample = float(sample)
        self.capacity = int(capacity)
        self._buf: list = []
        self._lock = threading.Lock()
        self._next_id = 1
        self._acc = 0.0  # deterministic sampling accumulator
        self.started = 0  # root spans handed out (sampled)
        self.dropped = 0  # completed spans evicted by the ring bound

    # --------------------------------------------------------- span creation
    def _ids(self, n: int = 1) -> int:
        with self._lock:
            first = self._next_id
            self._next_id += n
            return first

    def request_span(self, name: str, **attrs):
        """Start a root span for one request; returns :data:`NULL_SPAN` when
        disabled or when the sampler skips this request."""
        if not self.enabled:
            return NULL_SPAN
        with self._lock:
            self._acc += self.sample
            if self._acc < 1.0:
                return NULL_SPAN
            self._acc -= 1.0
            tid = self._next_id
            self._next_id += 2
            self.started += 1
        return Span(self, name, tid, tid + 1, 0, time.perf_counter_ns(), attrs)

    def child(self, parent, name: str, **attrs):
        """Start a span under ``parent`` (null/None parent -> null child)."""
        if not parent:
            return NULL_SPAN
        sid = self._ids()
        return Span(self, name, parent.trace_id, sid, parent.span_id,
                    time.perf_counter_ns(), attrs)

    def record(self, name: str, t0_ns: int, t1_ns: int, *, parent, **attrs):
        """Commit an already-measured span under ``parent`` — for stages
        timed with raw ``perf_counter_ns`` deep in the execution path."""
        if not parent:
            return
        sid = self._ids()
        s = Span(self, name, parent.trace_id, sid, parent.span_id,
                 int(t0_ns), attrs)
        s.t1 = int(t1_ns)
        self._push(s)

    # ------------------------------------------------------------ the buffer
    def _push(self, span: Span) -> None:
        with self._lock:
            self._buf.append(span)
            if len(self._buf) > self.capacity:
                # drop the oldest half in one slice: amortized O(1) per push
                excess = len(self._buf) - self.capacity // 2
                del self._buf[:excess]
                self.dropped += excess

    def spans(self) -> list:
        """A snapshot of the completed spans currently buffered."""
        with self._lock:
            return list(self._buf)

    def drain(self) -> list:
        """Remove and return every buffered span (for incremental export)."""
        with self._lock:
            out, self._buf = self._buf, []
        return out

    def __len__(self) -> int:
        return len(self._buf)


# the shared disabled tracer: what serving components fall back to when no
# tracer is attached, so the span API is always callable
NULL_TRACER = Tracer(capacity=1, enabled=False)

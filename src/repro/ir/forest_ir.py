"""The canonical, layout-free forest representation.

``ForestIR`` is the single point where quantization happens — the paper's
codegen-time conversions (Sec. III-A/III-B): FlInt int32 keys of every float32
threshold and uint32 fixed-point leaf probabilities at scale
``floor((2**32-1)/n_trees)``.  Everything downstream (node-table packing, the
Pallas kernel's padded tables, both native-C emitters) is a *materialization*
of this IR into a concrete memory layout and must not re-quantize; that is
what makes cross-layout bit-identity structural rather than coincidental.

Storage is CSR-style: per-node arrays for all trees concatenated in tree
order, with ``node_offsets`` (T+1,) delimiting each tree's slice.  Child
indices (``left``/``right``) are *tree-local*; layouts that want global
indices (``ragged``) rebase them at materialization time.  No padding exists
at this level — per-tree node counts are first-class, so depth-skewed forests
cost ``sum(n_nodes)`` nodes, not ``T * max(n_nodes)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.fixedpoint import prob_to_fixed_np, scale_for
from repro.core.flint import float_to_key_np


def tree_depth_from_arrays(feature, left, right) -> int:
    """Longest root-to-leaf path of one tree given its flat arrays."""
    depth = 0
    frontier = [(0, 0)]
    while frontier:
        node, d = frontier.pop()
        if feature[node] < 0:
            depth = max(depth, d)
            continue
        frontier.append((int(left[node]), d + 1))
        frontier.append((int(right[node]), d + 1))
    return depth


@dataclass
class ForestIR:
    """Canonical quantized forest: unpadded CSR node arrays + quantized data.

    Arrays are all ``(total_nodes, ...)`` with trees concatenated in ensemble
    order; ``node_offsets[t] : node_offsets[t+1]`` is tree ``t``'s slice.
    ``left``/``right`` are tree-local node indices; leaves (``feature == -1``)
    self-loop (``left == right == self``).
    """

    feature: np.ndarray  # (total,) int32, -1 for leaf
    threshold: np.ndarray  # (total,) float32
    threshold_key: np.ndarray  # (total,) int32 (FlInt keys)
    left: np.ndarray  # (total,) int32, tree-local
    right: np.ndarray  # (total,) int32, tree-local
    leaf_probs: np.ndarray  # (total, C) float64 (zeros on internal nodes)
    leaf_fixed: np.ndarray  # (total, C) uint32
    node_offsets: np.ndarray  # (T+1,) int64
    tree_depths: np.ndarray  # (T,) int32
    n_trees: int
    n_classes: int
    n_features: int
    # set on sub-forest IRs (see :meth:`subset`): the fixed-point scale the
    # leaves were quantized at — the *parent ensemble's* scale, not
    # scale_for(n_trees) of the subset.  None means "this IR is a whole
    # ensemble" and the scale is derived from n_trees.
    quant_scale: Optional[int] = None
    _layouts: dict = field(default_factory=dict, repr=False, compare=False)

    # ------------------------------------------------------------ properties
    @property
    def node_counts(self) -> np.ndarray:
        """Per-tree node counts (T,) — the quantity padding erases."""
        return np.diff(self.node_offsets).astype(np.int64)

    @property
    def total_nodes(self) -> int:
        return int(self.node_offsets[-1])

    @property
    def max_nodes(self) -> int:
        return int(self.node_counts.max())

    @property
    def max_depth(self) -> int:
        """Walk length that guarantees leaf arrival in every tree."""
        return int(self.tree_depths.max())

    @property
    def scale(self) -> int:
        """The fixed-point scale ``leaf_fixed`` is quantized at.  For a
        sub-forest carved by :meth:`subset` this is the parent ensemble's
        scale — leaves are sliced, never requantized."""
        return self.quant_scale if self.quant_scale is not None \
            else scale_for(self.n_trees)

    # --------------------------------------------------------- constructors
    @classmethod
    def from_forest(cls, forest) -> "ForestIR":
        """Quantize a trained forest (``trees_``/``n_classes_``/
        ``n_features_`` duck type) into the canonical IR."""
        trees = forest.trees_
        T = len(trees)
        C = forest.n_classes_
        offsets = np.zeros(T + 1, np.int64)
        np.cumsum([t.n_nodes for t in trees], out=offsets[1:])
        total = int(offsets[-1])
        probs = np.zeros((total, C), np.float64)
        for t, off in zip(trees, offsets[:-1]):
            is_leaf = t.feature < 0
            probs[off:off + t.n_nodes][is_leaf] = t.leaf_probs[is_leaf]
        threshold = np.concatenate([t.threshold for t in trees]).astype(np.float32)
        return cls(
            feature=np.concatenate([t.feature for t in trees]).astype(np.int32),
            threshold=threshold,
            threshold_key=float_to_key_np(threshold),
            left=np.concatenate([t.left for t in trees]).astype(np.int32),
            right=np.concatenate([t.right for t in trees]).astype(np.int32),
            leaf_probs=probs,
            leaf_fixed=prob_to_fixed_np(probs, T),
            node_offsets=offsets,
            tree_depths=np.asarray([t.depth for t in trees], np.int32),
            n_trees=T,
            n_classes=C,
            n_features=forest.n_features_,
        )

    @classmethod
    def from_packed(cls, packed) -> "ForestIR":
        """Recover the IR from a padded ``PackedEnsemble``.

        Padding nodes are, by construction, *trailing* self-looping leaves
        with zero probability mass in both representations; real leaves carry
        a class distribution summing to ~1, so their fixed row sum is > 0.
        That makes the per-tree real node count recoverable exactly.  The
        quantized data (``threshold_key``/``leaf_fixed``) is sliced, never
        recomputed, so round-tripping preserves bit-exactness.
        """
        T, N = packed.feature.shape
        counts = np.empty(T, np.int64)
        selfloop = np.arange(N, dtype=np.int32)
        for t in range(T):
            pad = (
                (packed.feature[t] < 0)
                & (packed.left[t] == selfloop)
                & (packed.right[t] == selfloop)
                & (packed.leaf_fixed[t].sum(axis=1) == 0)
                & (packed.leaf_probs[t].sum(axis=1) == 0)
            )
            n = N
            while n > 1 and pad[n - 1]:
                n -= 1
            counts[t] = n
        offsets = np.zeros(T + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        take = np.concatenate(
            [t * N + np.arange(counts[t]) for t in range(T)]
        ).astype(np.int64)
        flat = lambda a: a.reshape(T * N, *a.shape[2:])[take]
        feature, left, right = (flat(packed.feature), flat(packed.left),
                                flat(packed.right))
        depths = np.asarray(
            [
                tree_depth_from_arrays(
                    feature[offsets[t]:offsets[t + 1]],
                    left[offsets[t]:offsets[t + 1]],
                    right[offsets[t]:offsets[t + 1]],
                )
                for t in range(T)
            ],
            np.int32,
        )
        return cls(
            feature=feature,
            threshold=flat(packed.threshold),
            threshold_key=flat(packed.threshold_key),
            left=left,
            right=right,
            leaf_probs=flat(packed.leaf_probs).astype(np.float64),
            leaf_fixed=flat(packed.leaf_fixed),
            node_offsets=offsets,
            tree_depths=depths,
            n_trees=packed.n_trees,
            n_classes=packed.n_classes,
            n_features=packed.n_features,
            quant_scale=getattr(packed, "quant_scale", None),
        )

    # ------------------------------------------------------------- sharding
    def subset(self, start: int, stop: int = None) -> "ForestIR":
        """Carve the tree-contiguous sub-forest ``[start, stop)`` — no
        requantization, ever.

        Node arrays are pure slices of the parent's (CSR storage makes a tree
        range one contiguous node range), so the subset's FlInt keys and
        fixed-point leaves are bit-identical to the parent's by construction.
        The parent's quantization scale is carried along (``quant_scale``):
        a sub-forest's leaves stay at ``scale_for(parent.n_trees)``, which is
        exactly what makes per-shard uint32 partial sums mergeable into the
        full forest's accumulator with zero precision loss (the execution-plan
        layer's core invariant — see ``repro.plan``).

        Accepts ``subset(slice)`` or ``subset(start, stop)``.
        """
        if isinstance(start, slice):
            if start.step not in (None, 1):
                raise ValueError("tree subsets must be contiguous (step 1)")
            start, stop = start.indices(self.n_trees)[:2]
        if stop is None:
            raise ValueError("subset needs (start, stop) or a slice")
        start, stop = int(start), int(stop)
        if not (0 <= start < stop <= self.n_trees):
            raise ValueError(
                f"tree range [{start}, {stop}) out of bounds for "
                f"{self.n_trees} trees"
            )
        lo, hi = int(self.node_offsets[start]), int(self.node_offsets[stop])
        sl = slice(lo, hi)
        return ForestIR(
            feature=self.feature[sl],
            threshold=self.threshold[sl],
            threshold_key=self.threshold_key[sl],
            left=self.left[sl],
            right=self.right[sl],
            leaf_probs=self.leaf_probs[sl],
            leaf_fixed=self.leaf_fixed[sl],
            node_offsets=self.node_offsets[start:stop + 1] - lo,
            tree_depths=self.tree_depths[start:stop],
            n_trees=stop - start,
            n_classes=self.n_classes,
            n_features=self.n_features,
            quant_scale=self.scale,
        )

    # ------------------------------------------------------------- artifacts
    def to_itrf(self, path, **kwargs) -> dict:
        """Serialize as an ITRF binary artifact (see :mod:`repro.ir.artifact`
        for the format and the writer options)."""
        from repro.ir.artifact import write_itrf

        return write_itrf(path, self, **kwargs)

    @classmethod
    def from_itrf(cls, path, *, mmap: bool = True) -> "ForestIR":
        """Load an ITRF artifact.  ``mmap=True`` returns zero-copy read-only
        views over the file mapping; ``mmap=False`` returns private writable
        copies.  Either way the arrays are the file's bits verbatim — no
        re-quantization — so scores are bit-identical to the written IR."""
        from repro.ir.artifact import read_itrf

        return read_itrf(path, mmap_arrays=mmap)

    def nbytes_integer(self) -> int:
        """Bytes of the canonical integer-only CSR arrays (what an ITRF
        written with ``include_float=False, pack_leaves=False`` stores,
        minus header/alignment)."""
        return (self.feature.nbytes + self.threshold_key.nbytes
                + self.left.nbytes + self.right.nbytes
                + self.leaf_fixed.nbytes + self.node_offsets.nbytes
                + self.tree_depths.nbytes)

    def nbytes_float(self) -> int:
        return (self.feature.nbytes + self.threshold.nbytes
                + self.left.nbytes + self.right.nbytes
                + self.leaf_probs.nbytes + self.node_offsets.nbytes
                + self.tree_depths.nbytes)

    # ------------------------------------------------------- materialization
    def materialize(self, layout: str = "padded"):
        """The concrete artifact for one registered layout, memoized per IR."""
        if layout not in self._layouts:
            from repro.ir.layouts import materialize

            self._layouts[layout] = materialize(self, layout)
        return self._layouts[layout]

    def materialized_layouts(self) -> tuple:
        """Names of layouts already built for this IR (no side effects)."""
        return tuple(sorted(self._layouts))

    def nbytes_by_layout(self, mode: str = "integer") -> dict:
        """Deployment-artifact bytes of every registered layout.

        The padded node tables cost ``O(T * max(n_nodes))`` regardless of how
        depth-skewed the forest is; ``ragged`` costs ``O(sum(n_nodes))`` — this
        is the size axis the bench report breaks out per layout.
        """
        from repro.ir.layouts import available_layouts

        fn = "nbytes_integer" if mode == "integer" else "nbytes_float"
        return {
            name: getattr(self.materialize(name), fn)()
            for name in available_layouts()
        }


def resolve_artifact(model, layout: str):
    """Coerce ``model`` (ForestIR or a layout artifact) into ``layout``.

    An artifact already in the requested layout passes through untouched (so
    existing ``pack_forest``-then-``TreeEngine`` code never pays a rebuild);
    anything else resolves through the canonical IR — the artifact's back
    reference when it has one, else :meth:`ForestIR.from_packed`.
    """
    if isinstance(model, ForestIR):
        return model.materialize(layout)
    current = getattr(model, "layout", "padded")
    if current == layout:
        return model
    ir = getattr(model, "ir", None)
    if ir is None:
        if not hasattr(model, "to_ir"):
            raise ValueError(
                f"cannot rematerialize a {type(model).__name__!r} artifact "
                f"(layout {current!r}) as {layout!r}: no IR back-reference"
            )
        ir = model.to_ir()
    return ir.materialize(layout)

"""Warm-time measured autotuning of backend construction knobs.

The C emitters and the Pallas wrapper each expose one or two performance
knobs whose best value is a property of the *host*, not the model: the
table-walk C backend's ``block_rows`` (rows in flight per tree), the
bitvector backend's v-QuickScorer ``interleave`` width (trees per comparison
group), and the Pallas kernel's ``(block_b, block_t)`` VMEM tiling.  The
static defaults are sensible medians, but BENCH_7 showed the medians can be
1.3-1.8x off on a given machine.  This module is the measured answer: during
``TreeEngine.warm()`` each candidate is built on the engine's *already
materialized* layout artifact and timed (min-of-rounds ``predict_partials``
on deterministic pseudo-random rows), and the winner's kwargs are pinned.

Every candidate produces bit-identical uint32 partials (the knobs only
re-tile or re-group work — the conformance suite crosses them), so tuning
can never change an answer, only its latency.  Winner selection is
deterministic: strict-min time with the static default first, so ties — and
an injected constant timer — resolve to the default.

The winner is cached per (backend, layout, mode) route in the owning
``ModelVersion`` and copied across hot-swaps by the registry, so a swapped-in
version of the same model reuses the measurement instead of re-timing; the
measuring cost itself is surfaced through ``drain_compile_timings`` under the
``"tune"`` key and the chosen config through the metrics ``tuned`` column.

``REPRO_AUTOTUNE=0`` is the global kill switch; tuning is otherwise opt-in
per engine/gateway (``TreeEngine(autotune=True)``, ``Gateway(...,
autotune=True)``, ``--gw-autotune``).
"""
from __future__ import annotations

import os
import time

import numpy as np

# rows the candidates are timed on — one serving-sized bucket, enough to
# amortize per-call overheads without making warm() noticeably slower
_TUNE_ROWS = 256
_ROUNDS = 3
_WARMUP = 1

# backends with a measurable construction knob; anything else is a no-op
TUNABLE_BACKENDS = ("native_c_table", "native_c_bitvector", "pallas")


def autotune_enabled(flag) -> bool:
    """``flag`` gated by the ``REPRO_AUTOTUNE=0`` environment kill switch."""
    return bool(flag) and os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def config_str(kwargs: dict) -> str:
    """Compact human form of a winner, e.g. ``interleave=4`` — the metrics
    ``tuned`` column and the gateway table cell."""
    return ",".join(f"{k}={v}" for k, v in sorted(kwargs.items())) or "-"


def candidate_grid(backend_name: str, artifact, rows: int = _TUNE_ROWS) -> list:
    """The candidate ``backend_kwargs`` grid for one backend, static
    default/heuristic FIRST (ties resolve to it).  Empty when the backend has
    no tunable knob."""
    if backend_name == "native_c_table":
        return [{"block_rows": r} for r in (8, 1, 4, 16)]
    if backend_name == "native_c_bitvector":
        return [{"interleave": k} for k in (8, 1, 4)]
    if backend_name == "pallas":
        from repro.kernels.ops import pick_blocks_candidates

        t, n = artifact.feature.shape
        c = artifact.leaf_fixed.shape[-1]
        return [
            {"block_b": bb, "block_t": bt}
            for bb, bt in pick_blocks_candidates(
                rows, t, n, artifact.n_features, c
            )
        ]
    return []


def measure_backend(backend, X, *, rounds: int = _ROUNDS,
                    warmup: int = _WARMUP) -> float:
    """Min-of-rounds ``predict_partials`` wall seconds (warmup first, so a C
    build or jit compile never pollutes the measurement)."""
    for _ in range(warmup):
        backend.predict_partials(X)
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        backend.predict_partials(X)
        best = min(best, time.perf_counter() - t0)
    return best


def tune_backend(backend_name: str, artifact, mode: str, *,
                 rows: int = _TUNE_ROWS, baseline=None, measure=None):
    """Measure the candidate grid on ``artifact`` and return
    ``(winner_kwargs, winner_backend, report)``.

    ``baseline`` (optional) is an already-built backend for the grid's first
    (default) entry — reused instead of rebuilding it.  ``measure`` is
    injectable for deterministic tests.  Returns ``(None, None, [])`` when
    the backend has no grid to sweep.  The report is
    ``[(kwargs, seconds), ...]`` in grid order.
    """
    from repro.backends import create_backend

    # resolve the default at call time so tests can monkeypatch the module
    measure = measure if measure is not None else measure_backend
    grid = candidate_grid(backend_name, artifact, rows)
    if len(grid) < 2:
        return None, None, []
    rng = np.random.default_rng(0)
    X = rng.normal(0.0, 4.0, (rows, artifact.n_features)).astype(np.float32)
    report = []
    best_i, best_t, best_b = 0, float("inf"), None
    for i, kw in enumerate(grid):
        b = (baseline if i == 0 and baseline is not None
             else create_backend(backend_name, artifact, mode=mode, **kw))
        t = float(measure(b, X))
        report.append((dict(kw), t))
        if t < best_t:
            best_i, best_t, best_b = i, t, b
    return dict(grid[best_i]), best_b, report

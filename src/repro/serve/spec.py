"""`EngineSpec` — the one serializable description of an engine route.

Every layer that used to take loose route kwargs (``mode=``, ``backend=``,
``layout=``, ``plan=``, ``shards=``, ``backend_kwargs=``, ``autotune=``)
now accepts a single spec — as an :class:`EngineSpec`, a dict, or the
compact string grammar — and the loose kwargs survive only as a
deprecation shim that warns once per call site.  The spec is also what the
remote-worker wire protocol ships in its handshake, which is why it must
round-trip through plain JSON (`to_dict`/`from_dict`).

String grammar (every part optional)::

    [mode:]backend[|backend2...][@layout][+plan[:shards]][?key=val,...]

    integer:bitvector@leaf_major+tree_parallel:4
    flint:reference+remote_tree_parallel:2
    native_c_table?block_rows=8
    integer                      (bare mode; backend defaults to reference)
    pallas|native_c+tree_parallel:2   (heterogeneous shard backends)

``+auto:N`` pins a shard count while leaving plan selection to
``select_plan`` (it renders back the same way).  The reserved query key
``autotune=1`` arms the warm-time autotuner; every other query key lands
in ``backend_kwargs`` with int/float/bool literals parsed.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Tuple, Union

__all__ = ["EngineSpec", "MODES"]

#: Deterministic + float execution modes (kept in sync with
#: repro.core.ensemble.MODES; duplicated so parsing a spec never has to
#: import jax).
MODES = ("float", "flint", "integer")

_LOOSE_KEYS = ("mode", "backend", "layout", "plan", "shards",
               "backend_kwargs", "autotune")
_warned_callers: set = set()


def _parse_literal(text: str):
    """Query-string value -> int / float / bool / str."""
    low = text.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(text)
    except ValueError:
        pass
    try:
        return float(text)
    except ValueError:
        return text


def _fmt_literal(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


@dataclass(frozen=True)
class EngineSpec:
    """A complete, serializable engine route.

    ``backend`` is a registered backend name, a tuple of names (one per
    shard, cycled — a heterogeneous pool), or at runtime a live backend
    *instance* (which then cannot be serialized).  ``plan=None`` /
    ``layout=None`` mean "let ``select_plan`` / backend capabilities
    decide".
    """

    mode: str = "integer"
    backend: Union[str, Tuple[str, ...], Any] = "reference"
    layout: Optional[str] = None
    plan: Optional[str] = None
    shards: Optional[int] = None
    backend_kwargs: Optional[dict] = None
    autotune: bool = False

    def __post_init__(self):
        if isinstance(self.backend, list):
            object.__setattr__(self, "backend", tuple(self.backend))

    # -- construction ------------------------------------------------------

    @classmethod
    def parse(cls, text: str, *, validate: bool = True) -> "EngineSpec":
        """Parse the ``[mode:]backend[@layout][+plan[:shards]][?k=v]``
        grammar (see module docstring)."""
        s = str(text).strip()
        if not s:
            raise ValueError("empty engine spec")
        query = None
        if "?" in s:
            s, query = s.split("?", 1)
        plan_part = None
        if "+" in s:
            s, plan_part = s.split("+", 1)
        layout = None
        if "@" in s:
            s, layout = s.split("@", 1)
            if "@" in layout:
                raise ValueError(f"more than one @layout in spec {text!r}")
            layout = layout.strip() or None
        mode = "integer"
        s = s.strip()
        if ":" in s:
            mode, s = (p.strip() for p in s.split(":", 1))
        elif s in MODES:  # bare mode, default backend
            mode, s = s, ""
        backend: Union[str, Tuple[str, ...]] = s or "reference"
        if isinstance(backend, str) and "|" in backend:
            backend = tuple(b.strip() for b in backend.split("|") if b.strip())
        plan = shards = None
        if plan_part:
            plan = plan_part.strip()
            if ":" in plan:
                plan, shards_txt = plan.split(":", 1)
                try:
                    shards = int(shards_txt)
                except ValueError:
                    raise ValueError(
                        f"bad shard count {shards_txt!r} in spec {text!r}")
            if plan in ("", "auto"):
                plan = None  # shards pinned, plan auto-selected
        backend_kwargs: dict = {}
        autotune = False
        if query:
            for item in query.split(","):
                if not item:
                    continue
                k, sep, v = item.partition("=")
                if not sep:
                    raise ValueError(f"bad query item {item!r} in spec {text!r}")
                if k == "autotune":
                    autotune = bool(_parse_literal(v))
                else:
                    backend_kwargs[k] = _parse_literal(v)
        spec = cls(mode=mode, backend=backend, layout=layout, plan=plan,
                   shards=shards, backend_kwargs=backend_kwargs or None,
                   autotune=autotune)
        if validate:
            spec.validate()
        return spec

    @classmethod
    def from_dict(cls, d: Mapping) -> "EngineSpec":
        """Inverse of :meth:`to_dict` (extra keys rejected)."""
        extra = set(d) - set(_LOOSE_KEYS)
        if extra:
            raise ValueError(f"unknown EngineSpec keys {sorted(extra)}")
        kw = {k: d[k] for k in _LOOSE_KEYS if d.get(k) is not None}
        if isinstance(kw.get("backend"), list):
            kw["backend"] = tuple(kw["backend"])
        if "autotune" in kw:
            kw["autotune"] = bool(kw["autotune"])
        return cls(**kw)

    @classmethod
    def coerce(cls, spec=None, *, caller: str = "engine", **loose) -> "EngineSpec":
        """Accept an :class:`EngineSpec` | spec string | dict | ``None`` +
        loose kwargs, and return a spec.

        The loose-kwargs route (``backend=...`` etc. without a spec) is the
        pre-spec API; it still works but emits one ``DeprecationWarning``
        per call site.  Mixing a spec with loose kwargs is an error — there
        would be no unambiguous precedence.
        """
        loose = {k: v for k, v in loose.items()
                 if v is not None and not (k == "autotune" and v is False)}
        if spec is None:
            if loose and caller not in _warned_callers:
                _warned_callers.add(caller)
                warnings.warn(
                    f"{caller}: loose route kwargs "
                    f"({', '.join(sorted(loose))}) are deprecated; pass "
                    "spec=EngineSpec(...) or a spec string like "
                    "'integer:bitvector@leaf_major+tree_parallel:4'",
                    DeprecationWarning, stacklevel=3)
            return cls(**loose)
        if loose:
            raise ValueError(
                f"{caller}: pass the route either as a spec or as loose "
                f"kwargs, not both (got spec and {sorted(loose)})")
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, str):
            return cls.parse(spec)
        if isinstance(spec, Mapping):
            return cls.from_dict(spec)
        raise TypeError(f"{caller}: cannot interpret {type(spec).__name__} "
                        "as an EngineSpec")

    # -- validation --------------------------------------------------------

    def validate(self) -> "EngineSpec":
        """Check mode/backend/layout/plan names against the live registries
        (imports them lazily — parsing alone never pulls in jax)."""
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; have {MODES}")
        if self.shards is not None and self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        names = ([self.backend] if isinstance(self.backend, str)
                 else list(self.backend) if isinstance(self.backend, tuple)
                 else [])  # live instances validate themselves at build
        if names:
            from repro.backends import available_backends
            have = set(available_backends())
            for n in names:
                if n not in have:
                    raise ValueError(
                        f"unknown backend {n!r}; have {sorted(have)}")
        if self.layout is not None:
            from repro.ir import available_layouts
            if self.layout not in available_layouts():
                raise ValueError(f"unknown layout {self.layout!r}; have "
                                 f"{sorted(available_layouts())}")
        if self.plan is not None:
            from repro.plan import available_plans
            if self.plan not in available_plans():
                raise ValueError(f"unknown plan {self.plan!r}; have "
                                 f"{sorted(available_plans())}")
        return self

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-safe dict (the handshake payload form).  Raises if the
        backend is a live instance rather than registered names."""
        b = self.backend
        if not isinstance(b, str):
            if not (isinstance(b, tuple) and all(isinstance(n, str) for n in b)):
                raise TypeError("EngineSpec with a live backend instance "
                                "cannot be serialized; use registered names")
            b = list(b)
        return {
            "mode": self.mode,
            "backend": b,
            "layout": self.layout,
            "plan": self.plan,
            "shards": self.shards,
            "backend_kwargs": dict(self.backend_kwargs) if self.backend_kwargs else None,
            "autotune": bool(self.autotune),
        }

    def canonical(self) -> str:
        """Render back to the compact grammar (parse/canonical round-trip
        is stable)."""
        b = self.backend
        btxt = b if isinstance(b, str) else (
            "|".join(b) if isinstance(b, tuple) else
            getattr(b, "name", type(b).__name__))
        out = f"{self.mode}:{btxt}"
        if self.layout:
            out += f"@{self.layout}"
        if self.plan:
            out += f"+{self.plan}"
            if self.shards:
                out += f":{self.shards}"
        elif self.shards:
            out += f"+auto:{self.shards}"
        q = dict(sorted((self.backend_kwargs or {}).items()))
        if self.autotune:
            q["autotune"] = True
        if q:
            out += "?" + ",".join(f"{k}={_fmt_literal(v)}" for k, v in q.items())
        return out

    def __str__(self) -> str:
        return self.canonical()

    def replace(self, **changes) -> "EngineSpec":
        return dataclasses.replace(self, **changes)

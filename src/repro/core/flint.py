"""FlInt: order-preserving float32 <-> int32 key transform.

The paper (Sec. II-D / III) inherits FlInt [Hakert et al., DATE'24]: replace
every floating-point threshold comparison ``x <= t`` in a decision tree with an
integer comparison of the IEEE-754 *bit patterns*.  For non-negative floats the
raw bit pattern is already monotone; to obtain a total order over the full
float range (negative thresholds occur in real datasets) we apply the standard
sign-fix:

    b   = bitcast_int32(f)
    key = b               if b >= 0          (positive floats, +0)
          INT32_MIN - b   otherwise          (negative floats, -0)

Properties (hypothesis-tested in tests/test_flint.py):
  * strictly monotone:  f1 < f2  <=>  key(f1) < key(f2)   (finite floats)
  * key(-0.0) == key(+0.0) == 0                            (consistent with ==)
  * for f >= 0, key(f) == bitcast_int32(f)  (exactly the FlInt paper's form,
    so C codegen emits the same immediates the paper shows in Listing 2)
  * exactly invertible.

All ops are int32 adds/compares: on TPU they run on the VPU with no float
pipeline involvement; in the generated C they are plain integer instructions,
which is the paper's architecture-agnostic goal.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_INT32_MIN = np.int32(-2147483648)


def float_to_key(f):
    """Map float32 array -> order-preserving int32 keys (JAX)."""
    f = jnp.asarray(f, jnp.float32)
    b = jax_bitcast_i32(f)
    return jnp.where(b < 0, _INT32_MIN - b, b)


def key_to_float(k):
    """Inverse of :func:`float_to_key` (JAX). key(-0.0) inverts to +0.0."""
    k = jnp.asarray(k, jnp.int32)
    b = jnp.where(k < 0, _INT32_MIN - k, k)
    return jax_bitcast_f32(b)


def jax_bitcast_i32(f):
    import jax.lax as lax

    return lax.bitcast_convert_type(jnp.asarray(f, jnp.float32), jnp.int32)


def jax_bitcast_f32(i):
    import jax.lax as lax

    return lax.bitcast_convert_type(jnp.asarray(i, jnp.int32), jnp.float32)


# ---------------------------------------------------------------------------
# numpy variants (used at codegen/packing time, outside of jit)
# ---------------------------------------------------------------------------

def float_to_key_np(f: np.ndarray) -> np.ndarray:
    b = np.asarray(f, np.float32).view(np.int32)
    # int32 wraparound is intended; compute in int64 then cast to be explicit.
    neg = (np.int64(_INT32_MIN) - b.astype(np.int64)).astype(np.int32)
    return np.where(b < 0, neg, b)


def key_to_float_np(k: np.ndarray) -> np.ndarray:
    k = np.asarray(k, np.int32)
    b = np.where(k < 0, (np.int64(_INT32_MIN) - k.astype(np.int64)).astype(np.int32), k)
    return b.view(np.float32)

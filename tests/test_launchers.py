"""End-to-end launcher tests: the CLI drivers run, train losses descend,
serving agrees across implementations."""
import numpy as np
import pytest


@pytest.mark.slow
def test_train_driver_loss_descends(capsys):
    from repro.launch.train import main

    losses = main([
        "--arch", "granite-3-2b", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "5e-3", "--log-every", "10",
    ])
    assert len(losses) == 30
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


@pytest.mark.slow
def test_train_driver_checkpoints(tmp_path):
    from repro.launch.train import main

    main([
        "--arch", "mamba2-370m", "--smoke", "--steps", "12", "--batch", "4",
        "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
    ])
    from repro.checkpoint.manager import CheckpointManager

    assert CheckpointManager(tmp_path).latest_step() == 10


@pytest.mark.slow
def test_serve_driver_trees(capsys):
    import shutil

    from repro.launch.serve import main

    main(["--trees", "--rows", "4000", "--n-trees", "8", "--depth", "5", "--reps", "1"])
    out = capsys.readouterr().out
    assert "agree_with_float=1.000000" in out
    # float (self), flint, integer, integer-leafmajor, pallas — plus the two
    # native-C flavors (if-else + table-walk) when gcc exists
    expected = 7 if shutil.which("gcc") else 5
    assert out.count("agree_with_float=1.000000") == expected


@pytest.mark.slow
def test_serve_driver_gateway(capsys):
    from repro.launch.serve import main

    main(["--trees", "--gateway", "--rows", "3000", "--gw-requests", "60",
          "--gw-rate", "600", "--gw-batch-rows", "16"])
    out = capsys.readouterr().out
    assert "gateway == direct engine (bit-identical): True" in out
    assert "hot-swapped shuttle-rf -> v2" in out
    assert "hit_rate" in out and "queue_ms" in out  # metrics table rendered


@pytest.mark.slow
def test_serve_driver_lm(capsys):
    from repro.launch.serve import main

    main(["--arch", "granite-3-2b", "--smoke", "--batch", "2",
          "--prompt", "16", "--tokens", "4"])
    out = capsys.readouterr().out
    assert "generated (2, 4) tokens" in out

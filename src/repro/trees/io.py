"""Treelite-style JSON model exchange.

The paper's pipeline converts sklearn/XGBoost/LightGBM models into a common
Treelite representation before codegen (Sec. III-B).  This module provides
the equivalent boundary for this framework: export/import a trained forest as
a JSON document with the same information content (per-node feature,
threshold, children, leaf distribution), so externally-trained models can be
packed and served through the integer-only path.

Versioning: documents carry ``schema_version`` (see :data:`SCHEMA_VERSION`).
The reader is *forward-compatible within a version*: unknown keys — at the
document, tree, or any future nesting level — are ignored, so additive
metadata (e.g. per-layout hints from the ForestIR layer) can ship without
breaking older readers.  Documents from a *newer* schema version are refused
loudly rather than half-parsed; documents predating the field (the v1 era)
load as version 1.
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.trees.cart import TreeArrays
from repro.trees.forest import RandomForestClassifier

# v1: implicit (no version field): model_type, n_classes, n_features, trees
# v2: + schema_version field; unknown/additive keys are explicitly tolerated
SCHEMA_VERSION = 2


def forest_to_json(forest: RandomForestClassifier) -> str:
    doc = {
        "schema_version": SCHEMA_VERSION,
        "model_type": "random_forest_classifier",
        "n_classes": forest.n_classes_,
        "n_features": forest.n_features_,
        "trees": [
            {
                "feature": t.feature.tolist(),
                "threshold": [float(x) for x in t.threshold],
                "left": t.left.tolist(),
                "right": t.right.tolist(),
                "leaf_probs": t.leaf_probs.tolist(),
                "depth": t.depth,
            }
            for t in forest.trees_
        ],
    }
    return json.dumps(doc)


def forest_from_json(payload: str) -> RandomForestClassifier:
    doc = json.loads(payload)
    version = int(doc.get("schema_version", 1))
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"model JSON uses schema_version {version}, but this reader "
            f"understands <= {SCHEMA_VERSION}; refusing to half-parse a "
            "newer artifact"
        )
    assert doc["model_type"] == "random_forest_classifier"
    forest = RandomForestClassifier(n_estimators=len(doc["trees"]))
    forest.n_classes_ = int(doc["n_classes"])
    forest.n_features_ = int(doc["n_features"])
    forest.trees_ = [
        TreeArrays(
            feature=np.asarray(t["feature"], np.int32),
            threshold=np.asarray(t["threshold"], np.float32),
            left=np.asarray(t["left"], np.int32),
            right=np.asarray(t["right"], np.int32),
            leaf_probs=np.asarray(t["leaf_probs"], np.float64),
            depth=int(t["depth"]),
        )
        for t in doc["trees"]
    ]
    return forest

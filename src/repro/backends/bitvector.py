"""BitvectorBackend: QuickScorer-style traversal-free scoring, pure jnp.

The fifth backend, and the first consumer of the ``bitvector`` ForestIR
layout (``repro.ir.bitvector``): no per-row node walk at all — every
internal-node test in the forest is evaluated as one data-parallel compare
grid, false-node masks are OR/AND-folded into per-tree live-leaf bitvectors,
and each tree's exit leaf is its lowest surviving bit (see the kernel
docstring for the uint32-word mechanics under JAX's x64-disabled config).

Deterministic modes only: the QuickScorer tables hold FlInt int32 keys and
uint32 fixed-point leaves, so partials are the exact associative accumulators
every other backend produces — bit-identical to ``reference`` by the
conformance suite, shardable by every execution plan, and finalized by the
one shared numpy step.  The emitted-C sibling (``native_c_bitvector``)
streams the same tables sequentially with the sorted-list early exit.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.backends.base import BackendCapabilities, TreeBackend, register_backend
from repro.kernels.bitvector import make_bitvector_partials_fn


@register_backend
class BitvectorBackend(TreeBackend):
    name = "bitvector"
    capabilities = BackendCapabilities(
        modes=("flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,
        compiles_per_shape=True,
        supported_layouts=("bitvector",),
        preferred_layout="bitvector",
    )

    def __init__(self, packed, mode: str = "integer"):
        super().__init__(packed, mode)
        # flint and integer share the one integer accumulation; the modes
        # differ only in the shared finalize step
        self._partials_fn = make_bitvector_partials_fn(packed)

    def predict_partials(self, X):
        return np.asarray(self._partials_fn(jnp.asarray(X, jnp.float32)))

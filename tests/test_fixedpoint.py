"""Fixed-point probability conversion: paper Sec. III-A properties."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fixedpoint import (
    fixed_to_prob_np,
    max_abs_error,
    prob_to_fixed_np,
    scale_for,
)


@given(st.integers(min_value=1, max_value=300))
def test_scale_overflow_free(n):
    """n values each < scale sum to < 2**32 — the paper's overflow argument."""
    assert n * scale_for(n) <= 2**32 - 1


@given(
    st.integers(min_value=1, max_value=256),
    st.lists(st.floats(min_value=0.0, max_value=1.0, width=32), min_size=1, max_size=256),
)
@settings(max_examples=200, deadline=None)
def test_accumulation_error_bound(n, probs):
    """|reconstructed mean - exact mean| <= bound for any <=n-tree ensemble."""
    probs = np.asarray(probs[:n], np.float64)
    n_eff = len(probs)
    fx = prob_to_fixed_np(probs, n_eff)
    acc = np.sum(fx, dtype=np.uint64)
    assert acc <= 2**32 - 1  # never overflows uint32 accumulation
    rec = fixed_to_prob_np(np.uint32(acc), n_eff)
    exact = probs.mean()
    assert abs(rec - exact) <= max_abs_error(n_eff)


def test_paper_example():
    """Paper Sec. III-A worked example: p=0.75/0.25, 10 trees, scale 2^32/10.

    The paper's exact constants (322122547 / 107374182) assume scale
    2**32/10; ours uses floor((2**32-1)/10) for the documented overflow
    guard, so values differ by at most 1 ulp of the scale."""
    fx = prob_to_fixed_np(np.array([0.75, 0.25]), 10)
    assert abs(int(fx[0]) - 322122547) <= 1
    assert abs(int(fx[1]) - 107374182) <= 1


def test_precision_vs_float32_cutoff():
    """Paper: fixed point beats float32 precision iff n <= 256."""
    for n in (1, 100, 256):
        assert n / 2**32 <= 1 / 2**24 or n > 256
    assert 257 / 2**32 > 1 / 2**24


@given(st.integers(min_value=1, max_value=128))
@settings(max_examples=50)
def test_figure2_magnitude(n):
    """Probability deltas stay in the paper's Fig. 2 magnitude regime."""
    rng = np.random.default_rng(n)
    probs = rng.dirichlet(np.ones(4), size=n)  # (n, 4) rows sum to 1
    fx = prob_to_fixed_np(probs, n)
    acc = fx.sum(axis=0, dtype=np.uint64)
    rec = fixed_to_prob_np(acc.astype(np.uint32), n)
    exact = probs.mean(axis=0)
    err = np.abs(rec - exact).max()
    assert err < 1e-7  # paper reports ~1e-10 (1 tree) to ~1e-8 (100 trees)

"""Parse compiled (SPMD-partitioned) HLO text for collective traffic.

``compiled.cost_analysis()`` has no collective-bytes entry AND counts while
bodies once (ignoring trip counts), so we analyze the module text ourselves:

  1. split the module into computations,
  2. sum collective-op result bytes per computation,
  3. propagate execution multipliers through the call graph — while ops carry
     ``backend_config={"known_trip_count":{"n":...}}`` so a collective inside
     the scanned-layers loop is counted once per layer,
  4. total = sum over computations of bytes x multiplier.

Shapes in the partitioned module are per-device; the roofline layer uses the
assignment's formula ``collective_bytes/(chips * link_bw)`` with global bytes
= per-device x chips, so the chip factors cancel.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def _split_computations(hlo_text: str):
    comps = {}
    entry = None
    name, buf = None, []
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m and not line.startswith(" "):
            if name is not None:
                comps[name] = buf
            name = m.group(2)
            buf = []
            if m.group(1):
                entry = name
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = buf
    return comps, entry


def _line_collective(line: str):
    """(op, bytes) if this instruction line is a collective, else None."""
    if "=" not in line:
        return None
    lhs, rhs = line.split("=", 1)
    rhs = rhs.strip()
    for c in COLLECTIVE_OPS:
        if f"{c}-done(" in rhs:
            return None  # async pair: count the -start only
        if re.search(r"\b" + re.escape(c) + r"(-start)?\(", rhs):
            head = rhs.split(c)[0]
            nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
            return c, nbytes
    return None


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-aware per-device collective bytes by op type."""
    comps, entry = _split_computations(hlo_text)
    per_comp = {}
    edges = defaultdict(list)  # caller -> [(callee, multiplier)]
    for name, lines in comps.items():
        agg = defaultdict(int)
        counts = defaultdict(int)
        for line in lines:
            got = _line_collective(line)
            if got:
                op, nb = got
                agg[op] += nb
                counts[op] += 1
            if " while(" in line:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(line)
                if bm:
                    edges[name].append((bm.group(1), trip))
                cm = _COND_RE.search(line)
                if cm:
                    edges[name].append((cm.group(1), trip))
            else:
                for m in _CALLS_RE.finditer(line):
                    edges[name].append((m.group(1), 1))
                bm = _BRANCHES_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        edges[name].append((b.strip().lstrip("%"), 1))
        per_comp[name] = (dict(agg), dict(counts))

    mult = defaultdict(float)
    start = entry or (next(iter(comps)) if comps else None)
    if start is not None:
        stack = [(start, 1.0)]
        while stack:
            node, k = stack.pop()
            mult[node] += k
            for callee, trip in edges.get(node, ()):
                if callee in comps:
                    stack.append((callee, k * trip))

    out = defaultdict(float)
    counts = defaultdict(float)
    for name, (agg, cnt) in per_comp.items():
        k = mult.get(name, 0.0)
        if k == 0.0:
            continue
        for op, nb in agg.items():
            out[op] += nb * k
        for op, c in cnt.items():
            counts[op] += c * k
    result = {op: int(v) for op, v in out.items()}
    result["total"] = int(sum(out.values()))
    result["counts"] = {op: int(v) for op, v in counts.items()}
    return result


def flops_and_bytes(compiled) -> dict:
    """XLA's own aggregate numbers (NOT trip-count-aware — reference only;
    the roofline uses repro.launch.jaxpr_cost for flops/bytes)."""
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: int(getattr(ma, k, 0)) for k in keys}

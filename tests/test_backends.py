"""Cross-(backend, layout) conformance: the IR/backend layers' anchor suite.

InTreeger's claim — one trained ensemble, bit-identical integer-only
inference on any hardware — becomes testable through the TreeBackend
protocol and the ForestIR layout layer: for the deterministic modes
(flint/integer), every registered backend must produce *bit-identical*
scores and predictions on randomized forests, through every ForestIR layout
it declares (padded / ragged / leaf_major), including degenerate forests
(single-node stumps, T == 1, strongly depth-skewed).  Plus: registry
lookup/error behavior, capability/layout validation, TreeEngine bucketing
edge cases, and the deep-tree C emitter guard.

Run standalone via ``make conformance``.
"""
import numpy as np
import pytest

from repro.backends import (
    BackendCapabilities,
    TreeBackend,
    available_backends,
    backend_class,
    create_backend,
)
from repro.ir import ForestIR
from repro.serve.engine import TreeEngine, bucket_rows

ALL_BACKENDS = [
    "reference",
    "pallas",
    "bitvector",
    pytest.param("native_c", marks=pytest.mark.requires_gcc),
    pytest.param("native_c_table", marks=pytest.mark.requires_gcc),
    pytest.param("native_c_bitvector", marks=pytest.mark.requires_gcc),
]


@pytest.fixture(scope="module", params=[(3, 7, 5), (11, 16, 7)],
                ids=["t7d5", "t16d7"])
def random_case(request):
    """(packed, rows): a randomized forest + probe rows, per param seed."""
    from repro.core.packing import pack_forest
    from repro.data.tabular import make_shuttle_like, train_test_split
    from repro.trees.forest import RandomForestClassifier

    seed, n_trees, depth = request.param
    X, y = make_shuttle_like(n=3000, seed=seed)
    Xtr, ytr, Xte, _ = train_test_split(X, y, seed=seed)
    rf = RandomForestClassifier(
        n_estimators=n_trees, max_depth=depth, seed=seed
    ).fit(Xtr, ytr)
    return pack_forest(rf), Xte[:97]  # odd row count: exercises padding


def _scores(backend, rows):
    s, p = backend.predict_scores(rows)
    return np.asarray(s), np.asarray(p)


# ------------------------------------------------------------------ registry

def test_registry_has_all_six_backends():
    assert {"reference", "pallas", "native_c", "native_c_table",
            "bitvector", "native_c_bitvector"} <= set(available_backends())


def test_registry_unknown_name_lists_available(small_packed):
    with pytest.raises(KeyError, match="reference"):
        backend_class("no-such-backend")
    with pytest.raises(KeyError, match="no-such-backend"):
        create_backend("no-such-backend", small_packed)


def test_backend_rejects_unsupported_mode(small_packed):
    # pallas runs the integer accumulation; since the partials/finalize
    # split that serves both deterministic modes, but never float
    assert backend_class("pallas").capabilities.modes == ("flint", "integer")
    with pytest.raises(ValueError, match="pallas"):
        create_backend("pallas", small_packed, mode="float")


def test_capability_flags():
    ref = backend_class("reference").capabilities
    nat = backend_class("native_c").capabilities
    pal = backend_class("pallas").capabilities
    tbl = backend_class("native_c_table").capabilities
    assert set(ref.modes) == {"float", "flint", "integer"}
    assert ref.deterministic_modes == ("flint", "integer")
    assert pal.deterministic_modes == ("flint", "integer")
    assert ref.compiles_per_shape and pal.compiles_per_shape
    assert not nat.compiles_per_shape  # the C loop takes any row count
    assert pal.preferred_block_rows == 256  # aligns buckets with kernel tiles
    # layout axis: node-table backends walk both (T, N) orderings; the
    # table-walk C backend is the ragged layout's consumer.  Pallas prefers
    # leaf_major (the linear-scan kernel's layout); the others stay padded.
    # reference additionally serves the packed_leaf artifact layout by
    # decoding the group-quantized leaf table through the exact codec.
    assert set(ref.supported_layouts) == {"padded", "leaf_major",
                                          "packed_leaf"}
    for caps in (pal, nat):
        assert set(caps.supported_layouts) == {"padded", "leaf_major"}
    assert ref.preferred_layout == "padded"
    assert nat.preferred_layout == "padded"
    assert pal.preferred_layout == "leaf_major"
    assert tbl.supported_layouts == ("ragged",)
    assert tbl.preferred_layout == "ragged"
    assert set(tbl.modes) == {"flint", "integer"}  # integer-compare modes only
    assert tbl.preferred_block_rows == 8  # row-blocked table walk default
    assert not tbl.compiles_per_shape
    # the QuickScorer pair both walk (only) the bitvector layout; the jnp
    # path jit-compiles per batch shape, the C path takes any row count
    bv = backend_class("bitvector").capabilities
    cbv = backend_class("native_c_bitvector").capabilities
    for caps in (bv, cbv):
        assert set(caps.modes) == {"flint", "integer"}
        assert caps.deterministic_modes == ("flint", "integer")
        assert caps.supported_layouts == ("bitvector",)
        assert caps.preferred_layout == "bitvector"
    assert bv.compiles_per_shape
    assert not cbv.compiles_per_shape


def test_backend_rejects_unsupported_layout(small_packed):
    ragged = small_packed.to_ir().materialize("ragged")
    with pytest.raises(ValueError, match="layout"):
        create_backend("pallas", ragged, mode="integer")
    with pytest.raises(ValueError, match="layout"):
        create_backend("native_c_table", small_packed, mode="integer")
    with pytest.raises(ValueError, match="layout"):
        TreeEngine(small_packed, mode="integer", backend="reference",
                   layout="ragged")
    # a pre-constructed backend instance cannot satisfy a conflicting pin —
    # silently serving its existing artifact would ignore the request
    from repro.backends import ReferenceBackend

    with pytest.raises(ValueError, match="conflicts"):
        TreeEngine(backend=ReferenceBackend(small_packed, "integer"),
                   layout="leaf_major")


# --------------------------------------------------- cross-backend identity

def test_reference_vs_pallas_integer_bit_identical(random_case):
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode="integer"), rows)
    s_pal, p_pal = _scores(create_backend("pallas", packed, mode="integer"), rows)
    np.testing.assert_array_equal(s_ref, s_pal)
    np.testing.assert_array_equal(p_ref, p_pal)


@pytest.mark.requires_gcc
@pytest.mark.parametrize("mode", ["flint", "integer"])
def test_reference_vs_native_c_bit_identical(random_case, mode):
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode=mode), rows)
    s_nat, p_nat = _scores(create_backend("native_c", packed, mode=mode), rows)
    assert s_nat.dtype == s_ref.dtype
    np.testing.assert_array_equal(s_ref, s_nat)
    np.testing.assert_array_equal(p_ref, p_nat)


@pytest.mark.requires_gcc
def test_all_backends_identical_through_engine(small_packed, shuttle_small):
    """The acceptance property, at the TreeEngine level: same model, three
    backends, bit-identical integer scores through the bucketed path."""
    _, _, Xte, _ = shuttle_small
    rows = Xte[:50]
    outs = {
        name: TreeEngine(small_packed, mode="integer", backend=name).predict_scores(rows)
        for name in ("reference", "pallas", "native_c")
    }
    s_ref, p_ref = outs["reference"]
    for name in ("pallas", "native_c"):
        np.testing.assert_array_equal(outs[name][0], s_ref)
        np.testing.assert_array_equal(outs[name][1], p_ref)


@pytest.mark.requires_gcc
def test_gateway_serves_same_model_through_every_backend(small_forest, shuttle_small):
    """Gateway/ModelRegistry route per-(model, mode, backend) and all
    deterministic-mode responses are bit-identical across backends."""
    import asyncio

    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    _, _, Xte, _ = shuttle_small
    rows = Xte[:16]
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)

    results = {}
    for name in ("reference", "pallas", "native_c"):
        gw = Gateway(reg, mode="integer", backend=name, max_delay_ms=1.0)
        s, p = asyncio.run(gw.submit("m", rows))
        asyncio.run(gw.close())
        results[name] = (s, p)
    s_ref, p_ref = results["reference"]
    for name in ("pallas", "native_c"):
        np.testing.assert_array_equal(results[name][0], s_ref)
        np.testing.assert_array_equal(results[name][1], p_ref)
    # one engine per (mode, backend) route, memoized on the version
    mv = reg.get("m")
    assert mv.engine("integer", backend="pallas") is mv.engine("integer", backend="pallas")
    assert mv.engine("integer", backend="pallas") is not mv.engine("integer")


# ----------------------------------------------- cross-layout conformance

from forest_cases import (  # shared with test_plans.py
    DEGENERATE_FORESTS as _DEGENERATE,
    forest_from_trees as _forest_from_trees,
)


@pytest.fixture(scope="module", params=sorted(_DEGENERATE), ids=sorted(_DEGENERATE))
def degenerate_case(request):
    """(ForestIR, probe rows) for one degenerate forest shape."""
    forest = _DEGENERATE[request.param]()
    ir = ForestIR.from_forest(forest)
    rng = np.random.default_rng(hash(request.param) % 2**32)
    rows = rng.normal(0.0, 6.0, (33, ir.n_features)).astype(np.float32)
    return ir, rows


def _layout_mode_pairs(backend):
    caps = backend_class(backend).capabilities
    return [(lay, mode) for lay in caps.supported_layouts
            for mode in caps.deterministic_modes]


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_cross_layout_bit_identity_randomized(random_case, backend):
    """The acceptance property: flint/integer scores bit-identical across
    every (layout, backend) pair the backend declares, randomized forests."""
    packed, rows = random_case
    ir = packed.to_ir()
    ref = {}  # one reference run per mode; layouts reuse it
    for layout, mode in _layout_mode_pairs(backend):
        if mode not in ref:
            ref[mode] = _scores(create_backend("reference", packed, mode=mode), rows)
        s_ref, p_ref = ref[mode]
        eng = TreeEngine(ir, mode=mode, backend=backend, layout=layout)
        s, p = eng.predict_scores(rows)
        assert eng.layout == layout
        np.testing.assert_array_equal(np.asarray(s), s_ref,
                                      err_msg=f"{backend}/{layout}/{mode}")
        np.testing.assert_array_equal(np.asarray(p), p_ref,
                                      err_msg=f"{backend}/{layout}/{mode}")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_cross_layout_bit_identity_degenerate(degenerate_case, backend):
    """Stumps, T == 1, and depth-skewed forests through every (layout, mode)
    pair of every backend — the packing edge cases padding used to hide."""
    ir, rows = degenerate_case
    ref = {}
    for layout, mode in _layout_mode_pairs(backend):
        if mode not in ref:
            ref[mode] = _scores(
                create_backend("reference", ir.materialize("padded"), mode=mode),
                rows,
            )
        s_ref, p_ref = ref[mode]
        eng = TreeEngine(ir, mode=mode, backend=backend, layout=layout)
        s, p = eng.predict_scores(rows)
        np.testing.assert_array_equal(np.asarray(s), s_ref,
                                      err_msg=f"{backend}/{layout}/{mode}")
        np.testing.assert_array_equal(np.asarray(p), p_ref,
                                      err_msg=f"{backend}/{layout}/{mode}")


# ------------------------------------------- execution-variant conformance
# The layout axis above is crossed with each backend's execution variants:
# the Pallas walk strategies (per-depth gather / onehot select / leaf_major
# linear scan) and the table-walk C row-block sizes.  Every variant must be
# bit-identical to the reference walk on randomized AND degenerate forests.

PALLAS_IMPLS = ["gather", "onehot", "leaf_major"]
BLOCK_ROWS = [1, 4, 8]


def _pallas_variant_engine(ir, impl):
    layout = "leaf_major" if impl == "leaf_major" else "padded"
    return TreeEngine(ir, mode="integer", backend="pallas", layout=layout,
                      backend_kwargs={"impl": impl})


@pytest.mark.parametrize("impl", PALLAS_IMPLS)
def test_pallas_impl_variants_randomized(random_case, impl):
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode="integer"), rows)
    eng = _pallas_variant_engine(packed.to_ir(), impl)
    assert eng.backend.impl == impl
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref, err_msg=f"pallas/{impl}")
    np.testing.assert_array_equal(np.asarray(p), p_ref, err_msg=f"pallas/{impl}")


@pytest.mark.parametrize("impl", ["gather", "leaf_major"])
def test_pallas_impl_variants_degenerate(degenerate_case, impl):
    """Stumps (no internal prefix at all), T == 1, and depth-skewed trees
    through both the gather walk and the linear scan."""
    ir, rows = degenerate_case
    s_ref, p_ref = _scores(
        create_backend("reference", ir.materialize("padded"), mode="integer"), rows
    )
    s, p = _pallas_variant_engine(ir, impl).predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref, err_msg=f"pallas/{impl}")
    np.testing.assert_array_equal(np.asarray(p), p_ref, err_msg=f"pallas/{impl}")


def test_pallas_leaf_major_impl_rejects_padded_artifact(small_packed):
    with pytest.raises(ValueError, match="leaf_major"):
        create_backend("pallas", small_packed, mode="integer", impl="leaf_major")


def _child_before_parent_forest():
    """A topologically valid tree whose arrays order an internal child
    *before* its parent (0 -> 3 -> 1) — legal for every gather walker, but
    it breaks the forward-scan invariant; imported artifacts can look like
    this."""
    from repro.trees.cart import TreeArrays

    feature = np.array([0, 0, -1, 0, -1, -1, -1], np.int32)
    threshold = np.array([0.0, -2.0, 0, 2.0, 0, 0, 0], np.float32)
    left = np.array([3, 4, 2, 1, 4, 5, 6], np.int32)
    right = np.array([2, 5, 2, 6, 4, 5, 6], np.int32)
    probs = np.zeros((7, 3))
    for leaf, c in ((2, 0), (4, 1), (5, 2), (6, 0)):
        probs[leaf, c] = 1.0
    tree = TreeArrays(feature=feature, threshold=threshold, left=left,
                      right=right, leaf_probs=probs, depth=3)
    return _forest_from_trees([tree], 3, 2)


def test_pallas_auto_falls_back_to_gather_on_unscannable_order():
    """leaf_major materialization of a child-before-parent forest records no
    internal prefix; impl='auto' gather-walks it and stays bit-identical,
    while pinning the scan fails loudly instead of mis-scoring."""
    ir = ForestIR.from_forest(_child_before_parent_forest())
    lm = ir.materialize("leaf_major")
    assert lm.internal_counts is None
    rows = np.random.default_rng(3).normal(0, 3, (29, 2)).astype(np.float32)
    s_ref, p_ref = _scores(
        create_backend("reference", ir.materialize("padded"), mode="integer"), rows
    )
    eng = TreeEngine(ir, mode="integer", backend="pallas", layout="leaf_major")
    assert eng.backend.impl == "gather"  # auto resolved away from the scan
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref)
    np.testing.assert_array_equal(np.asarray(p), p_ref)
    with pytest.raises(ValueError, match="scannable"):
        create_backend("pallas", lm, mode="integer", impl="leaf_major")


@pytest.mark.requires_gcc
@pytest.mark.parametrize("block_rows", BLOCK_ROWS)
@pytest.mark.parametrize("mode", ["flint", "integer"])
def test_table_walk_block_rows_randomized(random_case, block_rows, mode):
    """Scalar vs row-blocked table-walk C: bit-identical at every block
    size, including batches that leave a partial tail block (97 rows)."""
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode=mode), rows)
    eng = TreeEngine(packed.to_ir(), mode=mode, backend="native_c_table",
                     backend_kwargs={"block_rows": block_rows})
    assert eng.backend.block_rows == block_rows
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref,
                                  err_msg=f"table/{block_rows}/{mode}")
    np.testing.assert_array_equal(np.asarray(p), p_ref,
                                  err_msg=f"table/{block_rows}/{mode}")


@pytest.mark.requires_gcc
@pytest.mark.parametrize("block_rows", BLOCK_ROWS)
def test_table_walk_block_rows_degenerate(degenerate_case, block_rows):
    """Degenerate forests through the blocked walk: stumps never enter the
    level loop, depth-skewed trees exercise the all-leaves early exit."""
    ir, rows = degenerate_case
    s_ref, p_ref = _scores(
        create_backend("reference", ir.materialize("padded"), mode="integer"), rows
    )
    eng = TreeEngine(ir, mode="integer", backend="native_c_table",
                     backend_kwargs={"block_rows": block_rows})
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref,
                                  err_msg=f"table/{block_rows}")
    np.testing.assert_array_equal(np.asarray(p), p_ref,
                                  err_msg=f"table/{block_rows}")


@pytest.mark.requires_gcc
@pytest.mark.parametrize("interleave", [1, 4, 8])
@pytest.mark.parametrize("mode", ["flint", "integer"])
def test_bitvector_interleave_widths_randomized(random_case, interleave, mode):
    """v-QuickScorer interleaved comparison groups: every width is
    bit-identical to the reference walk — the stream pads with inert entries
    (a key that never tests true, an all-ones mask) and grouping never
    reorders a real mask application."""
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode=mode), rows)
    eng = TreeEngine(packed.to_ir(), mode=mode, backend="native_c_bitvector",
                     backend_kwargs={"interleave": interleave})
    assert eng.backend.interleave == interleave
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref,
                                  err_msg=f"bitvector/k{interleave}/{mode}")
    np.testing.assert_array_equal(np.asarray(p), p_ref,
                                  err_msg=f"bitvector/k{interleave}/{mode}")


@pytest.mark.requires_gcc
@pytest.mark.parametrize("interleave", [1, 4, 8])
def test_bitvector_interleave_widths_degenerate(degenerate_case, interleave):
    """Degenerate forests through the interleaved scorer: stumps contribute
    no comparisons at all (pure padding groups), single-tree forests leave
    most of a K-group inert."""
    ir, rows = degenerate_case
    s_ref, p_ref = _scores(
        create_backend("reference", ir.materialize("padded"), mode="integer"), rows
    )
    eng = TreeEngine(ir, mode="integer", backend="native_c_bitvector",
                     backend_kwargs={"interleave": interleave})
    s, p = eng.predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s), s_ref,
                                  err_msg=f"bitvector/k{interleave}")
    np.testing.assert_array_equal(np.asarray(p), p_ref,
                                  err_msg=f"bitvector/k{interleave}")


@pytest.fixture(scope="module")
def itrf_case(random_case, tmp_path_factory):
    """The same randomized forest, round-tripped through an ITRF artifact
    and reloaded as zero-copy mmap views — the registry's load path."""
    packed, rows = random_case
    ir = packed.to_ir()
    path = tmp_path_factory.mktemp("itrf") / "conformance.itrf"
    ir.to_itrf(str(path), pack_leaves=True)
    return ir, ForestIR.from_itrf(str(path), mmap=True), rows


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_mmap_artifact_bit_identity(itrf_case, backend):
    """The conformance matrix over an mmap-loaded artifact: every (layout,
    mode) pair of every backend, built from read-only views over the file's
    pages, must match the direct in-memory IR bit for bit."""
    ir, ir_mmap, rows = itrf_case
    assert not ir_mmap.feature.flags.writeable  # really the mapped pages
    for layout, mode in _layout_mode_pairs(backend):
        s_ref, p_ref = _scores(
            create_backend("reference", ir.materialize("padded"), mode=mode),
            rows)
        eng = TreeEngine(ir_mmap, mode=mode, backend=backend, layout=layout)
        s, p = eng.predict_scores(rows)
        np.testing.assert_array_equal(np.asarray(s), s_ref,
                                      err_msg=f"itrf/{backend}/{layout}/{mode}")
        np.testing.assert_array_equal(np.asarray(p), p_ref,
                                      err_msg=f"itrf/{backend}/{layout}/{mode}")


def test_degenerate_ragged_has_no_padding_waste(degenerate_case):
    ir, _ = degenerate_case
    sizes = ir.nbytes_by_layout(mode="integer")
    if ir.max_nodes > int(ir.node_counts.min()):
        assert sizes["ragged"] < sizes["padded"]


# -------------------------------------------------------- engine bucketing

def test_bucket_rows_at_and_past_the_cap():
    assert bucket_rows(4096, max_bucket=4096) == 4096
    assert bucket_rows(4097, max_bucket=4096) == 8192
    assert bucket_rows(8, max_bucket=8) == 8
    assert bucket_rows(9, max_bucket=8) == 16
    assert bucket_rows(17, max_bucket=8) == 24


class _RaisingBackend(TreeBackend):
    name = "raising-stub"
    capabilities = BackendCapabilities(
        modes=("integer",), deterministic_modes=("integer",)
    )

    def predict_scores(self, X):
        raise RuntimeError("backend exploded")


def test_failed_predict_does_not_mark_bucket_compiled(small_packed):
    eng = TreeEngine(backend=_RaisingBackend(small_packed, "integer"))
    with pytest.raises(RuntimeError, match="exploded"):
        eng.predict(np.zeros((5, small_packed.n_features), np.float32))
    assert eng.compiled_buckets == set()  # a raising predict compiled nothing


def test_warm_covers_max_bucket_multiples(small_packed, shuttle_small):
    """warm() must pre-compile the max_bucket-multiple shapes that batches
    with b >= max_bucket are padded to, not just the power-of-two buckets."""
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", max_bucket=8)
    eng.warm(20)
    assert eng.compiled_buckets == {1, 2, 4, 8, 16, 24}
    # every batch size the warm range promises is now a known bucket
    pre = set(eng.compiled_buckets)
    for b in (3, 8, 9, 20):
        eng.predict_scores(Xte[:b])
    assert eng.compiled_buckets == pre


def test_warm_covers_rounded_up_power_of_two(small_packed, shuttle_small):
    """A non-power-of-two max_rows must still warm the bucket its largest
    batches round UP to (warm(20) serves 17..20-row batches from bucket 32)."""
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", max_bucket=64)
    eng.warm(20)
    assert eng.compiled_buckets == {1, 2, 4, 8, 16, 32}
    pre = set(eng.compiled_buckets)
    eng.predict_scores(Xte[:17])
    assert eng.compiled_buckets == pre


def test_engine_skips_padding_for_shape_oblivious_backends(small_packed, shuttle_small):
    class Probe(TreeBackend):
        name = "probe"
        capabilities = BackendCapabilities(
            modes=("integer",), deterministic_modes=("integer",),
            compiles_per_shape=False,
        )
        seen = []

        def predict_scores(self, X):
            self.seen.append(X.shape[0])
            c = self.packed.n_classes
            return (np.zeros((X.shape[0], c), np.uint32),
                    np.zeros(X.shape[0], np.int32))

    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(backend=Probe(small_packed, "integer"))
    eng.predict_scores(Xte[:5])
    assert eng.backend.seen == [5]  # not padded to 8
    eng.warm(64)
    assert eng.backend.seen == [5, 1]  # warm = one artifact-building call

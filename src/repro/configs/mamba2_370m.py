"""mamba2-370m [ssm]: SSD (state-space duality).  [arXiv:2405.21060]

48L d_model=1024 (attn-free) vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_chunk=64,  # (Q x Q) intra-chunk working set stays VMEM/HBM friendly
    microbatches=2,  # activation stacks exceed HBM at global_batch 256 otherwise
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=3,
    d_model=128,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=32,
)

"""intreeger-rf [trees]: the paper's own architecture as a serving config.

A production-scale random-forest ensemble served integer-only on TPU: 128
trees (paper Sec. III-A argues n <= 256 keeps fixed point strictly more
precise than float32; [32] shows no gains past 128), depth 10, ESA-scale
feature width (87), 8 classes (7-class Shuttle padded to the lane-friendly 8).
Batch serving sharding: node tables replicated, examples sharded over all
mesh axes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="intreeger-rf",
    family="trees",
    n_trees=128,
    tree_depth=10,
    n_tab_features=87,
    n_classes=8,
)

SMOKE = ModelConfig(
    name="intreeger-rf-smoke",
    family="trees",
    n_trees=8,
    tree_depth=4,
    n_tab_features=7,
    n_classes=4,
)

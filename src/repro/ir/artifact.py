"""ITRF: the versioned binary forest artifact (mmap-able ForestIR).

The trees/io JSON document is the *interchange* boundary; ITRF is the
*deployment* boundary — the struct-packed binary a production fleet loads.
The file is a fixed little-endian header, a section table, and 64-byte
aligned sections holding the IR's CSR arrays verbatim:

    header  := magic(4s=b"ITRF") version_major(u16) version_minor(u16)
               flags(u32) n_trees(u32) n_classes(u32) n_features(u32)
               total_nodes(u64) quant_scale(u64, 0 = derive from n_trees)
               n_sections(u32), zero-padded to 64 bytes
    section := name(16s, NUL-padded) dtype(8s, numpy str e.g. b"<i4")
               ndim(u32) shape(4 x u64) offset(u64, 64-aligned) nbytes(u64)

Loading with ``mmap=True`` maps the file read-only and returns a
:class:`~repro.ir.forest_ir.ForestIR` whose arrays are numpy views over the
mapping: zero copies, O(1) in forest size, and N co-resident processes
share one page cache.  The views are immutable (numpy refuses writes), and
every layout materializer already copies into fresh arrays, so backends
that need writable or device-resident data pay lazily per layout while the
canonical arrays stay shared.

Versioning mirrors ``trees/io``: a newer *major* version is refused loudly
(never half-parsed), unknown section names are skipped (minor versions may
add sections), and required sections missing raise.  Two optional section
families ride along:

  * ``leaf_pack_*`` — the group-quantized leaf payload (``--pack-leaves``):
    exact codec from :mod:`repro.ir.packed_leaf`; decoded on load (the one
    deliberate copy of that path).
  * ``tune_db`` — a JSON map ``{host_isa_key: {"backend|layout|mode":
    kwargs}}`` of measured autotune winners.  ``register_artifact`` seeds
    ``ModelVersion._tuned`` from the entry matching :func:`host_isa_key`,
    so a warm-tuned config survives process restart; foreign-host entries
    are carried but ignored (that host re-tunes).
"""
from __future__ import annotations

import json
import mmap
import os
import platform
import struct
import tempfile

import numpy as np

__all__ = [
    "ITRF_MAGIC", "ITRF_VERSION",
    "write_itrf", "read_itrf", "read_itrf_bytes", "inspect_itrf",
    "update_tuned", "serialize_tuned", "deserialize_tuned", "host_isa_key",
]

ITRF_MAGIC = b"ITRF"
ITRF_VERSION = (1, 0)  # (major, minor): major bumps break readers

FLAG_FLOAT = 1  # threshold/leaf_probs sections present
FLAG_PACKED_LEAVES = 2  # leaf_pack_* sections replace leaf_fixed
FLAG_TUNED = 4  # a tune_db section is present

_ALIGN = 64
_HEADER = struct.Struct("<4sHHIIIIQQI")  # 44 bytes, padded to _ALIGN
_SECTION = struct.Struct("<16s8sI4QQQ")  # name dtype ndim shape[4] off nbytes

# sections a reader must find to rebuild the IR (leaf payload checked apart)
_NODE_SECTIONS = ("feature", "threshold_key", "left", "right",
                  "node_offsets", "tree_depths")


def _align(n: int) -> int:
    return -(-n // _ALIGN) * _ALIGN


# ---------------------------------------------------------------------------
# host identity (the tune_db key)
# ---------------------------------------------------------------------------

def host_isa_key() -> str:
    """A stable name for this host's ISA capabilities, e.g.
    ``"x86_64+avx2+avx512f"`` — the key autotune winners are stored under.
    Same flags => same measured optimum is a reasonable prior; a host with
    different flags ignores the entry and re-tunes."""
    traits = []
    try:
        with open("/proc/cpuinfo") as fh:
            flags: set = set()
            for line in fh:
                if line.lower().startswith(("flags", "features")):
                    flags.update(line.split(":", 1)[1].split())
        for t in ("avx2", "avx512f"):
            if t in flags:
                traits.append(t)
        if {"neon", "asimd"} & flags:
            traits.append("neon")
    except OSError:
        pass
    return "+".join([platform.machine() or "unknown"] + traits)


def serialize_tuned(tuned: dict) -> dict:
    """``{(backend, layout, mode): kwargs}`` -> JSON-safe string keys."""
    return {"|".join((b, l or "", m)): dict(kw)
            for (b, l, m), kw in tuned.items()}


def deserialize_tuned(entries: dict) -> dict:
    """Inverse of :func:`serialize_tuned` (tuple keys, ``""`` -> None)."""
    out = {}
    for key, kw in entries.items():
        backend, layout, mode = key.split("|")
        out[(backend, layout or None, mode)] = dict(kw)
    return out


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------

def _le(a: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(a)
    return a.astype(a.dtype.newbyteorder("<"), copy=False)


def _write_raw(path, header_fields: tuple, sections: list) -> None:
    """Serialize (header, [(name, ndarray)]) to ``path`` atomically."""
    entries, blobs = [], []
    offset = _align(_HEADER.size) + _align(_SECTION.size * len(sections))
    for name, a in sections:
        a = _le(a)
        nm = name.encode()
        if len(nm) > 16:
            raise ValueError(f"section name {name!r} exceeds 16 bytes")
        if a.ndim > 4:
            raise ValueError(f"section {name!r} has ndim {a.ndim} > 4")
        shape = list(a.shape) + [0] * (4 - a.ndim)
        entries.append(_SECTION.pack(nm, a.dtype.str.encode(), a.ndim,
                                     *shape, offset, a.nbytes))
        blobs.append(a.tobytes())
        offset += _align(a.nbytes)
    head = _HEADER.pack(ITRF_MAGIC, *header_fields, len(sections))
    parts = [head, b"\0" * (_align(_HEADER.size) - len(head))]
    table = b"".join(entries)
    parts += [table, b"\0" * (_align(_SECTION.size * len(sections)) - len(table))]
    for blob in blobs:
        parts += [blob, b"\0" * (_align(len(blob)) - len(blob))]
    dirname = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".itrf.tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            fh.write(b"".join(parts))
        os.replace(tmp, path)  # atomic: readers see old or new, never torn
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_itrf(path, ir, *, include_float: bool = True,
               pack_leaves: bool = False, tuned: dict = None,
               group: int = None) -> dict:
    """Serialize ``ir`` (a ForestIR) as an ITRF file; returns a summary dict.

    ``include_float=False`` drops the float sections (threshold/leaf_probs)
    — a deterministic-serving artifact at roughly half the bytes; loading
    it yields zero float arrays, so only flint/integer routes may serve it.
    ``pack_leaves=True`` stores the leaf table through the exact group
    codec.  ``tuned`` is a ``{(backend, layout, mode): kwargs}`` map written
    to the ``tune_db`` section under this host's :func:`host_isa_key`.
    """
    from repro.ir.packed_leaf import GROUP_SIZE, pack_leaf_payload

    group = int(group or GROUP_SIZE)
    flags = 0
    sections = [
        ("feature", ir.feature.astype(np.int32, copy=False)),
        ("threshold_key", ir.threshold_key.astype(np.int32, copy=False)),
        ("left", ir.left.astype(np.int32, copy=False)),
        ("right", ir.right.astype(np.int32, copy=False)),
        ("node_offsets", ir.node_offsets.astype(np.int64, copy=False)),
        ("tree_depths", ir.tree_depths.astype(np.int32, copy=False)),
    ]
    if pack_leaves:
        flags |= FLAG_PACKED_LEAVES
        values = ir.leaf_fixed[ir.feature < 0].ravel()
        dictionary, base, bits, payload = pack_leaf_payload(values, group)
        sections += [("leaf_pack_dict", dictionary),
                     ("leaf_pack_base", base), ("leaf_pack_bits", bits),
                     ("leaf_pack_data", payload)]
    else:
        sections.append(("leaf_fixed", ir.leaf_fixed.astype(np.uint32,
                                                            copy=False)))
    if include_float:
        flags |= FLAG_FLOAT
        sections += [
            ("threshold", ir.threshold.astype(np.float32, copy=False)),
            ("leaf_probs", ir.leaf_probs.astype(np.float64, copy=False)),
        ]
    meta = {"group_size": group}
    sections.append(("meta", np.frombuffer(json.dumps(meta).encode(),
                                           np.uint8)))
    if tuned:
        flags |= FLAG_TUNED
        db = {host_isa_key(): serialize_tuned(tuned)}
        sections.append(("tune_db",
                         np.frombuffer(json.dumps(db).encode(), np.uint8)))
    header = (*ITRF_VERSION, flags, ir.n_trees, ir.n_classes, ir.n_features,
              ir.total_nodes, int(ir.quant_scale or 0))
    _write_raw(path, header, sections)
    return {"path": str(path), "flags": flags,
            "sections": [name for name, _ in sections],
            "file_bytes": os.path.getsize(path)}


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------

def _parse_header(buf) -> dict:
    if len(buf) < _HEADER.size:
        raise ValueError(f"not an ITRF artifact: {len(buf)} bytes")
    (magic, vmaj, vmin, flags, n_trees, n_classes, n_features, total_nodes,
     quant_scale, n_sections) = _HEADER.unpack_from(buf)
    if magic != ITRF_MAGIC:
        raise ValueError(f"not an ITRF artifact: bad magic {magic!r}")
    if vmaj > ITRF_VERSION[0]:
        # mirror trees/io schema gating: refuse loudly, never half-parse
        raise ValueError(
            f"ITRF artifact uses format version {vmaj}.{vmin}, but this "
            f"reader understands <= {ITRF_VERSION[0]}.x; refusing to "
            f"half-parse a newer artifact"
        )
    return dict(version=(vmaj, vmin), flags=flags, n_trees=n_trees,
                n_classes=n_classes, n_features=n_features,
                total_nodes=total_nodes,
                quant_scale=quant_scale or None, n_sections=n_sections)


def _parse_sections(buf, n_sections: int) -> dict:
    """-> {name: (dtype_str, shape, offset, nbytes)} from the section table."""
    out = {}
    off = _align(_HEADER.size)
    for _ in range(n_sections):
        name, dt, ndim, s0, s1, s2, s3, offset, nbytes = \
            _SECTION.unpack_from(buf, off)
        shape = tuple(int(s) for s in (s0, s1, s2, s3)[:ndim])
        out[name.rstrip(b"\0").decode()] = (dt.rstrip(b"\0").decode(),
                                            shape, int(offset), int(nbytes))
        off += _SECTION.size
    return out


def _section_array(buf, entry, *, copy: bool) -> np.ndarray:
    dt_str, shape, offset, nbytes = entry
    dt = np.dtype(dt_str)
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    a = np.frombuffer(buf, dt, count=count, offset=offset).reshape(shape)
    return a.copy() if copy else a


def _parse(buf, *, copy: bool, source=None):
    """Rebuild a ForestIR over ``buf`` (mmap, bytes, or memoryview)."""
    from repro.ir.forest_ir import ForestIR
    from repro.ir.packed_leaf import GROUP_SIZE, unpack_leaf_payload

    head = _parse_header(buf)
    table = _parse_sections(buf, head["n_sections"])
    missing = [n for n in _NODE_SECTIONS if n not in table]
    if missing:
        raise ValueError(f"ITRF artifact missing required sections {missing}")
    sec = lambda name: _section_array(buf, table[name], copy=copy)
    meta = {}
    if "meta" in table:
        meta = json.loads(_section_array(buf, table["meta"],
                                         copy=False).tobytes())
    total, C = head["total_nodes"], head["n_classes"]
    feature = sec("feature")
    if head["flags"] & FLAG_PACKED_LEAVES:
        values = unpack_leaf_payload(
            sec("leaf_pack_dict") if "leaf_pack_dict" in table
            else np.zeros(0, np.uint32),
            sec("leaf_pack_base"),
            sec("leaf_pack_bits"), sec("leaf_pack_data"),
            int((feature < 0).sum()) * C,
            int(meta.get("group_size", GROUP_SIZE)),
        )
        leaf_fixed = np.zeros((total, C), np.uint32)
        leaf_fixed[feature < 0] = values.reshape(-1, C)
    elif "leaf_fixed" in table:
        leaf_fixed = sec("leaf_fixed")
    else:
        raise ValueError("ITRF artifact carries neither leaf_fixed nor "
                         "leaf_pack_* sections")
    if head["flags"] & FLAG_FLOAT:
        threshold, leaf_probs = sec("threshold"), sec("leaf_probs")
    else:  # deterministic-only artifact: float tables are zero
        threshold = np.zeros(total, np.float32)
        leaf_probs = np.zeros((total, C), np.float64)
    ir = ForestIR(
        feature=feature,
        threshold=threshold,
        threshold_key=sec("threshold_key"),
        left=sec("left"),
        right=sec("right"),
        leaf_probs=leaf_probs,
        leaf_fixed=leaf_fixed,
        node_offsets=sec("node_offsets"),
        tree_depths=sec("tree_depths"),
        n_trees=head["n_trees"],
        n_classes=C,
        n_features=head["n_features"],
        quant_scale=head["quant_scale"],
    )
    tuned_db = {}
    if "tune_db" in table:
        tuned_db = json.loads(_section_array(buf, table["tune_db"],
                                             copy=False).tobytes())
    # artifact provenance, read by the registry (tune seeding, load ledger)
    # and the remote plan (HELLO ships the raw artifact bytes)
    ir.itrf_source = str(source) if source is not None else None
    ir.itrf_version = head["version"]
    ir.itrf_flags = head["flags"]
    ir.itrf_tuned = tuned_db
    ir.itrf_bytes = np.frombuffer(buf, np.uint8)
    return ir


def read_itrf(path, *, mmap_arrays: bool = True):
    """Load an ITRF file -> ForestIR.

    ``mmap_arrays=True`` (the default) maps the file read-only and returns
    zero-copy views: O(1) load regardless of forest size, pages shared with
    every other process mapping the same file.  ``mmap_arrays=False`` reads
    the file eagerly and returns private writable copies.
    """
    with open(path, "rb") as fh:
        if mmap_arrays:
            mm = mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ)
            return _parse(mm, copy=False, source=path)
        return _parse(fh.read(), copy=True, source=path)


def read_itrf_bytes(data):
    """Load an ITRF image already in memory (the worker HELLO fast path):
    arrays are zero-copy read-only views over ``data``."""
    return _parse(data, copy=False)


def inspect_itrf(path) -> dict:
    """Header + section table + tuned hosts, without touching array pages."""
    with open(path, "rb") as fh:
        mm = mmap.mmap(fh.fileno(), 0, access=mmap.ACCESS_READ)
        head = _parse_header(mm)
        table = _parse_sections(mm, head["n_sections"])
        tuned_hosts = []
        if "tune_db" in table:
            tuned_hosts = sorted(json.loads(
                _section_array(mm, table["tune_db"], copy=False).tobytes()))
        return {
            **{k: v for k, v in head.items() if k != "n_sections"},
            "file_bytes": os.path.getsize(path),
            "sections": {
                name: {"dtype": dt, "shape": list(shape),
                       "offset": off, "nbytes": nb}
                for name, (dt, shape, off, nb) in table.items()
            },
            "tuned_hosts": tuned_hosts,
        }


def update_tuned(path, tuned: dict, *, host_key: str = None) -> None:
    """Merge autotune winners into an existing artifact's ``tune_db``
    section (atomic rewrite; all other sections are carried verbatim).

    ``tuned`` uses the in-memory ``{(backend, layout, mode): kwargs}`` form
    — normally ``ModelVersion._tuned`` — and lands under ``host_key``
    (default: this host's :func:`host_isa_key`)."""
    with open(path, "rb") as fh:
        buf = fh.read()
    head = _parse_header(buf)
    table = _parse_sections(buf, head["n_sections"])
    db = {}
    if "tune_db" in table:
        db = json.loads(_section_array(buf, table["tune_db"],
                                       copy=False).tobytes())
    key = host_key or host_isa_key()
    db.setdefault(key, {}).update(serialize_tuned(tuned))
    sections = [
        (name, _section_array(buf, entry, copy=False))
        for name, entry in table.items() if name != "tune_db"
    ]
    sections.append(("tune_db",
                     np.frombuffer(json.dumps(db).encode(), np.uint8)))
    vmaj, vmin = head["version"]
    header = (vmaj, vmin, head["flags"] | FLAG_TUNED, head["n_trees"],
              head["n_classes"], head["n_features"], head["total_nodes"],
              int(head["quant_scale"] or 0))
    _write_raw(path, header, sections)

"""SingleShardPlan: the whole forest on one backend — today's path.

The degenerate plan, and the conformance baseline every sharded plan must be
bit-identical to.  It delegates ``predict_scores`` straight to the backend
(which already funnels deterministic modes through the shared
partials/finalize split), so routing the engine through plans changes
nothing for existing callers — including float mode, pre-constructed backend
instances, and shape-oblivious compiled-C execution.
"""
from __future__ import annotations

from repro.plan.base import ExecutionPlan, build_backend, register_plan


@register_plan
class SingleShardPlan(ExecutionPlan):
    name = "single"

    def __init__(self, model, *, mode: str = "integer", backend="reference",
                 shards=None, layout=None, backend_kwargs=None):
        if shards not in (None, 1):
            raise ValueError(
                f"the single plan runs exactly one shard, got shards={shards}; "
                "use plan='tree_parallel' or 'row_parallel' to shard"
            )
        self.backend = build_backend(backend, model, mode, layout, backend_kwargs)
        # an already-constructed backend instance carries its own mode/model
        super().__init__(self.backend.packed, mode=self.backend.mode)
        self._label = f"s0:{self.backend.name}"

    @property
    def backends(self) -> tuple:
        return (self.backend,)

    @property
    def packed(self):
        return self.backend.packed

    def predict_partials(self, X):
        return self._timed(self._label, self.backend.predict_partials, X)

    def predict_scores(self, X):
        return self._timed(self._label, self.backend.predict_scores, X)

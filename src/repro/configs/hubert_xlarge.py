"""hubert-xlarge [audio]: encoder-only (w2v2 arch).  [arXiv:2106.07447]

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.  Frontend stubbed per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(frame_dim=512, the conv-feature-extractor output dim); a linear projector
maps them to d_model.  No decode shapes (encoder-only).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    tie_embeddings=False,
    frontend="audio_stub",
    frontend_dim=512,
    act="gelu",
    microbatches=4,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    encoder_only=True,
    tie_embeddings=False,
    frontend="audio_stub",
    frontend_dim=32,
    act="gelu",
)

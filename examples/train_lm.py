"""End-to-end LM training driver on CPU (smoke-scale): trains a reduced
starcoder2 for a few hundred steps with checkpointing + fault injection,
demonstrating loss descent and crash recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.fault_tolerance import RestartableLoop, StepWatchdog
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import smoke_config
from repro.data.tokens import pipeline_for
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ostate = opt.init_opt_state(params)
    opt_cfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    jit_step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    pipe = pipeline_for(cfg, args.batch, args.seq)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    manager = CheckpointManager(ckpt_dir)
    watchdog = StepWatchdog()
    crash = {"armed": True}
    losses = []

    def step_fn(state, step):
        if step == args.steps // 2 and crash["armed"]:
            crash["armed"] = False
            raise RuntimeError("injected mid-run failure (recovered from checkpoint)")
        p, o = state["params"], state["opt"]
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        p, o, m = jit_step(p, o, batch)
        losses.append(float(m["loss"]))
        if step % 25 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f} lr {float(m['lr']):.2e}")
        return {"params": p, "opt": o}

    loop = RestartableLoop(manager, ckpt_every=50)
    state, info = loop.run(
        {"params": params, "opt": ostate}, step_fn, args.steps, watchdog=watchdog
    )
    print(
        f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} over {info['steps']} steps "
        f"with {info['restarts']} recovered crash(es)"
    )
    assert losses[-1] < losses[0] - 0.3, "loss should clearly descend"


if __name__ == "__main__":
    main()

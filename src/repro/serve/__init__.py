"""Serving subsystem: engines + the async dynamic-batching gateway.

Request path:  client → Gateway.submit → QuantizedKeyCache (per-row probe)
             → MicroBatcher (coalesce to block-shaped batches under a
               latency deadline, admission-controlled) → ModelRegistry
               (versioned, hot-swappable) → TreeEngine (shape-bucketed)
             → ExecutionPlan (single / tree-parallel / row-parallel shards,
               exact integer partial merge, one finalize)
             → TreeBackend → cache fill → response.
"""
from repro.serve.cache import QuantizedKeyCache, row_keys
from repro.serve.engine import LMEngine, TreeEngine, bucket_rows
from repro.serve.gateway import Gateway
from repro.serve.metrics import MetricsRegistry, ModelMetrics
from repro.serve.queue import AdmissionError, MicroBatcher
from repro.serve.registry import ModelRegistry, ModelVersion

__all__ = [
    "AdmissionError",
    "Gateway",
    "LMEngine",
    "MetricsRegistry",
    "MicroBatcher",
    "ModelMetrics",
    "ModelRegistry",
    "ModelVersion",
    "QuantizedKeyCache",
    "TreeEngine",
    "bucket_rows",
    "row_keys",
]

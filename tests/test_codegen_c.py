"""The paper's literal deliverable: integer-only if-else C.  When gcc is
available we compile the emitted file and diff argmax against the JAX path."""
import subprocess
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.codegen.c_emitter import emit_c, emit_test_harness
from repro.core.ensemble import predict_float, predict_integer
from repro.core.flint import float_to_key_np


def test_emit_integer_c_structure(small_packed):
    src = emit_c(small_packed, mode="integer")
    assert "#include <stdint.h>" in src
    assert "float" not in src  # integer-only: no float type anywhere
    assert "result[0] +=" in src
    assert "u;" in src  # uint32 literals
    assert src.count("if (") > small_packed.n_trees  # real branching structure


def test_emit_float_c_structure(small_packed):
    src = emit_c(small_packed, mode="float")
    assert "const float* data" in src
    assert "f;" in src


def test_harness_matches_mode_data_type(small_packed):
    """The stdin harness must read the element type the predict prototype
    expects: float32 rows for float mode, int32 FlInt keys otherwise."""
    f = small_packed.n_features
    for mode in ("integer", "flint"):
        src = emit_test_harness(small_packed, 4, mode=mode)
        assert f"static int32_t row[{f}]" in src
        assert "predict_class(const int32_t* data)" in src
        assert "sizeof(int32_t)" in src
    src = emit_test_harness(small_packed, 4, mode="float")
    assert f"static float row[{f}]" in src
    assert "predict_class(const float* data)" in src
    assert "sizeof(float)" in src


def _deep_chain_packed(depth):
    """A single degenerate tree: a right-leaning chain ``depth`` levels deep.

    Node 2k is internal (splits on feature 0), node 2k+1 is its left leaf,
    the final node is the rightmost leaf — worst case for a recursive
    emitter, which would nest two Python frames per level.
    """
    from repro.core.packing import PackedEnsemble
    from repro.core.fixedpoint import prob_to_fixed_np

    n = 2 * depth + 1
    feature = np.full((1, n), -1, np.int32)
    threshold = np.zeros((1, n), np.float32)
    left = np.tile(np.arange(n, dtype=np.int32), (1, 1))
    right = left.copy()
    probs = np.zeros((1, n, 2), np.float64)
    for k in range(depth):
        node = 2 * k
        feature[0, node] = 0
        threshold[0, node] = float(k)
        left[0, node] = node + 1  # leaf
        right[0, node] = node + 2  # next internal (or final leaf)
        probs[0, node + 1] = (1.0, 0.0)
    probs[0, n - 1] = (0.0, 1.0)
    return PackedEnsemble(
        feature=feature,
        threshold=threshold,
        threshold_key=float_to_key_np(threshold),
        left=left,
        right=right,
        leaf_probs=probs.astype(np.float32),
        leaf_fixed=prob_to_fixed_np(probs, 1),
        n_trees=1,
        n_classes=2,
        n_features=1,
        max_depth=depth,
    )


def test_emit_deep_tree_beyond_recursion_limit():
    """Depth ~1500 would need ~3000 nested Python frames with a recursive
    emitter; the explicit-stack emitter must handle it."""
    import sys

    depth = sys.getrecursionlimit()  # >> the safe recursion budget
    packed = _deep_chain_packed(depth)
    src = emit_c(packed, mode="integer")
    assert src.count("{") == src.count("}")
    assert src.count("if (data[") == depth  # one branch per chain level


def test_emit_table_walk_c_structure(small_packed):
    """The data-as-arrays emitter: static node arrays + one generic walk,
    integer-only in integer mode, code size O(1) in forest size."""
    from repro.codegen.table_emitter import emit_table_walk_c

    rg = small_packed.to_ir().materialize("ragged")
    src = emit_table_walk_c(rg, mode="integer")
    assert "#include <stdint.h>" in src
    assert "float" not in src  # integer-only: no float type anywhere
    for name in ("node_feature", "node_key", "node_left", "node_right",
                 "node_leaf", "tree_root"):
        assert f"static const" in src and name in src
    assert f"tree_root[{rg.n_trees}]" in src
    assert src.count("while (f >= 0)") == 1  # ONE walk loop, not per-tree code
    assert src.count("if (") <= 1  # no if-else cascade (argmax only)
    flint = emit_table_walk_c(rg, mode="flint")
    assert "float result" in flint or "float* result" in flint
    with pytest.raises(AssertionError):
        emit_table_walk_c(rg, mode="float")


def test_emit_table_walk_blocked_structure(small_packed):
    """block_rows=R switches to interleaved node quads and emits the blocked
    predict_batch: R register chains, branch-free selects, early exit."""
    from repro.codegen.table_emitter import emit_table_walk_c

    rg = small_packed.to_ir().materialize("ragged")
    src = emit_table_walk_c(rg, mode="integer", block_rows=4)
    assert "node_quad" in src and "node_feature" not in src  # interleaved
    assert f"node_quad[{rg.total_nodes * 4}]" in src
    assert "walk_block_full" in src and "void predict_batch" in src
    for k in range(4):
        assert f"int32_t n{k} = root;" in src  # register chains, unrolled
    assert "(f0 & f1 & f2 & f3) < 0" in src  # all-leaves early exit
    walk = src[src.index("walk_block_full"):src.index("void predict_batch")]
    assert "go0" in walk and "?" not in walk  # arithmetic selects, no ternary
    # single-row predict still present (tail blocks + harness contract)
    assert src.count("while (f >= 0)") == 1
    # the scalar emission is unchanged by the new parameter's default
    assert "node_quad" not in emit_table_walk_c(rg, mode="integer")


@pytest.mark.requires_gcc
def test_compiled_blocked_table_walk_matches_scalar(small_packed, shuttle_small):
    """The blocked shared-library path == the scalar path bit-for-bit on a
    row count that exercises full blocks AND a partial tail."""
    from repro.backends import create_backend

    _, _, Xte, _ = shuttle_small
    rows = Xte[:203]  # 25 full blocks of 8 + tail of 3
    rg = small_packed.to_ir().materialize("ragged")
    base = create_backend("native_c_table", rg, mode="integer", block_rows=1)
    s_ref, p_ref = base.predict_scores(rows)
    for br in (4, 8):
        be = create_backend("native_c_table", rg, mode="integer", block_rows=br)
        s, p = be.predict_scores(rows)
        np.testing.assert_array_equal(s, s_ref)
        np.testing.assert_array_equal(p, p_ref)


@pytest.mark.requires_gcc
def test_compiled_table_walk_matches_if_else(small_packed, shuttle_small):
    """Both C strategies — forest-as-code (if-else) and forest-as-data
    (table walk) — must agree bit-for-bit through the shared harness."""
    from repro.codegen.table_emitter import emit_table_walk_c

    _, _, Xte, _ = shuttle_small
    Xte = Xte[:300]
    rg = small_packed.to_ir().materialize("ragged")
    preds = {}
    for tag, src in (
        ("if_else", emit_c(small_packed, mode="integer")),
        ("table", emit_table_walk_c(rg, mode="integer")),
    ):
        full = src + emit_test_harness(small_packed, len(Xte), mode="integer")
        with tempfile.TemporaryDirectory() as d:
            c_file, binary = Path(d) / "m.c", Path(d) / "m"
            c_file.write_text(full)
            subprocess.run(["gcc", "-O2", "-o", str(binary), str(c_file)],
                           check=True, capture_output=True)
            keys = float_to_key_np(Xte.astype(np.float32))
            out = subprocess.run([str(binary)], input=keys.astype("<i4").tobytes(),
                                 capture_output=True, check=True)
        preds[tag] = np.array([int(v) for v in out.stdout.split()])
    np.testing.assert_array_equal(preds["if_else"], preds["table"])
    _, jax_preds = predict_integer(small_packed, Xte)
    np.testing.assert_array_equal(preds["table"], np.asarray(jax_preds))


@pytest.mark.requires_gcc
def test_compiled_c_matches_jax(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    Xte = Xte[:500]
    src = emit_c(small_packed, mode="integer") + emit_test_harness(small_packed, len(Xte))
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "model.c"
        binary = Path(d) / "model"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O2", "-o", str(binary), str(c_file)], check=True, capture_output=True
        )
        keys = float_to_key_np(Xte.astype(np.float32))
        out = subprocess.run(
            [str(binary)], input=keys.astype("<i4").tobytes(), capture_output=True, check=True
        )
        c_preds = np.array([int(v) for v in out.stdout.split()])
    _, jax_preds = predict_integer(small_packed, Xte)
    np.testing.assert_array_equal(c_preds, np.asarray(jax_preds))


@pytest.mark.requires_gcc
def test_compiled_float_harness_matches_jax(small_packed, shuttle_small):
    """Float-mode harness reads float32 rows (regression: it used to read
    int32 regardless of mode, so float-mode binaries saw garbage)."""
    _, _, Xte, _ = shuttle_small
    Xte = Xte[:200]
    src = emit_c(small_packed, mode="float") + emit_test_harness(
        small_packed, len(Xte), mode="float"
    )
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "model.c"
        binary = Path(d) / "model"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O2", "-o", str(binary), str(c_file)], check=True, capture_output=True
        )
        out = subprocess.run(
            [str(binary)], input=Xte.astype("<f4").tobytes(),
            capture_output=True, check=True,
        )
        c_preds = np.array([int(v) for v in out.stdout.split()])
    _, jax_preds = predict_float(small_packed, Xte)
    np.testing.assert_array_equal(c_preds, np.asarray(jax_preds))


@pytest.mark.requires_gcc
def test_c_binary_size_reported(small_packed):
    """Analog of the paper's Sec. IV-E memory-footprint measurement."""
    src = emit_c(small_packed, mode="integer")
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "model.c"
        obj = Path(d) / "model.o"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O2", "-c", "-o", str(obj), str(c_file)], check=True, capture_output=True
        )
        assert obj.stat().st_size > 0

"""Gradient-boosted trees (logistic loss), second substrate the paper's
pipeline supports (Sec. II-B: tl2cgen handles "RFs and GBTs").

Binary: standard Friedman GBM — stage t fits a regression tree to the
logistic gradient; leaves carry Newton-step values
``sum(residual) / sum(p(1-p))``.  Multiclass: one-vs-rest ensembles.

Integer-only applicability (DESIGN.md note): GBT leaves are *margins*
(unbounded log-odds), not probabilities, so the paper's 2^32/n probability
conversion does not apply verbatim.  What transfers:
  * FlInt integer threshold compares — identical (branch nodes are the same),
  * fixed-point accumulation with a *margin bound* M: scale
    floor((2^31-1)/(n*M)) keeps n signed contributions overflow-free by the
    same argument (the signed analogue of Sec. III-A; M measured at pack
    time).  `pack_gbt` emits exactly that, and argmax over summed fixed-point
    margins equals the float path's prediction (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.flint import float_to_key_np
from repro.trees.cart import TreeArrays, _quantile_bins


def _fit_regression_tree(X, codes, edges, grad, hess, *, max_depth, min_samples_leaf,
                         rng) -> TreeArrays:
    """Histogram tree on (grad, hess) — Newton leaves (XGBoost-style)."""
    n, F = X.shape
    B = max(max(len(e) + 1 for e in edges), 2)
    from repro.trees.cart import _GrowState

    st = _GrowState()
    root = st.add()
    sample_node = np.zeros(n, np.int32)
    frontier = {root}
    depth = 0
    for level in range(max_depth + 1):
        if not frontier:
            break
        active = sorted(frontier)
        slot_of = {nid: i for i, nid in enumerate(active)}
        slot_map = np.full(len(st.feature), -1, np.int64)
        for nid, i in slot_of.items():
            slot_map[nid] = i
        sslot = slot_map[sample_node]
        live = sslot >= 0
        idx = np.nonzero(live)[0]
        if idx.size == 0:
            break
        sl = sslot[idx]
        # fused histograms of gradient and hessian
        fuse = (sl[:, None] * F + np.arange(F)[None, :]) * B + codes[idx].astype(np.int64)
        gh = np.bincount(fuse.ravel(), weights=np.repeat(grad[idx], F), minlength=len(active) * F * B)
        hh = np.bincount(fuse.ravel(), weights=np.repeat(hess[idx], F), minlength=len(active) * F * B)
        ch = np.bincount(fuse.ravel(), minlength=len(active) * F * B)
        gh = gh.reshape(len(active), F, B)
        hh = hh.reshape(len(active), F, B)
        ch = ch.reshape(len(active), F, B)
        gl = np.cumsum(gh, axis=2)
        hl = np.cumsum(hh, axis=2)
        cl = np.cumsum(ch, axis=2)
        gt = gl[:, 0, -1][:, None, None]
        ht = hl[:, 0, -1][:, None, None]
        ct = cl[:, 0, -1][:, None, None]
        lam = 1.0
        gain = (gl**2 / (hl + lam)) + ((gt - gl) ** 2 / (ht - hl + lam)) - (gt**2 / (ht + lam))
        valid = (cl >= min_samples_leaf) & (ct - cl >= min_samples_leaf)
        for j in range(F):
            valid[:, j, len(edges[j]):] = False
        gain = np.where(valid, gain, -np.inf)
        flat = gain.reshape(len(active), F * B)
        best = flat.argmax(axis=1)
        best_gain = flat[np.arange(len(active)), best]
        best_f, best_b = best // B, best % B

        new_frontier = set()
        for i, nid in enumerate(active):
            m = sample_node == nid
            g_sum, h_sum = grad[m].sum(), hess[m].sum()
            if level == max_depth or not np.isfinite(best_gain[i]) or best_gain[i] <= 1e-12:
                st.feature[nid] = -1
                st.probs[nid] = np.array([g_sum / (h_sum + 1.0)])  # Newton leaf value
                continue
            f, bb = int(best_f[i]), int(best_b[i])
            st.feature[nid] = f
            st.threshold[nid] = float(edges[f][bb])
            lid, rid = st.add(), st.add()
            st.left[nid], st.right[nid] = lid, rid
            depth = max(depth, level + 1)
            ids = np.nonzero(m)[0]
            go_left = codes[ids, f] <= bb
            sample_node[ids[go_left]] = lid
            sample_node[ids[~go_left]] = rid
            new_frontier |= {lid, rid}
        frontier = new_frontier
    vals = np.stack([p if p is not None else np.zeros(1) for p in st.probs])
    return TreeArrays(
        feature=np.asarray(st.feature, np.int32),
        threshold=np.asarray(st.threshold, np.float32),
        left=np.asarray(st.left, np.int32),
        right=np.asarray(st.right, np.int32),
        leaf_probs=vals,  # (n_nodes, 1) leaf margins
        depth=depth,
    )


@dataclass
class GradientBoostedClassifier:
    n_estimators: int = 20
    max_depth: int = 4
    learning_rate: float = 0.3
    min_samples_leaf: int = 5
    n_bins: int = 64
    seed: int = 0

    trees_: List[List[TreeArrays]] = field(default_factory=list)  # [class][stage]
    base_: np.ndarray = None
    n_classes_: int = 0

    def fit(self, X, y):
        X = np.asarray(X, np.float32)
        y = np.asarray(y)
        self.n_classes_ = int(y.max()) + 1
        rng = np.random.default_rng(self.seed)
        codes, edges = _quantile_bins(X, self.n_bins, rng)
        self.base_ = np.zeros(self.n_classes_)
        self.trees_ = []
        for c in range(self.n_classes_):
            yc = (y == c).astype(np.float64)
            prior = np.clip(yc.mean(), 1e-6, 1 - 1e-6)
            margin = np.full(len(y), np.log(prior / (1 - prior)))
            self.base_[c] = margin[0]
            stages = []
            for _ in range(self.n_estimators):
                p = 1.0 / (1.0 + np.exp(-margin))
                grad = yc - p
                hess = p * (1 - p)
                tree = _fit_regression_tree(
                    X, codes, edges, grad, hess,
                    max_depth=self.max_depth, min_samples_leaf=self.min_samples_leaf,
                    rng=rng,
                )
                margin += self.learning_rate * tree.predict_proba(X)[:, 0]
                stages.append(tree)
            self.trees_.append(stages)
        return self

    def decision_function(self, X) -> np.ndarray:
        X = np.asarray(X, np.float32)
        out = np.tile(self.base_, (X.shape[0], 1))
        for c, stages in enumerate(self.trees_):
            for t in stages:
                out[:, c] += self.learning_rate * t.predict_proba(X)[:, 0]
        return out

    def predict(self, X) -> np.ndarray:
        return self.decision_function(X).argmax(axis=1)


@dataclass
class PackedGBT:
    """Integer-only GBT artifact: FlInt keys + fixed-point signed margins."""

    feature: np.ndarray  # (T, N) int32 over all (class, stage) trees
    threshold_key: np.ndarray
    left: np.ndarray
    right: np.ndarray
    leaf_fixed: np.ndarray  # (T, N) int32 fixed-point margin contributions
    tree_class: np.ndarray  # (T,) which class each tree contributes to
    base_fixed: np.ndarray  # (C,) int32
    scale: float
    n_classes: int
    max_depth: int


def pack_gbt(model: GradientBoostedClassifier) -> PackedGBT:
    trees = [t for stages in model.trees_ for t in stages]
    tree_class = np.concatenate(
        [np.full(len(stages), c, np.int32) for c, stages in enumerate(model.trees_)]
    )
    T = len(trees)
    N = max(t.n_nodes for t in trees)
    # margin bound M: max |contribution| over leaves (incl. base), paper-style
    # overflow-free scale for T signed additions
    m_bound = max(
        float(np.abs(model.base_).max()),
        max(float(np.abs(t.leaf_probs).max()) for t in trees) * model.learning_rate,
    ) + 1e-9
    scale = float((2**31 - 1) // ((T + 1) * np.ceil(m_bound)))
    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    left = np.tile(np.arange(N, dtype=np.int32), (T, 1))
    right = left.copy()
    leaf_fixed = np.zeros((T, N), np.int64)
    for i, t in enumerate(trees):
        n = t.n_nodes
        feature[i, :n] = t.feature
        threshold[i, :n] = t.threshold
        left[i, :n] = t.left
        right[i, :n] = t.right
        is_leaf = t.feature < 0
        vals = model.learning_rate * t.leaf_probs[:, 0]
        leaf_fixed[i, :n][is_leaf] = np.floor(vals[is_leaf] * scale)
    return PackedGBT(
        feature=feature,
        threshold_key=float_to_key_np(threshold),
        left=left,
        right=right,
        leaf_fixed=leaf_fixed.astype(np.int32),
        tree_class=tree_class,
        base_fixed=np.floor(model.base_ * scale).astype(np.int32),
        scale=scale,
        n_classes=model.n_classes_,
        max_depth=max(t.depth for t in trees),
    )


def predict_gbt_integer(packed: PackedGBT, X) -> np.ndarray:
    """Integer-only GBT inference (numpy reference): int32 compares + adds."""
    keys = float_to_key_np(np.asarray(X, np.float32))
    b = keys.shape[0]
    acc = np.tile(packed.base_fixed.astype(np.int64), (b, 1))
    for t in range(packed.feature.shape[0]):
        node = np.zeros(b, np.int32)
        for _ in range(packed.max_depth):
            f = packed.feature[t, node]
            thr = packed.threshold_key[t, node]
            xv = keys[np.arange(b), np.clip(f, 0, None)]
            nxt = np.where(xv <= thr, packed.left[t, node], packed.right[t, node])
            node = np.where(f < 0, node, nxt).astype(np.int32)
        acc[:, packed.tree_class[t]] += packed.leaf_fixed[t, node]
    assert np.abs(acc).max() < 2**31  # overflow-free by scale construction
    return acc.argmax(axis=1)

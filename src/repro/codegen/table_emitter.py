"""Vectorizable table-walk C: the ragged layout compiled data-as-arrays.

The paper's deliverable (``c_emitter.emit_c``) encodes the forest *in the
instruction stream* — one if-else cascade per tree, FlInt keys and fixed-point
leaves as immediates.  That is ideal for MCU-class single-row inference but
branchy at batch: every row takes a data-dependent path through thousands of
conditional jumps.  This emitter is the other point in the design space the
paper's architecture discussion motivates: the forest as *static data* (the
``ragged`` ForestIR layout — CSR node arrays with per-tree roots and global
child indices) plus one generic walk loop

    node = root[t];
    while (feature[node] >= 0)
      node = (data[feature[node]] <= key[node]) ? left[node] : right[node];

whose only branch is the loop itself — the child select compiles to a
conditional move, so the walk is branch-predictor-friendly and the code
footprint is O(1) in forest size instead of O(total_nodes).

Modes mirror the deterministic pair: ``integer`` (int32 FlInt compares,
uint32 fixed-point adds — bit-identical to every other backend) and ``flint``
(int32 compares, float32 adds in the same per-tree order plus the same
precomputed-reciprocal ensemble average the reference path lowers to).  The
emitted file needs only <stdint.h>.
"""
from __future__ import annotations

import numpy as np

from repro.codegen.c_emitter import _c_float, emit_predict_class

_VALS_PER_LINE = 12


def _i32(v: int) -> str:
    v = int(v)
    # INT32_MIN has no negatable literal form in C; every other value is fine
    return "(-2147483647-1)" if v == -(1 << 31) else str(v)


def _array_lines(name: str, ctype: str, values, fmt) -> list:
    lines = [f"static const {ctype} {name}[{len(values)}] = {{"]
    for i in range(0, len(values), _VALS_PER_LINE):
        chunk = ", ".join(fmt(v) for v in values[i:i + _VALS_PER_LINE])
        lines.append(f"  {chunk},")
    lines.append("};")
    return lines


def emit_table_walk_c(ragged, mode: str = "integer") -> str:
    """Emit a standalone table-walk C file for a ragged ensemble.

    Same entry-point contract as ``c_emitter.emit_c`` — ``predict(data,
    result)`` over FlInt int32 keys plus a comparison-only ``predict_class`` —
    so the shared batch entry (``emit_batch_entry``) and the test harness
    compose with it unchanged.
    """
    assert mode in ("integer", "flint"), (
        "the table walk serves the deterministic integer-compare modes; "
        "float thresholds would reintroduce the FPU the paper removes"
    )
    t, c = ragged.n_trees, ragged.n_classes
    total = ragged.total_nodes
    acc_t = "uint32_t" if mode == "integer" else "float"
    lines = ["#include <stdint.h>", ""]
    lines.append(
        f"/* InTreeger table-walk ensemble ({mode} mode): ragged ForestIR layout\n"
        f"   as static data. trees={t} classes={c} nodes={total}"
        + (f" scale={ragged.scale}" if mode == "integer" else "")
        + " */"
    )
    lines += _array_lines("node_feature", "int32_t", ragged.feature, _i32)
    lines += _array_lines("node_key", "int32_t", ragged.threshold_key, _i32)
    lines += _array_lines("node_left", "int32_t", ragged.left, _i32)
    lines += _array_lines("node_right", "int32_t", ragged.right, _i32)
    if mode == "integer":
        leaf_vals = ragged.leaf_fixed.reshape(-1)
        lines += _array_lines(
            "node_leaf", "uint32_t", leaf_vals, lambda v: f"{int(v)}u"
        )
    else:
        leaf_vals = ragged.leaf_probs.reshape(-1)
        lines += _array_lines("node_leaf", "float", leaf_vals, _c_float)
    lines += _array_lines("tree_root", "int32_t", ragged.roots, _i32)
    lines += [
        "",
        f"void predict(const int32_t* data, {acc_t}* result) {{",
        f"  for (int i = 0; i < {c}; ++i) result[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int32_t node = tree_root[t];",
        "    int32_t f = node_feature[node];",
        "    while (f >= 0) {",
        "      node = (data[f] <= node_key[node]) ? node_left[node]"
        " : node_right[node];",
        "      f = node_feature[node];",
        "    }",
        f"    const {acc_t}* leaf = node_leaf + (long)node * {c};",
        f"    for (int i = 0; i < {c}; ++i) result[i] += leaf[i];",
        "  }",
    ]
    if mode == "flint":
        # same precomputed float32 reciprocal the reference path's `acc / n`
        # lowers to, applied in the same place -> bit-identical averages
        rcp = np.float32(1.0) / np.float32(t)
        lines.append(f"  for (int i = 0; i < {c}; ++i) result[i] *= {_c_float(rcp)};")
    lines += ["}", ""]
    lines += emit_predict_class(c, acc_t, "int32_t")
    return "\n".join(lines)

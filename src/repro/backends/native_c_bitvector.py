"""NativeCBitvectorBackend: the emitted-C QuickScorer bitvector scorer.

The sequential sibling of the jnp ``bitvector`` backend, riding the shared
``CompiledCBackend`` gcc/ctypes machinery: ``codegen/bitvector_emitter``
compiles the bitvector layout's per-feature ascending threshold streams and
false-node leaf masks as static data, and scoring is one linear pass over
sorted keys per feature (first true compare breaks the stream) followed by a
lowest-set-bit scan per tree — no per-row tree traversal at all, which is
where the QuickScorer line of work wins on large-T shallow forests.

Deterministic modes only, and both compile the same integer translation unit
(uint32 partials out, shared numpy finalize), so scores are bit-identical to
every other backend across every execution plan — including multi-word
(>64-leaf) trees, which just widen the per-tree uint64 state.
"""
from __future__ import annotations

from repro.backends.base import BackendCapabilities, register_backend
from repro.backends.native_c import CompiledCBackend


@register_backend
class NativeCBitvectorBackend(CompiledCBackend):
    name = "native_c_bitvector"
    capabilities = BackendCapabilities(
        modes=("flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,
        compiles_per_shape=False,
        supported_layouts=("bitvector",),
        preferred_layout="bitvector",
    )

    def _emit_source(self) -> str:
        from repro.codegen.bitvector_emitter import emit_bitvector_c

        # flint and integer share the integer unit (partials + numpy finalize);
        # the emitter's TU is complete (blocked predict_batch included)
        return emit_bitvector_c(self.packed, mode="integer")

"""The TreeBackend protocol and the name-keyed backend registry.

InTreeger's central claim is that one trained ensemble yields bit-identical
integer-only inference on any hardware.  This module makes that claim an
*interface*: every execution strategy for a :class:`~repro.core.packing.
PackedEnsemble` — the jnp reference walk, the Pallas VMEM-tiled kernel, the
paper's literal emitted C — implements the same surface

    predict_partials(X) -> (B, C) uint32 partial accumulators
    predict_scores(X)   -> (scores, preds)

and declares what it can do via :class:`BackendCapabilities`.  The serving
stack (``repro.serve``) routes per-(model, mode, backend) purely through this
layer — through an execution plan (``repro.plan``) that may carve the forest
into tree shards, call ``predict_partials`` on each, merge the exact integer
partial sums, and run the standalone finalize step once; nothing above a
backend may special-case how inference runs.

``predict_partials`` is the shardable half of inference: for the
deterministic modes (flint/integer) it returns the raw uint32 fixed-point
accumulator, which is associative, so partials of a sub-forest artifact
(``ForestIR.subset``) merge into the full forest's accumulator bit-exactly.
``predict_scores`` is kept as the compatibility wrapper — for deterministic
modes the base class implements it as ``finalize(predict_partials(X))`` with
the one shared :func:`repro.core.ensemble.finalize_partials`, so every
backend's scores are the same function of the same exact integers.

Scores are mode-typed exactly as in ``repro.core.ensemble``: float32 average
probabilities for ``float``/``flint``, uint32 fixed-point class sums for
``integer``.  For the deterministic modes (flint/integer) every backend must
be bit-identical to :class:`~repro.backends.reference.ReferenceBackend` —
the cross-backend conformance suite (``tests/test_backends.py``, ``make
conformance``) enforces this on randomized forests.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import ClassVar, Optional


class BackendUnavailable(RuntimeError):
    """The backend cannot run on this host (e.g. no C toolchain)."""


@dataclass(frozen=True)
class BackendCapabilities:
    """What a backend supports and how the serving layer should drive it.

    modes:               inference modes the backend implements
                         (subset of ``repro.core.ensemble.MODES``).
    deterministic_modes: modes whose scores are bit-exact integers —
                         cacheable by the gateway's QuantizedKeyCache and
                         required to match the reference backend bit-for-bit.
    preferred_block_rows: row-blocking hint.  When set, ``TreeEngine`` uses
                         it as the default ``max_bucket`` so padded batch
                         shapes line up with the backend's internal tiling.
    compiles_per_shape:  True when each padded row bucket costs one compile
                         (jitted backends).  False for shape-oblivious
                         backends (native C), where the engine skips
                         bucket padding entirely.
    supported_layouts:   ForestIR layouts this backend can walk (see
                         ``repro.ir.layouts``).  The node-table backends take
                         ``padded``/``leaf_major`` (same (T, N) surface); the
                         table-walk C backend takes ``ragged``.
    preferred_layout:    the layout the serving layer materializes when the
                         caller does not pin one.  Deterministic-mode scores
                         are bit-identical across layouts, so this is purely
                         a performance/footprint choice.
    """

    modes: tuple
    deterministic_modes: tuple
    preferred_block_rows: Optional[int] = None
    compiles_per_shape: bool = True
    supported_layouts: tuple = ("padded",)
    preferred_layout: str = "padded"

    def require_layout(self, layout: str, backend_name: str) -> None:
        """Fail fast when ``layout`` is not walkable — the ONE validation
        every routing layer (backend ctor, engine, gateway) calls."""
        if layout not in self.supported_layouts:
            raise ValueError(
                f"backend {backend_name!r} cannot walk layout {layout!r}; "
                f"supported layouts: {self.supported_layouts}"
            )


class TreeBackend(abc.ABC):
    """One execution strategy for a materialized forest, fixed to one mode.

    ``packed`` is the layout artifact the backend walks — a
    :class:`~repro.core.packing.PackedEnsemble` for the node-table layouts, a
    :class:`~repro.ir.layouts.RaggedEnsemble` for ``ragged``.  The attribute
    keeps its historical name; every artifact exposes the same metadata
    surface (``n_trees``/``n_classes``/``n_features``/``max_depth``/
    ``scale``/``layout``/``nbytes_*``).
    """

    name: ClassVar[str]
    capabilities: ClassVar[BackendCapabilities]

    def __init__(self, packed, mode: str = "integer"):
        if mode not in self.capabilities.modes:
            raise ValueError(
                f"backend {self.name!r} does not implement mode {mode!r}; "
                f"supported modes: {self.capabilities.modes}"
            )
        self.capabilities.require_layout(getattr(packed, "layout", "padded"),
                                         self.name)
        self.packed = packed
        self.mode = mode

    @property
    def layout(self) -> str:
        """The layout of the artifact this backend was built on."""
        return getattr(self.packed, "layout", "padded")

    @property
    def deterministic(self) -> bool:
        """True when outputs are bit-exact integer scores (cacheable)."""
        return self.mode in self.capabilities.deterministic_modes

    def predict_partials(self, X):
        """Float features (B, F) in -> (B, C) uint32 partial accumulators.

        The shardable half of inference: the raw fixed-point sums *before*
        the finalize step, exact and associative, so a plan can merge them
        across tree shards bit-losslessly.  Defined for the deterministic
        modes; backends serving only non-deterministic modes (float) leave
        this unimplemented.  ``X`` is always in the *float* domain; the
        backend owns its own domain transform (FlInt keying).
        """
        raise NotImplementedError(
            f"backend {self.name!r} does not expose integer partials for "
            f"mode {self.mode!r}"
        )

    def predict_scores(self, X):
        """Float features (B, F) in -> (scores (B, C), preds (B,) int32).

        Compatibility wrapper over the partials/finalize split: for the
        deterministic modes this is ``finalize_partials(predict_partials(X))``
        — one shared numpy finalize, so scores cannot diverge across
        backends.  Backends with non-deterministic modes (float) override.
        """
        from repro.core.ensemble import finalize_partials

        if not self.deterministic:
            raise NotImplementedError(
                f"backend {self.name!r} must override predict_scores for "
                f"the non-deterministic mode {self.mode!r}"
            )
        acc = self.predict_partials(X)
        return finalize_partials(self.mode, acc, self.packed.n_trees,
                                 self.packed.scale)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} mode={self.mode!r}>"


# ---------------------------------------------------------------------------
# name-keyed registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_backend(cls):
    """Class decorator: make ``cls`` constructible via :func:`create_backend`."""
    if not (isinstance(cls, type) and issubclass(cls, TreeBackend)):
        raise TypeError(f"register_backend expects a TreeBackend subclass, got {cls!r}")
    _REGISTRY[cls.name] = cls
    return cls


def available_backends() -> list:
    return sorted(_REGISTRY)


def backend_class(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def create_backend(name: str, packed, *, mode: str = "integer",
                   **kwargs) -> TreeBackend:
    """Instantiate a registered backend by name for one (model, mode).

    ``packed`` must already be materialized in a layout the backend supports
    (see :func:`repro.ir.resolve_artifact`; ``TreeEngine`` does this
    resolution for the serving stack).
    """
    return backend_class(name)(packed, mode, **kwargs)

"""Ensemble inference paths: float baseline, FlInt, and integer-only.

Mirrors the paper's three evaluated implementations (Sec. IV):
  * ``float``   — float32 threshold compares, float32 probability adds
                  (the "naive" Listing 4 baseline),
  * ``flint``   — int32 key compares, float32 probability adds (FlInt [26]),
  * ``integer`` — int32 key compares, uint32 fixed-point adds (InTreeger).

On TPU the if-else cascade becomes a breadth-batched node-table walk: every
example advances one level per step via vectorized gathers; leaves self-loop.
This module is the pure-jnp reference; ``repro.kernels.tree_traverse`` is the
Pallas VMEM-tiled version of the ``integer`` path and must match it exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import fixed_to_prob
from repro.core.flint import float_to_key
from repro.core.packing import PackedEnsemble

MODES = ("float", "flint", "integer")


@dataclass(frozen=True)
class ModeSpec:
    """Everything that distinguishes one inference mode from another.

    The traversal itself (:func:`_predict`) is mode-oblivious; a mode is just
      * ``domain_transform`` — float32 features -> the threshold-compare
        domain (identity for ``float``, FlInt int32 keys otherwise),
      * ``acc_dtype``        — the leaf-accumulator dtype,
      * ``finalize``         — ``(acc, n_trees) -> scores`` (ensemble-average
        for the float-accumulating modes, identity for fixed-point),
      * ``deterministic``    — True when outputs are bit-deterministic given
        the row's FlInt keys (flint/integer), which is what makes gateway
        caching and cross-backend bit-identity sound.
    """

    name: str
    acc_dtype: Any
    domain_transform: Callable
    finalize: Callable
    deterministic: bool


_MODE_SPECS = {
    "float": ModeSpec(
        name="float",
        acc_dtype=jnp.float32,
        domain_transform=lambda x: x,
        finalize=lambda acc, n: acc / n,
        deterministic=False,
    ),
    "flint": ModeSpec(
        name="flint",
        acc_dtype=jnp.float32,
        domain_transform=float_to_key,
        finalize=lambda acc, n: acc / n,
        deterministic=True,
    ),
    "integer": ModeSpec(
        name="integer",
        acc_dtype=jnp.uint32,
        domain_transform=float_to_key,
        finalize=lambda acc, n: acc,
        deterministic=True,
    ),
}


def mode_spec(mode: str) -> ModeSpec:
    try:
        return _MODE_SPECS[mode]
    except KeyError:
        raise ValueError(f"unknown mode {mode!r}; have {MODES}") from None


def ensemble_device_arrays(packed: PackedEnsemble, mode: str) -> dict:
    """The deployment artifact for one mode, as a dict of jnp arrays."""
    mode_spec(mode)  # validate the name
    base = dict(
        feature=jnp.asarray(packed.feature),
        left=jnp.asarray(packed.left),
        right=jnp.asarray(packed.right),
    )
    if mode == "float":
        base["threshold"] = jnp.asarray(packed.threshold)
        base["leaf"] = jnp.asarray(packed.leaf_probs)
    elif mode == "flint":
        base["threshold"] = jnp.asarray(packed.threshold_key)
        base["leaf"] = jnp.asarray(packed.leaf_probs)
    else:
        base["threshold"] = jnp.asarray(packed.threshold_key)
        base["leaf"] = jnp.asarray(packed.leaf_fixed)
    return base


def _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth: int):
    """Walk one tree for a batch.  ``x``: (B, F) in the same domain as thr."""
    b = x.shape[0]
    node0 = jnp.zeros(b, jnp.int32)

    def body(_, node):
        feat = feature_t[node]  # (B,) gather
        thr = thr_t[node]
        xv = jnp.take_along_axis(x, jnp.clip(feat, 0)[:, None], axis=1)[:, 0]
        go_left = xv <= thr  # paper Listing 2 semantics
        # leaves have left == right == self, so they self-loop for free
        return jnp.where(go_left, left_t[node], right_t[node])

    return jax.lax.fori_loop(0, depth, body, node0)


@partial(jax.jit, static_argnames=("depth", "acc_dtype"))
def _predict(arrays, x, depth: int, acc_dtype):
    b = x.shape[0]
    c = arrays["leaf"].shape[-1]
    acc0 = jnp.zeros((b, c), acc_dtype)

    def per_tree(acc, tree):
        feature_t, thr_t, left_t, right_t, leaf_t = tree
        node = _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth)
        return acc + leaf_t[node].astype(acc_dtype), None

    acc, _ = jax.lax.scan(
        per_tree,
        acc0,
        (
            arrays["feature"],
            arrays["threshold"],
            arrays["left"],
            arrays["right"],
            arrays["leaf"],
        ),
    )
    return acc


def predict_mode(packed: PackedEnsemble, X, mode: str, arrays=None):
    """The one parametrized inference path: ``(scores, preds)`` for any mode.

    ``float``/``flint`` scores are float32 ensemble-average probabilities;
    ``integer`` scores are the raw uint32 fixed-point sums (overflow-free by
    construction: each tree contributes < scale = floor((2**32-1)/n) and
    there are n trees).
    """
    spec = mode_spec(mode)
    if arrays is None:
        arrays = ensemble_device_arrays(packed, mode)
    dom = spec.domain_transform(jnp.asarray(X, jnp.float32))
    acc = _predict(arrays, dom, packed.max_depth, spec.acc_dtype)
    scores = spec.finalize(acc, packed.n_trees)
    return scores, jnp.argmax(scores, axis=1).astype(jnp.int32)


def predict_float(packed: PackedEnsemble, X, arrays=None):
    """float32 path.  Returns (probs f32 (B,C), preds int32)."""
    return predict_mode(packed, X, "float", arrays)


def predict_flint(packed: PackedEnsemble, X, arrays=None):
    """FlInt path: integer compares, float prob accumulation."""
    return predict_mode(packed, X, "flint", arrays)


def predict_integer(packed: PackedEnsemble, X, arrays=None):
    """InTreeger path: integer compares + uint32 fixed-point accumulation."""
    return predict_mode(packed, X, "integer", arrays)


def integer_probs(packed: PackedEnsemble, acc):
    """Reconstruct ensemble-average probabilities from the uint32 scores."""
    return fixed_to_prob(acc, packed.n_trees)


def make_predict_fn(packed: PackedEnsemble, mode: str):
    """Close over device arrays; return a jitted X -> (scores, preds) fn."""
    spec = mode_spec(mode)
    arrays = ensemble_device_arrays(packed, mode)
    depth = packed.max_depth
    n = packed.n_trees

    def fn(x):
        dom = spec.domain_transform(jnp.asarray(x, jnp.float32))
        acc = _predict(arrays, dom, depth, spec.acc_dtype)
        scores = spec.finalize(acc, n)
        return scores, jnp.argmax(scores, axis=1).astype(jnp.int32)

    return jax.jit(fn)

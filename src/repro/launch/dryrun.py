import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture x input-shape) cell on the production
meshes — 16x16 single pod and 2x16x16 multi-pod — and records
memory_analysis / cost_analysis / collective-bytes to JSON for the roofline
table (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init, and only the dry-run may see 512 placeholder
host devices (smoke tests and benches see 1).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out benchmarks/artifacts/dryrun
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, get_config
from repro.launch import jaxpr_cost
from repro.launch import specs as sp
from repro.launch.hlo_analysis import collective_bytes, flops_and_bytes, memory_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, terms
from repro.launch.shapes import SHAPES, applicable_shapes
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train.step import make_train_step


def lower_cell(arch: str, shape_name: str, mesh):
    """Build abstract args and lower the right step function for the cell.

    Returns (lowered, jaxpr_cost_dict) — the jaxpr walk supplies the
    trip-count-aware global flops/bytes (see jaxpr_cost.py).
    """
    cfg = get_config(arch)
    if cfg.family == "trees":
        tables = sp.tree_table_specs(cfg, mesh)
        x = sp.tree_input_specs(cfg, shape_name, mesh)
        from repro.core.serving import tree_serve_step

        depth = cfg.tree_depth

        def serve_trees(tables, x_keys):
            return tree_serve_step(tables, x_keys, depth)

        jc = jaxpr_cost.analyze(serve_trees, tables, x)
        return jax.jit(serve_trees).lower(tables, x), jc

    mode = SHAPES[shape_name]["mode"]
    params = sp.params_specs(cfg, mesh)
    if mode == "train":
        batch = sp.batch_specs(cfg, shape_name, mesh, with_labels=True)
        ostate = sp.opt_state_specs(cfg, mesh)
        step = make_train_step(cfg, opt.AdamWConfig())
        out_sh = (
            jax.tree.map(lambda s: s.sharding, params),
            jax.tree.map(lambda s: s.sharding, ostate),
            None,
        )
        jc = jaxpr_cost.analyze(step, params, ostate, batch)
        return (
            jax.jit(step, donate_argnums=(0, 1), out_shardings=out_sh).lower(
                params, ostate, batch
            ),
            jc,
        )
    if mode == "prefill":
        batch = sp.batch_specs(cfg, shape_name, mesh, with_labels=False)
        if cfg.encoder_only:
            fn = lambda p, b: tfm.forward_logits(cfg, p, b)
            jc = jaxpr_cost.analyze(fn, params, batch)
            return jax.jit(fn).lower(params, batch), jc
        seq = SHAPES[shape_name]["seq"]
        fn = lambda p, b: tfm.prefill(cfg, p, b, max_seq=seq)
        jc = jaxpr_cost.analyze(fn, params, batch)
        return jax.jit(fn).lower(params, batch), jc
    # decode
    cache, (b, s) = sp.cache_specs(cfg, shape_name, mesh)
    tokens = sp.decode_token_specs(cfg, shape_name, mesh)
    fn = lambda p, c, t: tfm.decode_step(cfg, p, c, t)
    cache_sh = jax.tree.map(lambda x: x.sharding, cache)
    jc = jaxpr_cost.analyze(fn, params, cache, tokens)
    return (
        jax.jit(fn, donate_argnums=(1,), out_shardings=(None, cache_sh)).lower(
            params, cache, tokens
        ),
        jc,
    )


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": 512 if multi_pod else 256,
        "ok": False,
    }
    from repro.sharding.ops import use_mesh

    t0 = time.time()
    try:
        with mesh, use_mesh(mesh):
            lowered, jc = lower_cell(arch, shape_name, mesh)
            rec["jaxpr_cost"] = jc
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            rec["cost_xla_reference"] = flops_and_bytes(compiled)
            rec["memory"] = memory_stats(compiled)
            rec["collectives"] = collective_bytes(compiled.as_text())
            rec["model_flops"] = model_flops(cfg, shape_name)
            rec["roofline"] = terms(rec)
            rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        out_dir = pathlib.Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        fn = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
        fn.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            out = pathlib.Path(args.out) / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("ok"):
                    print(f"[skip] {arch} {shape} {mesh_name}")
                    continue
            rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
            if rec["ok"]:
                r = rec["roofline"]
                print(
                    f"[ok]  {arch:20s} {shape:12s} {mesh_name:8s} "
                    f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                    f"dom={r['dominant']:10s} "
                    f"c/m/x={r['compute_s']:.2e}/{r['memory_s']:.2e}/{r['collective_s']:.2e}s "
                    f"useful={r['useful_ratio']:.2f}",
                    flush=True,
                )
            else:
                failures += 1
                print(f"[FAIL] {arch} {shape} {mesh_name}: {rec['error']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Quickstart: the InTreeger pipeline end-to-end in ~60 lines.

dataset -> random forest -> ForestIR (quantized once) -> layout
materializations (padded / ragged / leaf_major) -> three inference paths
(float / FlInt / InTreeger) and layout-pinned serving engines -> identical
predictions + the emitted integer-only C file (the paper's deliverable).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.codegen.c_emitter import emit_c
from repro.core.ensemble import predict_flint, predict_float, predict_integer
from repro.core.fixedpoint import fixed_to_prob_np
from repro.core.packing import pack_forest
from repro.data.tabular import make_shuttle_like, train_test_split
from repro.trees.forest import RandomForestClassifier

# 1. train on a Shuttle-like dataset (58k x 7, 7 classes, paper Sec. IV-A)
X, y = make_shuttle_like(n=20000, seed=0)
Xtr, ytr, Xte, yte = train_test_split(X, y)
rf = RandomForestClassifier(n_estimators=25, max_depth=7, seed=0).fit(Xtr, ytr)
print(f"forest accuracy: {(rf.predict(Xte) == yte).mean():.4f}")

# 2. pack to the integer-only deployment artifact (FlInt keys + 2^32/n probs)
packed = pack_forest(rf)
print(f"packed: {packed.n_trees} trees, scale={packed.scale}, "
      f"{packed.nbytes_integer()/1e3:.1f} kB")

# 3. three inference paths — predictions must be identical (paper Sec. IV-B)
probs_f, pred_f = predict_float(packed, Xte)
_, pred_fl = predict_flint(packed, Xte)
acc_u32, pred_i = predict_integer(packed, Xte)
assert (np.asarray(pred_f) == np.asarray(pred_fl)).all()
assert (np.asarray(pred_f) == np.asarray(pred_i)).all()
print("float == flint == integer predictions on every test row")

# 4. fixed-point probabilities are within n/2^32 of the float64 oracle
delta = np.abs(fixed_to_prob_np(np.asarray(acc_u32), packed.n_trees)
               - rf.predict_proba(Xte)).max()
print(f"max probability delta vs oracle: {delta:.2e}  (paper Fig. 2: ~1e-9)")

# 5. the packed tables are one *layout* of the canonical ForestIR; every
#    other registered layout materializes from the same quantization
ir = packed.ir
sizes = ir.nbytes_by_layout(mode="integer")
print("layouts:", ", ".join(f"{k}={v/1e3:.1f}kB" for k, v in sorted(sizes.items())))

# 6. layout selection end-to-end: the engine materializes whatever layout
#    the backend prefers (or the one you pin) — scores stay bit-identical
from repro.backends import have_c_toolchain
from repro.serve.engine import TreeEngine

eng_padded = TreeEngine(ir, mode="integer")                       # padded
eng_lm = TreeEngine(ir, mode="integer", layout="leaf_major")      # pinned
engines = {"reference/padded": eng_padded, "reference/leaf_major": eng_lm}
# the layout-specialized Pallas route: leaf_major tables + the linear-scan
# kernel (pallas resolves impl="auto" to the scan on its preferred layout)
engines["pallas/leaf_major"] = TreeEngine(ir, mode="integer",
                                          backend="pallas", layout="leaf_major")
if have_c_toolchain():
    # table-walk C over the ragged layout, row-blocked: 8 register-resident
    # walk chains per tree (block_rows=1 would be the scalar walk)
    engines["native_c_table/ragged"] = TreeEngine(
        ir, mode="integer", backend="native_c_table",
        backend_kwargs={"block_rows": 8})
# sharded execution plans: carve the forest into tree-contiguous sub-forests
# (ForestIR.subset) or split the batch — the uint32 accumulator is an exact
# associative sum, so merged partial scores are bit-identical to single-shard
engines["plan/tree_parallel(4)"] = TreeEngine(ir, mode="integer",
                                              plan="tree_parallel", shards=4)
engines["plan/row_parallel(2)"] = TreeEngine(ir, mode="integer",
                                             plan="row_parallel", shards=2)
s_ref, _ = eng_padded.predict_scores(Xte[:256])
for name, eng in engines.items():
    s, _ = eng.predict_scores(Xte[:256])
    assert (np.asarray(s) == np.asarray(s_ref)).all(), name
print(f"bit-identical uint32 scores across {len(engines)} "
      "(backend, layout, plan) routes:", ", ".join(sorted(engines)))

# 7. the paper's deliverable: freestanding integer-only C
c_src = emit_c(packed, mode="integer")
open("/tmp/intreeger_model.c", "w").write(c_src)
print(f"emitted integer-only C ({len(c_src.splitlines())} lines) "
      "-> /tmp/intreeger_model.c  (gcc-compilable, no FPU needed)")

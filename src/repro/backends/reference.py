"""ReferenceBackend: the pure-jnp breadth-batched node-table walk.

This is the semantic oracle: one jitted predict per (model, mode), built from
the shared mode spec in ``repro.core.ensemble``.  Every other backend's
flint/integer output is defined as "bit-identical to this".
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.backends.base import BackendCapabilities, TreeBackend, register_backend
from repro.core.ensemble import MODES, make_predict_fn
from repro.core.packing import PackedEnsemble


@register_backend
class ReferenceBackend(TreeBackend):
    name = "reference"
    capabilities = BackendCapabilities(
        modes=MODES,
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,  # any padded shape is fine
        compiles_per_shape=True,
        # the jnp walk gathers by node index over (T, N) tables, so any
        # node-table layout works; node order cannot perturb scores
        supported_layouts=("padded", "leaf_major"),
        preferred_layout="padded",
    )

    def __init__(self, packed: PackedEnsemble, mode: str = "integer"):
        super().__init__(packed, mode)
        self._fn = make_predict_fn(packed, mode)

    def predict_scores(self, X):
        return self._fn(jnp.asarray(X, jnp.float32))

# The paper's primary contribution: integer-only tree-ensemble inference.
#   flint.py      — order-preserving float32<->int32 key transform (Sec. II-D)
#   fixedpoint.py — 2^32/n fixed-point probability conversion (Sec. III-A)
#   packing.py    — ensemble -> dense node tables (TPU analogue of codegen)
#   ensemble.py   — float / flint / integer inference paths (pure jnp)
from repro.core.ensemble import (
    MODES,
    ModeSpec,
    ensemble_device_arrays,
    finalize_partials,
    flint_recip,
    integer_probs,
    make_partials_fn,
    make_predict_fn,
    mode_spec,
    predict_flint,
    predict_float,
    predict_integer,
    predict_mode,
    predict_partials_mode,
)
from repro.core.fixedpoint import fixed_to_prob, max_abs_error, prob_to_fixed_np, scale_for
from repro.core.flint import float_to_key, float_to_key_np, key_to_float, key_to_float_np
from repro.core.packing import PackedEnsemble, pack_forest

__all__ = [
    "MODES",
    "ModeSpec",
    "ensemble_device_arrays",
    "finalize_partials",
    "flint_recip",
    "integer_probs",
    "make_partials_fn",
    "make_predict_fn",
    "mode_spec",
    "predict_mode",
    "predict_partials_mode",
    "predict_flint",
    "predict_float",
    "predict_integer",
    "fixed_to_prob",
    "max_abs_error",
    "prob_to_fixed_np",
    "scale_for",
    "float_to_key",
    "float_to_key_np",
    "key_to_float",
    "key_to_float_np",
    "PackedEnsemble",
    "pack_forest",
]

"""Trip-count-aware analytic cost model over closed jaxprs.

Why: XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE,
ignoring the trip count (verified empirically: a scan of 10 matmuls reports
the flops of one).  Every model here scans its layers, so XLA's number would
undercount by ~L.  This module walks the jaxpr instead, multiplying scan
bodies by their static length — exact *global* FLOPs for the roofline
compute term.

Byte accounting gives a *perfect-fusion lower bound* for HBM traffic: only
contraction operands/results, gather/scatter traffic, reduce inputs, and the
function boundary are counted; elementwise chains are assumed fused (free).
Additionally, a dot operand that is itself derived from an earlier dot output
(transitively through elementwise ops) is treated as on-chip — this models a
flash-attention/fused-SSD kernel where scores/probabilities never round-trip
to HBM.  This is the optimistic roofline — the memory term can only be worse
on a real chip, so reported roofline fractions are conservative.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np
from jax import core


def _size_bytes(aval) -> int:
    try:
        return int(math.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # tokens / abstract types
        return 0


def _numel(aval) -> int:
    try:
        return int(math.prod(aval.shape))
    except Exception:
        return 0


_TRANSCENDENTAL = {
    "exp", "log", "log1p", "expm1", "tanh", "sin", "cos", "erf", "erfc",
    "logistic", "rsqrt", "sqrt", "pow", "cbrt", "exp2",
}

_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")


@dataclass
class Cost:
    flops: float = 0.0
    bytes_lb: float = 0.0  # perfect-fusion HBM traffic lower bound
    transcendentals: float = 0.0
    collective_hints: dict = field(default_factory=dict)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes_lb * k, self.transcendentals * k)

    def add(self, other: "Cost"):
        self.flops += other.flops
        self.bytes_lb += other.bytes_lb
        self.transcendentals += other.transcendentals


def _dot_cost(eqn, derived) -> Cost:
    d = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = d
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    lhs_free = _numel(lhs) // max(batch * contract, 1)
    rhs_free = _numel(rhs) // max(batch * contract, 1)
    flops = 2.0 * batch * contract * lhs_free * rhs_free
    nbytes = sum(
        _size_bytes(v.aval)
        for v in eqn.invars
        if not (hasattr(v, "count") and v in derived)  # on-chip if dot-derived
    )
    # dot outputs assumed consumed fused (flash-style); not counted
    return Cost(flops=flops, bytes_lb=nbytes)


def _conv_cost(eqn) -> Cost:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    # flops ~= 2 * out_numel * (kernel elems per output channel)
    kernel_per_out = _numel(rhs) // max(rhs.shape[eqn.params["dimension_numbers"].rhs_spec[0]], 1)
    flops = 2.0 * _numel(out) * kernel_per_out
    nbytes = sum(_size_bytes(v.aval) for v in eqn.invars) + _size_bytes(out)
    return Cost(flops=flops, bytes_lb=nbytes)


def _jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    derived = set()  # vars that can live on-chip (dot outputs + elementwise of)

    def mark_derived(eqn):
        for v in eqn.outvars:
            if hasattr(v, "count"):
                derived.add(v)

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total.add(_dot_cost(eqn, derived))
            mark_derived(eqn)
            continue
        if prim == "conv_general_dilated":
            total.add(_conv_cost(eqn))
            continue
        if prim == "scan":
            inner = _jaxpr_cost(eqn.params["jaxpr"].jaxpr)
            length = eqn.params["length"]
            total.add(inner.scaled(length))
            continue
        if prim == "while":
            # unbounded in jaxpr; all our loops are scans/fori with static
            # bounds (lowered to scan) — count once and flag
            inner = _jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)
            total.add(inner)
            total.collective_hints["unbounded_while"] = (
                total.collective_hints.get("unbounded_while", 0) + 1
            )
            continue
        if prim == "cond":
            branches = eqn.params["branches"]
            costs = [_jaxpr_cost(b.jaxpr) for b in branches]
            worst = max(costs, key=lambda c: c.flops + c.bytes_lb)
            total.add(worst)
            continue
        if prim == "shard_map":
            # body shapes are per-shard; every device runs the body
            inner_jaxpr = eqn.params["jaxpr"]
            inner_jaxpr = inner_jaxpr.jaxpr if hasattr(inner_jaxpr, "jaxpr") else inner_jaxpr
            inner = _jaxpr_cost(inner_jaxpr)
            mesh = eqn.params.get("mesh")
            size = getattr(mesh, "size", None) or math.prod(
                getattr(mesh, "shape", {}).values() or [1]
            )
            total.add(inner.scaled(size))
            continue
        if prim in ("sharding_constraint", "copy", "broadcast_in_dim", "transpose", "reshape"):
            continue  # layout/annotation ops: no flops, fusable traffic
        handled_sub = False
        for pname in _SUBJAXPR_PARAMS:
            if pname in eqn.params:
                sub = eqn.params[pname]
                sub = sub.jaxpr if hasattr(sub, "jaxpr") else sub
                total.add(_jaxpr_cost(sub))
                handled_sub = True
                break
        if handled_sub:
            continue
        out_elems = sum(_numel(v.aval) for v in eqn.outvars)
        if prim in ("gather", "dynamic_slice"):
            total.bytes_lb += sum(_size_bytes(v.aval) for v in eqn.outvars) * 2
            continue
        if prim in ("scatter", "scatter-add", "scatter_add", "dynamic_update_slice"):
            upd = eqn.invars[-1].aval if eqn.invars else eqn.outvars[0].aval
            total.bytes_lb += _size_bytes(upd) * 2
            continue
        if prim.startswith("reduce") or prim in ("argmax", "argmin", "cumsum", "cumlogsumexp"):
            total.bytes_lb += sum(
                _size_bytes(v.aval)
                for v in eqn.invars
                if not (hasattr(v, "count") and v in derived)
            )
            total.flops += sum(_numel(v.aval) for v in eqn.invars)
            if any(hasattr(v, "count") and v in derived for v in eqn.invars):
                mark_derived(eqn)
            continue
        if prim in _TRANSCENDENTAL:
            total.transcendentals += out_elems
            total.flops += out_elems
            if any(hasattr(v, "count") and v in derived for v in eqn.invars):
                mark_derived(eqn)
            continue
        # generic elementwise / data movement: 1 flop per output element,
        # traffic assumed fused away (lower bound)
        total.flops += out_elems
        if any(hasattr(v, "count") and v in derived for v in eqn.invars):
            mark_derived(eqn)
    return total


def analyze(fn, *abstract_args) -> dict:
    """Trace ``fn`` with abstract args; return global flops/bytes costs."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    cost = _jaxpr_cost(closed.jaxpr)
    boundary = sum(_size_bytes(v.aval) for v in closed.jaxpr.invars) + sum(
        _size_bytes(v.aval) for v in closed.jaxpr.outvars
    )
    return {
        "flops": cost.flops,
        "bytes_lb": cost.bytes_lb + boundary,
        "transcendentals": cost.transcendentals,
        "flags": cost.collective_hints,
    }

"""The paper's fixed-point math applied to distributed training: integer
all-reduce demo on 8 placeholder devices.

Shows (1) the error stays within the paper-style bound, (2) the integer
reduction is bit-deterministic regardless of reduction order, while float
psum results depend on operand order.

    PYTHONPATH=src python examples/integer_allreduce_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import compat_make_mesh
from repro.sharding.ops import compat_shard_map
from repro.train.intreeger_allreduce import integer_psum, quantization_error_bound

mesh = compat_make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = rng.normal(size=(8, 4096)).astype(np.float32)  # 8 replicas' gradients

int_sum = compat_shard_map(
    lambda x: integer_psum(x, "data", 8), mesh=mesh,
    in_specs=P("data"), out_specs=P("data"),
)(g)
int_sum = np.asarray(int_sum).reshape(8, -1)[0]

exact = g.astype(np.float64).sum(axis=0)
bound = quantization_error_bound(8, float(np.abs(g).max()))
err = np.abs(int_sum - exact).max()
print(f"integer psum max error: {err:.3e}  (bound {bound:.3e})")
assert err <= bound * 1.01

# order-independence: permuting the replicas changes float sums, not integer
float_sums = {tuple(p): g[list(p)].astype(np.float32).sum(axis=0) for p in
              [(0, 1, 2, 3, 4, 5, 6, 7), (7, 3, 1, 5, 0, 6, 2, 4)]}
a, b = float_sums.values()
print(f"float32 order-dependent deltas: {np.abs(a - b).max():.3e}")
print("integer fixed-point accumulation is exactly order-independent "
      "(int addition is associative) -> bit-reproducible at any pod count")

# One-step entry points for the repo's standard workflows.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-fast conformance check bench bench-smoke ci obs \
	obs-artifacts worker-fleet artifact-check serve-trees serve-gateway

# tier-1 verify (see ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# tier-1 minus the long end-to-end drivers (the `slow` marker) — what the
# CI tier-1 job runs; `make check` still runs everything
test-fast:
	$(PY) -m pytest -q -m "not slow"

# the observability suite alone: histograms, tracer, span integrity
# through the gateway, exposition renderers
obs:
	$(PY) -m pytest -q tests/test_obs.py

# short fully-traced gateway run -> sample trace JSONL + metrics snapshot
# (Prometheus text + JSON) under benchmarks/artifacts/, uploaded by CI
obs-artifacts:
	mkdir -p benchmarks/artifacts
	$(PY) -m repro.launch.serve --trees --gateway --rows 2000 \
		--gw-requests 80 --gw-rate 1000 \
		--gw-trace-out benchmarks/artifacts/trace_sample.jsonl \
		--gw-metrics-out benchmarks/artifacts/metrics_snapshot.prom

# cross-(backend, layout, variant, plan) bit-identity suite: reference /
# pallas (gather + leaf_major linear scan) / native_c / native_c_table
# (block_rows 1/4/8) / native_c_bitvector (interleave widths K=1/4/8)
# x padded / ragged / leaf_major / bitvector x {single,
# tree_parallel(2,3,8), row_parallel(2,4)}.  XLA is forced to 8 host
# devices so the tree-parallel shard_map path runs for real (the same
# configuration CI uses) — without the flag those cases fall back to the
# threaded per-shard-backend path, which must be bit-identical anyway.
conformance:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PY) -m pytest -q tests/test_backends.py tests/test_plans.py

# the full gate: tier-1 tests, then the conformance suite standalone
check: test conformance

bench:
	$(PY) benchmarks/run.py

# tiny-forest bench pass: proves every backend and every execution plan
# executes (plan_scaling runs the shard_map tree-parallel path on 8 forced
# host devices) and produces the benchmarks/artifacts/bench_results.json
# artifact CI uploads
bench-smoke:
	REPRO_BENCH_TINY=1 REPRO_BENCH_DEVICES=8 \
		REPRO_BENCH_SNAPSHOT=BENCH_10.json \
		$(PY) benchmarks/run.py backend_matrix backend_bitvector \
		memory_footprint plan_scaling remote_scaleout coldstart_swap

# the remote-worker fabric suite: spawns loopback worker processes, runs
# the cross-process conformance + kill/re-dispatch tests, and (via
# REPRO_WORKER_SPAN_DIR) collects worker-side span JSONL under
# benchmarks/artifacts/ for the CI artifact upload
worker-fleet:
	mkdir -p benchmarks/artifacts
	REPRO_WORKER_SPAN_DIR=benchmarks/artifacts \
		$(PY) -m pytest -q tests/test_remote.py tests/test_spec.py

# ITRF artifact gate: the pytest artifact suite (round-trip bit-identity,
# mmap safety, registry retention, tune-db persistence), then the converter
# selftest, which trains a forest, converts it, and reloads the .itrf in a
# FRESH process via mmap asserting bit-identical reference partials.  Leaves
# benchmarks/artifacts/model.itrf for the CI artifact upload.
artifact-check:
	mkdir -p benchmarks/artifacts
	$(PY) -m pytest -q tests/test_artifact.py
	$(PY) -m repro.trees.convert --selftest benchmarks/artifacts/model.itrf
	$(PY) -m repro.trees.convert --inspect benchmarks/artifacts/model.itrf

# exactly what .github/workflows/ci.yml runs, as one local target
ci: test-fast conformance bench-smoke worker-fleet artifact-check

serve-trees:
	$(PY) -m repro.launch.serve --trees

serve-gateway:
	$(PY) -m repro.launch.serve --trees --gateway

"""Trace + metrics exposition: JSONL spans, flame summaries, Prometheus text.

Three consumers, three renderers over the same data:

  * machines replaying a request → :func:`spans_to_jsonl` /
    :func:`write_jsonl` (one span object per line, trace/span/parent ids
    preserved) and :func:`request_trees` (per-request nested dicts with the
    shared batch-execution subtree grafted under every request that rode it);
  * humans at a terminal → :func:`render_flame`, a flame-graph-style rollup
    (span paths aggregated by name, counts + total/mean ms, indented by
    depth);
  * scrapers → :func:`render_prometheus` over ``MetricsRegistry.stats()``
    output (counters, gauges, and *cumulative* histogram buckets in the
    Prometheus text exposition format) plus :func:`snapshot_json`, the same
    stats as strict JSON (NaN/Inf sanitized to null, numpy scalars coerced).
"""
from __future__ import annotations

import json
import math

__all__ = ["spans_to_jsonl", "write_jsonl", "request_trees", "render_flame",
           "render_prometheus", "snapshot_json"]


# ---------------------------------------------------------------------------
# span export
# ---------------------------------------------------------------------------

def spans_to_jsonl(spans) -> str:
    """One JSON object per completed span, one span per line."""
    return "\n".join(json.dumps(_sanitize(s.to_dict())) for s in spans)


def write_jsonl(spans, path) -> int:
    """Write the JSONL trace to ``path``; returns the span count."""
    spans = list(spans)
    with open(path, "w") as f:
        f.write(spans_to_jsonl(spans))
        if spans:
            f.write("\n")
    return len(spans)


def _children_index(spans):
    """(by_id, children) where ``children[pid]`` lists direct child spans
    plus batch spans adopted via their ``riders`` attr (the shared
    micro-batch execution subtree belongs to every request that rode it)."""
    by_id = {s.span_id: s for s in spans}
    children: dict = {}
    for s in spans:
        if s.parent_id:
            children.setdefault(s.parent_id, []).append(s)
        for rider in s.attrs.get("riders", ()):
            if rider != s.parent_id and rider in by_id:
                children.setdefault(rider, []).append(s)
    for sibs in children.values():
        sibs.sort(key=lambda s: s.t0)
    return by_id, children


def request_trees(spans, root_name: str = "request") -> list:
    """Per-request nested span trees (dicts), batch subtrees grafted under
    each rider."""
    _, children = _children_index(spans)

    def tree(s):
        return {
            "name": s.name,
            "span": s.span_id,
            "dur_ms": s.duration_ms,
            "attrs": _sanitize({k: v for k, v in s.attrs.items() if k != "riders"}),
            "children": [tree(c) for c in children.get(s.span_id, ())],
        }

    return [tree(s) for s in sorted(spans, key=lambda s: s.t0)
            if s.name == root_name]


def render_flame(spans, *, min_ms: float = 0.0) -> str:
    """Flame-style rollup: spans aggregated by their name-path, indented by
    depth, with call counts and total/mean wall ms.  Shard children of one
    batch overlap in time, so a level's totals may exceed its parent's —
    that overlap is the parallelism the plan bought."""
    by_id, children = _children_index(spans)

    # paths from each root; adoption means a span can appear on several paths
    agg: dict = {}  # path tuple -> [count, total_ns]
    roots = [s for s in spans if not s.parent_id or s.parent_id not in by_id]

    def walk(s, prefix):
        path = prefix + (s.name,)
        ent = agg.setdefault(path, [0, 0])
        ent[0] += 1
        ent[1] += (s.t1 or s.t0) - s.t0
        for c in children.get(s.span_id, ()):
            if c.parent_id == s.span_id or s.span_id in c.attrs.get("riders", ()):
                walk(c, path)

    for r in roots:
        walk(r, ())
    lines = [f"{'span':42s} {'count':>8s} {'total_ms':>12s} {'mean_ms':>10s}"]
    lines.append("-" * len(lines[0]))
    for path in sorted(agg):
        count, ns = agg[path]
        ms = ns / 1e6
        if ms < min_ms:
            continue
        label = "  " * (len(path) - 1) + path[-1]
        lines.append(f"{label:42s} {count:8d} {ms:12.3f} {ms / count:10.4f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# metrics exposition
# ---------------------------------------------------------------------------

def _sanitize(obj):
    """Strict-JSON coercion: NaN/Inf -> None, numpy scalars -> python."""
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, float):
        return None if (math.isnan(obj) or math.isinf(obj)) else obj
    if hasattr(obj, "item"):  # numpy scalar
        return _sanitize(obj.item())
    return obj


def snapshot_json(stats: dict, **meta) -> str:
    """The stats dict as strict JSON (scrape-safe: no NaN/Infinity tokens)."""
    return json.dumps(_sanitize({**meta, "stats": stats}), indent=2,
                      allow_nan=False) + "\n"


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, float):
        if math.isnan(v):
            return "NaN"
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


def _hist_lines(metric: str, labels: str, snap: dict) -> list:
    """Prometheus cumulative histogram series from a LogHistogram snapshot
    (whose buckets are per-bucket counts with ``None`` = +Inf edge)."""
    lines, cum = [], 0
    for le, c in snap.get("buckets", ()):
        cum += c
        edge = "+Inf" if le is None else repr(float(le))
        lines.append(f'{metric}_bucket{{{labels},le="{edge}"}} {cum}')
    if not snap.get("buckets") or snap["buckets"][-1][0] is not None:
        lines.append(f'{metric}_bucket{{{labels},le="+Inf"}} {snap["count"]}')
    lines.append(f"{metric}_sum{{{labels}}} {_fmt(float(snap['sum']))}")
    lines.append(f"{metric}_count{{{labels}}} {snap['count']}")
    return lines


_COUNTERS = (
    ("requests_total", "requests", "requests served"),
    ("hit_requests_total", "hit_requests", "requests served entirely from cache"),
    ("rows_total", "rows", "rows served"),
    ("rejected_total", "rejected", "requests rejected by admission control"),
    ("batches_total", "batches", "engine batch dispatches"),
    ("cache_hits_total", "cache_hits", "row cache hits"),
)
_GAUGES = (
    ("rows_per_s", "rows_per_s", "serving throughput over the active span"),
    ("batch_occupancy", "batch_occupancy", "mean real rows per engine dispatch"),
    ("pad_efficiency", "pad_efficiency", "real rows / bucket-padded rows"),
    ("cache_hit_rate", "cache_hit_rate", "row cache hit rate"),
)


def render_prometheus(per_model: dict, *, namespace: str = "repro") -> str:
    """``MetricsRegistry.stats()`` -> Prometheus text exposition format.

    Emits per-model counters and gauges, the request-latency histogram, one
    ``stage_ms`` histogram per pipeline stage (queue / pad / shard / merge /
    finalize / ...), per-shard cumulative wall ms, and per-bucket
    compile/warm times.
    """
    out = []

    def head(metric, mtype, help_):
        out.append(f"# HELP {namespace}_{metric} {help_}")
        out.append(f"# TYPE {namespace}_{metric} {mtype}")

    for metric, key, help_ in _COUNTERS:
        head(metric, "counter", help_)
        for mid, s in per_model.items():
            out.append(f'{namespace}_{metric}{{model="{mid}"}} {int(s[key])}')
    for metric, key, help_ in _GAUGES:
        head(metric, "gauge", help_)
        for mid, s in per_model.items():
            v = s[key]
            if isinstance(v, float) and math.isnan(v):
                continue
            out.append(f'{namespace}_{metric}{{model="{mid}"}} {_fmt(float(v))}')

    head("request_latency_ms", "histogram", "end-to-end request latency")
    for mid, s in per_model.items():
        if "latency" in s:
            out.extend(_hist_lines(f"{namespace}_request_latency_ms",
                                   f'model="{mid}"', s["latency"]))
    head("stage_ms", "histogram", "per-stage wall time within a request")
    for mid, s in per_model.items():
        for stage, snap in sorted(s.get("stages", {}).items()):
            out.extend(_hist_lines(f"{namespace}_stage_ms",
                                   f'model="{mid}",stage="{stage}"', snap))

    head("shard_ms_total", "counter", "cumulative per-shard execution wall ms")
    head("shard_calls_total", "counter", "per-shard execution calls")
    for mid, s in per_model.items():
        for label, sh in s.get("shards", {}).items():
            lbl = f'model="{mid}",shard="{label}"'
            out.append(f'{namespace}_shard_ms_total{{{lbl}}} {_fmt(float(sh["ms_total"]))}')
            out.append(f'{namespace}_shard_calls_total{{{lbl}}} {int(sh["calls"])}')

    head("bucket_compile_ms", "gauge",
         "compile/warm wall ms of each padded row bucket")
    for mid, s in per_model.items():
        # buckets are int row counts plus the autotuner's "tune" entry
        for bucket, ms in sorted(s.get("compile_ms_by_bucket", {}).items(),
                                 key=lambda kv: str(kv[0])):
            out.append(f'{namespace}_bucket_compile_ms'
                       f'{{model="{mid}",bucket="{bucket}"}} {_fmt(float(ms))}')
    return "\n".join(out) + "\n"

"""granite-34b [dense]: llama-arch code model, MQA.  [arXiv:2405.04324; hf]

88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    microbatches=16,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="granite-34b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)

"""Runnable serving driver.

Three modes, matching the paper's end-to-end story adapted to a serving stack:
  * ``--trees``: train an RF on a synthetic Shuttle-like dataset, quantize it
    into the ForestIR, and serve batched predictions through the three modes
    (float / flint / integer), every execution backend (reference jnp, Pallas
    kernel, if-else C, table-walk C) and multiple ForestIR layouts (padded /
    leaf_major / ragged), reporting agreement and latency — the InTreeger
    pipeline as a service.
  * ``--trees --gateway``: the async serving gateway end-to-end.  Trains
    several forests, registers them in a versioned ``ModelRegistry`` (one via
    the trees/io JSON artifact boundary), then replays a simulated-client
    workload — Poisson arrivals, mixed 1..16-row requests, a hot key pool so
    repeated FlInt-quantized keys exercise the response cache, and a mid-run
    hot-swap of one model to a new version.  Requests flow
    ``Gateway.submit → QuantizedKeyCache → MicroBatcher (coalesce to
    block-shaped batches under a latency deadline, with admission control)
    → ModelRegistry → TreeEngine (shape-bucketed, over the ``--gw-plan``
    execution plan and ``--gw-backend`` backend; ``--gw-shards`` carves the
    forest tree-parallel or the batch row-parallel with bit-identical
    outputs)``, and the run ends with a per-model metrics table (throughput,
    p50/p95/p99 latency, batch occupancy, cache hit rate, per-shard
    timings) plus a bit-identity check of gateway outputs against direct
    ``TreeEngine.predict_scores``.  Observability flags: ``--gw-trace``
    samples per-request span trees (``--gw-trace-sample`` sets the rate) and
    prints a flame-style stage summary; ``--gw-trace-out`` writes the spans
    as JSONL; ``--gw-metrics-out`` writes a Prometheus-text metrics snapshot
    (plus a ``.json`` sibling with the full stats dict).
  * LM mode: load a smoke config and run batched prefill+decode generation.

The gateway route is one ``--gw-spec`` EngineSpec string (the per-field
``--gw-*`` flags stay as overrides), and ``--workers`` serves tree shards on
worker *processes* over the ITRG wire protocol — spawn N on loopback or
connect to a fleet started with ``--worker-listen HOST:PORT`` (or
``python -m repro.serve.worker``).

  PYTHONPATH=src python -m repro.launch.serve --trees --rows 20000
  PYTHONPATH=src python -m repro.launch.serve --trees --gateway --gw-requests 400
  PYTHONPATH=src python -m repro.launch.serve --trees --gateway \
      --gw-spec 'integer:bitvector@leaf_major+tree_parallel:4'
  PYTHONPATH=src python -m repro.launch.serve --trees --gateway --workers 2
  PYTHONPATH=src python -m repro.launch.serve --worker-listen 0.0.0.0:7071
  PYTHONPATH=src python -m repro.launch.serve --trees --gateway \
      --gw-trace-out trace.jsonl --gw-metrics-out metrics.prom
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_trees(args):
    from repro.backends import have_c_toolchain
    from repro.core.packing import pack_forest
    from repro.data.tabular import make_shuttle_like, train_test_split
    from repro.serve.engine import TreeEngine
    from repro.trees.forest import RandomForestClassifier

    X, y = make_shuttle_like(n=args.rows, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y)
    rf = RandomForestClassifier(
        n_estimators=args.n_trees, max_depth=args.depth, seed=0
    ).fit(Xtr, ytr)
    packed = pack_forest(rf)
    print(
        f"forest: {args.n_trees} trees depth<={args.depth}; packed "
        f"integer artifact {packed.nbytes_integer()/1e3:.1f} kB "
        f"(float: {packed.nbytes_float()/1e3:.1f} kB)"
    )
    engines = {m: TreeEngine(packed, m) for m in ("float", "flint", "integer")}
    engines["integer-leafmajor"] = TreeEngine(packed,
                                              "integer:reference@leaf_major")
    engines["integer-pallas"] = TreeEngine(packed, "integer:pallas")
    if have_c_toolchain():
        engines["integer-native-c"] = TreeEngine(packed, "integer:native_c")
        # the table-walk C backend resolves the ragged ForestIR layout
        # through packed.ir — same model, fourth execution strategy
        engines["integer-c-table"] = TreeEngine(packed,
                                                "integer:native_c_table")
    else:
        print("gcc not found: skipping the native_c / native_c_table rows")
    ref = None
    for name, eng in engines.items():
        eng.predict(Xte[:128])  # warmup/compile
        t0 = time.time()
        for _ in range(args.reps):
            preds = eng.predict(Xte)
        dt = (time.time() - t0) / args.reps
        acc = (preds == yte).mean()
        agree = 1.0 if ref is None else (preds == ref).mean()
        ref = preds if ref is None else ref
        print(
            f"{name:16s} acc={acc:.4f} agree_with_float={agree:.6f} "
            f"{dt*1e6/len(Xte):8.3f} us/row"
        )


def build_gateway_models(registry, *, rows: int = 8000, seed: int = 0):
    """Train + register the demo model set; returns per-model row pools.

    ``esa-rf`` goes through the JSON artifact boundary on purpose — that is
    the registry's external-model load path and must stay exercised.
    """
    from repro.data.tabular import make_esa_like, make_shuttle_like, train_test_split
    from repro.trees.forest import RandomForestClassifier
    from repro.trees.io import forest_to_json

    pools = {}
    Xs, ys = make_shuttle_like(n=rows, seed=seed)
    Xtr, ytr, Xte, _ = train_test_split(Xs, ys, seed=seed)
    registry.register_forest(
        "shuttle-rf", RandomForestClassifier(n_estimators=20, max_depth=6, seed=seed).fit(Xtr, ytr)
    )
    pools["shuttle-rf"] = Xte
    registry.register_forest(
        "shuttle-deep", RandomForestClassifier(n_estimators=40, max_depth=8, seed=seed + 1).fit(Xtr, ytr)
    )
    pools["shuttle-deep"] = Xte
    Xe, ye = make_esa_like(n=rows, seed=seed)
    Xetr, yetr, Xete, _ = train_test_split(Xe, ye, seed=seed)
    rf_esa = RandomForestClassifier(n_estimators=12, max_depth=6, seed=seed + 2).fit(Xetr, yetr)
    registry.register_json("esa-rf", forest_to_json(rf_esa))
    pools["esa-rf"] = Xete
    return pools, (Xtr, ytr)


async def run_gateway_workload(gateway, pools, *, n_requests: int, rate_hz: float,
                               hot_frac: float = 0.3, seed: int = 0,
                               hot_swap=None, row_choices=(1, 1, 1, 1, 2, 2, 4, 8, 16)):
    """Poisson-arrival simulated clients.  Returns (results, n_rejected).

    ``rate_hz=inf`` degenerates to a burst (all requests at t=0), which
    measures pure gateway capacity.  ``hot_swap``: optional
    ``(request_index, fn)`` — ``fn(gateway)`` runs mid-workload to
    re-register a model (version bump under live traffic).
    """
    import asyncio

    from repro.serve.queue import AdmissionError

    rng = np.random.default_rng(seed)
    model_ids = list(pools)
    # a small hot pool per model -> repeated quantized keys -> cache hits
    hot = {m: pools[m][rng.integers(0, len(pools[m]), 24)] for m in model_ids}
    row_choices = np.asarray(row_choices)
    tasks, rejected = [], 0

    async def one(model_id, X):
        nonlocal rejected
        try:
            return model_id, X, await gateway.submit(model_id, X)
        except AdmissionError:
            rejected += 1
            return None

    for i in range(n_requests):
        if hot_swap is not None and i == hot_swap[0]:
            hot_swap[1](gateway)
        model_id = model_ids[int(rng.integers(0, len(model_ids)))]
        n_rows = int(rng.choice(row_choices))
        if rng.random() < hot_frac:
            X = hot[model_id][rng.integers(0, len(hot[model_id]), n_rows)]
        else:
            X = pools[model_id][rng.integers(0, len(pools[model_id]), n_rows)]
        tasks.append(asyncio.ensure_future(one(model_id, X)))
        if rate_hz != float("inf"):
            await asyncio.sleep(rng.exponential(1.0 / rate_hz))
    results = [r for r in await asyncio.gather(*tasks) if r is not None]
    return results, rejected


def resolve_gateway_spec(args):
    """One EngineSpec from ``--gw-spec`` plus the legacy per-field flags.

    ``--gw-spec`` is the canonical form; any legacy flag given explicitly
    overrides the corresponding spec field (the flags default to None so
    "not given" is distinguishable).  ``--workers`` selects the remote plan
    (when no plan was named) and becomes the plan's deployment kwargs:
    an integer spawns that many loopback worker processes, a comma list
    connects to an already-running fleet.
    Returns ``(spec, plan_kwargs)``.
    """
    from repro.serve.spec import EngineSpec

    spec = EngineSpec.parse(args.gw_spec) if args.gw_spec else EngineSpec()
    over = {}
    if args.gw_mode is not None:
        over["mode"] = args.gw_mode
    if args.gw_backend is not None:
        over["backend"] = args.gw_backend
    if args.gw_layout is not None:
        over["layout"] = args.gw_layout
    if args.gw_plan is not None:
        over["plan"] = None if args.gw_plan == "auto" else args.gw_plan
    if args.gw_shards is not None:
        over["shards"] = args.gw_shards
    if args.gw_autotune:
        over["autotune"] = True
    if args.gw_block_rows is not None:
        backend = over.get("backend", spec.backend)
        if backend != "native_c_table":
            raise SystemExit(
                "--gw-block-rows is the table-walk C row-block knob; it "
                f"needs the native_c_table backend (got {backend!r})"
            )
        over["backend_kwargs"] = dict(spec.backend_kwargs or {},
                                      block_rows=args.gw_block_rows)
    plan_kwargs = None
    if getattr(args, "workers", None):
        w = args.workers
        workers = int(w) if w.isdigit() else [a.strip() for a in w.split(",")]
        plan_kwargs = {"workers": workers}
        if spec.plan is None and "plan" not in over:
            over["plan"] = "remote_tree_parallel"
        if (over.get("shards") or spec.shards) is None:
            over["shards"] = workers if isinstance(workers, int) else len(workers)
    return (spec.replace(**over) if over else spec), plan_kwargs


def serve_gateway(args):
    import asyncio

    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry
    from repro.trees.forest import RandomForestClassifier

    spec, plan_kwargs = resolve_gateway_spec(args)
    print(f"gateway route: {spec}"
          + (f"  plan_kwargs={plan_kwargs}" if plan_kwargs else ""))

    registry = ModelRegistry()
    t0 = time.time()
    pools, (Xtr, ytr) = build_gateway_models(registry, rows=args.rows // 2 or 4000)
    print(f"registered models in {time.time()-t0:.1f}s: {registry.describe()}")
    tracer = None
    if args.gw_trace or args.gw_trace_out:
        from repro.obs import Tracer

        tracer = Tracer(sample=args.gw_trace_sample)
    gateway = Gateway(
        registry,
        spec,
        plan_kwargs=plan_kwargs,
        max_batch_rows=args.gw_batch_rows,
        max_delay_ms=args.gw_max_delay_ms,
        max_queue_rows=args.gw_queue_rows,
        tracer=tracer,
    )

    # warm every (model, bucket) pair — through the plan, so every shard of a
    # tree-/row-parallel route pre-compiles — so compiles don't pollute
    # latency stats
    t0 = time.time()
    for mid in registry.ids():
        eng = registry.get(mid).engine(spec, plan_kwargs=plan_kwargs)
        eng.warm(args.gw_batch_rows)
    print(f"warmed shape buckets in {time.time()-t0:.1f}s "
          f"(plan={eng.plan_name}, shards={eng.n_shards}, "
          f"tuned={eng.tuned_config or '-'})")

    def _do_swap(gw):
        mv = gw.registry.register_forest(
            "shuttle-rf",
            RandomForestClassifier(n_estimators=28, max_depth=6, seed=9).fit(Xtr, ytr),
        )
        # warm the new version too (every shard of its plan)
        mv.engine(spec, plan_kwargs=plan_kwargs).warm(args.gw_batch_rows)
        print(f"  hot-swapped shuttle-rf -> v{mv.version} under live traffic")

    swap_done = []

    def swap(gw):
        # train/warm off the event loop; the registry repoint itself is atomic
        swap_done.append(asyncio.get_running_loop().run_in_executor(None, _do_swap, gw))

    async def main():
        t0 = time.time()
        results, rejected = await run_gateway_workload(
            gateway, pools, n_requests=args.gw_requests, rate_hz=args.gw_rate,
            hot_swap=(args.gw_requests // 2, swap),
        )
        dt = time.time() - t0
        for fut in swap_done:  # make sure the hot-swap has landed
            await fut
        print(f"\nworkload: {len(results)} requests served, {rejected} rejected, "
              f"{dt:.2f}s wall ({len(results)/dt:.0f} req/s)")
        print(gateway.render_table())
        print(f"cache: {gateway.cache.stats()}")

        if tracer is not None:
            from repro.obs import render_flame, write_jsonl

            spans = tracer.spans()
            print(f"\ntraces: {tracer.started} requests sampled, "
                  f"{len(spans)} spans ({tracer.dropped} dropped)")
            print(render_flame(spans))
            if args.gw_trace_out:
                write_jsonl(spans, args.gw_trace_out)
                print(f"wrote trace JSONL -> {args.gw_trace_out}")
        if args.gw_metrics_out:
            import re

            from repro.obs import render_prometheus, snapshot_json

            st = gateway.stats()
            with open(args.gw_metrics_out, "w") as f:
                f.write(render_prometheus(st["per_model"]))
            jpath = re.sub(r"\.prom$", "", args.gw_metrics_out) + ".json"
            with open(jpath, "w") as f:
                f.write(snapshot_json(st, aggregate=gateway.metrics.aggregate()))
            print(f"wrote metrics exposition -> {args.gw_metrics_out} + {jpath}")

        # bit-identity: gateway outputs == direct engine on the same rows
        ok = True
        for mid in registry.ids():
            X = pools[mid][:48]
            g_scores, g_preds = await gateway.submit(mid, X)
            d_scores, d_preds = registry.get(mid).engine(
                spec, plan_kwargs=plan_kwargs
            ).predict_scores(X)
            ok &= bool((g_scores == d_scores).all() and (g_preds == d_preds).all())
        print(f"gateway == direct engine (bit-identical): {ok}")
        await gateway.close()
        return ok

    ok = asyncio.run(main())
    if not ok:
        raise SystemExit("gateway outputs diverged from direct engine")


def serve_lm(args):
    from repro.configs.base import get_config, smoke_config
    from repro.data.tokens import pipeline_for
    from repro.models import transformer as tfm
    from repro.serve.engine import LMEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    engine = LMEngine(cfg, params, max_seq=args.prompt + args.tokens)
    pipe = pipeline_for(cfg, args.batch, args.prompt)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items() if k != "labels"}
    t0 = time.time()
    out = engine.generate(batch, args.tokens, temperature=args.temperature)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s); sample: {np.asarray(out[0,:16])}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", action="store_true")
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--n-trees", type=int, default=50)
    ap.add_argument("--depth", type=int, default=7)
    ap.add_argument("--gateway", action="store_true",
                    help="run the async dynamic-batching gateway workload")
    ap.add_argument("--gw-requests", type=int, default=400)
    ap.add_argument("--gw-rate", type=float, default=400.0, help="Poisson arrival rate (req/s)")
    ap.add_argument("--gw-batch-rows", type=int, default=64)
    ap.add_argument("--gw-max-delay-ms", type=float, default=5.0)
    ap.add_argument("--gw-queue-rows", type=int, default=2048)
    ap.add_argument("--gw-spec", default=None, metavar="SPEC",
                    help="the serving route as one EngineSpec string, e.g. "
                         "'integer:bitvector@leaf_major+tree_parallel:4' or "
                         "'flint:reference+remote_tree_parallel:2'; the "
                         "--gw-mode/--gw-backend/--gw-layout/--gw-plan/"
                         "--gw-shards flags remain as per-field overrides")
    ap.add_argument("--gw-mode", default=None, choices=("float", "flint", "integer"))
    from repro.backends import available_backends

    ap.add_argument("--gw-backend", default=None,
                    choices=tuple(available_backends()),
                    help="execution backend behind the gateway "
                         "(default: reference)")
    from repro.ir import available_layouts

    ap.add_argument("--gw-layout", default=None,
                    choices=tuple(available_layouts()),
                    help="ForestIR layout to materialize (default: the "
                         "backend's preferred layout)")
    ap.add_argument("--gw-block-rows", type=int, default=None,
                    help="rows in flight per tree for the table-walk C "
                         "backend (1 = scalar walk; default: the backend's "
                         "preferred_block_rows)")
    from repro.plan import available_plans

    ap.add_argument("--gw-plan", default=None,
                    choices=tuple(available_plans()) + ("auto",),
                    help="execution plan behind the gateway (default: "
                         "single-shard; 'auto' selects by capability from "
                         "--gw-shards and the mode)")
    ap.add_argument("--gw-autotune", action="store_true",
                    help="measure backend construction knobs (table-walk "
                         "block_rows, bitvector interleave width, Pallas "
                         "block tiling) during warm and serve on the winner; "
                         "REPRO_AUTOTUNE=0 disables globally")
    ap.add_argument("--gw-shards", type=int, default=None,
                    help="shard count for tree-/row-parallel plans (trees "
                         "are carved via ForestIR.subset; partial integer "
                         "scores merge bit-exactly)")
    ap.add_argument("--workers", default=None, metavar="N|HOST:PORT,...",
                    help="serve tree shards on worker processes: an integer "
                         "spawns that many loopback workers, a comma list "
                         "connects to already-running ones (see "
                         "--worker-listen); implies the remote_tree_parallel "
                         "plan unless --gw-plan/--gw-spec name another")
    ap.add_argument("--worker-listen", default=None, metavar="HOST:PORT",
                    help="run as a shard worker instead of a gateway: bind "
                         "here, print WORKER_READY, and serve uint32 "
                         "partials over the ITRG wire protocol (equivalent "
                         "to python -m repro.serve.worker)")
    ap.add_argument("--worker-span-out", default=None, metavar="PATH",
                    help="worker mode: append per-request span JSONL here")
    ap.add_argument("--gw-trace", action="store_true",
                    help="sample per-request span trees and print a "
                         "flame-style stage summary after the workload")
    ap.add_argument("--gw-trace-sample", type=float, default=1.0,
                    help="fraction of requests to trace (deterministic "
                         "accumulator sampling; default 1.0)")
    ap.add_argument("--gw-trace-out", default=None,
                    help="write sampled spans as JSONL (implies --gw-trace)")
    ap.add_argument("--gw-metrics-out", default=None,
                    help="write a Prometheus-text metrics snapshot here "
                         "(plus a .json sibling with the full stats dict)")
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)
    if args.worker_listen:
        from repro.serve import worker

        wargv = ["--listen", args.worker_listen]
        if args.worker_span_out:
            wargv += ["--span-out", args.worker_span_out]
        return worker.main(wargv)
    if args.trees and args.gateway:
        serve_gateway(args)
    elif args.trees:
        serve_trees(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()

from repro.configs.base import ARCHS, ModelConfig, get_config, smoke_config

__all__ = ["ARCHS", "ModelConfig", "get_config", "smoke_config"]

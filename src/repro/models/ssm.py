"""Mamba2 (SSD — state-space duality) layer with chunked scan + decode step.

Follows arXiv:2405.21060: per-head scalar decay A, input-dependent dt/B/C,
causal depthwise conv on (x, B, C), gated output.  The chunked algorithm
computes intra-chunk contributions as masked (Q x Q) matmuls (MXU-friendly)
and carries an (H, P, N) state across chunks with an associative-scan-free
``lax.scan`` (sequential over chunks, parallel over everything else).

Decode is O(1) per token: conv ring buffer + state update
``S <- exp(dt A) S + dt B (x)``, ``y = C . S + D x``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.sharding.ops import constrain

HEADDIM = 64  # P: mamba2 default head dim
CONV_K = 4


def ssm_dims(d_model: int, expand: int, state: int):
    d_inner = expand * d_model
    n_heads = d_inner // HEADDIM
    conv_dim = d_inner + 2 * state  # x, B, C share the conv
    return d_inner, n_heads, conv_dim


def ssm_params(key, d_model: int, expand: int, state: int):
    d_inner, h, conv_dim = ssm_dims(d_model, expand, state)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        # [z, x, B, C, dt]
        "w_in": dense_init(k1, (d_model, 2 * d_inner + 2 * state + h)),
        "conv_w": dense_init(k2, (conv_dim, CONV_K)),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) in (-inf,0)
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_gamma": jnp.zeros((d_inner,), jnp.float32),
        "w_out": dense_init(k5, (d_inner, d_model)),
    }


def _split_in(params, x, d_inner, state, h):
    zxbcdt = x @ params["w_in"].astype(x.dtype)
    z, xs, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + state, 2 * d_inner + 2 * state], axis=-1
    )
    return z, xs, b, c, dt


def _causal_conv(u, w):
    """u: (B, S, C), w: (C, K) depthwise causal conv + silu."""
    k = w.shape[1]
    upad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    # windows: out[t] = sum_j u[t-K+1+j] * w[:, j]
    out = sum(upad[:, j : j + u.shape[1], :] * w[None, None, :, j].astype(u.dtype) for j in range(k))
    return jax.nn.silu(out)


def ssd_forward(params, x, *, d_model: int, expand: int, state: int, chunk: int = 128,
                return_final_state: bool = False):
    """Full-sequence SSD.  x: (B, S, D) -> (B, S, D).  S % chunk == 0 assumed
    (configs enforce it).  With ``return_final_state`` also returns the decode
    cache {conv, ssm} so prefill hands off exactly to ``ssd_decode_step``."""
    d_inner, h, conv_dim = ssm_dims(d_model, expand, state)
    bsz, s_real, _ = x.shape
    pad = (-s_real) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_real + pad
    z, xs, b, c, dt = _split_in(params, x, d_inner, state, h)
    z = constrain(z, "batch", None, "tp")
    conv_in = jnp.concatenate([xs, b, c], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"])
    xs, b, c = jnp.split(conv_out, [d_inner, d_inner + state], axis=-1)
    b = constrain(b, "batch", None, None)
    c = constrain(c, "batch", None, None)

    p = HEADDIM
    xh = constrain(xs.reshape(bsz, s, h, p), "batch", None, "tp", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    dt = constrain(dt, "batch", None, "tp")
    if pad:
        # padded steps must be state-identities: dt=0 -> decay=1, no input
        valid = (jnp.arange(s) < s_real)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    a = -jnp.exp(params["a_log"])  # (H,)
    da = dt * a  # (B,S,H) log-decay per step, negative

    nc = s // chunk
    dac = da.reshape(bsz, nc, chunk, h)
    dtc = dt.reshape(bsz, nc, chunk, h)
    xc = xh.reshape(bsz, nc, chunk, h, p)
    bc = b.reshape(bsz, nc, chunk, state)
    cc = c.reshape(bsz, nc, chunk, state)

    lcum = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H) inclusive cumulative log-decay
    ltot = lcum[:, :, -1]  # (B,nc,H)
    bf = x.dtype  # bf16 compute for the (Q x Q) MXU work; recurrence stays f32

    # --- intra-chunk: masked (Q x Q) attention-like matmul
    # decay(q,s) = exp(lcum_q - lcum_s) for s <= q; exp in f32, product in bf16
    dq = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    dec = jnp.exp(jnp.where(mask[None, None, :, :, None], dq, -jnp.inf)).astype(bf)
    scores = jnp.einsum("bnqs,bnts->bnqt", cc.astype(bf), bc.astype(bf))
    w = scores[..., None] * dec * dtc[:, :, None, :, :].astype(bf)  # (B,nc,Q,Q,H)
    w = constrain(w, "batch", None, None, None, "tp")
    y_intra = jnp.einsum(
        "bnqth,bnthp->bnqhp", w, xc.astype(bf), preferred_element_type=jnp.float32
    )
    y_intra = constrain(y_intra, "batch", None, None, "tp", None)

    # --- per-chunk end-state: S_c = sum_s exp(ltot - lcum_s) dt_s B_s x_s
    decay_to_end = jnp.exp(ltot[:, :, None, :] - lcum)  # (B,nc,Q,H)
    sc = jnp.einsum(
        "bnqs,bnqh,bnqhp->bnhsp",
        bc.astype(bf),
        (decay_to_end * dtc).astype(bf),
        xc.astype(bf),
        preferred_element_type=jnp.float32,
    )  # (B,nc,H,N,P)
    sc = constrain(sc, "batch", None, "tp", None, None)

    # --- inter-chunk recurrence over nc (sequential scan, f32 carry)
    def step(s_run, inp):
        sc_i, ltot_i = inp  # (B,H,N,P), (B,H)
        y_state = s_run.astype(bf)  # state entering this chunk (bf16 to HBM)
        s_next = s_run * jnp.exp(ltot_i)[:, :, None, None] + sc_i
        return s_next, y_state

    s0 = jnp.zeros((bsz, h, state, p), jnp.float32)
    s_final, s_in = jax.lax.scan(step, s0, (sc.swapaxes(0, 1), ltot.swapaxes(0, 1)))
    s_in = s_in.swapaxes(0, 1)  # (B,nc,H,N,P) state at chunk entry

    # --- inter-chunk output: y_q += C_q . S_entry * exp(lcum_q)
    y_inter = jnp.einsum(
        "bnqs,bnhsp,bnqh->bnqhp",
        cc.astype(bf),
        s_in,
        jnp.exp(lcum).astype(bf),
        preferred_element_type=jnp.float32,
    )
    y_inter = constrain(y_inter, "batch", None, None, "tp", None)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_gamma"])
    out = (y @ params["w_out"].astype(x.dtype))[:, :s_real]
    if return_final_state:
        final_cache = {
            "conv": conv_in[:, s_real - (CONV_K - 1) : s_real, :],
            "ssm": s_final,
        }
        return out, final_cache
    return out


def ssm_init_cache(batch: int, d_model: int, expand: int, state: int, dtype=jnp.float32):
    d_inner, h, conv_dim = ssm_dims(d_model, expand, state)
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, state, HEADDIM), jnp.float32),
    }


def ssd_decode_step(params, x, cache, *, d_model: int, expand: int, state: int):
    """One-token decode.  x: (B, 1, D) -> (B, 1, D), updated cache."""
    d_inner, h, conv_dim = ssm_dims(d_model, expand, state)
    bsz = x.shape[0]
    z, xs, b, c, dt = _split_in(params, x[:, 0], d_inner, state, h)
    conv_in = jnp.concatenate([xs, b, c], axis=-1)  # (B, conv_dim)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,C)
    w = params["conv_w"].astype(x.dtype)  # (C,K)
    conv_out = jax.nn.silu(jnp.einsum("bkc,ck->bc", hist, w))
    new_conv = hist[:, 1:]
    xs, b, c = jnp.split(conv_out, [d_inner, d_inner + state], axis=-1)

    p = HEADDIM
    xh = xs.reshape(bsz, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    s_new = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", b.astype(jnp.float32), dt, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), s_new)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm_gamma"])
    out = y @ params["w_out"].astype(x.dtype)
    return out[:, None], {"conv": new_conv, "ssm": s_new}

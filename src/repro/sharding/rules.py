"""Logical-to-mesh sharding rules (path-based, divisibility-checked).

Mesh axes (DESIGN.md Sec. 5):
  * ``pod``   — pure data parallelism across pods (params replicated),
  * ``data``  — FSDP: batch sharded AND parameter/optimizer-state sharded
                (XLA all-gathers params per scanned layer, overlapping with
                compute),
  * ``model`` — tensor parallelism: heads / ffn / experts / vocab.

Every rule is checked against the actual mesh axis sizes: a dimension that is
not divisible by its assigned axis size falls back to replication (e.g. the
49155-entry granite-3-2b vocab).  Rules match on the *leaf path suffix*, so
stacked (L, ...) block params get a ``None`` prepended automatically.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# leaf-name -> spec for the *unstacked* parameter
_RULES = {
    "embed": ("model", "data"),  # (V, D)
    "head": ("data", "model"),  # (D, V)
    "frontend_proj": (None, "model"),  # (F, D)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "w_router": ("data", None),
    "w_gate_e": ("model", "data", None),  # (E, D, F): experts on model
    "w_up_e": ("model", "data", None),
    "w_down_e": ("model", None, "data"),
    "w_in": ("data", "model"),
    "w_out": ("model", "data"),
    "conv_w": ("model", None),
    "a_log": (None,),
    "dt_bias": (None,),
    "d_skip": (None,),
    "norm_gamma": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "final_norm": (None,),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def spec_for(path, shape, mesh: Mesh) -> P:
    name = _leaf_name(path)
    rule = _RULES.get(name)
    if rule is None:
        return P()
    ndim = len(shape)
    base = list(rule)
    # stacked leading dims (blocks: (L, ...); shared sets: (S, ...))
    while len(base) < ndim:
        base.insert(0, None)
    base = base[:ndim]
    out = []
    for dim, axis in zip(shape, base):
        if axis is None:
            out.append(None)
            continue
        size = mesh.shape[axis] if axis in mesh.shape else 1
        out.append(axis if size > 1 and dim % size == 0 else None)
    return P(*out)


def params_shardings(params_tree, mesh: Mesh):
    """Map a (possibly abstract) params pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf.shape, mesh)), params_tree
    )


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Batch-axis spec: shard over (pod, data) when divisible."""
    axes = [a for a in ("pod", "data") if a in mesh.shape and mesh.shape[a] > 1]
    total = 1
    used = []
    for a in axes:
        if batch_size % (total * mesh.shape[a]) == 0:
            used.append(a)
            total *= mesh.shape[a]
    return P(tuple(used)) if used else P()


def batch_shardings(mesh: Mesh, batch_tree):
    """Shard every batch leaf on its leading (batch) dimension."""

    def one(leaf):
        spec = batch_pspec(mesh, leaf.shape[0])
        pad = [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*(list(spec) + pad)) if spec else P())

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree, *, shard_seq: bool = False):
    """KV/state cache shardings for serving.

    Layout: (L, B, S, K, Dh) for k/v; (L, B, ...) for ssm states.  Batch is
    sharded over (pod, data); kv-heads over model when divisible.  With
    ``shard_seq`` (long-context decode at batch 1), the cache *sequence* dim
    is sharded over data instead — attention over the sharded cache becomes a
    distributed flash-decode (partial softmax + combine), which XLA SPMD
    derives from the einsum sharding.
    """

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name in ("k", "v") and len(shape) == 5:
            l, b, s, k, dh = shape
            bspec = batch_pspec(mesh, b)
            bax = bspec[0] if len(bspec) else None
            seq_ax = None
            if shard_seq and "data" in mesh.shape and s % mesh.shape["data"] == 0:
                seq_ax = "data"
                if bax == "data":
                    bax = None
            model_sz = mesh.shape.get("model", 1)
            kax = "model" if model_sz > 1 and k % model_sz == 0 else None
            if kax is None and model_sz > 1 and s % model_sz == 0:
                # kv heads not shardable (GQA k < model): shard the cache
                # sequence over `model` instead — decode attention becomes a
                # distributed flash-decode (partial softmax + psum combine).
                seq_ax = ("model",) if seq_ax is None else (seq_ax, "model")
            return NamedSharding(mesh, P(None, bax, seq_ax, kax, None))
        if name == "pos":
            return NamedSharding(mesh, P())
        # ssm states: (L, B, ...) — shard batch; shard the widest inner dim on
        # model when divisible (conv: channel dim; ssm state: heads dim)
        if len(shape) >= 2:
            bspec = batch_pspec(mesh, shape[1])
            bax = bspec[0] if len(bspec) else None
            rest = [None] * (len(shape) - 2)
            if name == "conv" and len(shape) == 4 and shape[3] % mesh.shape.get("model", 1) == 0:
                rest[-1] = "model"
            if name == "ssm" and len(shape) == 5 and shape[2] % mesh.shape.get("model", 1) == 0:
                rest[0] = "model"
            return NamedSharding(mesh, P(None, bax, *rest))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_tree)

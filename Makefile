# One-step entry points for the repo's standard workflows.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test conformance bench serve-trees serve-gateway

# tier-1 verify (see ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# cross-backend bit-identity suite (reference / pallas / native_c)
conformance:
	$(PY) -m pytest -q tests/test_backends.py

bench:
	$(PY) benchmarks/run.py

serve-trees:
	$(PY) -m repro.launch.serve --trees

serve-gateway:
	$(PY) -m repro.launch.serve --trees --gateway

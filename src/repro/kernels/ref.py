"""Pure-jnp oracle for the tree-traversal kernel.

Standalone (does not import the kernel) so kernel tests can assert
``assert_allclose(kernel(...), ref(...))`` against an independent
implementation.  Math is identical to ``repro.core.ensemble.predict_integer``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_predict_integer_ref(x_keys, feature, threshold_key, left, right, leaf_fixed, depth: int):
    """Integer-only ensemble inference.

    Args:
      x_keys: (B, F) int32 FlInt keys of the feature rows.
      feature: (T, N) int32, -1 on leaves.
      threshold_key: (T, N) int32.
      left/right: (T, N) int32 child indices (self on leaves).
      leaf_fixed: (T, N, C) uint32 fixed-point leaf probabilities.
      depth: walk length (>= max tree depth).

    Returns: (B, C) uint32 accumulated class scores.
    """
    b = x_keys.shape[0]
    c = leaf_fixed.shape[-1]

    def per_tree(acc, tree):
        feat_t, thr_t, left_t, right_t, leaf_t = tree
        node = jnp.zeros(b, jnp.int32)

        def body(_, node):
            f = feat_t[node]
            thr = thr_t[node]
            xv = jnp.take_along_axis(x_keys, jnp.clip(f, 0)[:, None], axis=1)[:, 0]
            return jnp.where(xv <= thr, left_t[node], right_t[node])

        node = jax.lax.fori_loop(0, depth, body, node)
        return acc + leaf_t[node], None

    acc0 = jnp.zeros((b, c), jnp.uint32)
    acc, _ = jax.lax.scan(per_tree, acc0, (feature, threshold_key, left, right, leaf_fixed))
    return acc

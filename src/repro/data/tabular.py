"""Synthetic tabular datasets shaped like the paper's two benchmarks.

The container is offline, so we generate datasets with the same shape/class
structure as the paper's (Sec. IV-A):
  * Statlog (Shuttle):  58,000 x 7, 7 classes, heavily imbalanced
    (~80% of rows in one class, two classes nearly absent),
  * ESA Anomaly (first 3 months): 262,081 x 87, binary, rare positives.

Both are Gaussian-mixture generators with class-dependent informative
features, deterministic under a seed.
"""
from __future__ import annotations

import numpy as np


def make_shuttle_like(n: int = 58000, n_features: int = 7, n_classes: int = 7, seed: int = 0):
    rng = np.random.default_rng(seed)
    # Shuttle-like imbalance: class 0 dominates.
    weights = np.array([0.786, 0.1, 0.06, 0.03, 0.015, 0.006, 0.003])
    weights = weights[:n_classes] / weights[:n_classes].sum()
    y = rng.choice(n_classes, size=n, p=weights)
    centers = rng.normal(0, 3.0, size=(n_classes, n_features))
    scales = rng.uniform(0.5, 1.5, size=(n_classes, n_features))
    X = centers[y] + rng.normal(size=(n, n_features)) * scales[y]
    # shuttle features are small-magnitude integers; keep a similar flavor
    X = np.round(X * 8).astype(np.float32) / 2.0
    return X.astype(np.float32), y.astype(np.int64)


def make_esa_like(n: int = 262081, n_features: int = 87, seed: int = 0, anomaly_rate: float = 0.04):
    rng = np.random.default_rng(seed)
    y = (rng.random(n) < anomaly_rate).astype(np.int64)
    X = rng.normal(size=(n, n_features)).astype(np.float32)
    # anomalies shift a random subset of channels (telemetry-like)
    n_info = max(4, n_features // 8)
    info = rng.choice(n_features, n_info, replace=False)
    shift = rng.uniform(1.5, 3.5, size=n_info).astype(np.float32)
    X[np.ix_(y == 1, info)] += shift
    return X, y


def train_test_split(X, y, test_frac: float = 0.25, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    k = int(n * (1 - test_frac))
    tr, te = perm[:k], perm[k:]
    return X[tr], y[tr], X[te], y[te]

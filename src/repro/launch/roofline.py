"""Roofline-term derivation from compiled dry-run artifacts.

Hardware constants (TPU v5e-class, per assignment):
  peak bf16 compute 197 TFLOP/s/chip, HBM 819 GB/s/chip, ICI ~50 GB/s/link.

Sources (see jaxpr_cost.py for why XLA's cost_analysis can't be used):
  * FLOPs / HBM bytes: trip-count-aware jaxpr walk — *global* quantities,
  * collective bytes: trip-count-aware HLO parse — *per-device* quantities.

    compute    = flops_global / (chips * PEAK_FLOPS)
    memory     = bytes_global / (chips * HBM_BW)
    collective = collective_bytes_global / (chips * LINK_BW)
               = collective_bytes_per_dev / LINK_BW      (chip factor cancels)

MODEL_FLOPS is the analytic useful work (6·N·D train, 2·N·D forward, with
N_active for MoE); the ratio MODEL_FLOPS / HLO_FLOPs_global flags
remat/redundancy waste.
"""
from __future__ import annotations

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

from repro.configs.base import ModelConfig
from repro.launch.shapes import SHAPES, TREE_SHAPES


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic useful FLOPs per step (global)."""
    if cfg.family == "trees":
        rows = TREE_SHAPES[shape_name]["rows"]
        # per (row, tree, level): 1 int compare + 3 gathers + final C adds
        return float(rows * cfg.n_trees * (cfg.tree_depth * 4 + cfg.n_classes))
    info = SHAPES[shape_name]
    n = cfg.active_param_count()
    if info["mode"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n * tokens
    if info["mode"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * info["batch"]


def terms(record: dict) -> dict:
    """record: one dry-run JSON entry (jaxpr_cost global + HLO collectives)."""
    chips = record["chips"]
    flops_dev = record["jaxpr_cost"]["flops"] / chips
    bytes_dev = record["jaxpr_cost"]["bytes_lb"] / chips
    coll_dev = record["collectives"]["total"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / LINK_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    mf = record.get("model_flops", 0.0)
    hlo_global = flops_dev * chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "step_time_lb_s": max(compute_s, memory_s, collective_s),
        "mfu_bound": (mf / chips / PEAK_FLOPS) / max(compute_s, memory_s, collective_s)
        if max(compute_s, memory_s, collective_s) > 0
        else 0.0,
    }

"""Pallas TPU kernel: batched integer-only tree-ensemble traversal.

TPU adaptation of the paper's if-else trees (DESIGN.md Sec. 2): branches
become breadth-batched node-table walks.  One grid cell processes a block of
``block_b`` examples against a block of ``block_t`` trees with all node tables
resident in VMEM; examples advance one tree level per step; leaves self-loop.
Class scores are uint32 fixed-point sums (paper Sec. III-A) — overflow-free by
construction, so accumulation across tree-blocks is plain integer addition
with no rescaling.

Grid: ``(B/block_b, T/block_t)`` with the tree dimension innermost, so each
output block stays resident while all tree-blocks accumulate into it
(classic revisited-output reduction pattern).

VMEM budget per cell (int32/uint32 words):
    x block:      block_b * F
    node tables:  block_t * N * 4          (feature, key, left, right)
    leaf table:   block_t * N * C
    out block:    block_b * C
For the paper-scale ensembles (T<=100, depth<=8 -> N<=511, C<=7) everything
fits in well under 1 MiB, far below the ~16 MiB v5e VMEM; ``ops.py`` checks
the budget and splits the tree dimension when needed.

Three walk strategies, selected statically:
  * ``impl="gather"`` (default): ``jnp.take`` one-dim table gathers — lowers
    to Mosaic ``dynamic_gather`` (supported on v4+) and is O(block_b) work per
    level.
  * ``impl="onehot"``: branch-free masked reductions (compare-iota + select +
    sum) — O(block_b * N) work per level but uses only elementwise VPU ops;
    portable to any Pallas target.  This mirrors how the paper leans on the
    most basic ALU ops (load/add/compare) instead of specialized units.
  * ``impl="leaf_major"`` (:func:`tree_traverse_leaf_major`): the layout-
    specialized variant for ``leaf_major`` tables — a single forward linear
    scan over each tree's internal-node prefix with compare+select steps
    (children always sit after parents, so one pass routes every row), one
    leaf gather per tree at the end.  Depth-many table gathers disappear;
    the scan reads each node's fields exactly once per row block.
All are validated against ``ref.py`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_1d(row, idx, impl: str):
    """row: (N,), idx: (B,) int32 -> (B,)."""
    if impl == "gather":
        return jnp.take(row, idx, axis=0)
    # one-hot: (B, N) mask against iota, reduce over N.
    n = row.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    mask = iota == idx[:, None]
    return jnp.sum(jnp.where(mask, row[None, :], jnp.zeros_like(row[None, :])), axis=1)


def _gather_rows(table, idx, impl: str):
    """table: (N, C), idx: (B,) -> (B, C)."""
    if impl == "gather":
        return jnp.take(table, idx, axis=0)
    n, c = table.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    mask = (iota == idx[:, None])[:, :, None]
    return jnp.sum(jnp.where(mask, table[None], jnp.zeros_like(table[None])), axis=1)


def _gather_feature(x, feat, impl: str):
    """x: (B, F), feat: (B,) -> (B,) = x[i, feat[i]]."""
    if impl == "gather":
        return jnp.take_along_axis(x, feat[:, None], axis=1)[:, 0]
    f = x.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    mask = iota == feat[:, None]
    return jnp.sum(jnp.where(mask, x, jnp.zeros_like(x)), axis=1)


def _kernel(x_ref, feat_ref, key_ref, left_ref, right_ref, leaf_ref, out_ref, *, depth, block_t, impl):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (block_b, F) int32 keys
    bb = x.shape[0]

    def per_tree(t, acc):
        feat_t = feat_ref[t, :]
        key_t = key_ref[t, :]
        left_t = left_ref[t, :]
        right_t = right_ref[t, :]
        node = jnp.zeros((bb,), jnp.int32)

        def level(_, node):
            f = _gather_1d(feat_t, node, impl)
            thr = _gather_1d(key_t, node, impl)
            xv = _gather_feature(x, jnp.maximum(f, 0), impl)
            nl = _gather_1d(left_t, node, impl)
            nr = _gather_1d(right_t, node, impl)
            return jnp.where(xv <= thr, nl, nr)

        node = jax.lax.fori_loop(0, depth, level, node)
        return acc + _gather_rows(leaf_ref[t, :, :], node, impl)

    acc = jax.lax.fori_loop(0, block_t, per_tree, jnp.zeros_like(out_ref[...]))
    out_ref[...] += acc


def _kernel_leaf_major(x_ref, feat_ref, key_ref, left_ref, right_ref,
                       nint_ref, leaf_ref, out_ref, *, block_t):
    """Linear-scan walk over the leaf_major layout's internal-node prefix.

    The layout guarantees (a) tree nodes are permuted internal-first, so
    indices [0, n_internal) are exactly the split nodes, and (b) every child
    sits at a strictly larger index than its parent.  One forward pass over
    the prefix therefore routes every row to its leaf: when the scan reaches
    node j, any row currently parked at j steps to a child with index > j,
    which a later scan step (or the final leaf gather) picks up.  Per node
    the work is elementwise compare+select over the row block — no per-depth
    node-table gathers at all; the only gather left is one leaf-row fetch per
    (row, tree) at the end.  Rows parked on leaves are untouched by
    construction (leaves self-loop), so scanning past a tree's real prefix
    (padding nodes) is harmless and inert trees (n_internal == 0) skip the
    scan entirely.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # (block_b, F) int32 keys
    bb = x.shape[0]

    def per_tree(t, acc):
        n_int = nint_ref[t]

        def scan_node(j, node):
            feat = feat_ref[t, j]
            thr = key_ref[t, j]
            nl = left_ref[t, j]
            nr = right_ref[t, j]
            # the scanned node reads ONE feature column — a single dynamic
            # slice, O(block_b) work, no per-row gather
            xv = jax.lax.dynamic_slice_in_dim(
                x, jnp.maximum(feat, 0), 1, axis=1
            )[:, 0]
            nxt = jnp.where(xv <= thr, nl, nr)
            return jnp.where(node == j, nxt, node)

        node = jax.lax.fori_loop(0, n_int, scan_node, jnp.zeros((bb,), jnp.int32))
        return acc + _gather_rows(leaf_ref[t, :, :], node, "gather")

    acc = jax.lax.fori_loop(0, block_t, per_tree, jnp.zeros_like(out_ref[...]))
    out_ref[...] += acc


def tree_traverse_leaf_major(
    x_keys,
    feature,
    threshold_key,
    left,
    right,
    internal_counts,
    leaf_fixed,
    *,
    block_b: int = 256,
    block_t: int | None = None,
    interpret: bool = True,
):
    """Raw pallas_call over leaf_major tables; shapes must divide evenly.

    Same (B, C) uint32 contract as :func:`tree_traverse_pallas` but walks the
    internal-node prefix front-to-back (``internal_counts`` is the layout's
    per-tree prefix length) instead of gathering node fields per depth level.
    """
    b, f = x_keys.shape
    t, n = feature.shape
    c = leaf_fixed.shape[-1]
    block_t = block_t or t
    assert b % block_b == 0 and t % block_t == 0
    grid = (b // block_b, t // block_t)

    kernel = functools.partial(_kernel_leaf_major, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t,), lambda i, j: (j,)),
            pl.BlockSpec((block_t, n, c), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.uint32),
        interpret=interpret,
    )(x_keys, feature, threshold_key, left, right, internal_counts, leaf_fixed)


def tree_traverse_pallas(
    x_keys,
    feature,
    threshold_key,
    left,
    right,
    leaf_fixed,
    *,
    depth: int,
    block_b: int = 256,
    block_t: int | None = None,
    impl: str = "gather",
    interpret: bool = True,
):
    """Raw pallas_call; shapes must already divide evenly (see ops.py)."""
    b, f = x_keys.shape
    t, n = feature.shape
    c = leaf_fixed.shape[-1]
    block_t = block_t or t
    assert b % block_b == 0 and t % block_t == 0
    grid = (b // block_b, t // block_t)

    kernel = functools.partial(_kernel, depth=depth, block_t=block_t, impl=impl)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, n, c), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.uint32),
        interpret=interpret,
    )(x_keys, feature, threshold_key, left, right, leaf_fixed)

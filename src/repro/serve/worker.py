"""Remote shard worker: one process serving uint32 partials over the wire.

``python -m repro.serve.worker --listen 0.0.0.0:7411`` turns any host into
a shard worker.  The gateway's ``remote_tree_parallel`` plan connects,
sends one HELLO carrying the ForestIR arrays + the shard table, and then
streams PREDICT frames; the worker answers each with the raw uint32
partial accumulator of the requested tree shard (see
:mod:`repro.serve.wire` for the frame layout).

Design points that make the failure story simple:

* Session state is **per connection** — HELLO installs the forest and the
  shard table for that connection only, so one worker can serve several
  gateways (or several models) at once and a reconnect is a fresh
  handshake, never a stale-model hazard.
* Shard backends build **lazily on first use**: the shard table names every
  shard, so *any* worker can serve *any* shard.  Re-dispatching a dead
  worker's shard to a healthy one needs no re-handshake — the healthy
  worker just builds the extra sub-forest backend on demand.
* ``--delay-ms`` injects a fixed response delay, making a deliberately
  straggling worker for deadline/re-dispatch tests and the scale-out
  bench; ``--span-out`` appends each request's worker-side spans as JSONL
  (the same spans ride home in the PARTIALS trailer and are grafted into
  the gateway trace).

Imports stay stdlib+numpy at module level so ``WORKER_READY host:port``
prints before jax/backends load — spawners block on that line.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import sys
import threading
import time

import numpy as np

from repro.serve import wire

__all__ = ["WorkerServer", "spawn_local_workers", "main"]


class _Session:
    """Per-connection model state installed by HELLO."""

    def __init__(self, payload: bytes):
        meta, arrays = wire.decode_hello(payload)
        from repro.ir import ForestIR

        if meta.get("artifact_format") == "itrf":
            # artifact fast path: the payload carries a raw ITRF image —
            # rebuild the forest through the binary reader (views over the
            # received bytes) instead of the per-array directory
            from repro.ir.artifact import read_itrf_bytes

            self.ir = read_itrf_bytes(arrays["itrf"].tobytes())
        else:
            total = int(arrays["feature"].shape[0])
            n_classes = int(meta["n_classes"])
            self.ir = ForestIR(
                feature=arrays["feature"].astype(np.int32),
                threshold=arrays["threshold"].astype(np.float32),
                threshold_key=arrays["threshold_key"].astype(np.int32),
                left=arrays["left"].astype(np.int32),
                right=arrays["right"].astype(np.int32),
                # deterministic modes never read float leaf probabilities —
                # the one big float64 table stays off the wire (see wire.py)
                leaf_probs=np.zeros((total, n_classes), np.float64),
                leaf_fixed=arrays["leaf_fixed"].astype(np.uint32),
                node_offsets=arrays["node_offsets"].astype(np.int64),
                tree_depths=arrays["tree_depths"].astype(np.int32),
                n_trees=int(meta["n_trees"]),
                n_classes=n_classes,
                n_features=int(meta["n_features"]),
                quant_scale=int(meta["quant_scale"]),
            )
        self.meta = meta
        self.mode = str(meta["mode"])
        self.shard_table = {int(s["shard"]): s for s in meta["shards"]}
        self._backends: dict = {}
        self._lock = threading.Lock()

    def backend(self, shard_id: int):
        """-> (backend, built_now) for ``shard_id``, building lazily."""
        with self._lock:
            hit = self._backends.get(shard_id)
            if hit is not None:
                return hit, False
            spec = self.shard_table.get(shard_id)
            if spec is None:
                raise KeyError(f"shard {shard_id} not in shard table "
                               f"{sorted(self.shard_table)}")
            from repro.plan.base import build_backend

            sub = self.ir.subset(int(spec["start"]), int(spec["stop"]))
            b = build_backend(spec["backend"], sub, self.mode,
                              spec.get("layout"), spec.get("backend_kwargs"))
            self._backends[shard_id] = b
            return b, True


class WorkerServer:
    """Accept loop + one thread per gateway connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 span_out=None, delay_ms: float = 0.0):
        self.delay_ms = float(delay_ms)
        self._sock = socket.create_server((host, port))
        addr = self._sock.getsockname()
        self.host, self.port = addr[0], addr[1]
        self._span_fh = open(span_out, "a") if span_out else None
        self._span_lock = threading.Lock()
        self._closed = False

    @property
    def addr(self) -> str:
        return f"{self.host}:{self.port}"

    def serve_forever(self) -> None:
        while not self._closed:
            try:
                conn, _peer = self._sock.accept()
            except OSError:  # listener closed
                break
            threading.Thread(target=self._handle, args=(conn,),
                             daemon=True).start()

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        finally:
            if self._span_fh is not None:
                self._span_fh.close()
                self._span_fh = None

    # -- per-connection protocol loop -------------------------------------

    def _handle(self, conn: socket.socket) -> None:
        session = None
        try:
            while True:
                try:
                    msg_type, payload = wire.read_frame(conn)
                except wire.ConnectionClosed:
                    break
                if msg_type == wire.MSG_HELLO:
                    session = _Session(payload)
                    ack = {"pid": os.getpid(), "host": socket.gethostname(),
                           "wire": wire.WIRE_VERSION,
                           "model": session.meta.get("model_id"),
                           "version": session.meta.get("version")}
                    wire.send_frame(conn, wire.MSG_HELLO_ACK,
                                    json.dumps(ack).encode())
                elif msg_type == wire.MSG_PREDICT:
                    self._predict(conn, session, payload)
                elif msg_type == wire.MSG_CLOSE:
                    break
                else:
                    wire.send_frame(conn, wire.MSG_ERROR,
                                    wire.encode_error(0, f"bad msg {msg_type}"))
        except (ConnectionError, OSError):
            pass  # gateway vanished; nothing to tell it
        finally:
            conn.close()

    def _predict(self, conn, session, payload: bytes) -> None:
        t_recv = time.perf_counter_ns()
        req_id, shard_id, X = wire.decode_predict(payload)
        spans = [("decode", 0, time.perf_counter_ns() - t_recv)]
        if session is None:
            wire.send_frame(conn, wire.MSG_ERROR,
                            wire.encode_error(req_id, "PREDICT before HELLO"))
            return
        try:
            t0 = time.perf_counter_ns()
            backend, built = session.backend(shard_id)
            t1 = time.perf_counter_ns()
            if built:
                spans.append(("build", t0 - t_recv, t1 - t_recv))
            acc = np.asarray(backend.predict_partials(X), np.uint32)
            spans.append(("predict", t1 - t_recv,
                          time.perf_counter_ns() - t_recv))
        except Exception as exc:  # report, keep the connection alive
            wire.send_frame(conn, wire.MSG_ERROR,
                            wire.encode_error(req_id, repr(exc)))
            return
        if self.delay_ms:  # injected straggle, after the real work
            time.sleep(self.delay_ms / 1e3)
        wire.send_frame(conn, wire.MSG_PARTIALS,
                        wire.encode_partials(req_id, shard_id, acc, spans))
        self._log_spans(session, req_id, shard_id, len(X), spans)

    def _log_spans(self, session, req_id, shard_id, rows, spans) -> None:
        if self._span_fh is None:
            return
        rec = {"worker_pid": os.getpid(),
               "model": session.meta.get("model_id"),
               "version": session.meta.get("version"),
               "req": int(req_id), "shard": int(shard_id), "rows": int(rows),
               "spans": [{"name": n, "t0_rel_us": a / 1e3,
                          "dur_us": (b - a) / 1e3} for n, a, b in spans]}
        with self._span_lock:
            self._span_fh.write(json.dumps(rec) + "\n")
            self._span_fh.flush()


# ---------------------------------------------------------------------------
# local spawning (tests, bench, --workers N)
# ---------------------------------------------------------------------------

def spawn_local_workers(n: int, *, delays=None, span_dir=None,
                        ready_timeout_s: float = 60.0):
    """Spawn ``n`` loopback worker processes; -> (procs, ["host:port"]).

    Each worker prints ``WORKER_READY host:port`` once its listener is
    bound; this blocks until every line arrives (the workers themselves
    stay cheap to start — heavy imports happen at first PREDICT).
    ``delays[i]`` ms makes worker *i* a deliberate straggler.  Span JSONL
    files land in ``span_dir`` (default: ``$REPRO_WORKER_SPAN_DIR``).
    """
    import subprocess

    if span_dir is None:
        span_dir = os.environ.get("REPRO_WORKER_SPAN_DIR")
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs, addrs = [], []
    try:
        for i in range(int(n)):
            cmd = [sys.executable, "-m", "repro.serve.worker",
                   "--listen", "127.0.0.1:0"]
            delay = (delays[i] if delays and i < len(delays) else 0) or 0
            if delay:
                cmd += ["--delay-ms", str(delay)]
            if span_dir:
                os.makedirs(span_dir, exist_ok=True)
                cmd += ["--span-out",
                        os.path.join(span_dir, f"worker_{os.getpid()}_{i}.jsonl")]
            p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                 stderr=subprocess.DEVNULL, text=True, env=env)
            deadline = time.monotonic() + ready_timeout_s
            addr = None
            while time.monotonic() < deadline:
                line = p.stdout.readline()
                if not line:
                    raise RuntimeError(
                        f"worker {i} exited before READY (rc={p.poll()})")
                if line.startswith("WORKER_READY"):
                    addr = line.split()[1]
                    break
            if addr is None:
                p.kill()
                raise RuntimeError(f"worker {i} READY timeout")
            procs.append(p)
            addrs.append(addr)
    except Exception:
        for p in procs:
            p.kill()
            if p.stdout is not None:
                p.stdout.close()
        raise
    return procs, addrs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="repro shard worker: serves uint32 tree-shard partials "
                    "over the ITRG wire protocol")
    ap.add_argument("--listen", default="127.0.0.1:0", metavar="HOST:PORT",
                    help="bind address (port 0 = ephemeral; the bound port "
                         "is printed on the WORKER_READY line)")
    ap.add_argument("--span-out", default=None, metavar="PATH",
                    help="append worker-side request spans as JSONL")
    ap.add_argument("--delay-ms", type=float, default=0.0,
                    help="inject a fixed response delay (straggler testing)")
    args = ap.parse_args(argv)
    host, _, port = args.listen.rpartition(":")
    srv = WorkerServer(host or "127.0.0.1", int(port or 0),
                       span_out=args.span_out, delay_ms=args.delay_ms)
    print(f"WORKER_READY {srv.addr}", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()


if __name__ == "__main__":
    main()

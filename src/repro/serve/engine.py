"""Serving engines.

``LMEngine``: batched prefill + greedy/temperature decode for the LM archs
(jitted prefill and decode steps, KV/state cache carried on device).

``TreeEngine``: the paper's serving path — a packed integer-only ensemble
behind a batched predict() with three implementations (float / flint /
integer jnp, + the Pallas kernel), mirroring InTreeger's deployment story.
It is the execution backend behind the gateway (``repro.serve.gateway``):
incoming batches are padded up to a small set of power-of-two row buckets so
each (model, mode, bucket) compiles exactly once, no matter how ragged the
request stream is.  Tree traversal is row-independent, so padding rows never
perturbs real rows — bucketed outputs are bit-identical to unbucketed ones.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


class LMEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(lambda p, b: tfm.prefill(cfg, p, b, max_seq=max_seq))
        self._decode = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))

    def generate(self, batch: dict, n_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Greedy (T=0) or sampled decode.  Returns (B, n_tokens) int32."""
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        toks = []
        b = logits.shape[0]
        for i in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32).reshape(b, 1)
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(toks, axis=1)


def bucket_rows(b: int, *, max_bucket: int = 4096) -> int:
    """Padded row count for a batch of ``b`` rows: the next power of two,
    capped at ``max_bucket``; beyond the cap, the next ``max_bucket``
    multiple (so huge batches still see a bounded shape vocabulary)."""
    if b <= 0:
        raise ValueError("batch must have at least one row")
    if b >= max_bucket:
        return -(-b // max_bucket) * max_bucket
    return 1 << (b - 1).bit_length()


class TreeEngine:
    """Packed-ensemble execution backend.

    ``predict``/``predict_scores`` accept any row count; internally the batch
    is padded to a :func:`bucket_rows` bucket so the jitted function compiles
    once per bucket (tracked in ``compiled_buckets``).
    """

    def __init__(self, packed, *, mode: str = "integer", use_kernel: bool = False,
                 kernel_kwargs: Optional[dict] = None, max_bucket: int = 4096):
        from repro.core.ensemble import make_predict_fn
        from repro.kernels.ops import packed_predict_integer

        self.packed = packed
        self.mode = mode
        self.max_bucket = max_bucket
        self.compiled_buckets: set[int] = set()
        if use_kernel:
            assert mode == "integer", "the Pallas kernel implements the integer path"
            kw = kernel_kwargs or {}
            self._fn = lambda x: packed_predict_integer(packed, x, **kw)
        else:
            self._fn = make_predict_fn(packed, mode)

    @property
    def deterministic(self) -> bool:
        """True when outputs are bit-exact integer scores (cacheable)."""
        return self.mode in ("flint", "integer")

    def warm(self, max_rows: int) -> None:
        """Compile every power-of-two row bucket up to ``max_rows`` so the
        first live batches don't pay jit latency."""
        nb = 1
        while nb <= max_rows:
            self.predict(np.zeros((nb, self.packed.n_features), np.float32))
            nb *= 2

    def _run(self, X):
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected (B, F) features, got shape {X.shape}")
        b = X.shape[0]
        nb = bucket_rows(b, max_bucket=self.max_bucket)
        if nb != b:
            X = np.concatenate([X, np.zeros((nb - b, X.shape[1]), np.float32)])
        self.compiled_buckets.add(nb)
        scores, preds = self._fn(jnp.asarray(X))
        return np.asarray(scores)[:b], np.asarray(preds)[:b]

    def predict(self, X) -> np.ndarray:
        _, preds = self._run(X)
        return preds

    def predict_scores(self, X):
        return self._run(X)

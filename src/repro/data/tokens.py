"""Synthetic LM token pipeline: deterministic, host-sharded, restart-safe.

Each batch is a pure function of (seed, step), so a restarted job regenerates
exactly the batches it would have seen (checkpoint/restart consistency), and
each host in a multi-host pod generates only its shard by indexing with its
process rank — the same contract a real distributed loader provides.

The token stream is a Zipfian-ish unigram mix with short-range structure
(Markov blending) so cross-entropy actually decreases during the example
training runs instead of flat-lining at ln(V).
"""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq: int, *, seed: int = 0,
                 n_shards: int = 1, shard: int = 0, family: str = "lm", extra: dict | None = None):
        assert batch % n_shards == 0
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.n_shards = n_shards
        self.shard = shard
        self.family = family
        self.extra = extra or {}
        # fixed unigram distribution (Zipf) + per-token successor table
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._successor = rng.integers(0, vocab_size, size=vocab_size)

    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed * 1_000_003 + step) * self.n_shards + self.shard)

    def batch_at(self, step: int) -> dict:
        rng = self._rng_for(step)
        b = self.batch // self.n_shards
        s = self.seq
        iid = rng.choice(self.vocab, size=(b, s), p=self._unigram)
        toks = iid.copy()
        # 50% of positions copy a deterministic successor of the *realized*
        # previous token -> learnable first-order structure
        follow = rng.random((b, s)) < 0.5
        for t in range(1, s):
            toks[:, t] = np.where(follow[:, t], self._successor[toks[:, t - 1]], iid[:, t])
        out = {}
        if self.family == "audio":
            fd = self.extra["frontend_dim"]
            out["frames"] = rng.normal(size=(b, s, fd)).astype(np.float32)
            out["labels"] = toks.astype(np.int32)
            return out
        if self.family == "vlm":
            p = self.extra["vision_patches"]
            fd = self.extra["frontend_dim"]
            out["patches"] = rng.normal(size=(b, p, fd)).astype(np.float32)
            toks = toks[:, : s - p]
        out["tokens"] = toks.astype(np.int32)
        out["labels"] = np.roll(toks, -1, axis=1).astype(np.int32)
        out["labels"][:, -1] = -1  # no target for the final position
        return out


def pipeline_for(cfg, batch: int, seq: int, *, seed: int = 0, n_shards: int = 1, shard: int = 0):
    family = cfg.family if cfg.family in ("audio", "vlm") else "lm"
    extra = {"frontend_dim": cfg.frontend_dim, "vision_patches": cfg.vision_patches}
    return TokenPipeline(
        cfg.vocab_size, batch, seq, seed=seed, n_shards=n_shards, shard=shard,
        family=family, extra=extra,
    )

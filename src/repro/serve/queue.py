"""Async request queue + micro-batcher.

Turns a stream of independent single-row / small-batch submissions into the
block-shaped batches the kernels want: per model, a worker coalesces queued
requests until either ``max_batch_rows`` rows have accumulated or the oldest
request has waited ``max_delay_ms`` (the latency deadline), then dispatches
one engine call and scatters the per-row results back to each caller's
future.  Row outputs are independent of batch composition (tree traversal is
per-row), so coalescing is bit-transparent to callers.

Admission control: each model queue admits at most ``max_queue_rows`` rows;
beyond that ``submit`` fails fast with :class:`AdmissionError` (the
closed-loop client counts these as rejects) instead of letting latency grow
without bound.

Observability: ``submit`` optionally carries the caller's request span; at
dispatch the worker commits one ``queue`` span per pending request (enqueue →
dispatch, the micro-batching wait) under that parent and reports the same
waits to ``on_queue`` for the per-stage metric histograms.  With
``pass_spans=True`` the executor is called as ``execute(model_id, X,
rider_spans)`` so the gateway can graft the shared batch subtree under every
rider request.
"""
from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import Callable, Tuple

import numpy as np

ExecuteFn = Callable[[str, np.ndarray], Tuple[np.ndarray, np.ndarray, int, object]]


class AdmissionError(RuntimeError):
    """Raised when a model's queue is over its admission bound."""


# queued behind every pending request at close(): the lane worker drains all
# real work ahead of it, then exits cleanly instead of being cancelled
_CLOSE = object()


@dataclass
class _Pending:
    X: np.ndarray
    rows: int
    t_enqueue: float
    future: asyncio.Future = field(compare=False)
    span: object = None  # the caller's request span (None/NULL when untraced)


class MicroBatcher:
    """Per-model dynamic batcher.

    ``execute(model_id, X) -> (scores, preds, padded_rows, meta)`` runs a
    formed batch (in a thread so model workers overlap); it is supplied by
    the gateway so the batcher stays policy-only.  ``meta`` is opaque and
    handed back verbatim to every caller in the batch (the gateway uses it
    to learn which model *version* actually served the batch).  Each
    ``submit`` resolves to ``(scores, preds, meta)`` for exactly its rows.

    ``on_queue(model_id, waits_ms)`` (optional) receives each dispatched
    batch's per-request queue waits; ``tracer`` (a ``repro.obs.Tracer``)
    turns those waits into ``queue`` spans under each request's span; with
    ``pass_spans=True`` the executor is called with a third ``rider_spans``
    argument (the batch's request spans, in batch order).
    """

    def __init__(self, execute: ExecuteFn, *, max_batch_rows: int = 256,
                 max_delay_ms: float = 2.0, max_queue_rows: int = 4096,
                 on_batch: Callable[[str, int, int], None] | None = None,
                 on_queue: Callable[[str, list], None] | None = None,
                 close_timeout_s: float = 30.0,
                 tracer=None, pass_spans: bool = False):
        if max_batch_rows <= 0 or max_queue_rows <= 0:
            raise ValueError("batch and queue bounds must be positive")
        self._execute = execute
        self.max_batch_rows = max_batch_rows
        self.max_delay_s = max_delay_ms / 1e3
        self.max_queue_rows = max_queue_rows
        self.close_timeout_s = close_timeout_s
        self._on_batch = on_batch
        self._on_queue = on_queue
        self._tracer = tracer
        self._pass_spans = pass_spans
        self._queues: dict[str, asyncio.Queue] = {}
        self._queued_rows: dict[str, int] = {}
        self._workers: dict[str, asyncio.Task] = {}
        self._closed = False

    # ------------------------------------------------------------- submit
    def _lane(self, model_id: str) -> asyncio.Queue:
        # (re)spawn the lane if it has no live worker — e.g. the gateway is
        # reused across asyncio.run() calls and the old loop tore it down
        w = self._workers.get(model_id)
        if w is None or w.done():
            self._queues[model_id] = asyncio.Queue()
            self._queued_rows[model_id] = 0
            self._workers[model_id] = asyncio.get_running_loop().create_task(
                self._worker(model_id)
            )
        return self._queues[model_id]

    async def submit(self, model_id: str, X: np.ndarray, span=None):
        """Enqueue rows; resolves to (scores, preds, meta) for those rows.
        ``span`` (optional) is the caller's request span — the queue wait and
        batch execution spans are committed under it."""
        if self._closed:
            raise RuntimeError("batcher is closed")
        X = np.atleast_2d(np.asarray(X, np.float32))
        rows = X.shape[0]
        lane = self._lane(model_id)
        if self._queued_rows[model_id] + rows > self.max_queue_rows:
            raise AdmissionError(
                f"{model_id}: queue depth {self._queued_rows[model_id]}+{rows} "
                f"exceeds {self.max_queue_rows} rows"
            )
        fut = asyncio.get_running_loop().create_future()
        self._queued_rows[model_id] += rows
        lane.put_nowait(_Pending(X=X, rows=rows, t_enqueue=time.perf_counter(),
                                 future=fut, span=span))
        return await fut

    # ------------------------------------------------------------- worker
    async def _worker(self, model_id: str) -> None:
        lane = self._queues[model_id]
        loop = asyncio.get_running_loop()
        carry = None  # request that would have overflowed the previous batch
        closing = False  # close() sentinel seen: finish the drain, then exit
        while True:
            first = carry if carry is not None else await lane.get()
            carry = None
            if first is _CLOSE:  # close() with nothing in flight
                return
            batch = [first]
            rows = first.rows
            deadline = first.t_enqueue + self.max_delay_s
            while rows < self.max_batch_rows:
                # greedy drain: work already queued joins the batch for free
                # (this is what keeps occupancy high once the engine is the
                # bottleneck — the deadline only governs *idle* waiting)
                try:
                    nxt = lane.get_nowait()
                except asyncio.QueueEmpty:
                    if closing:
                        break  # nothing can arrive after the sentinel
                    timeout = deadline - time.perf_counter()
                    if timeout <= 0:
                        break
                    try:
                        nxt = await asyncio.wait_for(lane.get(), timeout)
                    except asyncio.TimeoutError:
                        break
                if nxt is _CLOSE:
                    # everything queued ahead of the sentinel still executes;
                    # this batch (and any carry) is the drain
                    closing = True
                    break
                if rows + nxt.rows > self.max_batch_rows:
                    # never exceed max_batch_rows (warmed buckets stop there);
                    # the overflow request opens the next batch instead
                    carry = nxt
                    break
                batch.append(nxt)
                rows += nxt.rows
            self._queued_rows[model_id] -= rows
            # dispatch instant: every pending request's micro-batching wait
            # ends here, together — one queue span per request, one stage
            # sample per request
            t_dispatch = time.perf_counter()
            if self._tracer is not None:
                for p in batch:
                    if p.span:
                        self._tracer.record(
                            "queue", int(p.t_enqueue * 1e9),
                            int(t_dispatch * 1e9), parent=p.span, rows=p.rows,
                        )
            if self._on_queue is not None:
                try:
                    self._on_queue(
                        model_id,
                        [(t_dispatch - p.t_enqueue) * 1e3 for p in batch],
                    )
                except Exception:
                    pass  # metrics callbacks must never take down the lane
            try:
                # concatenate inside the try: ragged feature widths from a
                # misbehaving client must fail its batch, not kill the worker
                X = np.concatenate([p.X for p in batch]) if len(batch) > 1 else batch[0].X
                if self._pass_spans:
                    spans = tuple(p.span for p in batch)
                    scores, preds, padded, meta = await loop.run_in_executor(
                        None, self._execute, model_id, X, spans
                    )
                else:
                    scores, preds, padded, meta = await loop.run_in_executor(
                        None, self._execute, model_id, X
                    )
            except asyncio.CancelledError:  # close() mid-batch: don't strand callers
                for p in batch + ([carry] if carry is not None else []):
                    if not p.future.done():
                        p.future.set_exception(RuntimeError("batcher closed"))
                raise
            except Exception as e:  # scatter the failure to every caller
                for p in batch:
                    if not p.future.done():
                        p.future.set_exception(e)
                if closing and carry is None:
                    return
                continue
            if self._on_batch is not None:
                try:
                    self._on_batch(model_id, rows, padded)
                except Exception:
                    pass  # metrics callbacks must never take down the lane
            off = 0
            for p in batch:
                if not p.future.done():
                    p.future.set_result(
                        (scores[off:off + p.rows], preds[off:off + p.rows], meta)
                    )
                off += p.rows
            if closing and carry is None:
                return

    def queued_rows(self, model_id: str) -> int:
        return self._queued_rows.get(model_id, 0)

    async def close(self) -> None:
        """Drain, then stop.

        Every request enqueued before this call — including batches already
        executing on the engine — runs to completion and resolves its
        future; a ``_CLOSE`` sentinel queued *behind* the pending work tells
        each lane worker to exit once it has drained past it.  Only if a
        lane overruns ``close_timeout_s`` is it cancelled, and only then are
        its remaining callers failed with "batcher closed".
        """
        self._closed = True  # no await above this line: nothing can sneak in
        live = [t for t in self._workers.values() if not t.done()]
        for model_id, t in self._workers.items():
            if not t.done():
                self._queues[model_id].put_nowait(_CLOSE)
        if live:
            _, stragglers = await asyncio.wait(
                live, timeout=self.close_timeout_s
            )
            for t in stragglers:
                t.cancel()
            for t in stragglers:
                try:
                    await t
                except (asyncio.CancelledError, Exception):
                    pass
        # fail anything still queued (only possible on a straggler cancel)
        for model_id, lane in self._queues.items():
            while True:
                try:
                    p = lane.get_nowait()
                except asyncio.QueueEmpty:
                    break
                if p is _CLOSE:
                    continue  # a lane whose worker was already done
                if not p.future.done():
                    p.future.set_exception(RuntimeError("batcher closed"))
            self._queued_rows[model_id] = 0
        self._workers.clear()

"""The serving gateway: cache → micro-batcher → registry → engine → plan.

``Gateway.submit(model_id, X)`` is the one client entry point.  Per row it
first probes the :class:`QuantizedKeyCache` (exact FlInt-key match — safe
because the flint/integer engines are bit-deterministic); rows that miss are
coalesced by the :class:`MicroBatcher` into block-shaped batches and executed
on the :class:`TreeEngine` of the model's *current* registry version for the
gateway's configured ``backend``, ForestIR ``layout``, and execution ``plan``
(reference / pallas / native_c / native_c_table, over padded / ragged /
leaf_major, single-shard or tree-/row-parallel across ``shards`` — all
bit-identical in the deterministic modes, so cache entries stay keyed on
(model, version, mode) only and are shared across every route and every
plan), then inserted into the cache.  The response stitches cached and
computed rows back into request order, so callers always see exactly what a
direct ``TreeEngine.predict_scores`` on their rows would return, bit for
bit.  Each batch dispatch also drains the plan's per-shard wall times into
``serve.metrics`` (``stats()["per_model"][mid]["shards"]``).

Metrics (per-model latency percentiles, throughput, batch occupancy, cache
hit rate, admission rejects) are recorded on every request — including
requests served entirely from cache, which count into the latency histogram
and the ``hit_requests`` counter — and surfaced via ``Gateway.stats()`` /
``Gateway.render_table()``.  Per-stage wall time (queue wait, bucket pad,
shard execute, merge, finalize, cache probe, response stitch) is always
recorded into log-scale histograms (``stats()["per_model"][mid]["stages"]``
and the ``*_ms`` table columns).

Tracing is opt-in: pass ``tracer=repro.obs.Tracer(...)`` and every sampled
request carries a span tree — ``request`` → ``cache_probe`` / ``queue`` /
``batch`` (→ ``pad`` → ``shard:*×N`` → ``merge`` → ``finalize``) →
``stitch``.  A batch shared by several coalesced requests emits ONE batch
subtree, parented under the first live rider and tagged with every rider's
span id (``attrs["riders"]``) so the export layer grafts it under each.
Untraced gateways pay one falsy-check per stage (``NULL_TRACER`` /
``NULL_SPAN`` propagate through every hook).
"""
from __future__ import annotations

import time

import numpy as np

from repro.backends import backend_class
from repro.obs import NULL_TRACER
from repro.serve.cache import QuantizedKeyCache, row_keys
from repro.serve.metrics import MetricsRegistry
from repro.serve.queue import AdmissionError, MicroBatcher
from repro.serve.registry import ModelRegistry


class Gateway:
    def __init__(self, registry: ModelRegistry, spec=None, *, mode: str = None,
                 backend=None, layout: str = None,
                 backend_kwargs: dict = None,
                 plan: str = None, shards: int = None,
                 autotune: bool = None, plan_kwargs: dict = None,
                 max_batch_rows: int = 256,
                 max_delay_ms: float = 2.0, max_queue_rows: int = 4096,
                 cache_rows: int = 65536, tracer=None):
        from repro.serve.spec import EngineSpec

        self.registry = registry
        # NULL_TRACER hands out falsy NULL_SPANs, so every span hook below
        # short-circuits to a no-op when tracing is off
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # the serving route is one EngineSpec (object, dict, or spec string
        # like "integer:bitvector@leaf_major+tree_parallel:4"); the loose
        # keyword arguments remain as the deprecation-shimmed pre-spec API
        spec = EngineSpec.coerce(spec, caller="Gateway", mode=mode,
                                 backend=backend, layout=layout, plan=plan,
                                 shards=shards, backend_kwargs=backend_kwargs,
                                 autotune=autotune)
        self.spec = spec
        self.mode = spec.mode
        self.backend = spec.backend
        self.layout = spec.layout  # None -> backend's preferred ForestIR layout
        # construction-time backend knobs (e.g. native_c_table's block_rows,
        # pallas' impl) — forwarded to every engine this gateway builds
        self.backend_kwargs = \
            dict(spec.backend_kwargs) if spec.backend_kwargs else None
        # execution plan spec: None/"auto"/"single"/"tree_parallel"/
        # "row_parallel"/"remote_tree_parallel" (+ shard count), resolved per
        # engine build.  Resolve once here so an impossible route (partial-
        # merging plans need exact integer partials, which float mode lacks)
        # fails at construction like any other bad route, not on the first
        # request's lazy engine build.
        from repro.core.ensemble import mode_spec
        from repro.plan import plan_class, select_plan

        self.plan = spec.plan
        self.shards = spec.shards
        # deployment knobs for the plan (e.g. the remote plan's ``workers`` /
        # ``deadline_ms``) — forwarded to every engine this gateway builds
        self.plan_kwargs = plan_kwargs
        # arm warm-time measured autotuning on every engine this gateway
        # builds (single-shard tunable routes; see repro.serve.autotune)
        self.autotune = bool(spec.autotune)
        resolved_plan = select_plan(spec.plan, mode=spec.mode,
                                    backend=spec.backend,
                                    shards=spec.shards)  # raises on unknowns
        if plan_class(resolved_plan).deterministic_only \
                and not mode_spec(spec.mode).deterministic:
            raise ValueError(
                f"plan {resolved_plan!r} needs exact integer partials; mode "
                f"{spec.mode!r} accumulates floats — use 'row_parallel' to "
                f"shard"
            )
        self.metrics = MetricsRegistry()
        # every engine this gateway built, so close() can drain and release
        # the executors (thread pools, remote worker processes) they own
        self._engines: dict = {}
        # validate the route up front and let the backends' declared
        # capabilities decide cacheability: the cache is only sound when
        # every shard backend promises bit-deterministic outputs for this
        # mode.  ``backend`` may be a sequence of names (heterogeneous
        # tree-parallel shards) — all of them must agree.
        names = [self.backend] if isinstance(self.backend, str) \
            else list(self.backend)
        deterministic = True
        for name in names:
            caps = backend_class(name).capabilities
            if self.mode not in caps.modes:
                raise ValueError(
                    f"backend {name!r} does not implement mode {self.mode!r}; "
                    f"supported modes: {caps.modes}"
                )
            if self.layout is not None:
                caps.require_layout(self.layout, name)
            deterministic &= self.mode in caps.deterministic_modes
        # cache keys stay (model, version, mode, row-key): deterministic-mode
        # scores are bit-identical across layouts, backends, AND execution
        # plans (the plan-conformance invariant), so entries are shared no
        # matter which route — or how many shards — computed them
        self.cache = QuantizedKeyCache(cache_rows if deterministic else 0)
        self.batcher = MicroBatcher(
            self._execute,
            max_batch_rows=max_batch_rows,
            max_delay_ms=max_delay_ms,
            max_queue_rows=max_queue_rows,
            on_batch=lambda mid, rows, padded: self.metrics.model(mid).record_batch(rows, padded),
            on_queue=self._record_queue_waits,
            tracer=self.tracer,
            pass_spans=True,
        )

    def _record_queue_waits(self, model_id: str, waits_ms: list) -> None:
        mm = self.metrics.model(model_id)
        for w in waits_ms:
            mm.record_stage("queue", w)

    # ----------------------------------------------------------- execution
    def _engine(self, mv):
        eng = mv.engine(self.spec, plan_kwargs=self.plan_kwargs)
        # memoized per route inside the ModelVersion, so this dict stays
        # small: one entry per (version, route) this gateway ever dispatched.
        # Engines the registry's retention policy closed (released versions)
        # are pruned here, so swapped-out versions actually free.
        if any(e.closed for e in self._engines.values()):
            self._engines = {k: e for k, e in self._engines.items()
                             if not e.closed}
        self._engines[id(eng)] = eng
        return eng

    def _execute(self, model_id: str, X: np.ndarray, rider_spans=()):
        """Batch executor handed to the MicroBatcher (runs in a thread).

        ``rider_spans`` are the coalesced requests' spans in batch order.
        The batch subtree (pad → shard×N → merge → finalize) is emitted
        once, parented under the first *live* rider and tagged with every
        rider's span id — the export layer grafts it under each of them.
        """
        mv = self.registry.get(model_id)  # resolve version at dispatch time
        eng = self._engine(mv)
        mm = self.metrics.model(model_id)
        live = [s for s in rider_spans if s]
        batch_span = None
        if live:
            batch_span = self.tracer.child(
                live[0], "batch", model=model_id, rows=len(X),
                riders=[s.span_id for s in live],
            )
        eng.attach_trace(self.tracer, batch_span)
        try:
            scores, preds = eng.predict_scores(X)
        finally:
            eng.detach_trace()
            if batch_span:
                batch_span.end()
        # per-shard + per-stage wall time of this dispatch -> metrics row
        mm.record_shards(eng.drain_shard_timings())
        mm.record_stages(eng.drain_stage_timings())
        mm.record_compiles(eng.drain_compile_timings())
        # dispatched SIMD ISA (free here: the batch above already built the
        # backend, so the probe never triggers a compile) + the autotuned
        # config the engine is serving on, if any
        mm.record_isa(eng.simd_isa())
        mm.record_tuned(eng.tuned_config)
        mm.record_spec(str(self.spec))
        # meta = the version that actually computed, so cache fills are keyed
        # consistently even when a hot-swap lands between submit and dispatch
        return scores, preds, eng.padded_rows(len(X)), mv.version

    # -------------------------------------------------------------- submit
    async def submit(self, model_id: str, X):
        """Serve one request of 1..n rows.  Returns (scores, preds)."""
        t0 = time.perf_counter()
        X = np.atleast_2d(np.asarray(X, np.float32))
        n = X.shape[0]
        if n == 0 or X.size == 0:
            raise ValueError("empty request")
        mm = self.metrics.model(model_id)
        mv = self.registry.get(model_id)
        cacheable = self.cache.capacity_rows > 0
        # NULL_SPAN when tracing is off or this request is unsampled —
        # every child hook below then short-circuits
        span = self.tracer.request_span("request", model=model_id, rows=n)

        tc0 = time.perf_counter_ns()
        keys = row_keys(X) if cacheable else [None] * n
        cached: dict[int, tuple] = {}
        if cacheable:
            for i, rk in enumerate(keys):
                hit = self.cache.get(
                    self.cache.key_for(model_id, mv.version, self.mode, rk)
                )
                if hit is not None:
                    cached[i] = hit
            mm.record_cache(len(cached), n - len(cached))
        tc1 = time.perf_counter_ns()
        mm.record_stage("cache", (tc1 - tc0) / 1e6)
        if span:
            self.tracer.record("cache_probe", tc0, tc1, parent=span,
                               hits=len(cached), rows=n)

        miss_idx = [i for i in range(n) if i not in cached]
        if not miss_idx:
            # served entirely from cache: skip the batcher, count the request
            # into hit_requests, and record latency like any other request —
            # a gateway that timed only its misses would report p50/p95 far
            # worse than what a high-hit-rate client stream experiences.
            scores, preds = self._stitch(n, cached, [], None, None)
            mm.hit_requests += 1
            mm.record_request(n, (time.perf_counter() - t0) * 1e3)
            span.end(cache="all_hit")
            return scores, preds
        try:
            m_scores, m_preds, served_version = await self.batcher.submit(
                model_id, X[miss_idx], span=span
            )
            if cached and served_version != mv.version:
                # a hot-swap landed between the cache probe and dispatch:
                # the hits are from the old version.  Recompute the whole
                # request in ONE batcher call — a single execute runs on a
                # single version, so the response cannot mix versions.
                cached = {}
                miss_idx = list(range(n))
                m_scores, m_preds, served_version = await self.batcher.submit(
                    model_id, X, span=span
                )
        except AdmissionError:
            # rejected requests still advance the throughput span: the
            # gateway was demonstrably live at this instant, and freezing
            # t_first/t_last here skews rows_per_s for everything after
            mm.record_rejected()
            span.end(rejected=True)
            raise
        ts0 = time.perf_counter_ns()
        if cacheable:
            for j, i in enumerate(miss_idx):
                self.cache.put(
                    self.cache.key_for(model_id, served_version, self.mode, keys[i]),
                    m_scores[j], m_preds[j],
                )
        scores, preds = self._stitch(n, cached, miss_idx, m_scores, m_preds)
        ts1 = time.perf_counter_ns()
        mm.record_stage("stitch", (ts1 - ts0) / 1e6)
        if span:
            self.tracer.record("stitch", ts0, ts1, parent=span,
                               cached=len(cached), computed=len(miss_idx))
        mm.record_request(n, (time.perf_counter() - t0) * 1e3)
        span.end()
        return scores, preds

    @staticmethod
    def _stitch(n, cached, miss_idx, m_scores, m_preds):
        """Reassemble cached and computed rows into request order."""
        # shape/dtype from the results themselves: after a mid-request
        # hot-swap the serving version's class count may differ from mv's
        proto = m_scores[0] if m_scores is not None else next(iter(cached.values()))[0]
        scores = np.empty((n, proto.shape[-1]), proto.dtype)
        preds = np.empty(n, np.int32)
        for i, (s_row, p) in cached.items():
            scores[i] = s_row
            preds[i] = p
        for j, i in enumerate(miss_idx):
            scores[i] = m_scores[j]
            preds[i] = m_preds[j]
        return scores, preds

    # ------------------------------------------------------------- control
    async def close(self) -> None:
        """Drain, then tear down.

        The batcher close first *drains*: every batch already dispatched to
        an engine (shard fan-outs in flight on plan thread pools or remote
        workers) runs to completion and resolves its futures; only rows
        still queued un-dispatched are failed.  Engines close after — their
        ``close()`` joins plan executors and, for the remote plan, sends
        CLOSE to every worker connection and reaps spawned worker
        processes — so no in-flight shard dispatch is ever abandoned.
        """
        await self.batcher.close()
        for eng in self._engines.values():
            eng.close()
        self._engines.clear()

    def stats(self) -> dict:
        return {
            "models": self.registry.describe(),
            "per_model": self.metrics.stats(),
            "cache": self.cache.stats(),
        }

    def render_table(self) -> str:
        return self.metrics.render_table()

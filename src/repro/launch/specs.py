"""Abstract input/param/cache specs for the dry-run (ShapeDtypeStruct only —
weak-type-correct, shardable, zero device allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.shapes import SHAPES, TREE_SHAPES
from repro.models import transformer as tfm
from repro.sharding import rules


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape_name: str, mesh, *, with_labels: bool):
    """ShapeDtypeStructs for one global batch of inputs."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    bspec = rules.batch_pspec(mesh, b)
    bax = bspec[0] if len(bspec) else None
    out = {}
    if cfg.family == "audio":
        out["frames"] = _sds((b, s, cfg.frontend_dim), jnp.bfloat16, mesh, P(bax, None, None))
        if with_labels:
            out["labels"] = _sds((b, s), jnp.int32, mesh, P(bax, None))
        return out
    if cfg.family == "vlm":
        st = s - cfg.vision_patches
        out["tokens"] = _sds((b, st), jnp.int32, mesh, P(bax, None))
        out["patches"] = _sds(
            (b, cfg.vision_patches, cfg.frontend_dim), jnp.bfloat16, mesh, P(bax, None, None)
        )
        if with_labels:
            out["labels"] = _sds((b, st), jnp.int32, mesh, P(bax, None))
        return out
    out["tokens"] = _sds((b, s), jnp.int32, mesh, P(bax, None))
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32, mesh, P(bax, None))
    return out


def params_specs(cfg: ModelConfig, mesh):
    shapes = tfm.param_shapes(cfg)
    shardings = rules.params_shardings(shapes, mesh)
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        shapes,
        shardings,
    )


def opt_state_specs(cfg: ModelConfig, mesh):
    from repro.train.optimizer import init_opt_state

    pspecs = params_specs(cfg, mesh)
    shapes = jax.eval_shape(init_opt_state, pspecs)

    def inherit(path, sds):
        name = rules._leaf_name(path)
        if name == "step":
            return jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=NamedSharding(mesh, P()))
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, rules.spec_for(path, sds.shape, mesh))
        )

    return jax.tree_util.tree_map_with_path(inherit, shapes)


def cache_specs(cfg: ModelConfig, shape_name: str, mesh):
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    shapes = jax.eval_shape(lambda: tfm.init_cache(cfg, b, s))
    shard_seq = shape_name == "long_500k"
    shardings = rules.cache_shardings(mesh, shapes, shard_seq=shard_seq)
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh), shapes, shardings
    ), (b, s)


def decode_token_specs(cfg: ModelConfig, shape_name: str, mesh):
    b = SHAPES[shape_name]["batch"]
    bspec = rules.batch_pspec(mesh, b)
    bax = bspec[0] if len(bspec) else None
    return _sds((b, 1), jnp.int32, mesh, P(bax, None))


# --- trees family (the paper's arch) ---------------------------------------

def tree_table_specs(cfg: ModelConfig, mesh):
    t = cfg.n_trees
    n = 2 ** (cfg.tree_depth + 1) - 1
    c = cfg.n_classes
    rep = P()
    return {
        "feature": _sds((t, n), jnp.int32, mesh, rep),
        "threshold_key": _sds((t, n), jnp.int32, mesh, rep),
        "left": _sds((t, n), jnp.int32, mesh, rep),
        "right": _sds((t, n), jnp.int32, mesh, rep),
        "leaf_fixed": _sds((t, n, c), jnp.uint32, mesh, rep),
    }


def tree_input_specs(cfg: ModelConfig, shape_name: str, mesh):
    rows = TREE_SHAPES[shape_name]["rows"]
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    return _sds((rows, cfg.n_tab_features), jnp.int32, mesh, P(axes, None))

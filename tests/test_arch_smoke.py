"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config, smoke_config
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train.step import make_train_step

LM_ARCHS = [a for a in ARCHS if a != "intreeger-rf"]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.frontend_dim)), jnp.float32)
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    elif cfg.family == "vlm":
        st = s - cfg.vision_patches
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st)))
        out["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_patches, cfg.frontend_dim)), jnp.float32
        )
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st)))
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = tfm.forward_logits(cfg, params, batch)
    b = batch.get("tokens", batch.get("frames")).shape[0]
    assert logits.shape[0] == b
    assert logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_one_train_step(arch):
    cfg = smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ostate = opt.init_opt_state(params)
    step = jax.jit(make_train_step(cfg, opt.AdamWConfig(lr=1e-3, warmup_steps=1)))
    batch = _batch(cfg)
    params2, ostate2, metrics = step(params, ostate, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) < 2 * np.log(cfg.vocab_size) + 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, pair: acc or pair,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2),
    )
    assert moved


def test_intreeger_rf_smoke():
    """The paper's own arch: reduced forest end-to-end on CPU."""
    from repro.core.packing import pack_forest
    from repro.core.ensemble import predict_integer, predict_float
    from repro.data.tabular import make_shuttle_like
    from repro.trees.forest import RandomForestClassifier

    cfg = smoke_config("intreeger-rf")
    X, y = make_shuttle_like(n=1500, n_classes=cfg.n_classes, n_features=cfg.n_tab_features, seed=0)
    rf = RandomForestClassifier(n_estimators=cfg.n_trees, max_depth=cfg.tree_depth, seed=0).fit(X, y)
    packed = pack_forest(rf)
    acc, predi = predict_integer(packed, X[:256])
    _, predf = predict_float(packed, X[:256])
    assert acc.shape == (256, cfg.n_classes)
    assert acc.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(predi), np.asarray(predf))


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-27b", "mamba2-370m",
                                  "zamba2-2.7b", "olmoe-1b-7b", "llava-next-34b"])
def test_prefill_decode_consistency(arch):
    """Decode with cache matches the full forward (bf16 tolerance)."""
    cfg = smoke_config(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 32
    if cfg.family == "vlm":
        st_ = s - cfg.vision_patches
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st_)))
        patches = jnp.asarray(rng.normal(size=(b, cfg.vision_patches, cfg.frontend_dim)), jnp.float32)
        full = tfm.forward_logits(cfg, params, {"tokens": toks, "patches": patches})
        _, cache = tfm.prefill(cfg, params, {"tokens": toks[:, :-1], "patches": patches}, max_seq=s)
        logits_d, _ = tfm.decode_step(cfg, params, cache, toks[:, -1:])
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))
        full = tfm.forward_logits(cfg, params, {"tokens": toks})
        _, cache = tfm.prefill(cfg, params, {"tokens": toks[:, : s - 1]}, max_seq=s)
        logits_d, _ = tfm.decode_step(cfg, params, cache, toks[:, s - 1 :])
    ref = np.asarray(full[:, -1])
    got = np.asarray(logits_d)
    assert np.abs(got - ref).max() < 0.08  # bf16 accumulation-order noise
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_exact_config_shapes():
    """The registry carries the exact published configurations."""
    c = get_config("gemma3-27b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == (
        62, 5376, 32, 16, 21504, 262144,
    )
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.experts_per_token, c.n_kv_heads, c.d_ff) == (128, 8, 4, 768)
    c = get_config("granite-34b")
    assert (c.n_layers, c.n_kv_heads) == (88, 1)
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1024, 128)
    c = get_config("olmoe-1b-7b")
    assert (c.n_experts, c.experts_per_token) == (64, 8)
    c = get_config("hubert-xlarge")
    assert c.encoder_only and c.vocab_size == 504
    c = get_config("llava-next-34b")
    assert (c.n_layers, c.d_model, c.n_heads) == (60, 7168, 56)
    c = get_config("starcoder2-3b")
    assert (c.n_kv_heads, c.d_ff) == (2, 12288)
    c = get_config("granite-3-2b")
    assert (c.n_layers, c.vocab_size) == (40, 49155)

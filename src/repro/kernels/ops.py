"""Jitted public wrapper around the tree-traversal Pallas kernel.

Handles padding (batch to ``block_b`` multiples, trees to ``block_t``
multiples with inert self-looping zero-probability trees), VMEM budgeting,
and exposes an ensemble-level entry point.

Layout contract (ForestIR): the kernel consumes dense ``(T, N)`` node tables
— the IR's ``padded`` or ``leaf_major`` materializations (the paper's codegen
step re-targeted at tensors).  ``packed_predict_integer`` accepts a
``ForestIR`` directly and materializes ``padded``; the ``ragged`` layout has
no VMEM-tileable shape and belongs to the table-walk C backend instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flint import float_to_key
from repro.kernels.tree_traverse import tree_traverse_pallas

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # stay well under ~16 MiB v5e VMEM


def pick_blocks(b, t, n, f, c, block_b=256):
    """Choose (block_b, block_t) so the working set fits the VMEM budget."""
    block_b = min(block_b, b)
    for block_t in range(t, 0, -1):
        words = block_b * f + block_t * n * 4 + block_t * n * c + block_b * c
        if words * 4 <= _VMEM_BUDGET_BYTES:
            return block_b, block_t
    return block_b, 1


@partial(jax.jit, static_argnames=("depth", "block_b", "block_t", "impl", "interpret"))
def _traverse_padded(x_keys, feature, key, left, right, leaf, *, depth, block_b, block_t, impl, interpret):
    return tree_traverse_pallas(
        x_keys, feature, key, left, right, leaf,
        depth=depth, block_b=block_b, block_t=block_t, impl=impl, interpret=interpret,
    )


def tree_predict_integer(
    x_keys,
    feature,
    threshold_key,
    left,
    right,
    leaf_fixed,
    *,
    depth: int,
    block_b: int = 256,
    block_t: int | None = None,
    impl: str = "gather",
    interpret: bool = True,
):
    """Integer ensemble inference via the Pallas kernel, any B/T.

    Returns (B, C) uint32 scores, bit-identical to ``ref.tree_predict_integer_ref``.
    """
    x_keys = jnp.asarray(x_keys, jnp.int32)
    b, f = x_keys.shape
    t, n = feature.shape
    c = leaf_fixed.shape[-1]
    auto_b, auto_t = pick_blocks(b, t, n, f, c, block_b)
    block_b = min(block_b, auto_b)
    block_t = block_t or auto_t

    pad_b = (-b) % block_b
    pad_t = (-t) % block_t
    if pad_b:
        x_keys = jnp.pad(x_keys, ((0, pad_b), (0, 0)))
    if pad_t:
        # inert trees: all nodes are self-looping leaves with zero mass
        feature = jnp.pad(feature, ((0, pad_t), (0, 0)), constant_values=-1)
        threshold_key = jnp.pad(threshold_key, ((0, pad_t), (0, 0)))
        selfloop = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (pad_t, n))
        left = jnp.concatenate([left, selfloop], axis=0)
        right = jnp.concatenate([right, selfloop], axis=0)
        leaf_fixed = jnp.pad(leaf_fixed, ((0, pad_t), (0, 0), (0, 0)))

    out = _traverse_padded(
        x_keys, feature, threshold_key, left, right, leaf_fixed,
        depth=depth, block_b=block_b, block_t=block_t, impl=impl, interpret=interpret,
    )
    return out[:b]


def packed_predict_integer(packed, X, **kw):
    """Node-table entry point: float features in, (scores, preds) out.

    ``packed``: a node-table artifact (``PackedEnsemble`` in ``padded`` or
    ``leaf_major`` layout) or a ``ForestIR`` (materialized as ``padded``).
    """
    if hasattr(packed, "materialize"):  # a ForestIR: take the kernel's layout
        packed = packed.materialize("padded")
    layout = getattr(packed, "layout", "padded")
    if layout not in ("padded", "leaf_major"):
        raise ValueError(
            f"the Pallas kernel walks (T, N) node tables, not the {layout!r} "
            "layout; ragged belongs to the table-walk C backend"
        )
    keys = float_to_key(jnp.asarray(X, jnp.float32))
    acc = tree_predict_integer(
        keys,
        jnp.asarray(packed.feature),
        jnp.asarray(packed.threshold_key),
        jnp.asarray(packed.left),
        jnp.asarray(packed.right),
        jnp.asarray(packed.leaf_fixed),
        depth=packed.max_depth,
        **kw,
    )
    return acc, jnp.argmax(acc, axis=1).astype(jnp.int32)

"""The paper's central claims, on our three inference paths:
identical predictions (Sec. IV-B) and Fig. 2 probability-delta magnitudes."""
import numpy as np
import pytest

from repro.core.ensemble import (
    integer_probs,
    make_predict_fn,
    predict_flint,
    predict_float,
    predict_integer,
)
from repro.core.fixedpoint import fixed_to_prob_np, max_abs_error
from repro.core.packing import pack_forest
from repro.data.tabular import make_shuttle_like, train_test_split
from repro.trees.forest import RandomForestClassifier


def test_flint_identical_to_float(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    pf, predf = predict_float(small_packed, Xte)
    pfl, predfl = predict_flint(small_packed, Xte)
    np.testing.assert_array_equal(np.asarray(predf), np.asarray(predfl))
    np.testing.assert_array_equal(np.asarray(pf), np.asarray(pfl))


def test_integer_predictions_identical(small_packed, shuttle_small, small_forest):
    """Paper Sec. IV-B: predictions identical on every sample tested."""
    _, _, Xte, _ = shuttle_small
    _, predf = predict_float(small_packed, Xte)
    acc, predi = predict_integer(small_packed, Xte)
    assert (np.asarray(predi) == np.asarray(predf)).all()


def test_probability_delta_magnitude(small_packed, shuttle_small, small_forest):
    """Fig. 2: deltas ~1e-10 (1 tree) .. ~1e-8 (100 trees); here 9 trees."""
    _, _, Xte, _ = shuttle_small
    oracle = small_forest.predict_proba(Xte)
    acc, _ = predict_integer(small_packed, Xte)
    rec = fixed_to_prob_np(np.asarray(acc), small_packed.n_trees)
    err = np.abs(rec - oracle).max()
    assert err <= max_abs_error(small_packed.n_trees)
    assert err < 1e-8


@pytest.mark.parametrize("n_trees", [1, 10, 40])
def test_paper_repro_multiple_splits(n_trees):
    """Reduced version of the paper's 10-split repetition protocol."""
    X, y = make_shuttle_like(n=3000, seed=11)
    for split_seed in range(3):
        Xtr, ytr, Xte, yte = train_test_split(X, y, seed=split_seed)
        rf = RandomForestClassifier(n_estimators=n_trees, max_depth=5, seed=split_seed).fit(
            Xtr, ytr
        )
        packed = pack_forest(rf)
        _, predf = predict_float(packed, Xte)
        _, predi = predict_integer(packed, Xte)
        assert (np.asarray(predf) == np.asarray(predi)).all()


def test_integer_probs_reconstruction(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    acc, _ = predict_integer(small_packed, Xte[:64])
    probs = np.asarray(integer_probs(small_packed, acc))
    assert probs.shape == (64, small_packed.n_classes)
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-4)


def test_make_predict_fn_jit_paths(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    fns = {m: make_predict_fn(small_packed, m) for m in ("float", "flint", "integer")}
    outs = {m: np.asarray(fn(Xte[:128])[1]) for m, fn in fns.items()}
    np.testing.assert_array_equal(outs["float"], outs["flint"])
    np.testing.assert_array_equal(outs["float"], outs["integer"])

"""Shared degenerate-forest builders for the conformance suites.

Used by ``test_backends.py`` (cross-backend/layout/variant bit-identity) and
``test_plans.py`` (cross-plan bit-identity + ``ForestIR.subset`` round
trips): single-node stumps, a one-tree forest, and a strongly depth-skewed
mix — the packing edge cases padding used to hide.
"""
import numpy as np


def forest_from_trees(trees, n_classes, n_features):
    from repro.trees.forest import RandomForestClassifier

    f = RandomForestClassifier(n_estimators=len(trees))
    f.trees_ = trees
    f.n_classes_ = n_classes
    f.n_features_ = n_features
    return f


def stump(probs):
    """A single-node tree: the root IS the leaf (n_nodes == 1, depth 0)."""
    from repro.trees.cart import TreeArrays

    return TreeArrays(
        feature=np.array([-1], np.int32),
        threshold=np.zeros(1, np.float32),
        left=np.zeros(1, np.int32),
        right=np.zeros(1, np.int32),
        leaf_probs=np.asarray([probs], np.float64),
        depth=0,
    )


def chain_tree(depth, n_classes):
    """A right-leaning chain: node 2k internal on feature 0, node 2k+1 its
    left leaf, final node the rightmost leaf — maximal depth skew."""
    from repro.trees.cart import TreeArrays

    n = 2 * depth + 1
    feature = np.full(n, -1, np.int32)
    threshold = np.zeros(n, np.float32)
    left = np.arange(n, dtype=np.int32)
    right = left.copy()
    probs = np.zeros((n, n_classes), np.float64)
    for k in range(depth):
        node = 2 * k
        feature[node] = 0
        threshold[node] = float(k) - depth / 2.0
        left[node] = node + 1
        right[node] = node + 2
        probs[node + 1, k % n_classes] = 1.0
    probs[n - 1, (depth + 1) % n_classes] = 1.0
    return TreeArrays(feature=feature, threshold=threshold, left=left,
                      right=right, leaf_probs=probs, depth=depth)


DEGENERATE_FORESTS = {
    # every tree is a single-node stump (n_nodes == 1, max_depth == 0)
    "stumps": lambda: forest_from_trees(
        [stump([1.0, 0.0, 0.0]), stump([0.0, 0.5, 0.5]),
         stump([0.25, 0.25, 0.5])], 3, 4),
    # a forest of exactly one (non-trivial) tree
    "single_tree": lambda: forest_from_trees([chain_tree(3, 3)], 3, 4),
    # one deep chain among stumps: ragged's O(sum nodes) vs padded's
    # O(T * max nodes) worst case, plus mixed per-tree depths in one walk
    "depth_skewed": lambda: forest_from_trees(
        [chain_tree(11, 3), stump([0.0, 1.0, 0.0]), stump([0.6, 0.2, 0.2])],
        3, 4),
}

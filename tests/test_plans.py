"""Execution-plan conformance: the sharded-serving layer's anchor suite.

The paper's integer-only accumulation makes the ensemble sum associative, so
a forest can be carved across devices or backends and the partial scores
merged with zero precision loss.  This suite pins that as an invariant: for
the deterministic modes (flint/integer), every execution plan — single-shard,
tree-parallel over {2, 3, 8} shards (threaded per-shard backends, or one
shard_map'd device computation when XLA exposes enough devices — ``make
conformance`` forces 8 host devices to run that path for real), row-parallel
over {2, 4} shards, and heterogeneous tree-parallel plans mixing two
backends — must produce scores *bit-identical* to the single-shard reference,
through every (backend, layout) route, on randomized AND degenerate forests.

Plus: ``ForestIR.subset`` round trips (slice bit-identity, partial-sum
re-concatenation, quantization-scale carrying), capability-driven plan
auto-selection, warm() covering every shard, and per-shard timing drains.

Run with ``make conformance``.
"""
import numpy as np
import pytest

from forest_cases import DEGENERATE_FORESTS
from repro.backends import backend_class, create_backend
from repro.core.ensemble import finalize_partials
from repro.ir import ForestIR
from repro.plan import (
    RowParallelPlan,
    SingleShardPlan,
    TreeParallelPlan,
    available_plans,
    create_plan,
    plan_class,
    select_plan,
    thread_shard_cap,
    tree_ranges,
)
from repro.serve.engine import TreeEngine

ALL_BACKENDS = [
    "reference",
    "pallas",
    "bitvector",
    pytest.param("native_c", marks=pytest.mark.requires_gcc),
    pytest.param("native_c_table", marks=pytest.mark.requires_gcc),
    pytest.param("native_c_bitvector", marks=pytest.mark.requires_gcc),
]

# the acceptance matrix: every plan spec below x every backend x its layouts
PLAN_SPECS = [
    ("single", None),
    ("tree_parallel", 2),
    ("tree_parallel", 3),
    ("tree_parallel", 8),
    ("row_parallel", 2),
    ("row_parallel", 4),
]


def _scores(obj, rows):
    s, p = obj.predict_scores(rows)
    return np.asarray(s), np.asarray(p)


def _layout_mode_pairs(backend):
    caps = backend_class(backend).capabilities
    return [(lay, mode) for lay in caps.supported_layouts
            for mode in caps.deterministic_modes]


@pytest.fixture(scope="module")
def probe_rows(shuttle_small):
    _, _, Xte, _ = shuttle_small
    return Xte[:33]  # odd row count: partial row-parallel chunks + padding


@pytest.fixture(scope="module")
def reference_scores(small_packed, probe_rows):
    """One single-shard reference run per mode; every plan case reuses it."""
    return {
        mode: _scores(create_backend("reference", small_packed, mode=mode),
                      probe_rows)
        for mode in ("flint", "integer")
    }


# ------------------------------------------------------------------ registry

def test_plan_registry_contents():
    assert {"single", "tree_parallel", "row_parallel"} <= set(available_plans())
    with pytest.raises(KeyError, match="single"):
        plan_class("no-such-plan")


def test_plan_auto_selection(small_packed):
    sel = lambda **kw: select_plan(None, **{"backend": "reference", **kw})
    assert sel(mode="integer") == "single"
    assert sel(mode="integer", shards=1) == "single"
    assert sel(mode="integer", shards=4, model=small_packed) == "tree_parallel"
    assert sel(mode="flint", shards=2, model=small_packed) == "tree_parallel"
    # float has no integer partials -> shard the batch instead
    assert sel(mode="float", shards=4, model=small_packed) == "row_parallel"
    # a sequence of backends IS a heterogeneous tree-parallel request
    assert select_plan(None, mode="integer",
                       backend=("reference", "pallas")) == "tree_parallel"
    # explicit names pass through; unknown ones fail fast
    assert select_plan("row_parallel", mode="integer",
                       backend="reference", shards=8) == "row_parallel"
    with pytest.raises(KeyError, match="no-such"):
        select_plan("no-such-plan", mode="integer", backend="reference")


def test_tree_parallel_rejects_float(small_packed):
    with pytest.raises(ValueError, match="partials"):
        create_plan("tree_parallel", small_packed, mode="float", shards=2)


def test_single_plan_rejects_multi_shards(small_packed):
    with pytest.raises(ValueError, match="single"):
        create_plan("single", small_packed, mode="integer", shards=3)


def test_tree_ranges_contiguous_and_capped():
    assert tree_ranges(9, 3) == [(0, 3), (3, 6), (6, 9)]
    assert tree_ranges(9, 2) == [(0, 4), (4, 9)] or \
        tree_ranges(9, 2) == [(0, 5), (5, 9)]
    # more shards than trees: empties dropped, one tree per shard
    assert tree_ranges(3, 8) == [(0, 1), (1, 2), (2, 3)]
    spans = tree_ranges(11, 4)
    assert spans[0][0] == 0 and spans[-1][1] == 11
    assert all(a2 == b1 for (_, b1), (a2, _) in zip(spans[:-1], spans[1:]))


def test_threaded_shards_clamped_to_core_budget(small_packed, probe_rows,
                                                reference_scores, monkeypatch):
    """BENCH_7 regression: oversubscribed threaded fan-out (s4/s8 on a 1-core
    host ran 1.4-1.8x slower than single-shard) is clamped to the core budget
    — and clamping never perturbs the merged partials."""
    monkeypatch.setattr("os.cpu_count", lambda: 2)
    assert thread_shard_cap() == 2
    ir = small_packed.to_ir()
    thr = {"device_parallel": False}
    eng = TreeEngine(ir, mode="integer", plan="tree_parallel", shards=8,
                     plan_kwargs=thr)
    assert not eng.plan.fused and eng.n_shards == 2
    s, p = _scores(eng, probe_rows)
    np.testing.assert_array_equal(s, reference_scores["integer"][0])
    np.testing.assert_array_equal(p, reference_scores["integer"][1])
    # the floor keeps two shards even on a single core (s2 beat single there)
    monkeypatch.setattr("os.cpu_count", lambda: 1)
    assert thread_shard_cap() == 2
    # clamp_shards=False opts out — scaling benches measure the full sweep
    eng = TreeEngine(ir, mode="integer", plan="tree_parallel", shards=8,
                     plan_kwargs={**thr, "clamp_shards": False})
    assert eng.n_shards == min(8, ir.n_trees)
    # an explicit heterogeneous mix is an explicit fan-out request: honored
    eng = TreeEngine(ir, mode="integer",
                     backend=("reference", "reference", "reference",
                              "reference"), plan_kwargs=thr)
    assert eng.n_shards == min(4, ir.n_trees)
    import jax

    if len(jax.devices()) >= 8:  # the forced-device conformance config
        # the fused shard_map path is never capped: devices are not cores
        eng = TreeEngine(ir, mode="integer", plan="tree_parallel", shards=8)
        assert eng.plan.fused and eng.n_shards == min(8, ir.n_trees)


# ----------------------------------------------------- the acceptance matrix

@pytest.mark.parametrize("plan,shards", PLAN_SPECS,
                         ids=[f"{p}-{s}" for p, s in PLAN_SPECS])
@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_plan_bit_identity_randomized(small_packed, probe_rows,
                                      reference_scores, backend, plan, shards):
    """flint/integer scores bit-identical across {single, tree_parallel(2,3,8),
    row_parallel(2,4)} x all four backends x every layout each declares."""
    ir = small_packed.to_ir()
    for layout, mode in _layout_mode_pairs(backend):
        s_ref, p_ref = reference_scores[mode]
        eng = TreeEngine(ir, mode=mode, backend=backend, layout=layout,
                         plan=plan, shards=shards)
        s, p = _scores(eng, probe_rows)
        np.testing.assert_array_equal(
            s, s_ref, err_msg=f"{plan}({shards})/{backend}/{layout}/{mode}")
        np.testing.assert_array_equal(
            p, p_ref, err_msg=f"{plan}({shards})/{backend}/{layout}/{mode}")
        assert eng.plan_name == plan
        if plan == "tree_parallel":
            # the fused device path keeps the requested carve; the threaded
            # path additionally caps fan-out at the host's core budget
            want = min(shards, ir.n_trees)
            if not eng.plan.fused:
                want = min(want, thread_shard_cap())
            assert eng.n_shards == want


@pytest.mark.parametrize("plan,shards",
                         [("tree_parallel", 3), ("row_parallel", 2)])
@pytest.mark.parametrize("case", sorted(DEGENERATE_FORESTS))
def test_plan_bit_identity_degenerate(case, plan, shards):
    """Stumps, T == 1, and depth-skewed forests through the sharded plans:
    subsetting must survive single-node trees and shard counts exceeding the
    tree count (tree_parallel over one tree degenerates to single-shard)."""
    ir = ForestIR.from_forest(DEGENERATE_FORESTS[case]())
    rng = np.random.default_rng(hash(case) % 2**32)
    rows = rng.normal(0.0, 6.0, (19, ir.n_features)).astype(np.float32)
    for mode in ("flint", "integer"):
        s_ref, p_ref = _scores(
            create_backend("reference", ir.materialize("padded"), mode=mode),
            rows,
        )
        eng = TreeEngine(ir, mode=mode, plan=plan, shards=shards)
        s, p = _scores(eng, rows)
        np.testing.assert_array_equal(s, s_ref, err_msg=f"{plan}/{case}/{mode}")
        np.testing.assert_array_equal(p, p_ref, err_msg=f"{plan}/{case}/{mode}")


def test_heterogeneous_tree_parallel_reference_plus_pallas(
        small_packed, probe_rows, reference_scores):
    """A tree-parallel plan mixing two *different* backends — half the forest
    on the jnp walk, half on the Pallas kernel — stays bit-identical."""
    for mode in ("flint", "integer"):
        s_ref, p_ref = reference_scores[mode]
        eng = TreeEngine(small_packed, mode=mode,
                         backend=("reference", "pallas"), shards=2)
        assert eng.plan_name == "tree_parallel"
        assert [b.name for b in eng.plan.backends] == ["reference", "pallas"]
        # each shard materializes its own preferred layout from one IR
        assert eng.layout == "padded+leaf_major"
        s, p = _scores(eng, probe_rows)
        np.testing.assert_array_equal(s, s_ref, err_msg=f"hetero/{mode}")
        np.testing.assert_array_equal(p, p_ref, err_msg=f"hetero/{mode}")


@pytest.mark.requires_gcc
def test_heterogeneous_tree_parallel_with_compiled_c(
        small_packed, probe_rows, reference_scores):
    """Heterogeneous across the jnp/compiled-C divide: shards on the ragged
    table-walk C and the reference walk, cycled over 3 shards."""
    s_ref, p_ref = reference_scores["integer"]
    eng = TreeEngine(small_packed, mode="integer",
                     backend=("native_c_table", "reference"), shards=3)
    assert [b.name for b in eng.plan.backends] == \
        ["native_c_table", "reference", "native_c_table"]
    s, p = _scores(eng, probe_rows)
    np.testing.assert_array_equal(s, s_ref)
    np.testing.assert_array_equal(p, p_ref)


def test_fused_and_threaded_tree_parallel_agree(small_packed, probe_rows):
    """The two tree-parallel strategies (shard_map fused vs per-shard
    threaded backends) are bit-identical; which one runs depends on the
    device count, and forcing threads must always work."""
    ir = small_packed.to_ir()
    eng_auto = TreeEngine(ir, mode="integer", plan="tree_parallel", shards=2)
    eng_thr = TreeEngine(ir, mode="integer", plan="tree_parallel", shards=2,
                         plan_kwargs={"device_parallel": False})
    assert not eng_thr.plan.fused
    s_a, p_a = _scores(eng_auto, probe_rows)
    s_t, p_t = _scores(eng_thr, probe_rows)
    np.testing.assert_array_equal(s_a, s_t)
    np.testing.assert_array_equal(p_a, p_t)
    import jax

    if len(jax.devices()) >= 2:  # the forced-device conformance config
        assert eng_auto.plan.fused


def test_engine_partials_match_scores(small_packed, probe_rows):
    """Engine-level predict_partials == the integer scores, through the
    bucketed path, for single and sharded plans alike."""
    for plan, shards in (("single", None), ("tree_parallel", 3),
                         ("row_parallel", 2)):
        eng = TreeEngine(small_packed, mode="integer", plan=plan, shards=shards)
        acc = eng.predict_partials(probe_rows)
        s, _ = _scores(eng, probe_rows)
        np.testing.assert_array_equal(acc, s, err_msg=f"{plan}")


# --------------------------------------------------- ForestIR.subset round trips

def test_subset_slices_are_bit_identical(small_packed):
    ir = small_packed.to_ir()
    sub = ir.subset(2, 5)
    assert sub.n_trees == 3
    lo, hi = int(ir.node_offsets[2]), int(ir.node_offsets[5])
    for name in ("feature", "threshold", "threshold_key", "left", "right",
                 "leaf_probs", "leaf_fixed"):
        np.testing.assert_array_equal(getattr(sub, name),
                                      getattr(ir, name)[lo:hi])
    np.testing.assert_array_equal(sub.node_offsets,
                                  ir.node_offsets[2:6] - lo)
    np.testing.assert_array_equal(sub.tree_depths, ir.tree_depths[2:5])
    # the parent's quantization scale rides along — never recomputed from
    # the subset's smaller tree count
    assert sub.scale == ir.scale
    assert sub.scale != ir.subset(0, 2).n_trees  # sanity: not scale_for(2)
    assert sub.materialize("padded").scale == ir.scale
    assert sub.materialize("ragged").scale == ir.scale
    # slice syntax and bounds checking
    assert ir.subset(slice(2, 5)).n_trees == 3
    full = ir.subset(0, ir.n_trees)
    np.testing.assert_array_equal(full.feature, ir.feature)
    with pytest.raises(ValueError, match="out of bounds"):
        ir.subset(0, ir.n_trees + 1)
    with pytest.raises(ValueError, match="out of bounds"):
        ir.subset(3, 3)
    with pytest.raises(ValueError, match="contiguous"):
        ir.subset(slice(0, 4, 2))


@pytest.mark.parametrize("splits", [2, 3, 9], ids=["s2", "s3", "s9"])
def test_subset_partials_reconcat_bit_identical(small_packed, shuttle_small,
                                                splits):
    """Subsetting then re-summing partial scores == the full forest, and
    finalize over the merged partials == full-forest flint scores."""
    _, _, Xte, _ = shuttle_small
    rows = Xte[:29]
    ir = small_packed.to_ir()
    full = np.asarray(
        create_backend("reference", small_packed, mode="integer").predict_partials(rows)
    )
    merged = np.zeros_like(full)
    for a, b in tree_ranges(ir.n_trees, splits):
        sub = ir.subset(a, b)
        merged = merged + np.asarray(
            create_backend("reference", sub.materialize("padded"),
                           mode="integer").predict_partials(rows)
        )
    np.testing.assert_array_equal(merged, full)
    s_fl, p_fl = finalize_partials("flint", merged, ir.n_trees, ir.scale)
    s_ref, p_ref = _scores(
        create_backend("reference", small_packed, mode="flint"), rows)
    np.testing.assert_array_equal(s_fl, s_ref)
    np.testing.assert_array_equal(p_fl, p_ref)


@pytest.mark.parametrize("case", sorted(DEGENERATE_FORESTS))
def test_subset_roundtrip_degenerate(case):
    """Single-tree, stump, and depth-skewed forests: per-tree subsets re-sum
    to the full partials, and a whole-forest subset is a no-op."""
    ir = ForestIR.from_forest(DEGENERATE_FORESTS[case]())
    rng = np.random.default_rng(hash(case) % 2**31)
    rows = rng.normal(0.0, 5.0, (17, ir.n_features)).astype(np.float32)
    full = np.asarray(
        create_backend("reference", ir.materialize("padded"),
                       mode="integer").predict_partials(rows)
    )
    merged = np.zeros_like(full)
    for t in range(ir.n_trees):  # one shard per tree — the finest carve
        sub = ir.subset(t, t + 1)
        assert sub.n_trees == 1 and sub.scale == ir.scale
        merged = merged + np.asarray(
            create_backend("reference", sub.materialize("padded"),
                           mode="integer").predict_partials(rows)
        )
    np.testing.assert_array_equal(merged, full)


# ------------------------------------------------------------- warm + timing

def test_warm_covers_every_shard(small_packed, monkeypatch):
    """warm() must pre-compile the *shard-level* shapes (row chunks, not just
    whole-forest buckets): the first post-warm predict presents no new shape
    to any shard backend, i.e. no compile happens on the request path."""
    from repro.backends.reference import ReferenceBackend

    seen = []
    orig = ReferenceBackend.predict_partials

    def spy(self, X):
        seen.append((id(self), np.asarray(X).shape[0]))
        return orig(self, X)

    monkeypatch.setattr(ReferenceBackend, "predict_partials", spy)
    for plan, shards in (("row_parallel", 4), ("tree_parallel", 3)):
        eng = TreeEngine(small_packed, mode="integer", plan=plan,
                         shards=shards, max_bucket=16,
                         plan_kwargs=({"device_parallel": False}
                                      if plan == "tree_parallel" else None))
        seen.clear()
        eng.warm(16)
        warm_shapes = set(seen)
        assert warm_shapes, plan  # warm really drove the shard backends
        seen.clear()
        for b in (1, 5, 13, 16):
            eng.predict(np.zeros((b, small_packed.n_features), np.float32))
        assert set(seen) <= warm_shapes, f"{plan}: post-warm shapes compiled"


def test_plan_shard_timings_drain(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", plan="tree_parallel",
                     shards=3, plan_kwargs={"device_parallel": False})
    eng.predict_scores(Xte[:8])
    t = eng.drain_shard_timings()
    assert len(t) == min(3, thread_shard_cap())  # threaded -> core-capped
    for label, (ms, calls) in t.items():
        assert label.startswith("s") and ms >= 0 and calls == 1
    assert eng.drain_shard_timings() == {}  # drained


def test_gateway_surfaces_shard_timings(small_forest, shuttle_small):
    import asyncio

    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", plan="tree_parallel", shards=2,
                 max_delay_ms=1.0)
    asyncio.run(gw.submit("m", Xte[:8]))
    asyncio.run(gw.close())
    shards = gw.stats()["per_model"]["m"]["shards"]
    assert shards and all(v["calls"] >= 1 for v in shards.values())

"""RowParallelPlan: shard the batch, concatenate the results.

Tree traversal is row-independent, so splitting a batch across concurrent
executions of the same backend artifact changes *nothing* about any row's
accumulation — row-parallel outputs are bit-identical to single-shard for
every mode, float included (the one plan that can shard the
non-deterministic mode).  The shards share one backend instance: jitted JAX
functions and the compiled-C ctypes entry are both reentrant and release the
GIL, so chunks genuinely overlap; what row-parallel buys is latency on large
batches for shape-oblivious backends and multi-core hosts.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.plan.base import ExecutionPlan, build_backend, register_plan

_DEFAULT_SHARDS = 2


@register_plan
class RowParallelPlan(ExecutionPlan):
    name = "row_parallel"

    def __init__(self, model, *, mode: str = "integer", backend="reference",
                 shards=None, layout: Optional[str] = None,
                 backend_kwargs: Optional[dict] = None):
        self.backend = build_backend(backend, model, mode, layout, backend_kwargs)
        super().__init__(self.backend.packed, mode=self.backend.mode)
        self.shards = int(shards or _DEFAULT_SHARDS)
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self._pool = None  # created lazily, released by close()

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.shards, thread_name_prefix="row-shard"
            )
        return self._pool

    def close(self) -> None:
        """Drain in-flight chunk dispatches and release the pool (lazily
        re-created on the next predict, like tree_parallel)."""
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # ------------------------------------------------------------ execution
    def _chunks(self, X):
        """Contiguous near-equal row chunks; short batches use fewer shards."""
        X = np.asarray(X, np.float32)
        return [c for c in np.array_split(X, self.shards) if len(c)]

    def _scatter(self, X, method):
        chunks = self._chunks(X)
        # capture the parent span here, on the dispatching thread
        parent = self.trace_parent
        pool = self._ensure_pool()
        futs = [
            pool.submit(self._timed, f"r{i}/{len(chunks)}", method, c,
                        span_parent=parent)
            for i, c in enumerate(chunks)
        ]
        return [f.result() for f in futs]

    def _merged(self, parts, parent):
        """Concatenate row chunks under a timed ``merge`` stage/span."""
        t0 = time.perf_counter_ns()
        out = np.concatenate([np.asarray(p) for p in parts])
        t1 = time.perf_counter_ns()
        self._record_stage("merge", (t1 - t0) / 1e9)
        self._span("merge", t0, t1, parent, shards=len(parts))
        return out

    def predict_partials(self, X):
        if not self.deterministic:
            raise NotImplementedError(
                f"mode {self.mode!r} has no integer partials; row_parallel "
                "serves it through predict_scores"
            )
        parent = self.trace_parent
        return self._merged(self._scatter(X, self.backend.predict_partials),
                            parent)

    def predict_scores(self, X):
        if self.deterministic:
            return super().predict_scores(X)  # finalize(concatenated partials)
        parent = self.trace_parent
        outs = self._scatter(X, self.backend.predict_scores)
        t0 = time.perf_counter_ns()
        scores = np.concatenate([np.asarray(s) for s, _ in outs])
        preds = np.concatenate([np.asarray(p) for _, p in outs])
        t1 = time.perf_counter_ns()
        self._record_stage("merge", (t1 - t0) / 1e9)
        self._span("merge", t0, t1, parent, shards=len(outs))
        return scores, preds

    # -------------------------------------------------------------- metadata
    @property
    def backends(self) -> tuple:
        return (self.backend,)

    @property
    def packed(self):
        return self.backend.packed

    @property
    def n_shards(self) -> int:
        return self.shards

    def describe(self) -> dict:
        d = super().describe()
        d.update(shards=self.shards)
        return d

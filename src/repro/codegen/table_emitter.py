"""Vectorizable table-walk C: the ragged layout compiled data-as-arrays.

The paper's deliverable (``c_emitter.emit_c``) encodes the forest *in the
instruction stream* — one if-else cascade per tree, FlInt keys and fixed-point
leaves as immediates.  That is ideal for MCU-class single-row inference but
branchy at batch: every row takes a data-dependent path through thousands of
conditional jumps.  This emitter is the other point in the design space the
paper's architecture discussion motivates: the forest as *static data* (the
``ragged`` ForestIR layout — CSR node arrays with per-tree roots and global
child indices) plus one generic walk loop

    node = root[t];
    while (feature[node] >= 0)
      node = (data[feature[node]] <= key[node]) ? left[node] : right[node];

whose code footprint is O(1) in forest size instead of O(total_nodes).

``block_rows=R`` selects the row-blocked variant (the memory-layout/blocking
optimization line of Koschel et al. and FLInt): node records are emitted
*interleaved* — one ``(feature, key, left, right)`` quad per node, so a walk
step touches one cache line instead of four arrays — and ``predict_batch``
walks R rows through each tree in lockstep.  The R walk states live in
registers (the emitter unrolls the row loop; a runtime-bounded loop would
spill the state to the stack every step), every child select is an
arithmetic mask — branchless, so the data-dependent 50%-mispredict branch
of the scalar walk disappears — and one well-predicted test per level exits
as soon as all R rows sit on leaves.  The R independent dependent-load
chains give the memory-level parallelism a single row's serial walk cannot,
and tree-major order keeps each tree's nodes cache-hot across the rows in
flight.

The blocked file also carries *explicit SIMD* walkers over the same quads —
AVX2 on x86-64 (8 rows per ``__m256i``: one gather per quad field, a
sign-bit movemask for the all-leaves exit, ``blendv`` child selects) and
NEON on aarch64 (4 lanes, per-lane quad loads + vector compare/select) —
selected at *runtime*: ``predict_batch`` dispatches via
``__builtin_cpu_supports("avx2")`` (NEON is baseline on aarch64) and falls
back to the scalar blocked walk, which remains mandatory: SIMD blocks are
compiled only under ``__GNUC__`` on a matching arch and are disabled
entirely by ``-DREPRO_NO_SIMD`` (the compile-flags degradation CI job), so
a no-intrinsics build is the scalar file plus a dispatcher that always says
``scalar``.  The selected ISA is exported as ``const char* simd_isa(void)``.
The AVX2 walker is a per-function ``target("avx2")`` attribute, NOT a
file-level ``-mavx2``: the rest of the translation unit (scalar fallback
included) must stay executable on non-AVX2 hosts, which file-level flags
would silently break by letting gcc auto-vectorize the fallback.  Every
walker applies each row's accumulation in the same per-tree order, so
scores are bit-identical across scalar/AVX2/NEON dispatch.

Modes mirror the deterministic pair: ``integer`` (int32 FlInt compares,
uint32 fixed-point adds — bit-identical to every other backend) and ``flint``
(int32 compares, float32 adds in the same per-tree order plus the same
precomputed-reciprocal ensemble average the reference path lowers to).
Blocking never reorders any single row's accumulation, so scores stay
bit-identical at every block size.  The emitted file needs only <stdint.h>.
"""
from __future__ import annotations

import numpy as np

from repro.codegen.c_emitter import _c_float, emit_predict_class

_VALS_PER_LINE = 12


def _i32(v: int) -> str:
    v = int(v)
    # INT32_MIN has no negatable literal form in C; every other value is fine
    return "(-2147483647-1)" if v == -(1 << 31) else str(v)


def _array_lines(name: str, ctype: str, values, fmt) -> list:
    lines = [f"static const {ctype} {name}[{len(values)}] = {{"]
    for i in range(0, len(values), _VALS_PER_LINE):
        chunk = ", ".join(fmt(v) for v in values[i:i + _VALS_PER_LINE])
        lines.append(f"  {chunk},")
    lines.append("};")
    return lines


def emit_table_walk_c(ragged, mode: str = "integer", block_rows: int = None) -> str:
    """Emit a standalone table-walk C file for a ragged ensemble.

    Same entry-point contract as ``c_emitter.emit_c`` — ``predict(data,
    result)`` over FlInt int32 keys plus a comparison-only ``predict_class`` —
    so the shared batch entry (``emit_batch_entry``) and the test harness
    compose with it unchanged.

    ``block_rows=R`` switches the node storage to interleaved quads and
    additionally emits the row-blocked ``predict_batch`` (see module
    docstring): R register-resident walk states per tree, branch-free
    arithmetic child selects, an all-leaves early exit per level, and a
    scalar-``predict`` tail for the final partial block.
    """
    assert mode in ("integer", "flint"), (
        "the table walk serves the deterministic integer-compare modes; "
        "float thresholds would reintroduce the FPU the paper removes"
    )
    t, c = ragged.n_trees, ragged.n_classes
    total = ragged.total_nodes
    acc_t = "uint32_t" if mode == "integer" else "float"
    lines = ["#include <stdint.h>", ""]
    if block_rows is not None:
        lines += _simd_prelude()
        lines.append("")
    lines.append(
        f"/* InTreeger table-walk ensemble ({mode} mode): ragged ForestIR layout\n"
        f"   as static data. trees={t} classes={c} nodes={total}"
        + (f" scale={ragged.scale}" if mode == "integer" else "")
        + (f" block_rows={int(block_rows)}" if block_rows is not None else "")
        + " */"
    )
    if block_rows is None:
        lines += _array_lines("node_feature", "int32_t", ragged.feature, _i32)
        lines += _array_lines("node_key", "int32_t", ragged.threshold_key, _i32)
        lines += _array_lines("node_left", "int32_t", ragged.left, _i32)
        lines += _array_lines("node_right", "int32_t", ragged.right, _i32)
        feat = "node_feature[{n}]"
        key = "node_key[{n}]"
        left = "node_left[{n}]"
        right = "node_right[{n}]"
    else:
        # interleaved (feature, key, left, right) records: one walk step
        # touches one 16-byte quad instead of four distinct arrays
        quad = np.stack(
            [ragged.feature, ragged.threshold_key, ragged.left, ragged.right],
            axis=1,
        ).reshape(-1)
        lines += _array_lines("node_quad", "int32_t", quad, _i32)
        feat = "node_quad[4 * (long)({n})]"
        key = "node_quad[4 * (long)({n}) + 1]"
        left = "node_quad[4 * (long)({n}) + 2]"
        right = "node_quad[4 * (long)({n}) + 3]"
    if mode == "integer":
        leaf_vals = ragged.leaf_fixed.reshape(-1)
        lines += _array_lines(
            "node_leaf", "uint32_t", leaf_vals, lambda v: f"{int(v)}u"
        )
    else:
        leaf_vals = ragged.leaf_probs.reshape(-1)
        lines += _array_lines("node_leaf", "float", leaf_vals, _c_float)
    lines += _array_lines("tree_root", "int32_t", ragged.roots, _i32)
    lines += [
        "",
        f"void predict(const int32_t* data, {acc_t}* result) {{",
        f"  for (int i = 0; i < {c}; ++i) result[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int32_t node = tree_root[t];",
        f"    int32_t f = {feat.format(n='node')};",
        "    while (f >= 0) {",
        f"      node = (data[f] <= {key.format(n='node')}) ? "
        f"{left.format(n='node')} : {right.format(n='node')};",
        f"      f = {feat.format(n='node')};",
        "    }",
        f"    const {acc_t}* leaf = node_leaf + (long)node * {c};",
        f"    for (int i = 0; i < {c}; ++i) result[i] += leaf[i];",
        "  }",
    ]
    if mode == "flint":
        # same precomputed float32 reciprocal the reference path's `acc / n`
        # lowers to, applied in the same place -> bit-identical averages
        rcp = np.float32(1.0) / np.float32(t)
        lines.append(f"  for (int i = 0; i < {c}; ++i) result[i] *= {_c_float(rcp)};")
    lines += ["}", ""]
    lines += emit_predict_class(c, acc_t, "int32_t")
    if block_rows is not None:
        lines += _emit_blocked_batch(ragged, mode, acc_t, int(block_rows))
    return "\n".join(lines)


def _emit_blocked_batch(ragged, mode: str, acc_t: str, block_rows: int) -> list:
    """The row-blocked ``predict_batch``: R walk chains per tree in registers.

    The emitter unrolls the row dimension so each chain is a named local —
    gcc keeps them in registers and the R dependent-load chains issue
    independently.  Per level it preloads every chain's node feature, takes
    one well-predicted exit branch when their AND is negative (all leaves:
    ``feature == -1`` is all-ones, and only an all-negative set keeps the
    sign bit through AND), and advances each chain with a branch-free
    arithmetic select.  The depth bound is a backstop: leaves self-loop, so
    extra levels are inert and the early exit usually fires first.
    """
    assert block_rows >= 1
    t, c, f = ragged.n_trees, ragged.n_classes, ragged.n_features
    depth, r = ragged.max_depth, block_rows
    chains = range(r)
    lines = [
        f"/* row-blocked walk: {r} register walk chains per tree, early exit",
        "   when every chain sits on a leaf (see table_emitter docstring). */",
        f"static void walk_block_full(const int32_t* data, {acc_t}* scores) {{",
        f"  for (long i = 0; i < {r} * {c}; ++i) scores[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    const int32_t root = tree_root[t];",
        "    " + " ".join(f"int32_t n{k} = root;" for k in chains),
    ]
    if depth > 0:
        lines.append(f"    for (int d = 0; d < {depth}; ++d) {{")
        for k in chains:
            lines.append(
                f"      const int32_t f{k} = node_quad[4 * (long)n{k}];"
            )
        all_leaves = " & ".join(f"f{k}" for k in chains)
        lines.append(f"      if (({all_leaves}) < 0) break;")
        for k in chains:
            lines += [
                f"      {{ const int32_t* q{k} = node_quad + 4 * (long)n{k};",
                f"        const int32_t fi{k} = f{k} & ~(f{k} >> 31);",
                f"        const int32_t go{k} = -(data[{k} * {f} + fi{k}] <= q{k}[1]);",
                f"        n{k} = (q{k}[2] & go{k}) | (q{k}[3] & ~go{k}); }}",
            ]
        lines.append("    }")
    lines.append(
        "    " + "const int32_t node[] = {"
        + ", ".join(f"n{k}" for k in chains) + "};"
    )
    lines += [
        f"    for (long w = 0; w < {r}; ++w) {{",
        f"      const {acc_t}* leaf = node_leaf + (long)node[w] * {c};",
        f"      for (int i = 0; i < {c}; ++i) scores[w * {c} + i] += leaf[i];",
        "    }",
        "  }",
    ]
    if mode == "flint":
        rcp = np.float32(1.0) / np.float32(t)
        lines.append(
            f"  for (long i = 0; i < {r} * {c}; ++i) scores[i] *= {_c_float(rcp)};"
        )
    lines += ["}", ""]
    lines += _emit_simd_walkers(ragged, mode, acc_t)
    lines += [
        "/* runtime ISA dispatch: AVX2 via cpuid, NEON baseline on aarch64,",
        "   scalar blocked walk as the mandatory fallback (and the whole",
        "   story under -DREPRO_NO_SIMD or a non-GNU compiler). */",
        "static const char* g_simd_isa = 0;",
        "",
        "static void pick_simd(void) {",
        "#if defined(REPRO_HAVE_AVX2)",
        '  if (__builtin_cpu_supports("avx2")) { g_simd_isa = "avx2"; return; }',
        "#endif",
        "#if defined(REPRO_HAVE_NEON)",
        '  g_simd_isa = "neon";',
        "#else",
        '  g_simd_isa = "scalar";',
        "#endif",
        "}",
        "",
        "const char* simd_isa(void) {",
        "  if (!g_simd_isa) pick_simd();",
        "  return g_simd_isa;",
        "}",
        "",
        f"void predict_batch(const int32_t* data, long n_rows,",
        f"                   {acc_t}* scores, int32_t* preds) {{",
        "  if (!g_simd_isa) pick_simd();",
        "  long r0 = 0;",
        "#if defined(REPRO_HAVE_AVX2)",
        "  if (g_simd_isa[0] == 'a')",
        f"    for (; r0 + {_SIMD_ROWS_AVX2} <= n_rows; r0 += {_SIMD_ROWS_AVX2})",
        f"      walk_block{_SIMD_ROWS_AVX2}_avx2(data + r0 * {f}, scores + r0 * {c});",
        "#endif",
        "#if defined(REPRO_HAVE_NEON)",
        f"  for (; r0 + {_SIMD_ROWS_NEON} <= n_rows; r0 += {_SIMD_ROWS_NEON})",
        f"    walk_block{_SIMD_ROWS_NEON}_neon(data + r0 * {f}, scores + r0 * {c});",
        "#endif",
        f"  for (; r0 + {r} <= n_rows; r0 += {r})",
        f"    walk_block_full(data + r0 * {f}, scores + r0 * {c});",
        "  for (; r0 < n_rows; ++r0)",
        f"    predict(data + r0 * {f}, scores + r0 * {c});",
        "  for (long w = 0; w < n_rows; ++w) {",
        f"    const {acc_t}* out = scores + w * {c};",
        "    int best = 0;",
        f"    for (int i = 1; i < {c}; ++i) if (out[i] > out[best]) best = i;",
        "    preds[w] = best;",
        "  }",
        "}",
        "",
    ]
    return lines


# Two interleaved __m256i state vectors (16 rows): one vector's five
# dependent gathers per level leave the gather ports idle most of the
# latency chain; a second independent chain roughly doubles throughput
# (measured: 1 vector is *slower* than the scalar 8-chain walk).
_AVX2_VECS = 4
_SIMD_ROWS_AVX2 = 8 * _AVX2_VECS
_SIMD_ROWS_NEON = 4   # one int32x4_t of walk states


def _simd_prelude() -> list:
    """The arch/toolchain gates.  ``REPRO_HAVE_*`` is defined only when the
    intrinsics can actually compile AND ``REPRO_NO_SIMD`` was not requested —
    everything SIMD downstream keys off these two macros alone."""
    return [
        "#if !defined(REPRO_NO_SIMD) && defined(__GNUC__) && defined(__x86_64__)",
        "#define REPRO_HAVE_AVX2 1",
        "#include <immintrin.h>",
        "#endif",
        "#if !defined(REPRO_NO_SIMD) && defined(__GNUC__) && defined(__aarch64__)",
        "#define REPRO_HAVE_NEON 1",
        "#include <arm_neon.h>",
        "#endif",
    ]


def _leaf_epilogue(acc_t: str, c: int, rows: int, mode: str, n_trees: int) -> list:
    """Shared walker tail: scatter the ``rows`` final nodes into leaf adds
    (same per-tree order as every other path -> bit-identical scores)."""
    lines = [
        f"    for (long w = 0; w < {rows}; ++w) {{",
        f"      const {acc_t}* leaf = node_leaf + (long)nn[w] * {c};",
        f"      for (int i = 0; i < {c}; ++i) scores[w * {c} + i] += leaf[i];",
        "    }",
        "  }",
    ]
    if mode == "flint":
        rcp = np.float32(1.0) / np.float32(n_trees)
        lines.append(
            f"  for (long i = 0; i < {rows} * {c}; ++i) scores[i] *= {_c_float(rcp)};"
        )
    return lines


def _emit_simd_walkers(ragged, mode: str, acc_t: str) -> list:
    """The AVX2 and NEON blocked walkers over the interleaved quads.

    Same walk semantics as ``walk_block_full``, vector-width rows at a time:
    per level, gather each state's quad fields, exit when every lane's
    feature is negative (all leaves), clamp the leaf features to 0, gather
    the compared values, and select children branch-free.  Leaves self-loop
    in the quads, so mixed leaf/internal lanes stay correct without masking.
    """
    t, c, f = ragged.n_trees, ragged.n_classes, ragged.n_features
    depth = ragged.max_depth
    v8, v4, nv = _SIMD_ROWS_AVX2, _SIMD_ROWS_NEON, _AVX2_VECS
    vecs = range(nv)
    lines = [
        "#if defined(REPRO_HAVE_AVX2)",
        f"/* {v8} walk states in {nv} interleaved __m256i: quad fields via i32",
        "   gathers (scale 4 over the int32 quad array), all-leaves exit via",
        "   the combined sign-bit movemask, branch-free child select via",
        "   blendv.  The vectors' per-level gather chains are independent, so",
        "   they overlap and hide each other's gather latency.  target()",
        "   keeps AVX2 codegen out of every other function in this unit. */",
        '__attribute__((target("avx2")))',
        f"static void walk_block{v8}_avx2(const int32_t* data, {acc_t}* scores) {{",
        f"  for (long i = 0; i < {v8} * {c}; ++i) scores[i] = 0;",
    ]
    for j in vecs:
        lines.append(
            f"  const __m256i vrow{j} = _mm256_setr_epi32("
            + ", ".join(str(k * f) for k in range(8 * j, 8 * j + 8)) + ");"
        )
    lines += [
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    const __m256i root = _mm256_set1_epi32(tree_root[t]);",
        "    " + " ".join(f"__m256i node{j} = root;" for j in vecs),
    ]
    if depth > 0:
        lines.append(f"    for (int d = 0; d < {depth}; ++d) {{")
        for j in vecs:
            lines += [
                f"      const __m256i q{j} = _mm256_slli_epi32(node{j}, 2);",
                f"      const __m256i fe{j} = _mm256_i32gather_epi32(node_quad, q{j}, 4);",
            ]
        all_mask = " & ".join(
            f"_mm256_movemask_ps(_mm256_castsi256_ps(fe{j}))" for j in vecs
        )
        lines.append(f"      if (({all_mask}) == 0xff) break;")
        for j in vecs:
            lines += [
                f"      const __m256i ky{j} = _mm256_i32gather_epi32(node_quad + 1, q{j}, 4);",
                f"      const __m256i lf{j} = _mm256_i32gather_epi32(node_quad + 2, q{j}, 4);",
                f"      const __m256i rt{j} = _mm256_i32gather_epi32(node_quad + 3, q{j}, 4);",
                # fi = fe & ~(fe >> 31): leaf lanes read feature 0 (inert
                # because their quads self-loop through the select)
                f"      const __m256i fi{j} = _mm256_andnot_si256("
                f"_mm256_srai_epi32(fe{j}, 31), fe{j});",
                f"      const __m256i xv{j} = _mm256_i32gather_epi32(",
                f"          data, _mm256_add_epi32(vrow{j}, fi{j}), 4);",
                f"      node{j} = _mm256_blendv_epi8(lf{j}, rt{j}, "
                f"_mm256_cmpgt_epi32(xv{j}, ky{j}));",
            ]
        lines.append("    }")
    lines.append(f"    int32_t nn[{v8}];")
    for j in vecs:
        lines.append(f"    _mm256_storeu_si256((__m256i*)(nn + {8 * j}), node{j});")
    lines += _leaf_epilogue(acc_t, c, v8, mode, t)
    lines += ["}", "#endif  /* REPRO_HAVE_AVX2 */", ""]

    lines += [
        "#if defined(REPRO_HAVE_NEON)",
        "/* 4 walk states in one int32x4_t; aarch64 has no gather, so quad",
        "   fields load per lane and the compare/select stay vectorized. */",
        f"static void walk_block{v4}_neon(const int32_t* data, {acc_t}* scores) {{",
        f"  for (long i = 0; i < {v4} * {c}; ++i) scores[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int32x4_t node = vdupq_n_s32(tree_root[t]);",
    ]
    if depth > 0:
        lines += [
            f"    for (int d = 0; d < {depth}; ++d) {{",
            f"      int32_t ni[{v4}], qf[{v4}], qk[{v4}], ql[{v4}], qr[{v4}];",
            "      vst1q_s32(ni, node);",
            f"      for (int w = 0; w < {v4}; ++w) {{",
            "        const int32_t* q = node_quad + 4 * (long)ni[w];",
            "        qf[w] = q[0]; qk[w] = q[1]; ql[w] = q[2]; qr[w] = q[3];",
            "      }",
            "      const int32x4_t fe = vld1q_s32(qf);",
            "      if (vmaxvq_s32(fe) < 0) break;  /* all lanes on leaves */",
            "      const int32x4_t fi = vbicq_s32(fe, vshrq_n_s32(fe, 31));",
            f"      int32_t fis[{v4}], xv[{v4}];",
            "      vst1q_s32(fis, fi);",
            f"      for (int w = 0; w < {v4}; ++w) xv[w] = data[w * {f} + fis[w]];",
            "      const uint32x4_t go_r = vcgtq_s32(vld1q_s32(xv), vld1q_s32(qk));",
            "      node = vbslq_s32(go_r, vld1q_s32(qr), vld1q_s32(ql));",
            "    }",
        ]
    lines += [
        f"    int32_t nn[{v4}];",
        "    vst1q_s32(nn, node);",
    ]
    lines += _leaf_epilogue(acc_t, c, v4, mode, t)
    lines += ["}", "#endif  /* REPRO_HAVE_NEON */", ""]
    return lines

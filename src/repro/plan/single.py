"""SingleShardPlan: the whole forest on one backend — today's path.

The degenerate plan, and the conformance baseline every sharded plan must be
bit-identical to.  It delegates ``predict_scores`` straight to the backend
(which already funnels deterministic modes through the shared
partials/finalize split), so routing the engine through plans changes
nothing for existing callers — including float mode, pre-constructed backend
instances, and shape-oblivious compiled-C execution.
"""
from __future__ import annotations

from repro.plan.base import ExecutionPlan, build_backend, register_plan


@register_plan
class SingleShardPlan(ExecutionPlan):
    name = "single"

    def __init__(self, model, *, mode: str = "integer", backend="reference",
                 shards=None, layout=None, backend_kwargs=None):
        if shards not in (None, 1):
            raise ValueError(
                f"the single plan runs exactly one shard, got shards={shards}; "
                "use plan='tree_parallel' or 'row_parallel' to shard"
            )
        self.backend = build_backend(backend, model, mode, layout, backend_kwargs)
        # an already-constructed backend instance carries its own mode/model
        super().__init__(self.backend.packed, mode=self.backend.mode)
        self._label = f"s0:{self.backend.name}"
        # a backend that never overrode predict_partials (custom
        # predict_scores-only implementations) keeps its direct route —
        # the partials/finalize split is only sound when partials exist
        from repro.backends.base import TreeBackend

        impl = getattr(type(self.backend), "predict_partials", None)
        self._has_partials = (impl is not None
                              and impl is not TreeBackend.predict_partials)

    @property
    def backends(self) -> tuple:
        return (self.backend,)

    @property
    def packed(self):
        return self.backend.packed

    def predict_partials(self, X):
        return self._timed(self._label, self.backend.predict_partials, X,
                           span_parent=self.trace_parent)

    def predict_scores(self, X):
        # deterministic modes funnel through the base partials+finalize
        # split (bit-identical to the backend's own wrapper — same
        # ``finalize_partials`` — but gives finalize its own stage span);
        # float mode and partials-less custom backends stay on the
        # backend's fused predict
        if self.deterministic and self._has_partials:
            return super().predict_scores(X)
        return self._timed(self._label, self.backend.predict_scores, X,
                           span_parent=self.trace_parent)

"""Fixed-point probability conversion (paper Sec. III-A).

Leaf class-probabilities ``p in [0,1]`` are converted once, at packing/codegen
time, to unsigned 32-bit fixed point with scale ``2**32 / n`` where ``n`` is
the ensemble size.  Accumulating the ``n`` per-tree contributions is then pure
uint32 addition and cannot overflow: each addend is ``< 2**32/n`` and there are
exactly ``n`` of them.  The accumulated value interpreted at scale ``2**32`` is
the ensemble-average probability, accurate to ``n / 2**32`` — i.e. ~1e-10 for a
single tree and ~1e-8 for 100 trees, matching the paper's Fig. 2.

Deviation (documented): the paper uses scale ``2**32/n`` exactly, which
overflows uint32 for the legal edge case ``n == 1, p == 1.0``.  We use
``scale = floor((2**32 - 1) / n)`` so that ``sum_t floor(p_t * scale)
<= n * scale <= 2**32 - 1`` holds unconditionally.  The precision statement is
unchanged up to a factor ~(1 + n/2**32).
"""
from __future__ import annotations

import numpy as np

FIXED_BITS = 32
_FULL = (1 << FIXED_BITS) - 1  # 2**32 - 1


def scale_for(n_trees: int) -> int:
    """Overflow-free per-tree scale (paper: 2**32/n; ours: floor((2**32-1)/n))."""
    if n_trees < 1:
        raise ValueError("n_trees must be >= 1")
    return _FULL // int(n_trees)


def prob_to_fixed_np(p: np.ndarray, n_trees: int) -> np.ndarray:
    """floor(p * scale) as uint32.  Done in float64: this runs at *codegen*
    time (paper Sec. III-A: "division is performed during code generation"),
    so double precision is available regardless of the target device."""
    p64 = np.asarray(p, np.float64)
    if np.any(p64 < 0) or np.any(p64 > 1):
        raise ValueError("probabilities must lie in [0, 1]")
    return np.floor(p64 * scale_for(n_trees)).astype(np.uint32)


def fixed_to_prob_np(acc: np.ndarray, n_trees: int) -> np.ndarray:
    """Interpret an accumulated uint32 at the ensemble scale -> float64 prob."""
    return np.asarray(acc, np.uint64).astype(np.float64) / (
        scale_for(n_trees) * float(n_trees)
    )


def max_abs_error(n_trees: int) -> float:
    """Worst-case |reconstructed - exact average| over an n-tree ensemble.

    Each tree contributes floor() error < 1 unit of the per-tree scale, i.e.
    < 1/scale in probability, divided by n at reconstruction -> total < 1/scale
    ... plus the scale deviation vs the paper's exact 2**32/n, which is
    bounded by n/2**32 relative.  A safe bound: (n_trees + 1) / scale / n.
    """
    s = scale_for(n_trees)
    return (n_trees + 1.0) / (s * n_trees)


# JAX-side helpers ----------------------------------------------------------

def fixed_to_prob(acc, n_trees: int):
    import jax.numpy as jnp

    # uint32 -> float32 via float64 is unavailable under jit on TPU (x64 off);
    # split into high/low halves to keep precision.
    acc = jnp.asarray(acc, jnp.uint32)
    hi = (acc >> 16).astype(jnp.float32) * float(1 << 16)
    lo = (acc & jnp.uint32(0xFFFF)).astype(jnp.float32)
    denom = float(scale_for(n_trees)) * float(n_trees)
    return (hi + lo) / denom

"""ForestIR layer: canonical-IR invariants, layout materializations, and
bit-exact equivalence between the IR-derived padded tables and the historical
``pack_forest`` packing algorithm."""
import numpy as np
import pytest

from repro.core.fixedpoint import prob_to_fixed_np
from repro.core.flint import float_to_key_np
from repro.core.packing import PackedEnsemble, pack_forest
from repro.ir import ForestIR, available_layouts, resolve_artifact


def test_layout_registry_contents():
    assert {"padded", "ragged", "leaf_major"} <= set(available_layouts())


def test_ir_shapes_and_offsets(small_forest):
    ir = ForestIR.from_forest(small_forest)
    total = ir.total_nodes
    assert ir.node_offsets.shape == (ir.n_trees + 1,)
    assert ir.node_offsets[0] == 0 and ir.node_offsets[-1] == total
    assert (ir.node_counts == [t.n_nodes for t in small_forest.trees_]).all()
    assert (ir.tree_depths == [t.depth for t in small_forest.trees_]).all()
    for arr in (ir.feature, ir.threshold, ir.threshold_key, ir.left, ir.right):
        assert arr.shape == (total,)
    assert ir.leaf_probs.shape == (total, ir.n_classes)
    assert ir.leaf_fixed.shape == (total, ir.n_classes)
    # quantization happened exactly once, in the IR
    np.testing.assert_array_equal(ir.threshold_key, float_to_key_np(ir.threshold))
    np.testing.assert_array_equal(ir.leaf_fixed,
                                  prob_to_fixed_np(ir.leaf_probs, ir.n_trees))


def test_padded_materialization_matches_seed_packing(small_forest):
    """The padded layout must stay *byte-identical* to the pre-IR packer."""
    trees = small_forest.trees_
    T, C = len(trees), small_forest.n_classes_
    N = max(t.n_nodes for t in trees)
    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    left = np.tile(np.arange(N, dtype=np.int32), (T, 1))
    right = left.copy()
    probs = np.zeros((T, N, C), np.float64)
    for i, t in enumerate(trees):
        n = t.n_nodes
        feature[i, :n] = t.feature
        threshold[i, :n] = t.threshold
        left[i, :n] = t.left
        right[i, :n] = t.right
        is_leaf = t.feature < 0
        probs[i, :n][is_leaf] = t.leaf_probs[is_leaf]

    p = pack_forest(small_forest)
    assert p.layout == "padded" and p.ir is not None
    np.testing.assert_array_equal(p.feature, feature)
    np.testing.assert_array_equal(p.threshold, threshold)
    np.testing.assert_array_equal(p.threshold_key, float_to_key_np(threshold))
    np.testing.assert_array_equal(p.left, left)
    np.testing.assert_array_equal(p.right, right)
    np.testing.assert_array_equal(p.leaf_probs, probs.astype(np.float32))
    np.testing.assert_array_equal(p.leaf_fixed, prob_to_fixed_np(probs, T))
    assert p.max_depth == max(t.depth for t in trees)


def test_materializations_are_memoized(small_forest):
    ir = ForestIR.from_forest(small_forest)
    assert ir.materialize("ragged") is ir.materialize("ragged")
    assert ir.materialize("padded") is ir.materialize("padded")
    with pytest.raises(KeyError, match="ragged"):
        ir.materialize("no-such-layout")


def test_ragged_layout_global_children_and_roots(small_packed):
    ir = small_packed.to_ir()
    rg = ir.materialize("ragged")
    assert rg.layout == "ragged"
    np.testing.assert_array_equal(rg.roots, ir.node_offsets[:-1])
    for t in range(ir.n_trees):
        lo, hi = int(ir.node_offsets[t]), int(ir.node_offsets[t + 1])
        sl = slice(lo, hi)
        # children stay within the owning tree's global slice
        assert (rg.left[sl] >= lo).all() and (rg.left[sl] < hi).all()
        assert (rg.right[sl] >= lo).all() and (rg.right[sl] < hi).all()
        # leaves self-loop globally
        leaf = rg.feature[sl] < 0
        idx = np.arange(lo, hi)
        assert (rg.left[sl][leaf] == idx[leaf]).all()


def test_leaf_major_layout_orders_internal_first(small_packed):
    lm = resolve_artifact(small_packed, "leaf_major")
    assert lm.layout == "leaf_major"
    ir = small_packed.ir
    for t in range(lm.n_trees):
        n = int(ir.node_counts[t])
        feats = lm.feature[t, :n]
        n_internal = int((feats >= 0).sum())
        # dense internal prefix, leaves grouped after
        assert (feats[:n_internal] >= 0).all()
        assert (feats[n_internal:] < 0).all()
        # the walk still starts at node 0
        root_is_internal = (small_packed.feature[t, 0] >= 0)
        assert (feats[0] >= 0) == root_is_internal


def test_leaf_major_records_internal_counts(small_packed):
    """The layout must record the per-tree internal-prefix length and keep
    children after parents inside the prefix — the two facts the linear-scan
    kernel walks on."""
    lm = resolve_artifact(small_packed, "leaf_major")
    ir = small_packed.ir
    assert lm.internal_counts is not None and len(lm.internal_counts) == lm.n_trees
    for t in range(lm.n_trees):
        n = int(ir.node_counts[t])
        n_int = int(lm.internal_counts[t])
        assert n_int == int((lm.feature[t, :n] >= 0).sum())
        # forward-scan invariant: every child index exceeds its parent's
        parents = np.flatnonzero(lm.feature[t, :n] >= 0)
        assert (lm.left[t, parents] > parents).all()
        assert (lm.right[t, parents] > parents).all()
    # the padded layout does not claim an internal prefix
    assert resolve_artifact(small_packed, "padded").internal_counts is None


def test_from_packed_recovers_ir_exactly(small_forest):
    ir = ForestIR.from_forest(small_forest)
    # a bare artifact with no back-reference (the register_packed path)
    p = ir.materialize("padded")
    bare = PackedEnsemble(
        feature=p.feature, threshold=p.threshold, threshold_key=p.threshold_key,
        left=p.left, right=p.right, leaf_probs=p.leaf_probs,
        leaf_fixed=p.leaf_fixed, n_trees=p.n_trees, n_classes=p.n_classes,
        n_features=p.n_features, max_depth=p.max_depth,
    )
    ir2 = bare.to_ir()
    for name in ("feature", "threshold", "threshold_key", "left", "right",
                 "leaf_fixed", "node_offsets", "tree_depths"):
        np.testing.assert_array_equal(getattr(ir2, name), getattr(ir, name))
    assert bare.to_ir() is ir2  # recovered once, then attached


def test_nbytes_by_layout(small_packed):
    ir = small_packed.ir
    sizes = ir.nbytes_by_layout(mode="integer")
    assert set(sizes) == set(available_layouts())
    assert sizes["padded"] == small_packed.nbytes_integer()
    assert sizes["leaf_major"] == sizes["padded"]  # same (T, N) tables
    # ragged pays sum(nodes), padded pays T * max(nodes)
    assert sizes["ragged"] <= sizes["padded"]
    rg = ir.materialize("ragged")
    assert sizes["ragged"] == rg.nbytes_integer()
    assert rg.nbytes_float() > 0


def test_resolve_artifact_passthrough_and_errors(small_packed):
    assert resolve_artifact(small_packed, "padded") is small_packed
    ir = small_packed.ir
    assert resolve_artifact(ir, "ragged") is ir.materialize("ragged")
    rg = ir.materialize("ragged")
    # artifact -> other layout resolves through the IR back-reference
    assert resolve_artifact(rg, "padded") is ir.materialize("padded")
    with pytest.raises(KeyError, match="no-such"):
        resolve_artifact(small_packed, "no-such-layout")

"""PallasBackend: the VMEM-tiled TPU kernel behind the TreeBackend protocol.

Wraps ``repro.kernels.ops.packed_predict_integer`` and owns the blocking
decisions: the row/tree block sizes fed to the kernel (VMEM-budgeted via
``pick_blocks``) and the ``preferred_block_rows`` hint that makes the serving
layer pad batches to shapes aligned with the kernel's ``block_b`` tiling.

The kernel implements exactly the paper's integer path (int32 FlInt compares,
uint32 fixed-point accumulation), so ``modes == ("integer",)``; uint32
addition is associative mod 2^32, which is why the tiled accumulation is
bit-identical to the reference walk no matter how the grid is carved.
"""
from __future__ import annotations

from typing import Optional

from repro.backends.base import BackendCapabilities, TreeBackend, register_backend
from repro.core.packing import PackedEnsemble

_DEFAULT_BLOCK_B = 256  # the kernel wrapper's row-tile default


@register_backend
class PallasBackend(TreeBackend):
    name = "pallas"
    capabilities = BackendCapabilities(
        modes=("integer",),
        deterministic_modes=("integer",),
        preferred_block_rows=_DEFAULT_BLOCK_B,
        compiles_per_shape=True,
        # the kernel consumes dense (T, N) VMEM-resident tables and gathers
        # by node index, so both node-table orderings are walkable
        supported_layouts=("padded", "leaf_major"),
        preferred_layout="padded",
    )

    def __init__(self, packed: PackedEnsemble, mode: str = "integer", *,
                 block_b: int = _DEFAULT_BLOCK_B, block_t: Optional[int] = None,
                 impl: str = "gather", interpret: bool = True):
        super().__init__(packed, mode)
        self._kernel_kwargs = dict(
            block_b=block_b, block_t=block_t, impl=impl, interpret=interpret
        )

    def predict_scores(self, X):
        from repro.kernels.ops import packed_predict_integer

        return packed_predict_integer(self.packed, X, **self._kernel_kwargs)

"""Compiled-C backends: the paper's if-else deliverable, servable via ctypes.

``CompiledCBackend`` owns everything shared by native-code execution — build a
C source string, compile it *once per (model, mode)* into a shared library
(`gcc -O2 -shared -fPIC`), and call the batched entry point through ctypes —
so a native backend is just an ``_emit_source`` hook over its layout artifact.
Two concrete backends ride on it:

  * ``native_c`` (this module): InTreeger's actual artifact — the
    freestanding if-else C of ``codegen/c_emitter.emit_c`` over the padded
    node tables, forest-in-the-instruction-stream.
  * ``native_c_table`` (``backends/native_c_table.py``): the ragged-layout
    data-as-arrays table walk of ``codegen/table_emitter.emit_table_walk_c``.

Shape-oblivious: the C loops take any row count, so ``compiles_per_shape`` is
False and the serving layer skips bucket padding entirely.  In integer mode
the C accumulates uint32 at the same scale and in the same tree order as the
reference, so scores are bit-identical; in flint/float modes gcc (without
-ffast-math) preserves the emitted float32 operation order, matching the
XLA scan's sequential per-tree adds.
"""
from __future__ import annotations

import ctypes
import shutil
import subprocess
import tempfile
import threading
from pathlib import Path

import numpy as np

from repro.backends.base import (
    BackendCapabilities,
    BackendUnavailable,
    TreeBackend,
    register_backend,
)
from repro.core.flint import float_to_key_np


def have_c_toolchain(cc: str = "gcc") -> bool:
    return shutil.which(cc) is not None


class CompiledCBackend(TreeBackend):
    """Shared compile-and-serve machinery for emitted-C backends.

    Subclasses implement :meth:`_emit_source` returning a translation unit
    that defines ``predict_batch(data, n_rows, scores, preds)`` (usually the
    mode-specific ``predict`` plus ``codegen.c_emitter.emit_batch_entry``).
    """

    def __init__(self, packed, mode: str = "integer", *,
                 cc: str = "gcc", cflags: tuple = ("-O2",)):
        super().__init__(packed, mode)
        self._cc = cc
        self._cflags = tuple(cflags)
        self._lib = None
        self._tmpdir = None  # owns the .so for the backend's lifetime
        self._compile_lock = threading.Lock()

    def _emit_source(self) -> str:
        raise NotImplementedError

    # ------------------------------------------------------------- compile
    def _ensure_lib(self):
        # double-checked locking: engines are shared across executor threads,
        # and a concurrent first predict must not compile twice (the loser's
        # tmpdir assignment would delete the winner's .so out from under it)
        if self._lib is not None:
            return self._lib
        with self._compile_lock:
            if self._lib is not None:
                return self._lib
            return self._build_lib()

    def _build_lib(self):
        if not have_c_toolchain(self._cc):
            raise BackendUnavailable(
                f"{self.name} backend needs a C compiler; {self._cc!r} not on PATH"
            )
        src = self._emit_source()
        self._tmpdir = tempfile.TemporaryDirectory(prefix=f"repro_{self.name}_")
        d = Path(self._tmpdir.name)
        c_file, so_file = d / "model.c", d / "model.so"
        c_file.write_text(src)
        proc = subprocess.run(
            [self._cc, *self._cflags, "-shared", "-fPIC",
             "-o", str(so_file), str(c_file)],
            capture_output=True,
        )
        if proc.returncode != 0:
            raise BackendUnavailable(
                f"{self._cc} failed to build the {self.name} backend:\n"
                + proc.stderr.decode(errors="replace")[:2000]
            )
        lib = ctypes.CDLL(str(so_file))  # RTLD_LOCAL: symbols stay per-model
        data_ct = ctypes.c_float if self.mode == "float" else ctypes.c_int32
        score_ct = ctypes.c_uint32 if self.mode == "integer" else ctypes.c_float
        lib.predict_batch.restype = None
        lib.predict_batch.argtypes = [
            ctypes.POINTER(data_ct),
            ctypes.c_long,
            ctypes.POINTER(score_ct),
            ctypes.POINTER(ctypes.c_int32),
        ]
        self._score_dtype = np.uint32 if self.mode == "integer" else np.float32
        self._lib = lib
        return lib

    # ------------------------------------------------------------- predict
    def predict_scores(self, X):
        lib = self._ensure_lib()
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        if X.ndim != 2 or X.shape[1] != self.packed.n_features:
            raise ValueError(
                f"expected (B, {self.packed.n_features}) features, got {X.shape}"
            )
        if self.mode == "float":
            data = X
        else:
            data = np.ascontiguousarray(float_to_key_np(X))
        b = X.shape[0]
        scores = np.empty((b, self.packed.n_classes), self._score_dtype)
        preds = np.empty(b, np.int32)
        lib.predict_batch(
            data.ctypes.data_as(lib.predict_batch.argtypes[0]),
            ctypes.c_long(b),
            scores.ctypes.data_as(lib.predict_batch.argtypes[2]),
            preds.ctypes.data_as(lib.predict_batch.argtypes[3]),
        )
        return scores, preds


@register_backend
class NativeCBackend(CompiledCBackend):
    """The paper's literal deliverable — if-else C — as a servable backend."""

    name = "native_c"
    capabilities = BackendCapabilities(
        modes=("float", "flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,
        compiles_per_shape=False,
        # the if-else emitter reads (T, N) node tables from the root down;
        # node order within a tree does not change the emitted cascade's
        # semantics, so both node-table layouts are accepted
        supported_layouts=("padded", "leaf_major"),
        preferred_layout="padded",
    )

    def _emit_source(self) -> str:
        from repro.codegen.c_emitter import emit_batch_entry, emit_c

        return emit_c(self.packed, mode=self.mode) + emit_batch_entry(
            self.packed, mode=self.mode
        )

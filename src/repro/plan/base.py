"""The ExecutionPlan protocol and the name-keyed plan registry.

A plan sits between the serving engine and the backend layer and decides how
one logical forest is *carved* across executors:

    engine -> ExecutionPlan -> backend.predict_partials -> merge -> finalize

The paper's integer-only accumulation is what makes this split sound: the
deterministic modes (flint/integer) accumulate exact uint32 fixed-point
partials, and uint32 addition is associative, so a forest can be cut into
tree-contiguous sub-forests (``ForestIR.subset``), each shard's partials
computed on a different jax device or a different backend entirely, and the
merged sum is *bit-identical* to the single-shard walk.  Finalize
(reciprocal-multiply averaging + argmax, ``repro.core.ensemble.
finalize_partials``) runs exactly once, on the merged accumulator.

Four registered plans:
  * ``single``        — today's path: one backend, the whole forest.
  * ``tree_parallel`` — shard trees across jax devices (``shard_map`` over a
                        stacked sub-forest table) or across per-shard
                        backends, possibly heterogeneous; integer merge.
  * ``row_parallel``  — shard the batch; rows are independent, so this is
                        bit-exact for *every* mode, float included.
  * ``remote_tree_parallel`` — tree shards on worker *processes* (loopback
                        or other hosts) over the wire protocol in
                        ``repro.serve.wire``; uint32 partials merge at the
                        gateway, stragglers/deaths re-dispatch.

*Adding a plan*: subclass :class:`ExecutionPlan`, set ``name``, implement
``predict_partials`` (and ``predict_scores`` if the plan serves
non-deterministic modes), decorate with ``@register_plan``; the serving stack
picks it up by name (``TreeEngine(spec="integer:reference+myplan:4")``,
``Gateway(registry, spec)``, ``--gw-spec``).  Plans that can only serve
exact-integer partial modes set ``deterministic_only = True`` so the
gateway rejects the route up front.  Plans that own executors beyond the
calling thread — thread pools, worker processes, sockets — override
``close()`` (drain in-flight work, then release); one-time setup cost
(connect/handshake) goes in the dict ``drain_setup_timings()`` returns
(e.g. ``{"remote": ms}``), which the engine folds into its compile/warm
ledger.  Remote plans additionally need a worker-side contract: ship the
model + shard table in one handshake so *any* worker can serve *any*
shard, which is what makes re-dispatching a dead worker's shard trivial
(see ``repro.plan.remote``).
"""
from __future__ import annotations

import abc
import threading
import time
from typing import ClassVar, Optional

import numpy as np

from repro.core.ensemble import finalize_partials, mode_spec


def build_backend(backend, model, mode: str, layout: Optional[str],
                  backend_kwargs: Optional[dict]):
    """Resolve one shard's backend: a registered name (materialize the wanted
    ForestIR layout, then construct) or an already-built instance (then the
    artifact/mode are taken from it; a conflicting layout pin fails loudly).

    This is THE one place plan code turns (model, backend spec) into an
    executor — the logic the pre-plan ``TreeEngine`` constructor owned.
    """
    from repro.backends import backend_class, create_backend
    from repro.ir import resolve_artifact

    if isinstance(backend, str):
        caps = backend_class(backend).capabilities
        wanted = layout or caps.preferred_layout
        caps.require_layout(wanted, backend)
        return create_backend(
            backend, resolve_artifact(model, wanted), mode=mode,
            **(backend_kwargs or {})
        )
    if layout is not None and getattr(backend, "layout", "padded") != layout:
        raise ValueError(
            f"layout {layout!r} conflicts with the constructed "
            f"backend's artifact (layout {backend.layout!r}); "
            "materialize the backend on the wanted layout instead"
        )
    return backend


def as_ir(model):
    """The canonical ForestIR behind ``model`` (IR or any layout artifact)."""
    from repro.ir import ForestIR

    if isinstance(model, ForestIR):
        return model
    ir = getattr(model, "ir", None)
    if ir is not None:
        return ir
    if hasattr(model, "to_ir"):
        return model.to_ir()
    raise ValueError(
        f"cannot shard a {type(model).__name__!r} artifact: no ForestIR "
        "back-reference to carve sub-forests from"
    )


class ExecutionPlan(abc.ABC):
    """How one logical forest is executed: shards, merge, finalize.

    Subclasses own their backends; the engine above sees the same surface a
    bare backend exposes (``predict_partials``/``predict_scores`` plus the
    capability aggregates the bucketing layer consults), so a plan composes
    with shape bucketing, the gateway, and the registry unchanged.
    """

    name: ClassVar[str]
    #: True for plans that only serve exact-integer partial modes (the
    #: gateway validates the route against this before building engines)
    deterministic_only: ClassVar[bool] = False

    def __init__(self, model, *, mode: str = "integer"):
        self.mode = mode
        self._spec = mode_spec(mode)
        # the FULL ensemble's finalize constants — a sub-forest's partials
        # must be averaged at the whole forest's (n_trees, scale)
        self._n_trees = getattr(model, "n_trees", None)
        self._scale = getattr(model, "scale", None)
        self._timings: dict = {}
        self._stages: dict = {}
        self._timings_lock = threading.Lock()
        # observability attach: the tracer is plan-wide, the active parent
        # span is per-*thread* (set by the dispatching thread — the gateway's
        # batch executor — and handed to shard pool threads explicitly at
        # submit time, so concurrent dispatches never cross-parent spans)
        self._tracer = None
        self._trace_tls = threading.local()

    # ------------------------------------------------------------ execution
    @abc.abstractmethod
    def predict_partials(self, X):
        """Float features (B, F) -> merged (B, C) uint32 partials."""

    def predict_scores(self, X):
        """(scores, preds) via the standalone finalize over merged partials."""
        if not self.deterministic:
            raise NotImplementedError(
                f"plan {self.name!r} must override predict_scores for the "
                f"non-deterministic mode {self.mode!r}"
            )
        acc = self.predict_partials(X)
        t0 = time.perf_counter_ns()
        out = finalize_partials(self.mode, acc, self._n_trees, self._scale)
        t1 = time.perf_counter_ns()
        self._record_stage("finalize", (t1 - t0) / 1e9)
        self._span("finalize", t0, t1, self.trace_parent)
        return out

    # ------------------------------------------------------- shard metadata
    @property
    @abc.abstractmethod
    def backends(self) -> tuple:
        """The shard backends (may be empty for fused device execution)."""

    @property
    @abc.abstractmethod
    def packed(self):
        """A metadata-bearing artifact for the full forest (n_features etc)."""

    @property
    def n_shards(self) -> int:
        return max(len(self.backends), 1)

    @property
    def deterministic(self) -> bool:
        return self._spec.deterministic

    @property
    def compiles_per_shape(self) -> bool:
        return any(b.capabilities.compiles_per_shape for b in self.backends)

    @property
    def preferred_block_rows(self) -> Optional[int]:
        hints = [b.capabilities.preferred_block_rows for b in self.backends]
        hints = [h for h in hints if h]
        return max(hints) if hints else None

    @property
    def layout(self) -> str:
        layouts = []
        for b in self.backends:
            if b.layout not in layouts:
                layouts.append(b.layout)
        return "+".join(layouts) if layouts else "padded"

    @property
    def backend_name(self) -> str:
        names = []
        for b in self.backends:
            if b.name not in names:
                names.append(b.name)
        return "+".join(names) if names else self.name

    def describe(self) -> dict:
        return {
            "plan": self.name,
            "mode": self.mode,
            "shards": self.n_shards,
            "backends": [b.name for b in self.backends],
            "layout": self.layout,
        }

    # ------------------------------------------------- timing + trace spans
    def attach_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.Tracer` (plan-wide; idempotent)."""
        self._tracer = tracer

    @property
    def trace_parent(self):
        """The span that parents this *thread's* execution spans (set by the
        dispatching thread via the setter; ``None`` when untraced)."""
        return getattr(self._trace_tls, "parent", None)

    @trace_parent.setter
    def trace_parent(self, span) -> None:
        self._trace_tls.parent = span

    def _span(self, name: str, t0_ns: int, t1_ns: int, parent, **attrs) -> None:
        """Commit one completed span under ``parent`` (no-op when untraced —
        the one branch the disabled path pays)."""
        if parent and self._tracer is not None:
            self._tracer.record(name, t0_ns, t1_ns, parent=parent, **attrs)

    def _record(self, label: str, seconds: float) -> None:
        with self._timings_lock:
            ms, calls = self._timings.get(label, (0.0, 0))
            self._timings[label] = (ms + seconds * 1e3, calls + 1)

    def _record_stage(self, stage: str, seconds: float) -> None:
        """Accumulate one pipeline-stage sample (pad/merge/finalize — the
        engine adds pad); drained separately from shard labels."""
        with self._timings_lock:
            ms, calls = self._stages.get(stage, (0.0, 0))
            self._stages[stage] = (ms + seconds * 1e3, calls + 1)

    def _timed(self, label: str, fn, *args, span_parent=None):
        """Run ``fn`` timing it into the shard ledger; when ``span_parent``
        is a live span, also commit a ``shard:<label>`` trace span.  Shard
        pool threads receive the parent explicitly (captured by the
        dispatching thread), never via the thread-local."""
        t0 = time.perf_counter_ns()
        out = fn(*args)
        t1 = time.perf_counter_ns()
        self._record(label, (t1 - t0) / 1e9)
        if span_parent:
            self._span(f"shard:{label}", t0, t1, span_parent, label=label)
        return out

    def drain_timings(self) -> dict:
        """Per-shard wall time accumulated since the last drain:
        ``{label: (ms_total, calls)}``.  The gateway feeds this into
        ``serve.metrics`` after each batch execute."""
        with self._timings_lock:
            out, self._timings = self._timings, {}
        return out

    def drain_stage_timings(self) -> dict:
        """Pipeline-stage wall time since the last drain:
        ``{stage: (ms_total, calls)}`` — pad / merge / finalize, fed into
        the per-stage metric histograms alongside the shard ledger."""
        with self._timings_lock:
            out, self._stages = self._stages, {}
        return out

    def drain_setup_timings(self) -> dict:
        """One-time setup cost to fold into the engine's compile/warm ledger
        (``{str_key: ms}``, drained once).  Remote plans report their
        connect + handshake wall time here under ``"remote"``."""
        return {}

    # -------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Release executors the plan owns (thread pools, worker processes,
        sockets).  Default: nothing to release.  Implementations must drain
        in-flight ``predict_partials`` work before tearing down."""


# ---------------------------------------------------------------------------
# name-keyed registry + capability-driven auto-selection
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_plan(cls):
    """Class decorator: make ``cls`` constructible via :func:`create_plan`."""
    if not (isinstance(cls, type) and issubclass(cls, ExecutionPlan)):
        raise TypeError(f"register_plan expects an ExecutionPlan subclass, got {cls!r}")
    _REGISTRY[cls.name] = cls
    return cls


def available_plans() -> list:
    return sorted(_REGISTRY)


def plan_class(name: str):
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown plan {name!r}; available: {available_plans()}"
        ) from None


def select_plan(plan: Optional[str], *, mode: str, backend, shards=None,
                model=None) -> str:
    """Capability-driven auto-selection (``plan in (None, "auto")``).

    A sequence of backend names means heterogeneous tree-parallel.  One shard
    (or none requested) is the single plan.  Multiple shards pick
    tree-parallel when the mode accumulates exact integer partials and the
    forest has trees to carve; otherwise row-parallel, which is bit-exact for
    any mode because rows are independent.
    """
    if plan not in (None, "auto"):
        plan_class(plan)  # fail fast on unknown names
        return plan
    if not isinstance(backend, str) and isinstance(backend, (list, tuple)):
        return "tree_parallel"
    if shards is None or int(shards) <= 1:
        return "single"
    n_trees = getattr(model, "n_trees", None)
    if mode_spec(mode).deterministic and (n_trees is None or n_trees >= 2):
        return "tree_parallel"
    return "row_parallel"


def create_plan(name: Optional[str], model, *, mode: str = "integer",
                backend="reference", shards=None, layout: Optional[str] = None,
                backend_kwargs: Optional[dict] = None,
                **plan_kwargs) -> ExecutionPlan:
    """Instantiate a plan by name (``None``/"auto" -> :func:`select_plan`)."""
    resolved = select_plan(name, mode=mode, backend=backend, shards=shards,
                           model=model)
    return plan_class(resolved)(
        model, mode=mode, backend=backend, shards=shards, layout=layout,
        backend_kwargs=backend_kwargs, **plan_kwargs
    )

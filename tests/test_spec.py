"""EngineSpec: parse / canonical round-trip, dict serialization, and the
loose-kwargs deprecation shim."""
import warnings

import pytest

from repro.serve.spec import MODES, EngineSpec


# ---------------------------------------------------------------------------
# parsing + canonical form
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("text", [
    "integer",
    "flint:reference",
    "integer:bitvector@leaf_major+tree_parallel:4",
    "flint:reference+remote_tree_parallel:2",
    "integer:native_c_table?block_rows=8",
    "integer:reference+auto:3",
    "integer:reference|native_c+tree_parallel:2",
    "integer:reference?autotune=true",
])
def test_parse_canonical_roundtrip(text):
    spec = EngineSpec.parse(text, validate=False)
    again = EngineSpec.parse(spec.canonical(), validate=False)
    assert again == spec
    # canonical is a fixed point
    assert again.canonical() == spec.canonical()


def test_parse_fields():
    s = EngineSpec.parse("integer:bitvector@leaf_major+tree_parallel:4",
                         validate=False)
    assert (s.mode, s.backend, s.layout) == ("integer", "bitvector", "leaf_major")
    assert (s.plan, s.shards) == ("tree_parallel", 4)


def test_bare_mode_and_bare_backend():
    for m in MODES:
        s = EngineSpec.parse(m, validate=False)
        assert s.mode == m and s.backend == "reference"
    s = EngineSpec.parse("bitvector", validate=False)
    assert s.mode == "integer" and s.backend == "bitvector"


def test_hetero_backends_parse_as_tuple():
    s = EngineSpec.parse("flint:reference|native_c+tree_parallel",
                         validate=False)
    assert s.backend == ("reference", "native_c")
    assert "|" in s.canonical()


def test_auto_shards_renders_auto():
    s = EngineSpec(shards=3)
    assert "+auto:3" in s.canonical()
    assert EngineSpec.parse(s.canonical(), validate=False) == s


def test_query_literals_and_autotune():
    s = EngineSpec.parse(
        "integer:native_c_table?block_rows=8,impl=jit,scale=0.5,autotune=true",
        validate=False)
    assert s.backend_kwargs == {"block_rows": 8, "impl": "jit", "scale": 0.5}
    assert s.autotune is True


def test_parse_errors():
    with pytest.raises(ValueError):
        EngineSpec.parse("integer:bitvector+tree_parallel:zero", validate=False)
    with pytest.raises(ValueError):
        EngineSpec.parse("integer:reference?keyonly", validate=False)
    with pytest.raises(ValueError):
        EngineSpec.parse("nosuchmode:nosuchbackend@x@y", validate=False)


def test_validate_rejects_unknown_names():
    with pytest.raises(ValueError):
        EngineSpec.parse("integer:nosuchbackend")
    with pytest.raises(ValueError):
        EngineSpec(plan="nosuchplan").validate()
    # a real route validates clean
    EngineSpec.parse("integer:reference+tree_parallel:2")


# ---------------------------------------------------------------------------
# dict round-trip (the wire-handshake serialization)
# ---------------------------------------------------------------------------

def test_dict_roundtrip():
    s = EngineSpec.parse("flint:reference|native_c+tree_parallel:2"
                         "?block_rows=4", validate=False)
    d = s.to_dict()
    assert isinstance(d, dict)
    import json
    assert EngineSpec.from_dict(json.loads(json.dumps(d))) == s


def test_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError):
        EngineSpec.from_dict({"mode": "integer", "bogus": 1})


def test_replace():
    s = EngineSpec.parse("integer:reference", validate=False)
    assert s.replace(shards=2).shards == 2
    assert s.shards is None  # frozen original untouched


# ---------------------------------------------------------------------------
# coerce: the deprecation shim
# ---------------------------------------------------------------------------

def test_coerce_passthrough_and_string():
    s = EngineSpec(mode="flint")
    assert EngineSpec.coerce(s, caller="t0") is s
    assert EngineSpec.coerce("flint:reference", caller="t1").mode == "flint"
    assert EngineSpec.coerce({"mode": "flint"}, caller="t2").mode == "flint"
    assert EngineSpec.coerce(None, caller="t3") == EngineSpec()


def test_coerce_loose_kwargs_warn_once_per_caller():
    with warnings.catch_warnings(record=True) as seen:
        warnings.simplefilter("always")
        a = EngineSpec.coerce(None, caller="t-warn", mode="flint", shards=2)
        b = EngineSpec.coerce(None, caller="t-warn", mode="integer")
    assert a.mode == "flint" and a.shards == 2
    assert b.mode == "integer"
    deps = [w for w in seen if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1  # second call from the same caller is silent


def test_coerce_rejects_spec_plus_loose():
    with pytest.raises(ValueError):
        EngineSpec.coerce("integer:reference", caller="t-mix", shards=2)

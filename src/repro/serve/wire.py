"""Length-prefixed wire protocol for shipping uint32 partials between hosts.

The fabric's one invariant is the paper's: integer partial accumulators are
associative uint32 sums, so a shard's partial buffer means exactly the same
thing no matter which process produced it.  The protocol is therefore tiny —
raw little-endian array bytes behind a fixed frame header, no serialization
framework:

    frame   := magic(4s=b"ITRG") msg_type(u8) payload_len(u32) payload
    HELLO   := arrays payload (JSON meta + ForestIR CSR arrays): the model
               handshake — model id/version, EngineSpec dict, the shard
               table, and every array a worker needs to rebuild the forest
               (leaf_probs ships as zeros: remote plans are
               deterministic-mode only, and the float leaf table is the one
               big array the uint32 path never reads).  When the gateway's
               model came from an ITRF artifact, ``meta["artifact_format"]
               == "itrf"`` and the single array ``"itrf"`` is the raw
               artifact image — the worker rebuilds the forest through
               ``repro.ir.artifact.read_itrf_bytes`` with no per-array
               directory round-trip (the artifact-bytes fast path)
    HELLO_ACK := JSON {pid, host, wire, model, version}
    PREDICT := u32 req_id, u32 shard_id, u32 rows, u32 features, then
               rows*features little-endian float32
    PARTIALS:= u32 req_id, u32 shard_id, u32 rows, u32 classes, then
               rows*classes little-endian uint32, then a JSON span trailer
               ([name, t0_rel_ns, t1_rel_ns] relative to request receipt,
               grafted into the gateway trace under the dispatch span)
    ERROR   := JSON {req_id, error} — the *attempt* failed (e.g. the worker
               lacks a C toolchain for its assigned backend); the
               connection itself is still healthy
    CLOSE   := empty; polite gateway-side teardown

All integers in frame headers are network byte order (``!``); array bytes
are explicitly little-endian so a big-endian host on either side still
round-trips bit-exactly.
"""
from __future__ import annotations

import json
import struct
import socket

import numpy as np

__all__ = [
    "MAGIC", "WIRE_VERSION",
    "MSG_HELLO", "MSG_HELLO_ACK", "MSG_PREDICT", "MSG_PARTIALS",
    "MSG_ERROR", "MSG_CLOSE",
    "ConnectionClosed", "send_frame", "read_frame",
    "pack_arrays", "unpack_arrays",
    "encode_hello", "decode_hello", "encode_predict", "decode_predict",
    "encode_partials", "decode_partials", "encode_error", "decode_error",
]

MAGIC = b"ITRG"
WIRE_VERSION = 1

MSG_HELLO = 1
MSG_HELLO_ACK = 2
MSG_PREDICT = 3
MSG_PARTIALS = 4
MSG_ERROR = 5
MSG_CLOSE = 6

_HEADER = struct.Struct("!4sBI")  # magic, msg_type, payload_len
_U32X4 = struct.Struct("!IIII")
_JLEN = struct.Struct("!I")


class ConnectionClosed(ConnectionError):
    """Peer closed the socket cleanly (EOF at a frame boundary or not)."""


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def send_frame(sock: socket.socket, msg_type: int, payload: bytes = b"") -> None:
    sock.sendall(_HEADER.pack(MAGIC, msg_type, len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray(n)
    view, got = memoryview(buf), 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise ConnectionClosed(f"peer closed with {n - got} bytes pending")
        got += k
    return bytes(buf)


def read_frame(sock: socket.socket) -> tuple:
    """-> (msg_type, payload).  Raises :class:`ConnectionClosed` on EOF."""
    magic, msg_type, n = _HEADER.unpack(_recv_exact(sock, _HEADER.size))
    if magic != MAGIC:
        raise ConnectionClosed(f"bad frame magic {magic!r}")
    return msg_type, (_recv_exact(sock, n) if n else b"")


# ---------------------------------------------------------------------------
# array payloads (HELLO)
# ---------------------------------------------------------------------------

def _le_bytes(a: np.ndarray) -> bytes:
    a = np.ascontiguousarray(a)
    return a.astype(a.dtype.newbyteorder("<"), copy=False).tobytes()


def pack_arrays(meta: dict, arrays: dict) -> bytes:
    """JSON header (meta + array directory) followed by the raw
    little-endian bytes of each array, in directory order."""
    entries, blobs = [], []
    for name, a in arrays.items():
        a = np.ascontiguousarray(a)
        entries.append({"name": name,
                        "dtype": a.dtype.newbyteorder("<").str,
                        "shape": list(a.shape)})
        blobs.append(_le_bytes(a))
    head = json.dumps({"meta": meta, "arrays": entries}).encode()
    return _JLEN.pack(len(head)) + head + b"".join(blobs)


def unpack_arrays(payload: bytes) -> tuple:
    """-> (meta, {name: ndarray}).  Arrays are copies (writable)."""
    (hlen,) = _JLEN.unpack_from(payload)
    head = json.loads(payload[_JLEN.size:_JLEN.size + hlen])
    off = _JLEN.size + hlen
    arrays = {}
    for ent in head["arrays"]:
        dt = np.dtype(ent["dtype"])
        count = int(np.prod(ent["shape"], dtype=np.int64)) if ent["shape"] else 1
        a = np.frombuffer(payload, dt, count=count, offset=off)
        arrays[ent["name"]] = a.reshape(ent["shape"]).copy()
        off += count * dt.itemsize
    return head["meta"], arrays


encode_hello = pack_arrays
decode_hello = unpack_arrays


# ---------------------------------------------------------------------------
# request / response payloads
# ---------------------------------------------------------------------------

def encode_predict(req_id: int, shard_id: int, X) -> bytes:
    X = np.ascontiguousarray(X, np.float32)
    if X.ndim != 2:
        raise ValueError(f"PREDICT wants a 2-D row block, got shape {X.shape}")
    return (_U32X4.pack(req_id, shard_id, X.shape[0], X.shape[1])
            + X.astype("<f4", copy=False).tobytes())


def decode_predict(payload: bytes) -> tuple:
    req_id, shard_id, rows, feats = _U32X4.unpack_from(payload)
    X = np.frombuffer(payload, "<f4", count=rows * feats,
                      offset=_U32X4.size).reshape(rows, feats)
    return req_id, shard_id, X


def encode_partials(req_id: int, shard_id: int, acc, spans=()) -> bytes:
    acc = np.ascontiguousarray(acc, np.uint32)
    if acc.ndim != 2:
        raise ValueError(f"PARTIALS wants (rows, classes), got shape {acc.shape}")
    trailer = json.dumps([[n, int(a), int(b)] for n, a, b in spans]).encode()
    return (_U32X4.pack(req_id, shard_id, acc.shape[0], acc.shape[1])
            + acc.astype("<u4", copy=False).tobytes() + trailer)


def decode_partials(payload: bytes) -> tuple:
    """-> (req_id, shard_id, uint32 (rows, classes) acc, span trailer)."""
    req_id, shard_id, rows, classes = _U32X4.unpack_from(payload)
    count = rows * classes
    # astype: native byte order + a writable copy (frombuffer views are
    # read-only and the merge accumulates in place)
    acc = np.frombuffer(payload, "<u4", count=count,
                        offset=_U32X4.size).reshape(rows, classes) \
        .astype(np.uint32)
    tail = payload[_U32X4.size + count * 4:]
    spans = [(n, int(a), int(b)) for n, a, b in json.loads(tail or b"[]")]
    return req_id, shard_id, acc, spans


def encode_error(req_id: int, error: str) -> bytes:
    return json.dumps({"req_id": int(req_id), "error": str(error)}).encode()


def decode_error(payload: bytes) -> tuple:
    d = json.loads(payload)
    return int(d.get("req_id", 0)), str(d.get("error", ""))

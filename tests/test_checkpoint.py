"""Checkpoint/restart + fault tolerance: atomic commit, resume, crash loop,
straggler watchdog, integer/compression utilities."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.fault_tolerance import RestartableLoop, StepWatchdog
from repro.checkpoint.manager import CheckpointManager
from repro.train.compression import compress_tree_with_feedback, dequantize_int8


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 5, (4,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(3, t)
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, jax.tree.map(np.zeros_like, t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, restored)


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=False)
        mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_partial_write_not_restored(tmp_path):
    """A crash mid-save must never be picked up (no COMMITTED marker)."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    broken = tmp_path / "step_000000009"
    broken.mkdir()
    (broken / "MANIFEST.json").write_text("{}")  # no COMMITTED
    assert mgr.latest_step() == 1


def test_restartable_loop_recovers(tmp_path):
    mgr = CheckpointManager(tmp_path)
    crashes = {"left": 2}

    def step_fn(state, step):
        if step == 7 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1}

    loop = RestartableLoop(mgr, ckpt_every=5, max_restarts=5)
    state, info = loop.run({"x": jnp.zeros(())}, step_fn, total_steps=12)
    assert info["restarts"] == 2
    assert float(state["x"]) == 12  # deterministic despite crashes


def test_restart_limit(tmp_path):
    mgr = CheckpointManager(tmp_path)

    def bad(state, step):
        raise RuntimeError("always")

    loop = RestartableLoop(mgr, max_restarts=2)
    with pytest.raises(RuntimeError):
        loop.run({"x": jnp.zeros(())}, bad, total_steps=3)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=3.0)
    for _ in range(10):
        assert not wd.observe(0.1)
    assert wd.observe(1.0)  # 10x median
    assert wd.stragglers == 1


def test_int8_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(512,)), jnp.float32)}
    residual = None
    acc_err = []
    est_sum = np.zeros(512)
    exact_sum = np.zeros(512)
    for step in range(20):
        q, s, residual = compress_tree_with_feedback(g, residual)
        deq = dequantize_int8(q["w"], s["w"])
        est_sum += np.asarray(deq)
        exact_sum += np.asarray(g["w"])
        acc_err.append(np.abs(est_sum - exact_sum).max())
    # error feedback keeps cumulative drift bounded (does not grow ~linearly)
    assert acc_err[-1] < 3 * max(acc_err[:3]) + 1e-3

"""Serving engines.

``LMEngine``: batched prefill + greedy/temperature decode for the LM archs
(jitted prefill and decode steps, KV/state cache carried on device).

``TreeEngine``: the paper's serving path — a thin shape-bucketing wrapper
over one :class:`~repro.plan.ExecutionPlan`, which in turn drives any
registered :class:`~repro.backends.TreeBackend` (reference jnp, Pallas
kernel, or either emitted-C flavor compiled into a shared library) on one or
many forest shards, mirroring InTreeger's "one model, any hardware"
deployment story.  The execution path is

    engine -> ExecutionPlan -> backend.predict_partials -> merge -> finalize

with the default ``single`` plan reproducing the historical engine->backend
route exactly; ``plan="tree_parallel"``/``"row_parallel"`` + ``shards=N``
shard the forest or the batch with bit-identical deterministic-mode outputs.
The plan layer (via ``repro.plan.build_backend``) is also where the ForestIR
pipeline (IR -> layout -> backend) is resolved: it materializes the layout
the backend prefers (or the caller pins) before constructing it, so callers
hand over a ForestIR or any artifact and never deal in layouts unless they
want to.  The engine is the execution layer behind the gateway
(``repro.serve.gateway``): for plans that compile per shape, incoming batches
are padded up to a small set of power-of-two row buckets so each (model,
mode, plan, bucket) compiles exactly once, no matter how ragged the request
stream is.  Tree traversal is row-independent, so padding rows never perturb
real rows — bucketed outputs are bit-identical to unbucketed ones.
Shape-oblivious plans (native C, single shard) skip padding entirely; the
engine consults the plan's aggregated capabilities for both decisions.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


class LMEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(lambda p, b: tfm.prefill(cfg, p, b, max_seq=max_seq))
        self._decode = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))

    def generate(self, batch: dict, n_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Greedy (T=0) or sampled decode.  Returns (B, n_tokens) int32."""
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        toks = []
        b = logits.shape[0]
        for i in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32).reshape(b, 1)
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(toks, axis=1)


def bucket_rows(b: int, *, max_bucket: int = 4096) -> int:
    """Padded row count for a batch of ``b`` rows: the next power of two,
    capped at ``max_bucket``; beyond the cap, the next ``max_bucket``
    multiple (so huge batches still see a bounded shape vocabulary)."""
    if b <= 0:
        raise ValueError("batch must have at least one row")
    if b >= max_bucket:
        return -(-b // max_bucket) * max_bucket
    return 1 << (b - 1).bit_length()


class TreeEngine:
    """Shape-bucketing wrapper over one :class:`~repro.plan.ExecutionPlan`.

    ``packed`` is a :class:`~repro.ir.ForestIR` or any materialized layout
    artifact.  The route — mode, backend(s), layout, plan, shards, backend
    kwargs, autotune — is one :class:`~repro.serve.spec.EngineSpec`, passed
    as ``spec`` (an EngineSpec, a dict, or a spec string like
    ``"integer:bitvector@leaf_major+tree_parallel:4"``); the individual
    keyword arguments survive as a deprecation shim that warns once per
    call site.  Within the spec: ``backend`` is a registered backend name
    (``"reference"``, ``"pallas"``, ``"native_c"``, ``"native_c_table"``,
    ...), a sequence of names (heterogeneous tree-parallel: one per shard,
    cycled), or an already-constructed backend instance (then
    ``packed``/``mode`` are taken from it).  ``plan`` selects the execution
    plan (``"single"`` | ``"tree_parallel"`` | ``"row_parallel"`` |
    ``"remote_tree_parallel"``; ``None``/``"auto"`` picks by capability:
    one shard -> single, many shards -> tree-parallel for the deterministic
    modes, row-parallel otherwise) and ``shards`` the shard count.
    ``layout`` pins a ForestIR layout; by default each shard backend's
    declared ``preferred_layout`` is materialized (resolution goes through
    the artifact's IR back-reference, so a ``pack_forest`` output can feed
    a ragged-only backend without re-quantizing).  ``plan_kwargs`` carries
    plan-specific knobs outside the spec (e.g. the remote plan's
    ``workers``/``deadline_ms`` — deployment facts, not route identity).
    ``predict``/``predict_scores`` accept any row count; for shape-compiling
    plans the batch is padded to a :func:`bucket_rows` bucket so each
    bucket compiles once (tracked in ``compiled_buckets``).  ``max_bucket``
    defaults to the plan's ``preferred_block_rows`` hint so padded shapes
    line up with the backends' internal tiling.  Engines whose plan owns
    executors (thread pools, remote workers) release them via
    :meth:`close`.

    ``autotune=True`` measures the serving backend's construction knobs
    (table-walk ``block_rows``, bitvector ``interleave``, Pallas block tiling
    — see :mod:`repro.serve.autotune`) during :meth:`warm` and rebuilds the
    plan on the measured winner; single-shard string-backend routes only, and
    knobs the caller already pinned via ``backend_kwargs`` are never
    overridden.  ``tuned_store`` (a mutable dict, normally the owning
    ``ModelVersion``'s) caches winners per (backend, layout, mode) route so a
    hot-swapped version or a rebuilt engine skips re-measuring; the
    ``REPRO_AUTOTUNE=0`` env var disables tuning globally.
    """

    def __init__(self, packed=None, spec=None, *, mode: Optional[str] = None,
                 backend=None, backend_kwargs: Optional[dict] = None,
                 max_bucket: Optional[int] = None, layout: Optional[str] = None,
                 plan: Optional[str] = None, shards: Optional[int] = None,
                 plan_kwargs: Optional[dict] = None, autotune=None,
                 tuned_store: Optional[dict] = None):
        from repro.plan import create_plan, select_plan
        from repro.serve.autotune import TUNABLE_BACKENDS, autotune_enabled, \
            config_str
        from repro.serve.spec import EngineSpec

        spec = EngineSpec.coerce(spec, caller="TreeEngine", mode=mode,
                                 backend=backend, layout=layout, plan=plan,
                                 shards=shards, backend_kwargs=backend_kwargs,
                                 autotune=autotune)
        self.spec = spec
        mode, backend, layout = spec.mode, spec.backend, spec.layout
        plan, shards, autotune = spec.plan, spec.shards, spec.autotune
        backend_kwargs = dict(spec.backend_kwargs) if spec.backend_kwargs else None
        self._ctor = dict(packed=packed, mode=mode, backend=backend,
                          backend_kwargs=backend_kwargs, layout=layout,
                          plan=plan, shards=shards, plan_kwargs=plan_kwargs)
        self._tuned_store = tuned_store if tuned_store is not None else {}
        self._tuned_config: Optional[str] = None
        self._pending_tune = False
        if autotune_enabled(autotune) and isinstance(backend, str) \
                and backend in TUNABLE_BACKENDS \
                and select_plan(plan, mode=mode, backend=backend,
                                shards=shards, model=packed) == "single":
            winner = self._tuned_store.get(self._tune_key())
            if winner is not None:
                # a cached measurement (hot-swap, rebuilt engine): apply it
                # now — caller-pinned kwargs still win on key collisions
                backend_kwargs = {**winner, **(backend_kwargs or {})}
                self._tuned_config = config_str(winner)
            else:
                self._pending_tune = True
        self.plan = create_plan(
            plan, packed, mode=mode, backend=backend, shards=shards,
            layout=layout, backend_kwargs=backend_kwargs,
            **(plan_kwargs or {})
        )
        self.packed = self.plan.packed
        self.mode = self.plan.mode
        self.max_bucket = max_bucket or self.plan.preferred_block_rows or 4096
        self.compiled_buckets: set[int] = set()
        # first-execution wall ms per bucket (jit compile / native build /
        # warm cost) plus the autotune measuring cost under the "tune" key
        # and the registry's artifact-load ms under "load", drained by the
        # gateway into per-model metrics
        self._compile_ms: dict = {}
        # set by close(); the registry's retention policy closes engines of
        # released versions and the gateway prunes closed engines
        self.closed = False

    def _tune_key(self):
        c = self._ctor
        return (c["backend"], c["layout"], c["mode"])

    @property
    def backend(self):
        """The (first) shard backend — the whole backend for single/row
        plans; ``None`` for a fused device-parallel plan (no per-shard
        backend objects exist)."""
        backends = self.plan.backends
        return backends[0] if backends else None

    @property
    def backend_name(self) -> str:
        return self.plan.backend_name

    @property
    def plan_name(self) -> str:
        return self.plan.name

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def layout(self) -> str:
        """The ForestIR layout(s) the plan's backends are walking."""
        return self.plan.layout

    @property
    def deterministic(self) -> bool:
        """True when outputs are bit-exact integer scores (cacheable)."""
        return self.plan.deterministic

    def simd_isa(self):
        """The SIMD ISA the serving backend dispatches to ("avx2" / "neon" /
        "scalar" for the C backends), or ``None`` for backends without the
        surface (JAX paths, fused device-parallel plans).  May trigger the
        backend's first build — callers wanting a free probe should ask
        after serving has started."""
        fn = getattr(self.backend, "simd_isa", None)
        return fn() if fn is not None else None

    @property
    def tuned_config(self) -> Optional[str]:
        """The autotuned backend config serving this engine (e.g.
        ``"interleave=4"``), or ``None`` when untuned (autotune off, tuning
        still pending, or a knob the caller pinned)."""
        return self._tuned_config

    def _run_autotune(self, max_rows: int) -> None:
        """Measure the backend's candidate grid and rebuild the plan on the
        winner (see :mod:`repro.serve.autotune`).  Runs at most once, at the
        start of the first :meth:`warm`; the measuring wall-ms lands in the
        compile ledger under ``"tune"`` and the winner in ``tuned_store``."""
        from repro.plan import create_plan
        from repro.serve import autotune as at

        self._pending_tune = False
        c = self._ctor
        user_kw = c["backend_kwargs"] or {}
        backend = self.backend  # builds the default-config backend
        grid = at.candidate_grid(self.backend_name, backend.packed)
        if not grid or set(grid[0]) & set(user_kw):
            return  # nothing to sweep, or the caller pinned the knob
        t0 = time.perf_counter()
        winner, winner_backend, _ = at.tune_backend(
            self.backend_name, backend.packed, self.mode,
            rows=min(max(max_rows, 1), at._TUNE_ROWS), baseline=backend,
        )
        self._compile_ms["tune"] = (time.perf_counter() - t0) * 1e3
        if winner is None:
            return
        self._tuned_store[self._tune_key()] = winner
        self._tuned_config = at.config_str(winner)
        if winner_backend is not backend:
            # serve on the measured winner: rebuild the plan around the
            # already-built winning backend (no recompile)
            self.plan = create_plan(
                c["plan"], c["packed"], mode=c["mode"],
                backend=winner_backend, shards=c["shards"],
                layout=c["layout"], **(c["plan_kwargs"] or {})
            )
            self.compiled_buckets.clear()

    def drain_shard_timings(self) -> dict:
        """Per-shard wall time since the last drain (``{label: (ms, calls)}``)
        — what the gateway records into ``serve.metrics`` per batch."""
        return self.plan.drain_timings()

    def drain_stage_timings(self) -> dict:
        """Pipeline-stage wall time since the last drain — pad (recorded
        here), merge + finalize (recorded by the plan)."""
        return self.plan.drain_stage_timings()

    def drain_compile_timings(self) -> dict:
        """First-execution (compile/warm) wall ms per bucket since the last
        drain: ``{bucket_rows: ms}``, plus the autotuner's ``"tune"`` entry
        and the plan's one-time setup cost (the remote plan's
        connect + handshake ms under ``"remote"``)."""
        out, self._compile_ms = self._compile_ms, {}
        out.update(self.plan.drain_setup_timings())
        return out

    def close(self) -> None:
        """Release executors the plan owns: shard thread pools drain and
        re-create lazily; remote worker connections/processes tear down for
        good.  Marks the engine closed so holders (the gateway's engine set)
        can drop their references."""
        self.closed = True
        self.plan.close()

    # ------------------------------------------------------------- tracing
    def attach_trace(self, tracer, parent) -> None:
        """Attach a tracer and the span that parents this *thread's*
        execution spans (pad → shard×N → merge → finalize).  The gateway
        calls this around each batch execute; direct callers can too."""
        self.plan.attach_tracer(tracer)
        self.plan.trace_parent = parent

    def detach_trace(self) -> None:
        """Clear this thread's parent span (the tracer attach persists)."""
        self.plan.trace_parent = None

    def warm(self, max_rows: int) -> None:
        """Pre-compile every bucket any batch of 1..``max_rows`` rows can map
        to: the power-of-two buckets below ``max_bucket``, plus the
        ``max_bucket``-multiple shapes used once batches reach the cap.
        Warming goes *through the plan*, so every shard of a multi-shard plan
        sees exactly the sub-batch shapes real predicts will hand it (chunked
        rows for row-parallel, full buckets per tree shard) — no shard is
        left to compile on the first live request.  For shape-oblivious plans
        one call builds every shard's artifact (e.g. compiles the native
        libraries) and no further shapes exist.

        When autotuning is armed, the candidate sweep runs first — warm is
        the one moment the engine may measure and swap its backend without a
        request in flight — and the buckets below warm whatever won."""
        if self._pending_tune:
            self._run_autotune(max_rows)
        zeros = lambda nb: np.zeros((nb, self.packed.n_features), np.float32)
        if not self.plan.compiles_per_shape:
            self.predict(zeros(1))
            return
        # `top` is the bucket the largest batch rounds UP to — walking only to
        # max_rows would leave the covering bucket cold (e.g. 20 rows -> 32)
        top = bucket_rows(max_rows, max_bucket=self.max_bucket)
        nb = 1
        while nb <= top and nb < self.max_bucket:
            self.predict(zeros(nb))
            nb *= 2
        if top >= self.max_bucket:
            for m in range(self.max_bucket, top + 1, self.max_bucket):
                self.predict(zeros(m))

    def padded_rows(self, b: int) -> int:
        """Rows actually executed for a ``b``-row batch: the bucket shape
        for compiling plans, ``b`` itself for shape-oblivious ones."""
        if not self.plan.compiles_per_shape:
            return b
        return bucket_rows(b, max_bucket=self.max_bucket)

    def _pad(self, X):
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"expected (B, F) features, got shape {X.shape}")
        b = X.shape[0]
        nb = self.padded_rows(b)
        if nb != b:
            X = np.concatenate([X, np.zeros((nb - b, X.shape[1]), np.float32)])
        return X, b, nb

    def _pad_traced(self, X):
        """Bucket-pad under a timed ``pad`` stage (and span when traced)."""
        t0 = time.perf_counter_ns()
        X, b, nb = self._pad(X)
        t1 = time.perf_counter_ns()
        self.plan._record_stage("pad", (t1 - t0) / 1e9)
        self.plan._span("pad", t0, t1, self.plan.trace_parent, rows=b, padded=nb)
        return X, b, nb

    def _run(self, X):
        X, b, nb = self._pad_traced(X)
        cold = self.plan.compiles_per_shape and nb not in self.compiled_buckets
        t0 = time.perf_counter()
        scores, preds = self.plan.predict_scores(X)
        if self.plan.compiles_per_shape:
            # only a predict that actually returned has compiled its bucket
            self.compiled_buckets.add(nb)
            if cold:
                self._compile_ms[nb] = (time.perf_counter() - t0) * 1e3
        return np.asarray(scores)[:b], np.asarray(preds)[:b]

    def predict(self, X) -> np.ndarray:
        _, preds = self._run(X)
        return preds

    def predict_scores(self, X):
        return self._run(X)

    def predict_partials(self, X):
        """Merged (B, C) uint32 partials through the bucketed path
        (deterministic modes)."""
        X, b, nb = self._pad_traced(X)
        cold = self.plan.compiles_per_shape and nb not in self.compiled_buckets
        t0 = time.perf_counter()
        acc = self.plan.predict_partials(X)
        if self.plan.compiles_per_shape:
            self.compiled_buckets.add(nb)
            if cold:
                self._compile_ms[nb] = (time.perf_counter() - t0) * 1e3
        return np.asarray(acc)[:b]

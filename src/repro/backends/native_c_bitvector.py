"""NativeCBitvectorBackend: the emitted-C QuickScorer bitvector scorer.

The sequential sibling of the jnp ``bitvector`` backend, riding the shared
``CompiledCBackend`` gcc/ctypes machinery: ``codegen/bitvector_emitter``
compiles the bitvector layout's per-feature ascending threshold streams and
false-node leaf masks as static data, and scoring is one linear pass over
sorted keys per feature (first true compare breaks the stream) followed by a
lowest-set-bit scan per tree — no per-row tree traversal at all, which is
where the QuickScorer line of work wins on large-T shallow forests.

``interleave=K`` is the v-QuickScorer multi-tree blocking knob (default 8):
the emitter pads each feature's ascending stream to K-entry groups and every
block variant runs one early-exit test + K unrolled mask applies per group —
the warm-time autotuner sweeps this grid and pins the measured winner.
``simd=False`` pins the scalar blocked path per instance (same macro as the
degradation CI job, scoped to this build) so one process can measure
dispatch variants against each other on identical artifacts.

Deterministic modes only, and both compile the same integer translation unit
(uint32 partials out, shared numpy finalize), so scores are bit-identical to
every other backend across every execution plan — including multi-word
(>64-leaf) trees, which just widen the per-tree uint64 state — and across
every interleave width, since padding entries are inert and grouping never
reorders any real mask application.
"""
from __future__ import annotations

from repro.backends.base import BackendCapabilities, register_backend
from repro.backends.native_c import CompiledCBackend

_DEFAULT_INTERLEAVE = 8


@register_backend
class NativeCBitvectorBackend(CompiledCBackend):
    name = "native_c_bitvector"
    capabilities = BackendCapabilities(
        modes=("flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,
        compiles_per_shape=False,
        supported_layouts=("bitvector",),
        preferred_layout="bitvector",
    )

    def __init__(self, packed, mode: str = "integer", *,
                 interleave: int = None, simd: bool = True, **kwargs):
        super().__init__(packed, mode, **kwargs)
        self.interleave = (_DEFAULT_INTERLEAVE if interleave is None
                           else int(interleave))
        if self.interleave < 1:
            raise ValueError(f"interleave must be >= 1, got {interleave}")
        self.simd = bool(simd)
        if not self.simd:
            self._cflags = self._cflags + ("-DREPRO_NO_SIMD",)

    def _emit_source(self) -> str:
        from repro.codegen.bitvector_emitter import emit_bitvector_c

        # flint and integer share the integer unit (partials + numpy finalize);
        # the emitter's TU is complete (blocked predict_batch included)
        return emit_bitvector_c(
            self.packed, mode="integer", interleave=self.interleave
        )

"""NativeCTableBackend: the ragged layout compiled as a vectorized table walk.

The fourth backend, and the first consumer of a non-padded ForestIR layout:
``codegen/table_emitter.emit_table_walk_c`` compiles the ragged ensemble's
CSR node arrays as static data plus a generic branch-free-select walk loop,
into the same ``predict_batch`` shared-library contract as ``native_c``.
Where the if-else backend puts the forest in the instruction stream (ideal
for MCU single-row latency), this one keeps the code O(1) and streams node
*data* — the layout trade the ARM tree-ensemble literature shows dominates
throughput at batch, now directly measurable via
``benchmarks/run.py backend_matrix`` (if-else vs table-walk C, same model,
several batch sizes).

Row-blocked by default: ``block_rows=R`` (default 8, the capability's
``preferred_block_rows``) emits a batch entry that walks R rows per tree in
lockstep through fixed-size state arrays and an exact ``max_depth`` select
trip count — tree-major memory order, branch-free inner loop, vectorizable.
``block_rows=1`` keeps the scalar per-row while-loop walk (the baseline the
blocked variant is benchmarked against in ``backend_matrix``).

Deterministic modes only (integer + flint), and since the partials/finalize
split both compile the *same* integer translation unit: the library emits
uint32 partial accumulators (``predict_partials``) and the shared numpy
finalize produces the mode's scores.  Thresholds stay FlInt int32 keys, so
partials are bit-identical to every other backend — the conformance suite
holds across the layout axis AND every block size, since blocking only
reorders *which rows* walk when, never any row's own accumulation order.
"""
from __future__ import annotations

from repro.backends.base import BackendCapabilities, register_backend
from repro.backends.native_c import CompiledCBackend

_DEFAULT_BLOCK_ROWS = 8


@register_backend
class NativeCTableBackend(CompiledCBackend):
    name = "native_c_table"
    capabilities = BackendCapabilities(
        modes=("flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=_DEFAULT_BLOCK_ROWS,
        compiles_per_shape=False,
        supported_layouts=("ragged",),
        preferred_layout="ragged",
    )

    def __init__(self, packed, mode: str = "integer", *,
                 block_rows: int = None, simd: bool = True, **kwargs):
        super().__init__(packed, mode, **kwargs)
        self.block_rows = (_DEFAULT_BLOCK_ROWS if block_rows is None
                           else int(block_rows))
        if self.block_rows < 1:
            raise ValueError(f"block_rows must be >= 1, got {block_rows}")
        # simd=False pins the scalar blocked walk per *instance* (the SIMD
        # blocks compile but the dispatcher is forced off via the same macro
        # the degradation CI job sets process-wide) — what lets one bench
        # process measure avx2-vs-scalar on identical artifacts
        self.simd = bool(simd)
        if not self.simd:
            self._cflags = self._cflags + ("-DREPRO_NO_SIMD",)

    def _emit_source(self) -> str:
        from repro.codegen.c_emitter import emit_batch_entry
        from repro.codegen.table_emitter import emit_table_walk_c

        mode = self._exec_mode  # flint and integer share the integer unit
        if self.block_rows == 1:  # scalar per-row walk, the pre-blocking path
            return emit_table_walk_c(self.packed, mode=mode) + \
                emit_batch_entry(self.packed, mode=mode)
        return emit_table_walk_c(
            self.packed, mode=mode, block_rows=self.block_rows
        )

# One-step entry points for the repo's standard workflows.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench serve-trees serve-gateway

# tier-1 verify (see ROADMAP.md)
test:
	$(PY) -m pytest -x -q

bench:
	$(PY) benchmarks/run.py

serve-trees:
	$(PY) -m repro.launch.serve --trees

serve-gateway:
	$(PY) -m repro.launch.serve --trees --gateway

"""Mixture-of-Experts layer: FlInt top-k routing + capacity-factor dispatch.

Paper tie-in (DESIGN.md Sec. 4): expert selection only needs the *order* of
router logits, so top-k runs on FlInt int32 keys (``repro.core.flint``) —
bit-identical selection, integer-only compare path.  This is the
within-LM-stack application of the paper's threshold-comparison insight.

Dispatch is scatter-based (no (T, E, C) one-hot): tokens are scattered into an
(E, C, d) buffer by (expert, slot) with slot = per-expert running count;
overflow beyond capacity drops (mode="drop"), standard Switch/GShard
semantics with capacity_factor.  Experts are sharded on the ``model`` mesh
axis; XLA SPMD inserts the dispatch/combine collectives (baseline; the
hillclimb in EXPERIMENTS.md Sec. Perf attacks exactly these).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.flint import float_to_key
from repro.models.layers import act_fn, dense_init
from repro.sharding.ops import compat_shard_map, constrain


def moe_params(key, d_model: int, n_experts: int, d_ff: int):
    kg, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "w_router": dense_init(kg, (d_model, n_experts)),
        "w_gate_e": dense_init(k1, (n_experts, d_model, d_ff), in_axis=1),
        "w_up_e": dense_init(k2, (n_experts, d_model, d_ff), in_axis=1),
        "w_down_e": dense_init(k3, (n_experts, d_ff, d_model), in_axis=1),
    }


def flint_topk(logits, k: int):
    """Top-k on int32 FlInt keys: integer compares only, identical order.

    Returns (gate_weights (T,k) f32 softmaxed over the k, expert_ids (T,k)).
    """
    keys = float_to_key(logits.astype(jnp.float32))
    _, ids = jax.lax.top_k(keys, k)  # int32 comparisons
    sel = jnp.take_along_axis(logits.astype(jnp.float32), ids, axis=-1)
    w = jax.nn.softmax(sel, axis=-1)  # normalize over the selected k (qwen3/olmoe)
    return w, ids


def _aux_loss(logits, ids, n_experts):
    """Switch-style load-balancing loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=0)
    one_hot_top1 = jax.nn.one_hot(ids[:, 0], n_experts, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=0)
    return n_experts * jnp.sum(me * ce)


def moe_block(params, x, *, n_experts: int, k: int, act: str = "silu",
              capacity_factor: float = 1.25):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss.

    Dispatches to the expert-parallel shard_map path when a mesh with a
    non-trivial ``model`` axis is active (see ``moe_block_ep``); otherwise the
    single-program scatter path below (CPU tests, 1-device meshes).
    """
    from repro.sharding.ops import current_mesh

    mesh = current_mesh()
    if mesh is not None and mesh.shape.get("model", 1) > 1 and n_experts % mesh.shape["model"] == 0:
        # EP only pays off when the per-shard expert batch amortizes the
        # weight gather and keeps capacity sane; at decode (a few tokens per
        # shard) the single-program path is both faster and drop-free.
        b, s, _ = x.shape
        dp = 1
        for a in ("pod", "data"):
            dp *= mesh.shape.get(a, 1)
        t_loc = (b * s) // max(dp, 1)
        if t_loc * k >= 4 * n_experts:
            return moe_block_ep(
                params, x, n_experts=n_experts, k=k, act=act,
                capacity_factor=capacity_factor, mesh=mesh,
            )
    return _moe_block_jit(
        params, x, n_experts=n_experts, k=k, act=act, capacity_factor=capacity_factor
    )


def _moe_block_jit(params, x, *, n_experts: int, k: int, act: str,
                   capacity_factor: float):
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = xt @ params["w_router"].astype(x.dtype)  # (T, E)
    gates, ids = flint_topk(logits, k)  # (T,k)
    aux_loss = _aux_loss(logits, ids, n_experts)

    capacity = int(max(1, (t * k * capacity_factor) // n_experts))

    ids_flat = ids.reshape(-1)  # (T*k,)
    # slot within expert = rank of this pair among same-expert pairs
    onehot = jax.nn.one_hot(ids_flat, n_experts, dtype=jnp.int32)  # (T*k, E)
    slots = (jnp.cumsum(onehot, axis=0) - onehot)  # exclusive prefix count
    slot_flat = jnp.take_along_axis(slots, ids_flat[:, None], axis=1)[:, 0]

    xrep = jnp.repeat(xt, k, axis=0)  # (T*k, D) token copies per routed pair
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[ids_flat, slot_flat].set(xrep, mode="drop")
    # dispatch buffer lives expert-sharded: the scatter above IS the all-to-all
    buf = constrain(buf, "expert", None, None)

    a = act_fn(act)
    gate = a(jnp.einsum("ecd,edf->ecf", buf, params["w_gate_e"].astype(x.dtype)))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up_e"].astype(x.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", gate * up, params["w_down_e"].astype(x.dtype))
    out_e = constrain(out_e, "expert", None, None)

    # combine: read back each pair's slot; dropped pairs (slot >= capacity) -> 0
    in_cap = slot_flat < capacity
    safe_slot = jnp.minimum(slot_flat, capacity - 1)
    yrep = out_e[ids_flat, safe_slot]  # (T*k, D)
    yrep = jnp.where(in_cap[:, None], yrep, 0)
    y = (yrep.reshape(t, k, d) * gates[..., None].astype(x.dtype)).sum(axis=1)
    return y.reshape(b, s, d), aux_loss


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------
#
# GSPMD cannot partition the scatter-based dispatch sanely: the baseline
# dry-run showed 1.1-2.8 TB/device/step of dispatch all-gathers on the MoE
# cells (EXPERIMENTS.md §Perf).  The manual pattern exploits the 2-D mesh
# directly: device (i, j) owns data-shard i's tokens AND model-shard j's
# experts, so dispatch/FFN/partial-combine are fully local; the ONLY
# communication is a psum of the combined output over `model` (plus the FSDP
# weight all-gather over `data`, which AD transposes to the grad
# reduce-scatter).  No all-to-all is needed at all in this topology.

def _ep_body(wr, wg, wu, wd, xb, *, n_experts, e_loc, k, act, capacity_factor,
             batch_axes):
    b, s, d = xb.shape
    t = b * s
    xt = xb.reshape(t, d)
    logits = xt @ wr.astype(xt.dtype)  # (t_loc, E) — full expert range
    gates, ids = flint_topk(logits, k)
    aux = _aux_loss(logits, ids, n_experts)
    aux = jax.lax.pmean(aux, batch_axes)  # identical across `model` already

    lo = jax.lax.axis_index("model") * e_loc
    ids_loc = jnp.where((ids >= lo) & (ids < lo + e_loc), ids - lo, e_loc)
    ids_flat = ids_loc.reshape(-1)  # (t*k,) — e_loc == "not mine"

    capacity = int(max(1, (t * k * capacity_factor) // n_experts))
    onehot = jax.nn.one_hot(ids_flat, e_loc + 1, dtype=jnp.int32)
    slots = jnp.cumsum(onehot, axis=0) - onehot
    slot_flat = jnp.take_along_axis(slots, ids_flat[:, None], axis=1)[:, 0]

    # Compact dispatch: scatter only the (token-id, gate) bookkeeping (a few
    # MB), then GATHER the <= e_loc*capacity landed rows — never materialize
    # the (t*k, d) token-copy tensor (12-16x traffic vs. the landed rows).
    pair_tok = jnp.arange(t * k, dtype=jnp.int32) // k
    src_tok = jnp.full((e_loc, capacity), t, jnp.int32)  # t == padding row
    src_tok = src_tok.at[ids_flat, slot_flat].set(pair_tok, mode="drop")
    gate_slot = jnp.zeros((e_loc, capacity), jnp.float32)
    gate_slot = gate_slot.at[ids_flat, slot_flat].set(gates.reshape(-1), mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    buf = xt_pad[src_tok]  # (e_loc, capacity, d)

    a = act_fn(act)
    gate = a(jnp.einsum("ecd,edf->ecf", buf, wg.astype(xb.dtype)))
    up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(xb.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", gate * up, wd.astype(xb.dtype))

    # Compact combine: scatter-add the gated expert rows straight into the
    # (t, d) output (padding rows target index t -> dropped).
    contrib = out_e * gate_slot[..., None].astype(xb.dtype)
    y = jnp.zeros((t, d), xb.dtype)
    y = y.at[src_tok.reshape(-1)].add(contrib.reshape(-1, d), mode="drop")
    y = jax.lax.psum(y, "model")  # combine partial expert outputs
    return y.reshape(b, s, d), aux


def moe_block_ep(params, x, *, n_experts: int, k: int, act: str,
                 capacity_factor: float, mesh):
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape["model"]
    e_loc = n_experts // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
              None, None)
    body = functools.partial(
        _ep_body, n_experts=n_experts, e_loc=e_loc, k=k, act=act,
        capacity_factor=capacity_factor, batch_axes=batch_axes,
    )
    fn = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(),  # router weight: replicated (tiny)
            P("model", None, None),  # expert weights: local experts, full d
            P("model", None, None),
            P("model", None, None),
            bspec,  # tokens: local batch shard, replicated over model
        ),
        out_specs=(bspec, P()),
    )
    return fn(params["w_router"], params["w_gate_e"], params["w_up_e"],
              params["w_down_e"], x)

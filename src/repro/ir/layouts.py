"""Layout materializers: ForestIR -> concrete memory layouts.

Each layout is the analogue of the paper's codegen step (Sec. III-B) for one
execution strategy — instead of one fixed artifact, the IR materializes into
whichever layout the chosen backend walks fastest:

  * ``padded``     — dense ``(T, N)`` node tables, every tree padded to the
                     max node count with self-looping zero-mass leaves.  The
                     TPU layout: uniform shapes for vectorized gathers
                     (reference jnp walk, Pallas kernel) and the layout the
                     if-else C emitter reads.  Bit-identical to the historical
                     ``pack_forest`` output.
  * ``ragged``     — CSR-style contiguous node arrays with per-tree offsets
                     and *global* child indices.  No O(T*N_max) padding waste
                     on depth-skewed forests; the layout the table-walk C
                     backend (``native_c_table``) compiles data-as-arrays.
  * ``leaf_major`` — padded tables with each tree's nodes permuted internal-
                     first/leaves-last, so a table walk touches a dense
                     internal-node prefix and leaves sit in one contiguous
                     block (the linear-scan-friendly ordering from the ARM
                     tree-ensemble layout literature).  Same dtype/shape
                     surface as ``padded`` — any node-table backend runs it.

Materializers never quantize: they only rearrange the IR's arrays, which is
why every layout is score-bit-identical in the flint/integer modes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict

import numpy as np

from repro.core.fixedpoint import scale_for

_LAYOUTS: Dict[str, Callable] = {}


def register_layout(name: str):
    """Decorator: register ``fn(ir) -> artifact`` as a named layout."""

    def deco(fn):
        _LAYOUTS[name] = fn
        return fn

    return deco


def available_layouts() -> list:
    return sorted(_LAYOUTS)


def materialize(ir, name: str):
    try:
        fn = _LAYOUTS[name]
    except KeyError:
        raise KeyError(
            f"unknown layout {name!r}; available: {available_layouts()}"
        ) from None
    return fn(ir)


# ---------------------------------------------------------------------------
# padded: the historical PackedEnsemble node tables
# ---------------------------------------------------------------------------

def _padded_tables(ir, order=None):
    """Scatter the IR into (T, N) tables; ``order`` optionally permutes each
    tree's nodes (``order[t]`` maps new position -> IR-local index)."""
    from repro.core.packing import PackedEnsemble

    T, C, N = ir.n_trees, ir.n_classes, ir.max_nodes
    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    threshold_key = np.zeros((T, N), np.int32)  # == float_to_key(0.0)
    left = np.tile(np.arange(N, dtype=np.int32), (T, 1))
    right = left.copy()
    probs = np.zeros((T, N, C), np.float64)
    fixed = np.zeros((T, N, C), np.uint32)
    counts = ir.node_counts
    for t in range(T):
        off, n = int(ir.node_offsets[t]), int(counts[t])
        sl = slice(off, off + n)
        if order is None:
            perm = slice(None)
            child = lambda a: a
        else:
            perm = order[t]  # new -> old
            inv = np.empty(n, np.int32)
            inv[perm] = np.arange(n, dtype=np.int32)  # old -> new
            child = lambda a, inv=inv: inv[a]
        feature[t, :n] = ir.feature[sl][perm]
        threshold[t, :n] = ir.threshold[sl][perm]
        threshold_key[t, :n] = ir.threshold_key[sl][perm]
        left[t, :n] = child(ir.left[sl][perm])
        right[t, :n] = child(ir.right[sl][perm])
        probs[t, :n] = ir.leaf_probs[sl][perm]
        fixed[t, :n] = ir.leaf_fixed[sl][perm]
    return PackedEnsemble(
        feature=feature,
        threshold=threshold,
        threshold_key=threshold_key,
        left=left,
        right=right,
        leaf_probs=probs.astype(np.float32),
        leaf_fixed=fixed,
        n_trees=T,
        n_classes=C,
        n_features=ir.n_features,
        max_depth=ir.max_depth,
        quant_scale=ir.quant_scale,
        node_counts=counts.copy(),
        ir=ir,
    )


@register_layout("padded")
def padded_layout(ir):
    """Dense (T, N) self-looping node tables — the TPU/codegen layout."""
    return _padded_tables(ir)


@register_layout("leaf_major")
def leaf_major_layout(ir):
    """Padded tables with internal nodes first, leaves grouped last per tree.

    The permutation is stable within each group, and a tree's root stays at
    index 0 (the first internal node in BFS order is the root; a single-leaf
    stump has no internal nodes, so its one leaf stays put).  Traversal is
    index-gather-based, so reordering cannot perturb scores.

    Records ``internal_counts`` (T,) — the per-tree internal-prefix length —
    when the *topological* property the linear-scan kernel
    (``kernels.tree_traverse.tree_traverse_leaf_major``) relies on holds:
    within the internal prefix every child sits at a strictly larger index
    than its parent, so one forward pass over the prefix routes every row
    from the root to its leaf.  Tree builders append children after their
    parent and the stable permutation preserves that order, but imported
    artifacts (``trees/io``) may order nodes arbitrarily — the tables are
    still valid for every gather-based walker, so such forests materialize
    fine with ``internal_counts = None`` and the Pallas backend's
    ``impl="auto"`` falls back to the gather walk instead of the scan.
    """
    order = []
    internal_counts = np.zeros(ir.n_trees, np.int32)
    scannable = True
    for t in range(ir.n_trees):
        sl = slice(int(ir.node_offsets[t]), int(ir.node_offsets[t + 1]))
        is_leaf = ir.feature[sl] < 0
        internal = np.flatnonzero(~is_leaf)
        internal_counts[t] = len(internal)
        perm = np.concatenate([internal, np.flatnonzero(is_leaf)]).astype(np.int32)
        if scannable and len(internal):
            inv = np.empty(len(perm), np.int32)
            inv[perm] = np.arange(len(perm), dtype=np.int32)
            kids = np.concatenate(
                [inv[ir.left[sl][internal]], inv[ir.right[sl][internal]]]
            )
            scannable = bool((kids > np.tile(inv[internal], 2)).all())
        order.append(perm)
    out = _padded_tables(ir, order)
    out.layout = "leaf_major"
    out.internal_counts = internal_counts if scannable else None
    return out


# ---------------------------------------------------------------------------
# ragged: CSR node arrays, global child indices
# ---------------------------------------------------------------------------

@dataclass
class RaggedEnsemble:
    """CSR materialization: all trees' nodes contiguous, no padding.

    ``left``/``right`` are *global* node indices (leaves self-loop globally),
    ``roots[t] == node_offsets[t]`` is tree ``t``'s entry point — exactly the
    arrays the table-walk C emitter (``codegen/table_emitter.py``) compiles
    as static data.  Exposes the same metadata surface as ``PackedEnsemble``
    so engines and emitters stay layout-polymorphic.
    """

    feature: np.ndarray  # (total,) int32, -1 for leaf
    threshold: np.ndarray  # (total,) float32
    threshold_key: np.ndarray  # (total,) int32
    left: np.ndarray  # (total,) int32, global
    right: np.ndarray  # (total,) int32, global
    leaf_probs: np.ndarray  # (total, C) float32
    leaf_fixed: np.ndarray  # (total, C) uint32
    roots: np.ndarray  # (T,) int32
    node_offsets: np.ndarray  # (T+1,) int64
    n_trees: int
    n_classes: int
    n_features: int
    max_depth: int
    layout: str = "ragged"
    # sub-forest artifacts: the parent ensemble's quantization scale
    quant_scale: int = field(default=None, repr=False)
    ir: object = field(default=None, repr=False, compare=False)

    @property
    def scale(self) -> int:
        return self.quant_scale if self.quant_scale is not None \
            else scale_for(self.n_trees)

    @property
    def total_nodes(self) -> int:
        return int(self.node_offsets[-1])

    def nbytes_integer(self) -> int:
        """Bytes of the integer-only ragged deployment artifact."""
        return (
            self.feature.nbytes
            + self.threshold_key.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_fixed.nbytes
            + self.roots.nbytes
        )

    def nbytes_float(self) -> int:
        return (
            self.feature.nbytes
            + self.threshold.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_probs.nbytes
            + self.roots.nbytes
        )


@register_layout("ragged")
def ragged_layout(ir):
    base = np.repeat(ir.node_offsets[:-1], ir.node_counts).astype(np.int32)
    return RaggedEnsemble(
        feature=ir.feature.copy(),
        threshold=ir.threshold.copy(),
        threshold_key=ir.threshold_key.copy(),
        left=ir.left + base,
        right=ir.right + base,
        leaf_probs=ir.leaf_probs.astype(np.float32),
        leaf_fixed=ir.leaf_fixed.copy(),
        roots=ir.node_offsets[:-1].astype(np.int32),
        node_offsets=ir.node_offsets.copy(),
        n_trees=ir.n_trees,
        n_classes=ir.n_classes,
        n_features=ir.n_features,
        max_depth=ir.max_depth,
        quant_scale=ir.quant_scale,
        ir=ir,
    )

"""The paper's central claims, on our three inference paths:
identical predictions (Sec. IV-B) and Fig. 2 probability-delta magnitudes."""
import numpy as np
import pytest

from repro.core.ensemble import (
    integer_probs,
    make_predict_fn,
    predict_flint,
    predict_float,
    predict_integer,
)
from repro.core.fixedpoint import fixed_to_prob_np, max_abs_error
from repro.core.packing import pack_forest
from repro.data.tabular import make_shuttle_like, train_test_split
from repro.trees.forest import RandomForestClassifier


def test_flint_matches_float(small_packed, shuttle_small):
    """FlInt-keyed path: identical predictions; probabilities agree to the
    fixed-point bound.  (Since the partials/finalize split, flint
    accumulates the exact uint32 partials — shardable with zero loss — and
    recovers float probabilities by one reciprocal multiply, so scores are
    within quantization error of the float path rather than equal to it.)"""
    _, _, Xte, _ = shuttle_small
    pf, predf = predict_float(small_packed, Xte)
    pfl, predfl = predict_flint(small_packed, Xte)
    np.testing.assert_array_equal(np.asarray(predf), np.asarray(predfl))
    assert np.abs(np.asarray(pf) - np.asarray(pfl)).max() < 1e-6
    assert np.asarray(pfl).dtype == np.float32


def test_flint_scores_are_finalized_integer_partials(small_packed, shuttle_small):
    """flint == finalize(integer partials): same exact accumulator, one
    reciprocal multiply — the property that makes flint tree-shardable."""
    from repro.core.ensemble import finalize_partials, predict_partials_mode

    _, _, Xte, _ = shuttle_small
    acc_i, _ = predict_integer(small_packed, Xte[:64])
    acc_fl = predict_partials_mode(small_packed, Xte[:64], "flint")
    np.testing.assert_array_equal(np.asarray(acc_i), np.asarray(acc_fl))
    s_np, p_np = finalize_partials("flint", np.asarray(acc_fl),
                                   small_packed.n_trees, small_packed.scale)
    s_jnp, p_jnp = predict_flint(small_packed, Xte[:64])
    # numpy finalize (backends/plans) == jitted jnp finalize, bit for bit
    np.testing.assert_array_equal(s_np, np.asarray(s_jnp))
    np.testing.assert_array_equal(p_np, np.asarray(p_jnp))


def test_integer_predictions_identical(small_packed, shuttle_small, small_forest):
    """Paper Sec. IV-B: predictions identical on every sample tested."""
    _, _, Xte, _ = shuttle_small
    _, predf = predict_float(small_packed, Xte)
    acc, predi = predict_integer(small_packed, Xte)
    assert (np.asarray(predi) == np.asarray(predf)).all()


def test_probability_delta_magnitude(small_packed, shuttle_small, small_forest):
    """Fig. 2: deltas ~1e-10 (1 tree) .. ~1e-8 (100 trees); here 9 trees."""
    _, _, Xte, _ = shuttle_small
    oracle = small_forest.predict_proba(Xte)
    acc, _ = predict_integer(small_packed, Xte)
    rec = fixed_to_prob_np(np.asarray(acc), small_packed.n_trees)
    err = np.abs(rec - oracle).max()
    assert err <= max_abs_error(small_packed.n_trees)
    assert err < 1e-8


@pytest.mark.parametrize("n_trees", [1, 10, 40])
def test_paper_repro_multiple_splits(n_trees):
    """Reduced version of the paper's 10-split repetition protocol."""
    X, y = make_shuttle_like(n=3000, seed=11)
    for split_seed in range(3):
        Xtr, ytr, Xte, yte = train_test_split(X, y, seed=split_seed)
        rf = RandomForestClassifier(n_estimators=n_trees, max_depth=5, seed=split_seed).fit(
            Xtr, ytr
        )
        packed = pack_forest(rf)
        _, predf = predict_float(packed, Xte)
        _, predi = predict_integer(packed, Xte)
        assert (np.asarray(predf) == np.asarray(predi)).all()


def test_integer_probs_reconstruction(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    acc, _ = predict_integer(small_packed, Xte[:64])
    probs = np.asarray(integer_probs(small_packed, acc))
    assert probs.shape == (64, small_packed.n_classes)
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-4)


def test_make_predict_fn_jit_paths(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    fns = {m: make_predict_fn(small_packed, m) for m in ("float", "flint", "integer")}
    outs = {m: np.asarray(fn(Xte[:128])[1]) for m, fn in fns.items()}
    np.testing.assert_array_equal(outs["float"], outs["flint"])
    np.testing.assert_array_equal(outs["float"], outs["integer"])

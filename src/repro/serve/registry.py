"""Multi-model registry: versioned packed ensembles behind stable model ids.

Models enter through either boundary the repo supports:
  * a trained forest object (``register_forest``), or
  * the Treelite-style JSON artifact (``register_json``), i.e. the
    ``trees/io`` exchange format — the path externally-trained models take.

Each ``register_*`` call creates a new immutable :class:`ModelVersion` and
atomically repoints the model id at it (hot-swap).  In-flight batches formed
against the previous version keep their reference and finish on it; new
requests route to the new version.  Engines are built lazily per (version,
mode, backend, layout) and memoized, so a registry fronts every route —
reference jnp, Pallas kernel, either compiled-C flavor, over any ForestIR
layout the backend walks — with one compile set per version.  The version's
padded tables carry the canonical IR, so every layout materializes from one
quantization.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from repro.core.packing import PackedEnsemble, pack_forest
from repro.serve.engine import TreeEngine
from repro.trees.io import forest_from_json


def _freeze(obj):
    """Nested dict/list -> hashable tuples (the plan_kwargs memo-key leg)."""
    if isinstance(obj, dict):
        return tuple(sorted(((k, _freeze(v)) for k, v in obj.items()),
                            key=lambda kv: kv[0]))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


@dataclass
class ModelVersion:
    model_id: str
    version: int
    packed: PackedEnsemble
    source: str  # "forest" | "json"
    _engines: dict = field(default_factory=dict, repr=False)
    # wall-ms spent constructing each route's engine (backend builds, native
    # compiles) — the cold-start cost ``describe()`` surfaces per model
    _build_ms: dict = field(default_factory=dict, repr=False)
    # measured autotune winners per (backend, layout, mode) route — written
    # by TreeEngine warm-time tuning, copied forward across hot-swaps by the
    # registry so a swapped-in version reuses the measurement
    _tuned: dict = field(default_factory=dict, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def engine(self, spec=None, *, mode: str = None, backend=None,
               layout: str = None, backend_kwargs: dict = None,
               plan: str = None, shards: int = None,
               autotune=None, plan_kwargs: dict = None) -> TreeEngine:
        """The memoized TreeEngine for one route.

        The route is an :class:`~repro.serve.spec.EngineSpec` (object, dict,
        or spec string) — ``engine("integer:bitvector+tree_parallel:4")``;
        a bare mode name (``engine("integer")``) and the loose keyword
        arguments remain as the deprecation-shimmed pre-spec API.

        Within the spec: ``layout=None`` resolves to the backend's
        ``preferred_layout`` (and memoizes under the resolved name, so a
        later explicit request for that layout reuses the same engine); a
        sequence of backend names (heterogeneous tree-parallel) memoizes
        under the tuple.  ``backend_kwargs`` only apply on the call that
        first builds the engine; later lookups for the same route return it
        as-is.  ``autotune`` arms warm-time measured tuning (memoized
        separately, so tuned and untuned routes never alias); winners land
        in this version's ``_tuned`` cache and survive hot-swaps.
        ``plan_kwargs`` carries plan deployment knobs (e.g. the remote
        plan's ``workers``) and participates in the memo key; the remote
        plan additionally receives this version's identity so its handshake
        carries the model id + version.
        """
        from repro.backends import backend_class
        from repro.plan import select_plan
        from repro.serve.spec import MODES, EngineSpec

        if isinstance(spec, str) and spec in MODES and mode is None:
            # a bare mode name is valid under both APIs: alone it is simply
            # the spec string "integer" (no deprecation); combined with loose
            # route kwargs it is the pre-spec positional call
            # engine("integer", backend=...) and goes through the shim
            loose = (backend, layout, plan, shards, backend_kwargs)
            if any(v is not None for v in loose) or autotune is not None:
                mode, spec = spec, None
        spec = EngineSpec.coerce(spec, caller="ModelVersion.engine",
                                 mode=mode, backend=backend, layout=layout,
                                 plan=plan, shards=shards,
                                 backend_kwargs=backend_kwargs,
                                 autotune=autotune)
        if isinstance(spec.backend, str):
            resolved = spec.layout or \
                backend_class(spec.backend).capabilities.preferred_layout
            backend_key = spec.backend
        else:  # heterogeneous shard spec: memoize under the name tuple
            resolved = spec.layout
            backend_key = tuple(spec.backend) \
                if isinstance(spec.backend, tuple) else spec.backend
        # memoize under the *resolved* plan so plan=None / "auto" / "single"
        # (and their equivalent shard counts) share one engine instead of
        # rebuilding — and recompiling — the same route per alias
        resolved_plan = select_plan(spec.plan, mode=spec.mode,
                                    backend=spec.backend, shards=spec.shards,
                                    model=self.packed)
        key = (spec.mode, backend_key, resolved, resolved_plan,
               None if resolved_plan == "single" else spec.shards,
               bool(spec.autotune), _freeze(plan_kwargs))
        with self._lock:
            if key not in self._engines:
                t0 = time.perf_counter()
                pk = dict(plan_kwargs or {})
                if resolved_plan == "remote_tree_parallel":
                    # the wire handshake carries the model identity
                    pk.setdefault("model_id", self.model_id)
                    pk.setdefault("version", self.version)
                self._engines[key] = TreeEngine(
                    self.packed, spec.replace(layout=resolved),
                    plan_kwargs=pk or None, tuned_store=self._tuned,
                )
                route = "/".join(
                    str(p) for p in (spec.mode, backend_key, resolved,
                                     resolved_plan)
                )
                self._build_ms[route] = (time.perf_counter() - t0) * 1e3
            return self._engines[key]


class ModelRegistry:
    def __init__(self):
        self._models: dict[str, ModelVersion] = {}
        self._history: dict[str, int] = {}  # model_id -> latest version number
        self._lock = threading.Lock()

    # ---------------------------------------------------------- registration
    def _install(self, model_id: str, packed: PackedEnsemble, source: str) -> ModelVersion:
        with self._lock:
            version = self._history.get(model_id, 0) + 1
            mv = ModelVersion(model_id=model_id, version=version, packed=packed,
                              source=source)
            prev = self._models.get(model_id)
            if prev is not None:
                # carry measured autotune winners across the hot-swap: the
                # host didn't change, so the new version serves on the tuned
                # config immediately instead of re-measuring during warm
                mv._tuned.update(prev._tuned)
            self._history[model_id] = version
            self._models[model_id] = mv  # atomic repoint = hot-swap
            return mv

    def register_packed(self, model_id: str, packed: PackedEnsemble) -> ModelVersion:
        return self._install(model_id, packed, "packed")

    def register_forest(self, model_id: str, forest) -> ModelVersion:
        return self._install(model_id, pack_forest(forest), "forest")

    def register_json(self, model_id: str, payload: str) -> ModelVersion:
        """Load from the trees/io JSON artifact boundary."""
        return self._install(model_id, pack_forest(forest_from_json(payload)), "json")

    # ---------------------------------------------------------------- lookup
    def get(self, model_id: str) -> ModelVersion:
        try:
            return self._models[model_id]
        except KeyError:
            raise KeyError(f"unknown model id {model_id!r}; have {sorted(self._models)}")

    def version(self, model_id: str) -> int:
        return self.get(model_id).version

    def ids(self) -> list:
        return sorted(self._models)

    def describe(self) -> dict:
        out = {}
        for mid, mv in sorted(self._models.items()):
            d = {
                "version": mv.version,
                "source": mv.source,
                "n_trees": mv.packed.n_trees,
                "n_classes": mv.packed.n_classes,
                "n_features": mv.packed.n_features,
                "artifact_kb": mv.packed.nbytes_integer() / 1e3,
            }
            # bytes per layout, for the layouts serving routes have actually
            # materialized (reporting must not force builds of the others)
            ir = getattr(mv.packed, "ir", None)
            if ir is not None:
                d["layout_kb"] = {
                    name: ir.materialize(name).nbytes_integer() / 1e3
                    for name in ir.materialized_layouts()
                }
            if mv._build_ms:
                d["engine_builds"] = dict(sorted(mv._build_ms.items()))
            out[mid] = d
        return out

"""Serving engines.

``LMEngine``: batched prefill + greedy/temperature decode for the LM archs
(jitted prefill and decode steps, KV/state cache carried on device).

``TreeEngine``: the paper's serving path — a packed integer-only ensemble
behind a batched predict() with three implementations (float / flint /
integer jnp, + the Pallas kernel), mirroring InTreeger's deployment story.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


class LMEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 1024):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._prefill = jax.jit(lambda p, b: tfm.prefill(cfg, p, b, max_seq=max_seq))
        self._decode = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))

    def generate(self, batch: dict, n_tokens: int, *, temperature: float = 0.0,
                 seed: int = 0):
        """Greedy (T=0) or sampled decode.  Returns (B, n_tokens) int32."""
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        toks = []
        b = logits.shape[0]
        for i in range(n_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32).reshape(b, 1)
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt)
        return jnp.concatenate(toks, axis=1)


class TreeEngine:
    def __init__(self, packed, *, mode: str = "integer", use_kernel: bool = False,
                 kernel_kwargs: Optional[dict] = None):
        from repro.core.ensemble import make_predict_fn
        from repro.kernels.ops import packed_predict_integer

        self.packed = packed
        self.mode = mode
        if use_kernel:
            assert mode == "integer", "the Pallas kernel implements the integer path"
            kw = kernel_kwargs or {}
            self._fn = lambda x: packed_predict_integer(packed, x, **kw)
        else:
            self._fn = make_predict_fn(packed, mode)

    def predict(self, X) -> np.ndarray:
        _, preds = self._fn(jnp.asarray(X, jnp.float32))
        return np.asarray(preds)

    def predict_scores(self, X):
        scores, preds = self._fn(jnp.asarray(X, jnp.float32))
        return np.asarray(scores), np.asarray(preds)

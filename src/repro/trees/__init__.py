from repro.trees.cart import DecisionTree, TreeArrays, train_tree
from repro.trees.forest import RandomForestClassifier

__all__ = ["DecisionTree", "TreeArrays", "train_tree", "RandomForestClassifier"]

"""Gradient compression for cross-pod (DCN) reduction.

int8 per-tensor quantization with error feedback (residual carried between
steps).  Intended for the ``pod`` axis where links are slowest; composes with
``intreeger_allreduce`` (int32 fixed point, exact-ish) which targets the
in-pod ``data`` axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree_with_feedback(grads, residual):
    """Returns (quantized_tree, scales_tree, new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qs = jax.tree.unflatten(treedef, [o[0] for o in out])
    ss = jax.tree.unflatten(treedef, [o[1] for o in out])
    res = jax.tree.unflatten(treedef, [o[2] for o in out])
    return qs, ss, res

"""GBT substrate + Treelite-style JSON exchange."""
import numpy as np
import pytest

from repro.core.packing import pack_forest
from repro.core.ensemble import predict_integer
from repro.data.tabular import make_shuttle_like, train_test_split
from repro.trees.gbt import GradientBoostedClassifier, pack_gbt, predict_gbt_integer
from repro.trees.io import forest_from_json, forest_to_json
from repro.trees.forest import RandomForestClassifier


@pytest.fixture(scope="module")
def data():
    X, y = make_shuttle_like(n=6000, n_classes=4, seed=5)
    return train_test_split(X, y, seed=5)


def test_gbt_learns(data):
    Xtr, ytr, Xte, yte = data
    gbt = GradientBoostedClassifier(n_estimators=15, max_depth=4, seed=0).fit(Xtr, ytr)
    acc = (gbt.predict(Xte) == yte).mean()
    prior = max(np.bincount(yte)) / len(yte)
    assert acc > max(prior + 0.05, 0.85), acc


def test_gbt_integer_margins_match_float(data):
    """Signed fixed-point margin accumulation (the paper's Sec. III-A math
    with a margin bound) gives identical argmax to the float path."""
    Xtr, ytr, Xte, yte = data
    gbt = GradientBoostedClassifier(n_estimators=12, max_depth=3, seed=1).fit(Xtr, ytr)
    packed = pack_gbt(gbt)
    pred_f = gbt.predict(Xte[:800])
    pred_i = predict_gbt_integer(packed, Xte[:800])
    agree = (pred_f == pred_i).mean()
    # margins can tie within quantization; require near-total agreement
    assert agree >= 0.999, agree


def test_gbt_fixed_point_never_overflows(data):
    Xtr, ytr, Xte, _ = data
    gbt = GradientBoostedClassifier(n_estimators=25, max_depth=4, seed=2).fit(Xtr, ytr)
    packed = pack_gbt(gbt)
    predict_gbt_integer(packed, Xte[:500])  # internal overflow assert


def test_forest_json_roundtrip_scores_bit_identical(data):
    """The registry's load path: JSON round-trip must preserve the integer
    artifact exactly — uint32 scores, not just argmax, are bit-identical."""
    Xtr, ytr, Xte, _ = data
    rf = RandomForestClassifier(n_estimators=7, max_depth=6, seed=3).fit(Xtr, ytr)
    restored = forest_from_json(forest_to_json(rf))
    p1, p2 = pack_forest(rf), pack_forest(restored)
    np.testing.assert_array_equal(p1.threshold_key, p2.threshold_key)
    np.testing.assert_array_equal(p1.leaf_fixed, p2.leaf_fixed)
    s1, pr1 = predict_integer(p1, Xte[:400])
    s2, pr2 = predict_integer(p2, Xte[:400])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(pr1), np.asarray(pr2))


def test_json_schema_version_round_trip(data):
    """Documents carry the schema version; the reader accepts the current
    version and everything older."""
    import json

    from repro.trees.io import SCHEMA_VERSION

    Xtr, ytr, Xte, _ = data
    rf = RandomForestClassifier(n_estimators=3, max_depth=4, seed=11).fit(Xtr, ytr)
    payload = forest_to_json(rf)
    assert json.loads(payload)["schema_version"] == SCHEMA_VERSION

    # backward compat: v1-era documents (no version field) still load
    doc = json.loads(payload)
    del doc["schema_version"]
    legacy = forest_from_json(json.dumps(doc))
    np.testing.assert_array_equal(rf.predict(Xte[:200]), legacy.predict(Xte[:200]))


def test_json_forward_compat_ignores_additive_metadata(data):
    """Additive evolution (e.g. ForestIR layout hints) must not break the
    reader: unknown document- and tree-level keys are ignored, and the model
    loads bit-identically."""
    import json

    Xtr, ytr, Xte, _ = data
    rf = RandomForestClassifier(n_estimators=4, max_depth=4, seed=12).fit(Xtr, ytr)
    doc = json.loads(forest_to_json(rf))
    doc["layout_hints"] = {"preferred": "ragged", "node_counts": [1, 2, 3]}
    doc["generator"] = "some-future-exporter/9.9"
    for t in doc["trees"]:
        t["n_internal"] = 0  # per-tree metadata a newer writer might add
    restored = forest_from_json(json.dumps(doc))
    p1, p2 = pack_forest(rf), pack_forest(restored)
    np.testing.assert_array_equal(p1.threshold_key, p2.threshold_key)
    np.testing.assert_array_equal(p1.leaf_fixed, p2.leaf_fixed)


def test_json_newer_schema_version_refused(data):
    import json

    Xtr, ytr, _, _ = data
    rf = RandomForestClassifier(n_estimators=2, max_depth=3, seed=13).fit(Xtr, ytr)
    doc = json.loads(forest_to_json(rf))
    doc["schema_version"] = 99
    with pytest.raises(ValueError, match="schema_version 99"):
        forest_from_json(json.dumps(doc))


def test_forest_json_roundtrip(data):
    Xtr, ytr, Xte, _ = data
    rf = RandomForestClassifier(n_estimators=6, max_depth=5, seed=0).fit(Xtr, ytr)
    restored = forest_from_json(forest_to_json(rf))
    np.testing.assert_array_equal(rf.predict(Xte[:500]), restored.predict(Xte[:500]))
    # imported models flow through the integer pipeline unchanged
    p1 = pack_forest(rf)
    p2 = pack_forest(restored)
    _, pred1 = predict_integer(p1, Xte[:300])
    _, pred2 = predict_integer(p2, Xte[:300])
    np.testing.assert_array_equal(np.asarray(pred1), np.asarray(pred2))

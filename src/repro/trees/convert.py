"""Converter CLI: trees/io JSON -> ITRF binary artifact.

Closes the paper's dataset -> deployable-artifact loop at the command line:

    python -m repro.trees.convert model.json model.itrf
    python -m repro.trees.convert model.json model.itrf --strip-float --pack-leaves
    python -m repro.trees.convert --inspect model.itrf
    python -m repro.trees.convert --selftest /tmp/demo.itrf

``--strip-float`` drops the float threshold/leaf-probability sections
(deterministic-serving artifact, roughly half the bytes); ``--pack-leaves``
stores the fixed-point leaf table through the exact group codec
(:mod:`repro.ir.packed_leaf`).  ``--inspect`` dumps the header, the section
table, and any tuned-host entries without loading array pages.

``--selftest`` is the end-to-end proof CI runs: train a small forest, write
its JSON, convert, then reload the artifact **in a fresh process** via mmap
and assert the reloaded reference partials are bit-identical to the
in-process ones (``--verify`` is that subprocess entry point: it prints
``PARTIALS_SHA256 <hex>`` for deterministic probe rows).
"""
from __future__ import annotations

import argparse
import hashlib
import json
import subprocess
import sys
import tempfile


def _partials_digest(ir, rows: int = 64, seed: int = 0) -> str:
    """SHA-256 of the reference backend's integer partials on deterministic
    probe rows — the cross-process identity fingerprint."""
    import numpy as np

    from repro.backends import create_backend

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((rows, ir.n_features)).astype(np.float32)
    backend = create_backend("reference", ir.materialize("padded"),
                             mode="integer")
    acc = np.ascontiguousarray(np.asarray(backend.predict_partials(X)),
                               dtype="<u4")
    return hashlib.sha256(acc.tobytes()).hexdigest()


def _convert(args) -> int:
    from repro.ir import ForestIR
    from repro.trees.io import forest_from_json

    with open(args.input) as fh:
        forest = forest_from_json(fh.read())
    ir = ForestIR.from_forest(forest)
    info = ir.to_itrf(args.output, include_float=not args.strip_float,
                      pack_leaves=args.pack_leaves, group=args.group)
    sizes = ir.nbytes_by_layout("integer")
    print(f"wrote {info['path']}: {info['file_bytes']} bytes, "
          f"sections {info['sections']}")
    print("layout bytes (integer): "
          + "; ".join(f"{k}={v}" for k, v in sorted(sizes.items())))
    return 0


def _inspect(path) -> int:
    from repro.ir.artifact import inspect_itrf

    print(json.dumps(inspect_itrf(path), indent=2))
    return 0


def _verify(path) -> int:
    from repro.ir import ForestIR

    ir = ForestIR.from_itrf(path, mmap=True)
    print(f"PARTIALS_SHA256 {_partials_digest(ir)}")
    return 0


def _selftest(out_path) -> int:
    import numpy as np

    from repro.data.tabular import make_shuttle_like, train_test_split
    from repro.ir import ForestIR
    from repro.trees.forest import RandomForestClassifier
    from repro.trees.io import forest_to_json

    Xtr, ytr, _, _ = train_test_split(*make_shuttle_like(n=1500, seed=0),
                                      seed=0)
    rf = RandomForestClassifier(n_estimators=10, max_depth=8, seed=0).fit(
        Xtr, ytr)
    with tempfile.NamedTemporaryFile("w", suffix=".json",
                                     delete=False) as fh:
        fh.write(forest_to_json(rf))
        json_path = fh.name
    rc = main([json_path, out_path, "--pack-leaves"])
    if rc:
        return rc
    expect = _partials_digest(ForestIR.from_forest(rf))
    # the fresh-process reload: a new interpreter mmaps the artifact and
    # must reproduce the in-process partials bit-for-bit
    out = subprocess.run([sys.executable, "-m", "repro.trees.convert",
                          "--verify", out_path],
                         capture_output=True, text=True, timeout=600)
    sys.stderr.write(out.stderr)
    got = None
    for line in out.stdout.splitlines():
        if line.startswith("PARTIALS_SHA256 "):
            got = line.split(None, 1)[1].strip()
    if out.returncode or got != expect:
        print(f"SELFTEST FAIL: fresh-process digest {got} != {expect}")
        return 1
    print(f"SELFTEST OK: fresh-process mmap reload bit-identical ({expect})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trees.convert",
        description="Convert trees/io JSON models to ITRF binary artifacts.")
    ap.add_argument("input", nargs="?", help="model JSON path")
    ap.add_argument("output", nargs="?", help="output .itrf path")
    ap.add_argument("--strip-float", action="store_true",
                    help="omit float threshold/leaf-probability sections")
    ap.add_argument("--pack-leaves", action="store_true",
                    help="group-quantize/bit-pack the fixed-point leaf table")
    ap.add_argument("--group", type=int, default=None,
                    help="codec group size (default 64)")
    ap.add_argument("--inspect", metavar="ITRF",
                    help="dump an artifact's header/section table as JSON")
    ap.add_argument("--verify", metavar="ITRF",
                    help="mmap-load an artifact and print its partials digest")
    ap.add_argument("--selftest", metavar="OUT_ITRF",
                    help="train, convert, and verify in a fresh process")
    args = ap.parse_args(argv)
    if args.inspect:
        return _inspect(args.inspect)
    if args.verify:
        return _verify(args.verify)
    if args.selftest:
        return _selftest(args.selftest)
    if not args.input or not args.output:
        ap.error("need INPUT.json and OUTPUT.itrf (or one of --inspect/"
                 "--verify/--selftest)")
    return _convert(args)


if __name__ == "__main__":
    raise SystemExit(main())

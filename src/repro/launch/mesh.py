"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: >= 0.5 wants explicit axis_types;
    0.4.x has neither the kwarg nor jax.sharding.AxisType — feature-detect
    instead of version-parsing."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (axis sizes 1)."""
    return compat_make_mesh((1, 1), ("data", "model"))


def elastic_mesh_shape(n_devices: int, *, model: int = 16):
    """Pick the largest (pod, data, model) grid for a degraded device count.

    Fault-tolerance path (DESIGN.md Sec. 5): after node failures the job
    restarts with whatever is healthy; ``model`` is kept fixed (weight layout
    stability) and the data axis absorbs the loss; leftover devices idle.
    """
    model = min(model, n_devices)
    while n_devices % model:
        model //= 2
    rest = n_devices // model
    # prefer a pod axis of 2 when even (cross-pod DP), else single pod
    if rest % 2 == 0 and rest >= 4:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")

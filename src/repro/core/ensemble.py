"""Ensemble inference paths: float baseline, FlInt, and integer-only.

Mirrors the paper's three evaluated implementations (Sec. IV):
  * ``float``   — float32 threshold compares, float32 probability adds
                  (the "naive" Listing 4 baseline),
  * ``flint``   — int32 key compares, float32 probability adds (FlInt [26]),
  * ``integer`` — int32 key compares, uint32 fixed-point adds (InTreeger).

On TPU the if-else cascade becomes a breadth-batched node-table walk: every
example advances one level per step via vectorized gathers; leaves self-loop.
This module is the pure-jnp reference; ``repro.kernels.tree_traverse`` is the
Pallas VMEM-tiled version of the ``integer`` path and must match it exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import fixed_to_prob
from repro.core.flint import float_to_key
from repro.core.packing import PackedEnsemble

MODES = ("float", "flint", "integer")


def ensemble_device_arrays(packed: PackedEnsemble, mode: str) -> dict:
    """The deployment artifact for one mode, as a dict of jnp arrays."""
    base = dict(
        feature=jnp.asarray(packed.feature),
        left=jnp.asarray(packed.left),
        right=jnp.asarray(packed.right),
    )
    if mode == "float":
        base["threshold"] = jnp.asarray(packed.threshold)
        base["leaf"] = jnp.asarray(packed.leaf_probs)
    elif mode == "flint":
        base["threshold"] = jnp.asarray(packed.threshold_key)
        base["leaf"] = jnp.asarray(packed.leaf_probs)
    elif mode == "integer":
        base["threshold"] = jnp.asarray(packed.threshold_key)
        base["leaf"] = jnp.asarray(packed.leaf_fixed)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return base


def _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth: int):
    """Walk one tree for a batch.  ``x``: (B, F) in the same domain as thr."""
    b = x.shape[0]
    node0 = jnp.zeros(b, jnp.int32)

    def body(_, node):
        feat = feature_t[node]  # (B,) gather
        thr = thr_t[node]
        xv = jnp.take_along_axis(x, jnp.clip(feat, 0)[:, None], axis=1)[:, 0]
        go_left = xv <= thr  # paper Listing 2 semantics
        # leaves have left == right == self, so they self-loop for free
        return jnp.where(go_left, left_t[node], right_t[node])

    return jax.lax.fori_loop(0, depth, body, node0)


@partial(jax.jit, static_argnames=("depth", "acc_dtype"))
def _predict(arrays, x, depth: int, acc_dtype):
    b = x.shape[0]
    c = arrays["leaf"].shape[-1]
    acc0 = jnp.zeros((b, c), acc_dtype)

    def per_tree(acc, tree):
        feature_t, thr_t, left_t, right_t, leaf_t = tree
        node = _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth)
        return acc + leaf_t[node].astype(acc_dtype), None

    acc, _ = jax.lax.scan(
        per_tree,
        acc0,
        (
            arrays["feature"],
            arrays["threshold"],
            arrays["left"],
            arrays["right"],
            arrays["leaf"],
        ),
    )
    return acc


def predict_float(packed: PackedEnsemble, X, arrays=None):
    """float32 path.  Returns (probs f32 (B,C), preds int32)."""
    if arrays is None:
        arrays = ensemble_device_arrays(packed, "float")
    x = jnp.asarray(X, jnp.float32)
    acc = _predict(arrays, x, packed.max_depth, jnp.float32)
    probs = acc / packed.n_trees
    return probs, jnp.argmax(probs, axis=1).astype(jnp.int32)


def predict_flint(packed: PackedEnsemble, X, arrays=None):
    """FlInt path: integer compares, float prob accumulation."""
    if arrays is None:
        arrays = ensemble_device_arrays(packed, "flint")
    keys = float_to_key(jnp.asarray(X, jnp.float32))
    acc = _predict(arrays, keys, packed.max_depth, jnp.float32)
    probs = acc / packed.n_trees
    return probs, jnp.argmax(probs, axis=1).astype(jnp.int32)


def predict_integer(packed: PackedEnsemble, X, arrays=None):
    """InTreeger path: integer compares + uint32 fixed-point accumulation.

    Returns (acc uint32 (B,C), preds int32).  ``acc`` never overflows: each
    tree contributes < scale = floor((2**32-1)/n) and there are n trees.
    """
    if arrays is None:
        arrays = ensemble_device_arrays(packed, "integer")
    keys = float_to_key(jnp.asarray(X, jnp.float32))
    acc = _predict(arrays, keys, packed.max_depth, jnp.uint32)
    return acc, jnp.argmax(acc, axis=1).astype(jnp.int32)


def integer_probs(packed: PackedEnsemble, acc):
    """Reconstruct ensemble-average probabilities from the uint32 scores."""
    return fixed_to_prob(acc, packed.n_trees)


def make_predict_fn(packed: PackedEnsemble, mode: str):
    """Close over device arrays; return a jitted X -> (scores, preds) fn."""
    arrays = ensemble_device_arrays(packed, mode)
    depth = packed.max_depth
    n = packed.n_trees

    if mode == "float":

        def fn(x):
            acc = _predict(arrays, jnp.asarray(x, jnp.float32), depth, jnp.float32)
            probs = acc / n
            return probs, jnp.argmax(probs, axis=1).astype(jnp.int32)

    elif mode == "flint":

        def fn(x):
            keys = float_to_key(jnp.asarray(x, jnp.float32))
            acc = _predict(arrays, keys, depth, jnp.float32)
            probs = acc / n
            return probs, jnp.argmax(probs, axis=1).astype(jnp.int32)

    else:

        def fn(x):
            keys = float_to_key(jnp.asarray(x, jnp.float32))
            acc = _predict(arrays, keys, depth, jnp.uint32)
            return acc, jnp.argmax(acc, axis=1).astype(jnp.int32)

    return jax.jit(fn)

"""gemma3-27b [dense]: 5 local (sliding-window 1024) : 1 global, 128k ctx.

[hf:google/gemma-3-1b-pt pattern]  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.  Runs long_500k: decode over the cache is linear per
token; local layers bound reads to the window; global layers use
sequence-sharded flash-decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    rope_theta=1_000_000.0,
    act="gelu",
    microbatches=16,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    sliding_window=16,
    global_every=2,
    act="gelu",
)

"""olmoe-1b-7b [moe]: 64 experts, top-8.  [arXiv:2409.02060; hf]

16L d_model=2048 16H (GQA kv=16) d_ff=1024 (per expert) vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    microbatches=2,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    moe_capacity_factor=4.0,  # = E/k -> dropless for exactness tests
)

"""Sharded checkpointing with async save, atomic commit, and resume.

Layout (one directory per step):
    <dir>/step_000123/
        MANIFEST.json      {step, leaf paths, shapes, dtypes, mesh shape}
        <leaf-path>.npy    one file per pytree leaf
        COMMITTED          written last — a checkpoint without it is ignored

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * saves are atomic: partial writes (simulated crash) are never restored,
  * restore reshards automatically: leaves are device_put against whatever
    mesh/shardings the restarted job passes (elastic re-mesh after failures),
  * ``latest_step`` skips uncommitted/corrupt directories.

On a real multi-host pod each host writes only the shards it owns
(``jax.experimental.multihost_utils``); on this single-process container the
full array is written, which is the degenerate single-host case of the same
protocol.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(getattr(p, "idx", p))
            for p in path
        )
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, blocking: bool = True):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # pull to host before async

        def _write():
            tmp = self.dir / f"step_{step:09d}.tmp"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            flat = _flatten(host_tree)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in flat.items():
                arr = np.asarray(leaf)
                fn = key.replace("/", "__") + ".npy"
                np.save(tmp / fn, arr)
                manifest["leaves"][key] = {
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self._committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def _committed_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree``; reshard if given."""
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        flat_target = _flatten(target_tree)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if key in flat_shard:
                arr = jax.device_put(arr, flat_shard[key])
            restored[key] = arr
        missing = set(flat_target) - set(restored)
        if missing:
            raise KeyError(f"checkpoint at step {step} missing leaves: {sorted(missing)[:5]}")
        # rebuild tree in target structure
        paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        leaves = []
        for path, _ in paths_and_leaves:
            key = "/".join(
                str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(getattr(p, "idx", p))
                for p in path
            )
            leaves.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

"""Serving subsystem: engines + the async dynamic-batching gateway.

Request path:  client → Gateway.submit → QuantizedKeyCache (per-row probe)
             → MicroBatcher (coalesce to block-shaped batches under a
               latency deadline, admission-controlled) → ModelRegistry
               (versioned, hot-swappable) → TreeEngine (shape-bucketed)
             → ExecutionPlan (single / tree-parallel / row-parallel /
               remote worker shards, exact integer partial merge, one
               finalize)
             → TreeBackend → cache fill → response.

Exports resolve lazily (PEP 562): ``repro.serve.wire`` and
``repro.serve.worker`` — the modules a remote shard worker needs before it
can print WORKER_READY — import without dragging in the jax-heavy engine,
and ``repro.plan.remote`` can import the wire protocol without a circular
trip through the gateway.
"""
from typing import TYPE_CHECKING

_EXPORTS = {
    "AdmissionError": "repro.serve.queue",
    "EngineSpec": "repro.serve.spec",
    "Gateway": "repro.serve.gateway",
    "LMEngine": "repro.serve.engine",
    "MetricsRegistry": "repro.serve.metrics",
    "MicroBatcher": "repro.serve.queue",
    "ModelMetrics": "repro.serve.metrics",
    "ModelRegistry": "repro.serve.registry",
    "ModelVersion": "repro.serve.registry",
    "QuantizedKeyCache": "repro.serve.cache",
    "TreeEngine": "repro.serve.engine",
    "WorkerServer": "repro.serve.worker",
    "bucket_rows": "repro.serve.engine",
    "row_keys": "repro.serve.cache",
    "spawn_local_workers": "repro.serve.worker",
}

__all__ = sorted(_EXPORTS)

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from repro.serve.cache import QuantizedKeyCache, row_keys  # noqa: F401
    from repro.serve.engine import LMEngine, TreeEngine, bucket_rows  # noqa: F401
    from repro.serve.gateway import Gateway  # noqa: F401
    from repro.serve.metrics import MetricsRegistry, ModelMetrics  # noqa: F401
    from repro.serve.queue import AdmissionError, MicroBatcher  # noqa: F401
    from repro.serve.registry import ModelRegistry, ModelVersion  # noqa: F401
    from repro.serve.spec import EngineSpec  # noqa: F401
    from repro.serve.worker import WorkerServer, spawn_local_workers  # noqa: F401


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
    import importlib

    obj = getattr(importlib.import_module(module), name)
    globals()[name] = obj  # cache: next access skips __getattr__
    return obj


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

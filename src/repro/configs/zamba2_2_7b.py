"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  A shared transformer block (attn+mlp, two
alternating parameter sets) is applied every 6 Mamba2 blocks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=64,  # see mamba2 note
    hybrid_attn_every=6,
    hybrid_shared_sets=2,
    microbatches=8,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=32,
    hybrid_attn_every=2,
    hybrid_shared_sets=2,
)

"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8.  [hf:Qwen/Qwen3-30B-A3B]

48L d_model=2048 32H (GQA kv=4, head_dim=128) d_ff=768 (per expert)
vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    microbatches=4,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    moe_capacity_factor=4.0,  # = E/k -> dropless for exactness tests
)

"""Runnable serving driver.

Two modes, matching the paper's end-to-end story adapted to a serving stack:
  * ``--trees``: train an RF on a synthetic Shuttle-like dataset, convert to
    the integer-only packed form, and serve batched predictions through the
    three implementations (float / flint / integer), reporting agreement and
    latency — the InTreeger pipeline as a service.
  * LM mode: load a smoke config and run batched prefill+decode generation.

  PYTHONPATH=src python -m repro.launch.serve --trees --rows 20000
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_trees(args):
    from repro.core.packing import pack_forest
    from repro.data.tabular import make_shuttle_like, train_test_split
    from repro.serve.engine import TreeEngine
    from repro.trees.forest import RandomForestClassifier

    X, y = make_shuttle_like(n=args.rows, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y)
    rf = RandomForestClassifier(
        n_estimators=args.n_trees, max_depth=args.depth, seed=0
    ).fit(Xtr, ytr)
    packed = pack_forest(rf)
    print(
        f"forest: {args.n_trees} trees depth<={args.depth}; packed "
        f"integer artifact {packed.nbytes_integer()/1e3:.1f} kB "
        f"(float: {packed.nbytes_float()/1e3:.1f} kB)"
    )
    engines = {m: TreeEngine(packed, mode=m) for m in ("float", "flint", "integer")}
    engines["integer-pallas"] = TreeEngine(packed, mode="integer", use_kernel=True)
    ref = None
    for name, eng in engines.items():
        eng.predict(Xte[:128])  # warmup/compile
        t0 = time.time()
        for _ in range(args.reps):
            preds = eng.predict(Xte)
        dt = (time.time() - t0) / args.reps
        acc = (preds == yte).mean()
        agree = 1.0 if ref is None else (preds == ref).mean()
        ref = preds if ref is None else ref
        print(
            f"{name:16s} acc={acc:.4f} agree_with_float={agree:.6f} "
            f"{dt*1e6/len(Xte):8.3f} us/row"
        )


def serve_lm(args):
    from repro.configs.base import get_config, smoke_config
    from repro.data.tokens import pipeline_for
    from repro.models import transformer as tfm
    from repro.serve.engine import LMEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    engine = LMEngine(cfg, params, max_seq=args.prompt + args.tokens)
    pipe = pipeline_for(cfg, args.batch, args.prompt)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items() if k != "labels"}
    t0 = time.time()
    out = engine.generate(batch, args.tokens, temperature=args.temperature)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s); sample: {np.asarray(out[0,:16])}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", action="store_true")
    ap.add_argument("--rows", type=int, default=20000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--n-trees", type=int, default=50)
    ap.add_argument("--depth", type=int, default=7)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)
    if args.trees:
        serve_trees(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()

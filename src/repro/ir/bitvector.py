"""QuickScorer-family ``bitvector`` layout: traversal-free scoring tables.

The QuickScorer line of work ("QuickScorer" Lucchese et al.; "Fast Inference
of Tree Ensembles on ARM Devices" Koschel/Buschjäger/Lucchese — PAPERS.md)
replaces the per-row root-to-leaf walk with *comparison streaming*: all
internal-node tests of the whole forest are regrouped per feature and sorted
by threshold, and every internal node carries a bitmask over its tree's
leaves marking which leaves stay reachable when the node's test is FALSE.

Scoring one row then never chases a pointer:

  1. start every tree's leaf bitvector at "all leaves live",
  2. for each feature ``f``, stream its ascending threshold list and, while
     ``x[f] > key`` (the test ``x <= key`` is false), AND the entry's mask
     into its tree's bitvector — the FIRST true comparison ends the feature
     (ascending order makes every later test true too),
  3. each tree's exit leaf is its first surviving bit.

Correctness is the QuickScorer theorem: leaves are numbered in left-to-right
(in-order) order, so any subtree's leaves form a contiguous bit range.  A
false node's mask clears its *left* subtree's range (those leaves become
unreachable when the walk goes right).  Every false ancestor of the true exit
leaf sends the walk right, so the exit leaf is never cleared; and any
surviving leaf strictly to the left of the exit leaf would need its lowest
common ancestor with the exit leaf to have tested true — but that ancestor
sent the real walk right, i.e. tested false, and its mask cleared that leaf.
Hence the exit leaf is exactly the lowest surviving bit.

Masks are uint64 words, ``words = ceil(max_leaves_per_tree / 64)`` — one word
covers trees up to 64 leaves; deeper trees get multi-word bitvectors, and the
whole pipeline (jnp backend, emitted C, conformance) handles ``words > 1``.

Like every materializer, this one never quantizes: threshold keys and
fixed-point leaves are pure rearrangements of the IR's arrays, which is what
keeps ``bitvector`` scores bit-identical to every other layout's in the
deterministic modes — including sub-forest artifacts (``ForestIR.subset``),
whose parent ``quant_scale`` is carried through so tree-parallel partials
merge exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.fixedpoint import scale_for
from repro.ir.layouts import register_layout


def _leaf_order_and_ranges(feature, left, right):
    """In-order leaf numbering for one tree's local arrays.

    Returns ``(leaf_nodes, left_ranges)``:
      * ``leaf_nodes``  — local node index of leaf ``j`` (in left-to-right
        order), length ``n_leaves``;
      * ``left_ranges`` — for every *internal* local node ``n`` (in local
        node order), the ``[lo, hi)`` leaf-index range of its LEFT subtree —
        the bits its false-node mask clears.

    Iterative post-order (explicit stack) so pathologically deep trees don't
    hit the recursion limit, mirroring ``c_emitter._emit_node``.
    """
    n_leaves_seen = 0
    leaf_nodes = []
    # span[n] = (first_leaf, last_leaf_exclusive) of the subtree rooted at n
    span_lo = {}
    span_hi = {}
    left_ranges = {}
    # state 0: descend; state 1: children done, fill ranges
    stack = [(0, 0)]
    while stack:
        node, state = stack.pop()
        if feature[node] < 0:  # leaf: assign the next in-order index
            span_lo[node] = n_leaves_seen
            span_hi[node] = n_leaves_seen + 1
            leaf_nodes.append(node)
            n_leaves_seen += 1
            continue
        if state == 0:
            stack.append((node, 1))
            # left pushed LAST so it pops (and numbers its leaves) first
            stack.append((int(right[node]), 0))
            stack.append((int(left[node]), 0))
        else:
            l, r = int(left[node]), int(right[node])
            span_lo[node] = span_lo[l]
            span_hi[node] = span_hi[r]
            left_ranges[node] = (span_lo[l], span_hi[l])
    return leaf_nodes, left_ranges


def _range_mask(lo: int, hi: int, words: int) -> np.ndarray:
    """All-ones ``(words,)`` uint64 vector with leaf bits [lo, hi) cleared."""
    mask = np.full(words, np.uint64(0xFFFFFFFFFFFFFFFF), np.uint64)
    for bit in range(lo, hi):
        mask[bit // 64] &= ~np.uint64(1 << (bit % 64))
    return mask


@dataclass
class BitvectorEnsemble:
    """The QuickScorer tables: per-feature sorted threshold streams + masks.

    Threshold entries (one per internal node, forest-wide) are grouped by
    feature and sorted ascending by FlInt key within each feature;
    ``feat_offsets[f] : feat_offsets[f+1]`` is feature ``f``'s slice.  Leaves
    are stored leaf-only (no internal-node rows) in in-order sequence per
    tree — ``leaf_offsets[t] + j`` is tree ``t``'s ``j``-th leaf, exactly the
    row the surviving bit ``j`` selects.  Exposes the same metadata surface
    as the other layout artifacts so engines/backends stay polymorphic.
    """

    # threshold stream, grouped by feature, ascending key inside a feature
    feat_offsets: np.ndarray   # (F+1,) int64
    thr_key: np.ndarray        # (E,) int32 FlInt keys (E = total internal)
    thr_threshold: np.ndarray  # (E,) float32 (reporting only; never compared)
    thr_tree: np.ndarray       # (E,) int32 owning tree
    thr_mask: np.ndarray       # (E, words) uint64 false-node masks
    # per-tree live-leaf init vectors and leaf tables
    init_mask: np.ndarray      # (T, words) uint64 — first n_leaves bits set
    n_leaves: np.ndarray       # (T,) int32
    leaf_offsets: np.ndarray   # (T+1,) int64 rows into the leaf tables
    leaf_probs: np.ndarray     # (total_leaves, C) float32, in-order per tree
    leaf_fixed: np.ndarray     # (total_leaves, C) uint32, in-order per tree
    words: int                 # uint64 words per bitvector
    n_trees: int
    n_classes: int
    n_features: int
    max_depth: int
    layout: str = "bitvector"
    quant_scale: int = field(default=None, repr=False)
    ir: object = field(default=None, repr=False, compare=False)

    @property
    def scale(self) -> int:
        return self.quant_scale if self.quant_scale is not None \
            else scale_for(self.n_trees)

    @property
    def total_entries(self) -> int:
        return int(self.thr_key.shape[0])

    @property
    def total_leaves(self) -> int:
        return int(self.leaf_offsets[-1])

    def nbytes_integer(self) -> int:
        """Bytes of the integer-only bitvector deployment artifact."""
        return (
            self.feat_offsets.nbytes
            + self.thr_key.nbytes
            + self.thr_tree.nbytes
            + self.thr_mask.nbytes
            + self.init_mask.nbytes
            + self.leaf_offsets.nbytes
            + self.leaf_fixed.nbytes
        )

    def nbytes_float(self) -> int:
        return (
            self.feat_offsets.nbytes
            + self.thr_threshold.nbytes
            + self.thr_tree.nbytes
            + self.thr_mask.nbytes
            + self.init_mask.nbytes
            + self.leaf_offsets.nbytes
            + self.leaf_probs.nbytes
        )


@register_layout("bitvector")
def bitvector_layout(ir) -> BitvectorEnsemble:
    """Materialize the IR as QuickScorer threshold streams + leaf bitmasks."""
    T, C, F = ir.n_trees, ir.n_classes, ir.n_features
    counts = ir.node_counts
    # -------- per-tree in-order leaf numbering + false-node mask ranges
    leaf_rows = []          # IR row of every leaf, concatenated in-order
    n_leaves = np.zeros(T, np.int32)
    per_node = []           # (feature, key, threshold, tree, lo, hi)
    for t in range(T):
        off, n = int(ir.node_offsets[t]), int(counts[t])
        sl = slice(off, off + n)
        feat, left, right = ir.feature[sl], ir.left[sl], ir.right[sl]
        leaves, left_ranges = _leaf_order_and_ranges(feat, left, right)
        n_leaves[t] = len(leaves)
        leaf_rows.extend(off + l for l in leaves)
        for node, (lo, hi) in left_ranges.items():
            per_node.append(
                (int(feat[node]), int(ir.threshold_key[off + node]),
                 float(ir.threshold[off + node]), t, lo, hi)
            )
    words = max(1, -(-int(n_leaves.max()) // 64))

    # -------- the per-feature ascending threshold stream
    # stable sort by (feature, key): equal keys may order arbitrarily — the
    # streamed predicate ``x > key`` is identical for equal keys, so entry
    # order among ties cannot change which masks apply
    per_node.sort(key=lambda e: (e[0], e[1]))
    E = len(per_node)
    thr_key = np.fromiter((e[1] for e in per_node), np.int32, E)
    thr_threshold = np.fromiter((e[2] for e in per_node), np.float32, E)
    thr_tree = np.fromiter((e[3] for e in per_node), np.int32, E)
    thr_mask = np.empty((E, words), np.uint64)
    for i, (_, _, _, _, lo, hi) in enumerate(per_node):
        thr_mask[i] = _range_mask(lo, hi, words)
    feat_offsets = np.zeros(F + 1, np.int64)
    feats = np.fromiter((e[0] for e in per_node), np.int64, E)
    np.cumsum(np.bincount(feats, minlength=F), out=feat_offsets[1:])

    # -------- init vectors (first n_leaves bits live) + in-order leaf tables
    init_mask = np.zeros((T, words), np.uint64)
    for t in range(T):
        full, rem = divmod(int(n_leaves[t]), 64)
        init_mask[t, :full] = np.uint64(0xFFFFFFFFFFFFFFFF)
        if rem:
            init_mask[t, full] = np.uint64((1 << rem) - 1)
    leaf_offsets = np.zeros(T + 1, np.int64)
    np.cumsum(n_leaves, out=leaf_offsets[1:])
    take = np.asarray(leaf_rows, np.int64)
    return BitvectorEnsemble(
        feat_offsets=feat_offsets,
        thr_key=thr_key,
        thr_threshold=thr_threshold,
        thr_tree=thr_tree,
        thr_mask=thr_mask,
        init_mask=init_mask,
        n_leaves=n_leaves,
        leaf_offsets=leaf_offsets,
        leaf_probs=ir.leaf_probs[take].astype(np.float32),
        leaf_fixed=ir.leaf_fixed[take].copy(),
        words=words,
        n_trees=T,
        n_classes=C,
        n_features=F,
        max_depth=ir.max_depth,
        quant_scale=ir.quant_scale,
        ir=ir,
    )

"""PallasBackend: the VMEM-tiled TPU kernel behind the TreeBackend protocol.

Wraps ``repro.kernels.ops.packed_predict_integer`` and owns the blocking
decisions: the row/tree block sizes fed to the kernel (VMEM-budgeted via
``pick_blocks``) and the ``preferred_block_rows`` hint that makes the serving
layer pad batches to shapes aligned with the kernel's ``block_b`` tiling.

Layout-specialized: the backend prefers the ``leaf_major`` layout, where the
linear-scan kernel (``impl="leaf_major"``) walks each tree's internal-node
prefix front-to-back with compare+select steps — no per-depth node-table
gathers.  ``impl="auto"`` (the default) resolves per layout: linear scan on
``leaf_major`` tables, the per-level ``gather`` walk on ``padded`` ones —
i.e. pinning ``layout="padded"`` falls back to padded+gather untouched.

The kernel implements exactly the paper's integer accumulation (int32 FlInt
compares, uint32 fixed-point adds) — which, since the partials/finalize
split, is the *whole* deterministic-mode story: the kernel produces the
uint32 partial accumulators and the shared finalize turns them into scores.
``flint`` therefore rides the same kernel (its finalize is one reciprocal
multiply), so ``modes == ("flint", "integer")``.  uint32 addition is
associative mod 2^32, which is why the tiled accumulation is bit-identical
to the reference walk no matter how the grid is carved — and why a
tree-parallel plan can merge per-shard kernel partials bit-exactly.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.backends.base import BackendCapabilities, TreeBackend, register_backend
from repro.core.packing import PackedEnsemble

_DEFAULT_BLOCK_B = 256  # the kernel wrapper's row-tile default

# when impl="auto" resolved to the linear scan, batches below this row count
# run the gather walk instead: the scan's per-cell prefix pass costs the same
# for 2 rows as for 256, so at tiny batches the cheaper per-call gather wins
# (measured on the BENCH_7 b32 pathology).  Both impls produce identical
# uint32 partials, so the switch is invisible to conformance.
_SMALL_BATCH_GATHER_ROWS = 64


@register_backend
class PallasBackend(TreeBackend):
    name = "pallas"
    capabilities = BackendCapabilities(
        modes=("flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=_DEFAULT_BLOCK_B,
        compiles_per_shape=True,
        # the kernel consumes dense (T, N) VMEM-resident tables, so both
        # node-table orderings are walkable; leaf_major is preferred because
        # the linear-scan impl replaces depth-many gathers with one forward
        # pass over the internal-node prefix
        supported_layouts=("leaf_major", "padded"),
        preferred_layout="leaf_major",
    )

    def __init__(self, packed: PackedEnsemble, mode: str = "integer", *,
                 block_b: int = _DEFAULT_BLOCK_B, block_t: Optional[int] = None,
                 impl: str = "auto", interpret: bool = True):
        super().__init__(packed, mode)
        scannable = getattr(packed, "internal_counts", None) is not None
        was_auto = impl == "auto"
        if impl == "auto":
            # the linear scan needs the layout's internal prefix AND its
            # children-after-parents ordering (internal_counts is None when
            # an imported forest violates it) — otherwise gather-walk the
            # tables, which any node order satisfies
            impl = "leaf_major" if self.layout == "leaf_major" and scannable \
                else "gather"
        if impl == "leaf_major" and not (self.layout == "leaf_major" and scannable):
            raise ValueError(
                "impl='leaf_major' scans the leaf_major internal-node prefix; "
                f"this backend was materialized on the {self.layout!r} layout"
                + ("" if scannable else " without a scannable node order")
            )
        self.impl = impl
        # only an *auto* resolution may fall back per batch — an explicitly
        # pinned impl is a routing decision the caller owns
        self._auto_small_batch = impl == "leaf_major" and was_auto
        self._kernel_kwargs = dict(
            block_b=block_b, block_t=block_t, impl=impl, interpret=interpret
        )

    def predict_partials(self, X):
        from repro.kernels.ops import packed_predict_integer

        kw = self._kernel_kwargs
        if self._auto_small_batch and len(X) < _SMALL_BATCH_GATHER_ROWS:
            kw = dict(kw, impl="gather")
        acc, _ = packed_predict_integer(self.packed, X, **kw)
        return np.asarray(acc)

"""Pluggable execution backends for materialized tree ensembles.

One protocol (:class:`TreeBackend`: ``predict_partials(X) -> uint32
accumulators`` — the shardable half of inference — with ``predict_scores(X)
-> (scores, preds)`` as the finalize-wrapping compatibility surface, plus
declared :class:`BackendCapabilities`) behind four implementations:

  * ``reference``      — the jitted jnp node-table walk (all three modes),
  * ``pallas``         — the VMEM-tiled TPU kernel (flint + integer: one
                         integer accumulation, two finalizes),
  * ``native_c``       — the paper's emitted if-else C, compiled once per
                         model into a shared library and called via ctypes,
  * ``native_c_table`` — the ragged-layout table-walk C (data-as-arrays,
                         integer/flint), same shared-library contract.

Backends register by name and declare which ForestIR layouts they walk
(``supported_layouts``/``preferred_layout``); the serving stack (``TreeEngine``
/ ``ExecutionPlan`` / ``ModelRegistry`` / ``Gateway``) resolves the layout
through the IR and routes per-(model, mode, plan, backend, layout) via
:func:`create_backend`, never special-casing an implementation.  For the
deterministic modes (flint/integer) all backends are bit-identical across
all supported layouts AND all execution plans — see ``tests/test_backends.py``
/ ``tests/test_plans.py`` / ``make conformance``.
"""
from repro.backends.base import (
    BackendCapabilities,
    BackendUnavailable,
    TreeBackend,
    available_backends,
    backend_class,
    create_backend,
    register_backend,
)
from repro.backends.native_c import CompiledCBackend, NativeCBackend, have_c_toolchain
from repro.backends.native_c_table import NativeCTableBackend
from repro.backends.pallas import PallasBackend
from repro.backends.reference import ReferenceBackend

__all__ = [
    "BackendCapabilities",
    "BackendUnavailable",
    "CompiledCBackend",
    "NativeCBackend",
    "NativeCTableBackend",
    "PallasBackend",
    "ReferenceBackend",
    "TreeBackend",
    "available_backends",
    "backend_class",
    "create_backend",
    "have_c_toolchain",
    "register_backend",
]

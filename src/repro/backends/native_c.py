"""Compiled-C backends: the paper's if-else deliverable, servable via ctypes.

``CompiledCBackend`` owns everything shared by native-code execution — build a
C source string, compile it *once per (model, mode)* into a shared library
(`gcc -O2 -shared -fPIC`), and call the batched entry point through ctypes —
so a native backend is just an ``_emit_source`` hook over its layout artifact.
Two concrete backends ride on it:

  * ``native_c`` (this module): InTreeger's actual artifact — the
    freestanding if-else C of ``codegen/c_emitter.emit_c`` over the padded
    node tables, forest-in-the-instruction-stream.
  * ``native_c_table`` (``backends/native_c_table.py``): the ragged-layout
    data-as-arrays table walk of ``codegen/table_emitter.emit_table_walk_c``.

Shape-oblivious: the C loops take any row count, so ``compiles_per_shape`` is
False and the serving layer skips bucket padding entirely.  Since the
partials/finalize split, both deterministic modes (flint/integer) compile the
*integer* translation unit: the C accumulates uint32 partials at the same
scale and in the same tree order as the reference — exact, associative, and
mergeable across tree shards — and the shared numpy finalize
(``repro.core.ensemble.finalize_partials``) turns them into mode-typed
scores, so bit-identity needs no compiler float guarantees at all.  Float
mode still compiles the float32 translation unit; gcc (without -ffast-math)
preserves the emitted operation order, matching the XLA scan's sequential
per-tree adds.
"""
from __future__ import annotations

import ctypes
import os
import shutil
import subprocess
import tempfile
import threading
from pathlib import Path

import numpy as np

from repro.backends.base import (
    BackendCapabilities,
    BackendUnavailable,
    TreeBackend,
    register_backend,
)
from repro.core.flint import float_to_key_np


def have_c_toolchain(cc: str = "gcc") -> bool:
    return shutil.which(cc) is not None


class CompiledCBackend(TreeBackend):
    """Shared compile-and-serve machinery for emitted-C backends.

    Subclasses implement :meth:`_emit_source` returning a translation unit
    that defines ``predict_batch(data, n_rows, scores, preds)`` (usually the
    mode-specific ``predict`` plus ``codegen.c_emitter.emit_batch_entry``).
    """

    def __init__(self, packed, mode: str = "integer", *,
                 cc: str = "gcc", cflags: tuple = ("-O2",)):
        super().__init__(packed, mode)
        self._cc = cc
        self._cflags = tuple(cflags)
        self._lib = None
        self._tmpdir = None  # owns the .so for the backend's lifetime
        self._compile_lock = threading.Lock()

    def _emit_source(self) -> str:
        raise NotImplementedError

    @property
    def _exec_mode(self) -> str:
        """The mode the compiled translation unit executes.  Deterministic
        modes (flint/integer) both run the integer accumulation — the library
        produces uint32 partials and finalize happens in shared numpy — so
        one emitted source serves both."""
        return "float" if self.mode == "float" else "integer"

    # ------------------------------------------------------------- compile
    def _ensure_lib(self):
        # double-checked locking: engines are shared across executor threads,
        # and a concurrent first predict must not compile twice (the loser's
        # tmpdir assignment would delete the winner's .so out from under it)
        if self._lib is not None:
            return self._lib
        with self._compile_lock:
            if self._lib is not None:
                return self._lib
            return self._build_lib()

    @property
    def _effective_cflags(self) -> tuple:
        """Constructor cflags + ``REPRO_CC_EXTRA_FLAGS`` from the environment
        (the CI degradation job's hook).  ``-mno-avx2`` defines no feature
        macro and cannot disable per-function ``target("avx2")`` attributes,
        so its intent is translated to ``-DREPRO_NO_SIMD`` as well — one env
        var degrades every emitted TU to the scalar paths."""
        extra = tuple(os.environ.get("REPRO_CC_EXTRA_FLAGS", "").split())
        flags = self._cflags + extra
        if "-mno-avx2" in extra and "-DREPRO_NO_SIMD" not in flags:
            flags += ("-DREPRO_NO_SIMD",)
        return flags

    def _build_lib(self):
        if not have_c_toolchain(self._cc):
            raise BackendUnavailable(
                f"{self.name} backend needs a C compiler; {self._cc!r} not on PATH"
            )
        src = self._emit_source()
        self._tmpdir = tempfile.TemporaryDirectory(prefix=f"repro_{self.name}_")
        d = Path(self._tmpdir.name)
        c_file, so_file = d / "model.c", d / "model.so"
        c_file.write_text(src)
        proc = subprocess.run(
            [self._cc, *self._effective_cflags, "-shared", "-fPIC",
             "-o", str(so_file), str(c_file)],
            capture_output=True,
        )
        if proc.returncode != 0:
            raise BackendUnavailable(
                f"{self._cc} failed to build the {self.name} backend:\n"
                + proc.stderr.decode(errors="replace")[:2000]
            )
        lib = ctypes.CDLL(str(so_file))  # RTLD_LOCAL: symbols stay per-model
        exec_mode = self._exec_mode
        data_ct = ctypes.c_float if exec_mode == "float" else ctypes.c_int32
        score_ct = ctypes.c_uint32 if exec_mode == "integer" else ctypes.c_float
        lib.predict_batch.restype = None
        lib.predict_batch.argtypes = [
            ctypes.POINTER(data_ct),
            ctypes.c_long,
            ctypes.POINTER(score_ct),
            ctypes.POINTER(ctypes.c_int32),
        ]
        self._score_dtype = np.uint32 if exec_mode == "integer" else np.float32
        self._lib = lib
        return lib

    # ------------------------------------------------------------- predict
    def _run_batch(self, X):
        """One ``predict_batch`` call: (exec-mode scores, C-side preds)."""
        lib = self._ensure_lib()
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        if X.ndim != 2 or X.shape[1] != self.packed.n_features:
            raise ValueError(
                f"expected (B, {self.packed.n_features}) features, got {X.shape}"
            )
        if self._exec_mode == "float":
            data = X
        else:
            data = np.ascontiguousarray(float_to_key_np(X))
        b = X.shape[0]
        scores = np.empty((b, self.packed.n_classes), self._score_dtype)
        preds = np.empty(b, np.int32)
        lib.predict_batch(
            data.ctypes.data_as(lib.predict_batch.argtypes[0]),
            ctypes.c_long(b),
            scores.ctypes.data_as(lib.predict_batch.argtypes[2]),
            preds.ctypes.data_as(lib.predict_batch.argtypes[3]),
        )
        return scores, preds

    def predict_partials(self, X):
        if not self.deterministic:
            return super().predict_partials(X)  # raises with the shared message
        scores, _ = self._run_batch(X)  # integer exec: scores ARE the partials
        return scores

    def predict_scores(self, X):
        if self.deterministic:
            return super().predict_scores(X)  # shared finalize(partials)
        return self._run_batch(X)

    # ---------------------------------------------------------------- SIMD
    def simd_isa(self):
        """The ISA the compiled library's batch walk dispatches to on this
        host: ``"avx2"`` | ``"neon"`` | ``"scalar"`` (TUs without a runtime
        dispatcher — the if-else cascade — are scalar by construction), or
        ``None`` when the library cannot build here.  Builds on first call
        like every other entry point."""
        try:
            lib = self._ensure_lib()
        except BackendUnavailable:
            return None
        try:
            fn = lib.simd_isa
        except AttributeError:
            return "scalar"
        fn.restype = ctypes.c_char_p
        fn.argtypes = []
        return fn().decode("ascii")


@register_backend
class NativeCBackend(CompiledCBackend):
    """The paper's literal deliverable — if-else C — as a servable backend."""

    name = "native_c"
    capabilities = BackendCapabilities(
        modes=("float", "flint", "integer"),
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,
        compiles_per_shape=False,
        # the if-else emitter reads (T, N) node tables from the root down;
        # node order within a tree does not change the emitted cascade's
        # semantics, so both node-table layouts are accepted
        supported_layouts=("padded", "leaf_major"),
        preferred_layout="padded",
    )

    def _emit_source(self) -> str:
        from repro.codegen.c_emitter import emit_batch_entry, emit_c

        return emit_c(self.packed, mode=self._exec_mode) + emit_batch_entry(
            self.packed, mode=self._exec_mode
        )

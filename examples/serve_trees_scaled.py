"""Batched tree-ensemble serving: all four implementations side by side
(float / FlInt / integer jnp / integer Pallas-kernel), plus the multi-device
shard_map serving step used by the production dry-run.

    PYTHONPATH=src python examples/serve_trees_scaled.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.flint import float_to_key
from repro.core.packing import pack_forest
from repro.core.serving import tree_serve_step
from repro.data.tabular import make_esa_like, train_test_split
from repro.serve.engine import TreeEngine
from repro.trees.forest import RandomForestClassifier

X, y = make_esa_like(n=40000, seed=0)
Xtr, ytr, Xte, yte = train_test_split(X, y)
rf = RandomForestClassifier(n_estimators=32, max_depth=8, seed=0).fit(Xtr, ytr)
packed = pack_forest(rf)

engines = {
    "float": TreeEngine(packed, mode="float"),
    "flint": TreeEngine(packed, mode="flint"),
    "integer": TreeEngine(packed, mode="integer"),
    "integer+pallas": TreeEngine(packed, mode="integer", backend="pallas"),
}
ref = None
for name, eng in engines.items():
    eng.predict(Xte[:64])  # compile
    t0 = time.perf_counter()
    preds = eng.predict(Xte)
    dt = time.perf_counter() - t0
    if ref is None:
        ref = preds
    assert (preds == ref).all(), f"{name} diverged from float"
    recall = (preds[yte == 1] == 1).mean()
    print(f"{name:16s} {dt*1e6/len(Xte):7.3f} us/row  anomaly-recall={recall:.3f}")

# the pod-scale serving step (shard_map over every mesh axis; here 1 device)
tables = {
    "feature": jnp.asarray(packed.feature),
    "threshold_key": jnp.asarray(packed.threshold_key),
    "left": jnp.asarray(packed.left),
    "right": jnp.asarray(packed.right),
    "leaf_fixed": jnp.asarray(packed.leaf_fixed),
}
acc, preds = tree_serve_step(tables, float_to_key(jnp.asarray(Xte)), packed.max_depth)
assert (np.asarray(preds) == ref).all()
print(f"tree_serve_step (production path) matches: {len(ref)} rows")

"""Pack a trained ensemble into dense, TPU-friendly node tables.

This is the TPU analogue of the paper's codegen step: instead of emitting
if-else C, we emit *tensors*.  All per-node quantities are padded to the max
node count across trees; padding nodes are self-looping leaves with zero
probability mass, so they are semantically inert.

The integer artifacts produced here are exactly the paper's:
  * ``threshold_key``: FlInt int32 keys of the float thresholds,
  * ``leaf_fixed``:  uint32 fixed-point leaf probabilities at scale
    ``floor((2**32-1)/n_trees)`` (Sec. III-A), overflow-free by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.fixedpoint import prob_to_fixed_np, scale_for
from repro.core.flint import float_to_key_np


@dataclass
class PackedEnsemble:
    feature: np.ndarray  # (T, N) int32, -1 for leaf
    threshold: np.ndarray  # (T, N) float32
    threshold_key: np.ndarray  # (T, N) int32 (FlInt keys)
    left: np.ndarray  # (T, N) int32
    right: np.ndarray  # (T, N) int32
    leaf_probs: np.ndarray  # (T, N, C) float32 (zeros on internal/pad nodes)
    leaf_fixed: np.ndarray  # (T, N, C) uint32
    n_trees: int
    n_classes: int
    n_features: int
    max_depth: int  # walk length that guarantees leaf arrival

    @property
    def scale(self) -> int:
        return scale_for(self.n_trees)

    def nbytes_integer(self) -> int:
        """Bytes of the integer-only deployment artifact."""
        return (
            self.feature.nbytes
            + self.threshold_key.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_fixed.nbytes
        )

    def nbytes_float(self) -> int:
        """Bytes of the float deployment artifact."""
        return (
            self.feature.nbytes
            + self.threshold.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_probs.nbytes
        )


def pack_forest(forest) -> PackedEnsemble:
    trees = forest.trees_
    T = len(trees)
    C = forest.n_classes_
    N = max(t.n_nodes for t in trees)
    feature = np.full((T, N), -1, np.int32)
    threshold = np.zeros((T, N), np.float32)
    left = np.tile(np.arange(N, dtype=np.int32), (T, 1))
    right = left.copy()
    probs = np.zeros((T, N, C), np.float64)
    for i, t in enumerate(trees):
        n = t.n_nodes
        feature[i, :n] = t.feature
        threshold[i, :n] = t.threshold
        left[i, :n] = t.left
        right[i, :n] = t.right
        is_leaf = t.feature < 0
        probs[i, :n][is_leaf] = t.leaf_probs[is_leaf]
    fixed = prob_to_fixed_np(probs, T)
    return PackedEnsemble(
        feature=feature,
        threshold=threshold,
        threshold_key=float_to_key_np(threshold),
        left=left,
        right=right,
        leaf_probs=probs.astype(np.float32),
        leaf_fixed=fixed,
        n_trees=T,
        n_classes=C,
        n_features=forest.n_features_,
        max_depth=max(t.depth for t in trees),
    )

"""RemoteTreeParallelPlan: tree shards on worker *processes*, partials on
the wire.

The paper's uint32 partial accumulators are associative, so the
tree-parallel merge is transport-agnostic — `tree_parallel` proved it
across threads and ``shard_map``; this plan proves it across processes and
hosts.  The forest is carved into tree-contiguous shards
(``ForestIR.subset``), each dispatched as a PREDICT frame to a worker over
the compact length-prefixed protocol in :mod:`repro.serve.wire`, and the
returned raw uint32 buffers merge at the gateway bit-identically to the
single-process walk, finalized once through the base plan's
``finalize_partials`` path.

Fleet semantics:

* **Heterogeneous pool** — like ``tree_parallel``, ``backend`` may be a
  sequence of names cycled over shards, so compiled-C bitvector workers
  can serve shards next to Pallas workers; each worker builds whatever
  backend its shard table entry names.
* **Straggler/death policy** — every dispatch carries a deadline
  (``deadline_ms``; ``None`` disables).  A timeout, EOF, or socket error
  marks that connection dead (its socket is closed, so a late straggler
  response can never be confused with a live request) and the shard is
  re-dispatched to the next healthy connection — the HELLO shard table
  named every shard to every worker, so re-dispatch needs no
  re-handshake.  A worker-side MSG_ERROR (e.g. a toolchain-less host
  assigned a C backend) fails the *attempt* but keeps the connection.
* **Workers** — ``workers=N`` (or ``None``) spawns N loopback worker
  processes owned by the plan (terminated on ``close()``; an ``atexit``
  net catches leaked plans); ``workers=["host:port", ...]`` (or a
  comma-joined string) connects to an existing fleet.
* **Tracing** — each dispatch runs under a ``shard:w<idx>:...`` span, and
  the worker's own decode/build/predict spans (shipped home in the
  PARTIALS trailer as request-relative ns offsets) are grafted under it as
  ``worker:*`` children, so a request trace shows wall time *inside* the
  remote process.

Connect + handshake cost is recorded once under the ``"remote"`` key of
the engine's compile/warm ledger (via ``drain_setup_timings``), landing in
``compile_ms_by_bucket`` next to the jit buckets and the autotuner's
``"tune"`` entry.
"""
from __future__ import annotations

import atexit
import json
import socket
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from functools import reduce
from itertools import cycle, islice
from typing import Optional

import numpy as np

from repro.plan.base import ExecutionPlan, as_ir, register_plan
from repro.plan.tree_parallel import tree_ranges
from repro.serve import wire

_DEFAULT_WORKERS = 2
_DEFAULT_DEADLINE_MS = 30000.0


class WorkerError(RuntimeError):
    """The worker answered MSG_ERROR: this attempt failed, the connection
    is still healthy (do not evict)."""


class _WorkerConn:
    """One gateway-side connection: serialized request/response framing."""

    def __init__(self, idx: int, addr: str, proc=None):
        self.idx = idx
        self.addr = addr
        self.proc = proc  # owned subprocess (loopback spawn) or None
        self.sock: Optional[socket.socket] = None
        self.info: dict = {}
        self.alive = False
        self._req = 0
        self._lock = threading.Lock()

    def connect(self, hello: bytes, *, timeout_s: float) -> None:
        host, _, port = self.addr.rpartition(":")
        self.sock = socket.create_connection((host, int(port)),
                                             timeout=timeout_s)
        wire.send_frame(self.sock, wire.MSG_HELLO, hello)
        msg_type, payload = wire.read_frame(self.sock)
        if msg_type != wire.MSG_HELLO_ACK:
            raise ConnectionError(
                f"worker {self.addr}: expected HELLO_ACK, got {msg_type}")
        self.info = json.loads(payload)
        self.alive = True

    def call(self, shard_id: int, X, deadline_s: Optional[float]):
        """One PREDICT round-trip -> (uint32 partials, worker spans).
        Raises OSError/ConnectionError on death or deadline (evict),
        WorkerError on a reported failure (keep)."""
        with self._lock:
            if not self.alive:
                raise ConnectionError(f"worker {self.addr} is dead")
            self._req += 1
            rid = self._req
            self.sock.settimeout(deadline_s)
            wire.send_frame(self.sock, wire.MSG_PREDICT,
                            wire.encode_predict(rid, shard_id, X))
            msg_type, payload = wire.read_frame(self.sock)
            if msg_type == wire.MSG_ERROR:
                _, err = wire.decode_error(payload)
                raise WorkerError(f"worker {self.addr}: {err}")
            if msg_type != wire.MSG_PARTIALS:
                raise ConnectionError(
                    f"worker {self.addr}: unexpected frame {msg_type}")
            got_rid, got_shard, acc, spans = wire.decode_partials(payload)
            if got_rid != rid or got_shard != shard_id:
                raise ConnectionError(
                    f"worker {self.addr}: out-of-sync response "
                    f"(req {got_rid}/{rid}, shard {got_shard}/{shard_id})")
            return acc, spans

    def mark_dead(self) -> None:
        """Evict: close the socket so a late straggler response can never be
        read as the reply to a future request."""
        self.alive = False
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass

    def close(self) -> None:
        if self.sock is not None and self.alive:
            try:
                wire.send_frame(self.sock, wire.MSG_CLOSE)
            except OSError:
                pass
        self.mark_dead()
        if self.proc is not None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except Exception:
                self.proc.kill()
            self.proc = None


@register_plan
class RemoteTreeParallelPlan(ExecutionPlan):
    name = "remote_tree_parallel"
    deterministic_only = True

    def __init__(self, model, *, mode: str = "integer", backend="reference",
                 shards=None, layout: Optional[str] = None,
                 backend_kwargs: Optional[dict] = None,
                 workers=None, deadline_ms: Optional[float] = _DEFAULT_DEADLINE_MS,
                 connect_timeout_s: float = 60.0, retries: Optional[int] = None,
                 span_dir=None, model_id: str = "model", version: int = 0):
        ir = as_ir(model)
        super().__init__(ir, mode=mode)
        if not self._spec.deterministic:
            raise ValueError(
                f"remote_tree_parallel ships exact integer partials; mode "
                f"{mode!r} accumulates floats — use row_parallel locally"
            )
        self.ir = ir
        self.deadline_ms = deadline_ms
        self._retries = retries
        self._closed = False
        self._redispatches = 0

        t_setup = time.perf_counter()
        # -- worker pool: spawn loopback processes or connect to a fleet
        self._procs = []
        if workers is None or isinstance(workers, int):
            from repro.serve.worker import spawn_local_workers

            n = int(workers) if workers else int(shards or _DEFAULT_WORKERS)
            self._procs, addrs = spawn_local_workers(n, span_dir=span_dir)
        else:
            if isinstance(workers, str):
                workers = [w.strip() for w in workers.split(",") if w.strip()]
            addrs = list(workers)
        if not addrs:
            raise ValueError("remote_tree_parallel needs at least one worker")

        # -- shard table: like tree_parallel, heterogeneous names cycle
        if isinstance(backend, str):
            names = [backend] * int(shards or len(addrs))
        else:
            names = list(islice(cycle(backend), int(shards or len(backend))))
        if not names:
            raise ValueError("remote_tree_parallel needs at least one shard")
        self.ranges = tree_ranges(ir.n_trees, len(names))
        self._names = names[: len(self.ranges)]
        shard_table = [
            {"shard": i, "start": a, "stop": b, "backend": name,
             "layout": layout, "backend_kwargs": backend_kwargs}
            for i, (name, (a, b)) in enumerate(zip(self._names, self.ranges))
        ]

        # -- one HELLO payload, sent on every connection
        from repro.serve.spec import EngineSpec

        spec = EngineSpec(mode=mode,
                          backend=backend if isinstance(backend, str)
                          else tuple(backend),
                          layout=layout, plan=self.name,
                          shards=len(self.ranges),
                          backend_kwargs=backend_kwargs)
        meta = {"wire": wire.WIRE_VERSION, "model_id": model_id,
                "version": int(version), "mode": mode,
                "spec": spec.to_dict(), "shards": shard_table,
                "n_trees": int(ir.n_trees), "n_classes": int(ir.n_classes),
                "n_features": int(ir.n_features),
                "quant_scale": int(ir.scale)}
        itrf_bytes = getattr(ir, "itrf_bytes", None)
        wire_arrays = (ir.feature, ir.threshold, ir.threshold_key, ir.left,
                       ir.right, ir.leaf_fixed, ir.node_offsets,
                       ir.tree_depths)
        if itrf_bytes is not None \
                and itrf_bytes.nbytes <= sum(a.nbytes for a in wire_arrays):
            # artifact fast path: the model came from an ITRF file, so HELLO
            # ships the raw artifact image verbatim — no per-array encode or
            # JSON directory on the send side, and the worker rebuilds the
            # IR through the binary reader (zero-copy views over the
            # payload).  Guarded by size so a float-bearing artifact (whose
            # image carries the float64 leaf table the wire deliberately
            # omits) falls back to the explicit array payload.
            meta["artifact_format"] = "itrf"
            hello = wire.encode_hello(meta, {"itrf": itrf_bytes})
        else:
            hello = wire.encode_hello(meta, {
                "feature": ir.feature, "threshold": ir.threshold,
                "threshold_key": ir.threshold_key, "left": ir.left,
                "right": ir.right, "leaf_fixed": ir.leaf_fixed,
                "node_offsets": ir.node_offsets, "tree_depths": ir.tree_depths,
            })

        self._conns = []
        try:
            for i, addr in enumerate(addrs):
                proc = self._procs[i] if i < len(self._procs) else None
                conn = _WorkerConn(i, addr, proc)
                conn.connect(hello, timeout_s=connect_timeout_s)
                for key in ("model", "version"):
                    if conn.info.get(key) != meta[
                            "model_id" if key == "model" else key]:
                        raise ConnectionError(
                            f"worker {addr} acked {key}="
                            f"{conn.info.get(key)!r}, wanted "
                            f"{meta['model_id' if key == 'model' else key]!r}")
                self._conns.append(conn)
        except Exception:
            self._teardown()
            raise
        self._setup_ms = {"remote": (time.perf_counter() - t_setup) * 1e3}
        self._pool = ThreadPoolExecutor(max_workers=len(self.ranges),
                                        thread_name_prefix="remote-shard")
        atexit.register(self._teardown)  # net for plans never close()d

    # ------------------------------------------------------------ execution
    def predict_partials(self, X):
        if self._closed:
            raise RuntimeError("remote_tree_parallel plan is closed")
        X = np.ascontiguousarray(np.asarray(X, np.float32))
        parent = self.trace_parent
        futs = [self._pool.submit(self._dispatch_shard, i, X, parent)
                for i in range(len(self.ranges))]
        partials = [np.asarray(f.result()) for f in futs]
        t0 = time.perf_counter_ns()
        merged = reduce(np.add, partials)
        t1 = time.perf_counter_ns()
        self._record_stage("merge", (t1 - t0) / 1e9)
        self._span("merge", t0, t1, parent, shards=len(partials))
        return merged

    def _dispatch_shard(self, i: int, X, parent):
        """Run shard ``i`` on its primary connection, re-dispatching to the
        next healthy one on death/deadline (the straggler policy: a worker
        past its deadline is treated exactly like a dead one)."""
        a, b = self.ranges[i]
        n = len(self._conns)
        order = [self._conns[(i + off) % n] for off in range(n)]
        max_attempts = 1 + (self._retries if self._retries is not None
                            else n - 1)
        deadline_s = (self.deadline_ms / 1e3) if self.deadline_ms else None
        attempts, last_err = 0, None
        for conn in order:
            if attempts >= max_attempts:
                break
            if not conn.alive:
                continue
            attempts += 1
            label = f"w{conn.idx}:{self._names[i]}[{a}:{b}]"
            span = None
            if parent and self._tracer is not None:
                span = self._tracer.child(parent, f"shard:{label}",
                                          worker=conn.addr, shard=i)
            t0 = time.perf_counter_ns()
            try:
                acc, wspans = conn.call(i, X, deadline_s)
            except WorkerError as exc:  # attempt failed; worker stays
                last_err = exc
                if span:
                    span.end(error=str(exc))
                continue
            except (ConnectionError, OSError) as exc:  # dead or straggling
                last_err = exc
                conn.mark_dead()
                with self._timings_lock:
                    self._redispatches += 1
                if span:
                    span.end(error=type(exc).__name__, evicted=True)
                continue
            t1 = time.perf_counter_ns()
            self._record(label, (t1 - t0) / 1e9)
            if span:
                # graft the worker's request-relative spans under the
                # dispatch span, anchored at dispatch start: worker wall
                # time is contained in the round-trip by construction
                for name, r0, r1 in wspans:
                    self._tracer.record(f"worker:{name}", t0 + int(r0),
                                        t0 + int(r1), parent=span)
                span.end(rows=int(X.shape[0]), attempts=attempts)
            return acc
        raise RuntimeError(
            f"shard {i} trees[{a}:{b}]: no worker served it after "
            f"{attempts} attempt(s); last error: {last_err!r}")

    # -------------------------------------------------------------- metadata
    @property
    def backends(self) -> tuple:
        return ()  # executors live in other processes

    @property
    def packed(self):
        return self.ir

    @property
    def n_shards(self) -> int:
        return len(self.ranges)

    @property
    def layout(self) -> str:
        from repro.backends import backend_class

        layouts = []
        for name in self._names:
            lay = backend_class(name).capabilities.preferred_layout
            if lay not in layouts:
                layouts.append(lay)
        return "+".join(layouts) if layouts else "padded"

    @property
    def backend_name(self) -> str:
        names = []
        for name in self._names:
            if name not in names:
                names.append(name)
        return "+".join(names)

    @property
    def compiles_per_shape(self) -> bool:
        # worker-side jit backends compile per batch shape exactly like they
        # would in-process, so the engine's shape bucketing still pays off
        from repro.backends import backend_class

        return any(backend_class(n).capabilities.compiles_per_shape
                   for n in self._names)

    @property
    def preferred_block_rows(self) -> Optional[int]:
        from repro.backends import backend_class

        hints = [backend_class(n).capabilities.preferred_block_rows
                 for n in self._names]
        hints = [h for h in hints if h]
        return max(hints) if hints else None

    @property
    def redispatches(self) -> int:
        """Shard attempts re-routed after a death/deadline eviction."""
        return self._redispatches

    def workers(self) -> list:
        return [{"idx": c.idx, "addr": c.addr, "alive": c.alive,
                 "pid": c.info.get("pid")} for c in self._conns]

    def describe(self) -> dict:
        d = super().describe()
        d.update(shards=self.n_shards, tree_ranges=self.ranges,
                 backends=list(self._names), workers=self.workers(),
                 redispatches=self._redispatches)
        return d

    def drain_setup_timings(self) -> dict:
        out, self._setup_ms = self._setup_ms, {}
        return out

    # -------------------------------------------------------------- lifecycle
    def _teardown(self) -> None:
        for conn in getattr(self, "_conns", ()):
            conn.close()
        for proc in self._procs:
            if proc.poll() is None:
                proc.terminate()
                try:
                    proc.wait(timeout=10)
                except Exception:
                    proc.kill()
            if proc.stdout is not None:
                proc.stdout.close()
        self._procs = []

    def close(self) -> None:
        """Drain in-flight dispatches, close worker connections, terminate
        owned worker processes."""
        if self._closed:
            return
        self._closed = True
        pool = getattr(self, "_pool", None)
        if pool is not None:
            pool.shutdown(wait=True)
        self._teardown()
        atexit.unregister(self._teardown)

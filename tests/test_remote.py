"""Multi-process partial-merge fabric: wire protocol round-trips, cross-
process bit-identity (the conformance invariant asserted *across sockets*),
straggler/kill re-dispatch, gateway integration, and lifecycle teardown.

Worker processes are spawned on loopback via
``repro.serve.worker.spawn_local_workers``; the plan under test is
``remote_tree_parallel`` (``repro.plan.remote``).
"""
import threading
import time

import numpy as np
import pytest

from repro.serve import wire
from repro.serve.spec import EngineSpec
from repro.serve.worker import spawn_local_workers


def _kill_all(procs):
    for p in procs:
        if p.poll() is None:
            p.kill()
        if p.stdout is not None:
            p.stdout.close()


@pytest.fixture(scope="module")
def worker_pair():
    """Two plain loopback worker processes shared by the happy-path tests."""
    procs, addrs = spawn_local_workers(2)
    yield addrs
    _kill_all(procs)


@pytest.fixture()
def remote_engine(small_packed, worker_pair):
    """Factory: an engine on the remote plan against the shared pair."""
    made = []

    def make(mode, **plan_kwargs):
        from repro.serve.engine import TreeEngine

        eng = TreeEngine(
            small_packed,
            EngineSpec(mode=mode, backend="reference",
                       plan="remote_tree_parallel", shards=2),
            plan_kwargs={"workers": list(worker_pair), "model_id": "t",
                         "version": 1, **plan_kwargs},
        )
        made.append(eng)
        return eng

    yield make
    for eng in made:
        eng.close()


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def test_wire_partials_roundtrip():
    acc = np.arange(4 * 7, dtype=np.uint32).reshape(4, 7) * 2654435761
    payload = wire.encode_partials(9, 3, acc, spans=[("predict", 100, 2500)])
    rid, sid, out, spans = wire.decode_partials(payload)
    assert (rid, sid) == (9, 3)
    assert out.dtype == np.uint32 and np.array_equal(out, acc)
    assert spans == [("predict", 100, 2500)]
    assert out.flags.writeable  # decoded copy, not a view of the recv buffer


def test_wire_pack_arrays_roundtrip():
    arrays = {
        "feature": np.array([0, -1, 2], np.int32),
        "threshold": np.array([0.5, 1.5], np.float32),
        "leaf_fixed": np.array([[1, 2], [3, 4]], np.uint32),
        "offsets": np.array([0, 3], np.int64),
    }
    payload = wire.pack_arrays({"model": "m", "version": 3}, arrays)
    meta, out = wire.unpack_arrays(payload)
    assert meta == {"model": "m", "version": 3}
    for name, a in arrays.items():
        assert out[name].dtype == a.dtype
        assert np.array_equal(out[name], a)


def test_wire_frame_rejects_bad_magic():
    import io
    import socket

    a, b = socket.socketpair()
    try:
        a.sendall(b"XXXX" + bytes(5))
        with pytest.raises(wire.ConnectionClosed):
            wire.read_frame(b)
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# cross-process conformance: merged remote partials == single-process walk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["flint", "integer"])
def test_two_worker_bit_identity(small_packed, remote_engine, shuttle_small,
                                 mode):
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:96].astype(np.float32)
    ref_s, ref_p = TreeEngine(small_packed, mode).predict_scores(X)
    eng = remote_engine(mode)
    s, p = eng.predict_scores(X)
    assert np.array_equal(s, ref_s)
    assert np.array_equal(p, ref_p)
    # every shard executed on a worker, none locally
    labels = list(eng.drain_shard_timings())
    assert labels and all(lbl.startswith("w") for lbl in labels)


def test_remote_rejects_float_mode(small_packed, worker_pair):
    from repro.serve.engine import TreeEngine

    with pytest.raises(ValueError):
        TreeEngine(small_packed,
                   EngineSpec(mode="float", plan="remote_tree_parallel"),
                   plan_kwargs={"workers": list(worker_pair)})


def test_connect_cost_lands_in_compile_ledger(remote_engine, shuttle_small):
    eng = remote_engine("integer")
    eng.predict_scores(shuttle_small[2][:8].astype(np.float32))
    drained = eng.drain_compile_timings()
    assert "remote" in drained and drained["remote"] > 0.0


def test_worker_kill_redispatch_bit_identity(small_packed, shuttle_small):
    """Kill a straggling worker mid-request: its shard re-dispatches to the
    survivor and the merged result stays bit-identical."""
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:64].astype(np.float32)
    ref_s, ref_p = TreeEngine(small_packed, "integer").predict_scores(X)
    procs, addrs = spawn_local_workers(2, delays=[3000, 0])
    try:
        eng = TreeEngine(
            small_packed,
            EngineSpec(mode="integer", backend="reference",
                       plan="remote_tree_parallel", shards=2),
            plan_kwargs={"workers": addrs, "model_id": "t", "version": 1},
        )
        # worker 0 sleeps 3 s before answering; kill it mid-request
        killer = threading.Timer(0.5, procs[0].kill)
        killer.start()
        try:
            s, p = eng.predict_scores(X)
        finally:
            killer.cancel()
        assert np.array_equal(s, ref_s)
        assert np.array_equal(p, ref_p)
        assert eng.plan.redispatches >= 1
        assert [w["alive"] for w in eng.plan.workers()] == [False, True]
        eng.close()
    finally:
        _kill_all(procs)


@pytest.mark.slow
def test_straggler_deadline_redispatch(small_packed, shuttle_small):
    """A worker that exceeds the per-shard deadline is evicted and its shard
    re-dispatched — without killing the process."""
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:32].astype(np.float32)
    ref_s, ref_p = TreeEngine(small_packed, "integer").predict_scores(X)
    procs, addrs = spawn_local_workers(2, delays=[5000, 0])
    try:
        eng = TreeEngine(
            small_packed,
            EngineSpec(mode="integer", backend="reference",
                       plan="remote_tree_parallel", shards=2),
            plan_kwargs={"workers": addrs, "model_id": "t", "version": 1,
                         "deadline_ms": None},  # no deadline during warm
        )
        eng.plan.deadline_ms = 1500.0
        t0 = time.perf_counter()
        s, p = eng.predict_scores(X)
        dt = time.perf_counter() - t0
        assert np.array_equal(s, ref_s)
        assert np.array_equal(p, ref_p)
        assert eng.plan.redispatches >= 1
        assert dt < 4.5  # did not wait out the 5 s straggler
        eng.close()
    finally:
        _kill_all(procs)


@pytest.mark.requires_gcc
def test_heterogeneous_worker_backends(small_packed, worker_pair,
                                       shuttle_small):
    """Compiled-C shard next to a reference shard, each on its own worker."""
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:48].astype(np.float32)
    ref_s, ref_p = TreeEngine(small_packed, "integer").predict_scores(X)
    eng = TreeEngine(
        small_packed,
        EngineSpec(mode="integer", backend=("reference", "native_c"),
                   plan="remote_tree_parallel", shards=2),
        plan_kwargs={"workers": list(worker_pair), "model_id": "t",
                     "version": 1},
    )
    s, p = eng.predict_scores(X)
    assert np.array_equal(s, ref_s)
    assert np.array_equal(p, ref_p)
    eng.close()


def test_engine_close_reaps_owned_workers(small_packed, shuttle_small):
    """workers=N spawns processes the plan owns; close() terminates them."""
    from repro.serve.engine import TreeEngine

    eng = TreeEngine(
        small_packed,
        EngineSpec(mode="integer", plan="remote_tree_parallel", shards=2),
        plan_kwargs={"workers": 2, "model_id": "t", "version": 1},
    )
    eng.predict_scores(shuttle_small[2][:8].astype(np.float32))
    procs = [c.proc for c in eng.plan._conns if c.proc is not None]
    assert len(procs) == 2
    eng.close()
    for p in procs:
        assert p.wait(timeout=10) is not None


# ---------------------------------------------------------------------------
# gateway integration
# ---------------------------------------------------------------------------

def test_gateway_remote_spec_end_to_end(small_packed, worker_pair,
                                        shuttle_small):
    import asyncio

    from repro.obs import Tracer
    from repro.serve import Gateway, ModelRegistry
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:40].astype(np.float32)
    ref_s, ref_p = TreeEngine(small_packed, "integer").predict_scores(X)
    reg = ModelRegistry()
    reg.register_packed("m", small_packed)
    tracer = Tracer(sample=1.0)

    async def run():
        gw = Gateway(reg, "integer:reference+remote_tree_parallel:2",
                     plan_kwargs={"workers": list(worker_pair)},
                     cache_rows=0, tracer=tracer)
        s, p = await gw.submit("m", X)
        st = gw.stats()["per_model"]["m"]
        await gw.close()
        return s, p, st

    s, p, st = asyncio.run(run())
    assert np.array_equal(s, ref_s)
    assert np.array_equal(p, ref_p)
    assert st["spec"] == "integer:reference+remote_tree_parallel:2"
    assert "remote" in st["compile_ms_by_bucket"]
    assert all(lbl.startswith("w") for lbl in st["shards"])
    # worker-side spans were grafted under the shard dispatch spans
    spans = tracer.spans()
    shard_ids = {s_.span_id for s_ in spans if s_.name.startswith("shard:w")}
    worker_spans = [s_ for s_ in spans if s_.name.startswith("worker:")]
    assert shard_ids and worker_spans
    assert all(s_.parent_id in shard_ids for s_ in worker_spans)


def test_gateway_close_drains_inflight(small_packed, shuttle_small):
    """close() resolves requests already enqueued instead of failing them."""
    import asyncio

    from repro.serve import Gateway, ModelRegistry
    from repro.serve.engine import TreeEngine

    X = shuttle_small[2][:16].astype(np.float32)
    ref_s, _ = TreeEngine(small_packed, "integer").predict_scores(X)
    reg = ModelRegistry()
    reg.register_packed("m", small_packed)

    async def run():
        gw = Gateway(reg, "integer:reference+tree_parallel:2", cache_rows=0,
                     max_delay_ms=50.0)
        tasks = [asyncio.ensure_future(gw.submit("m", X)) for _ in range(4)]
        await asyncio.sleep(0)  # let every submit reach its queue
        await gw.close()  # must drain, not cancel
        return await asyncio.gather(*tasks)

    for s, _ in asyncio.run(run()):
        assert np.array_equal(s, ref_s)


def test_worker_span_jsonl(small_packed, shuttle_small, tmp_path):
    """Workers append per-request span JSONL when given --span-out."""
    import json

    from repro.serve.engine import TreeEngine

    procs, addrs = spawn_local_workers(1, span_dir=str(tmp_path))
    try:
        eng = TreeEngine(
            small_packed,
            EngineSpec(mode="integer", plan="remote_tree_parallel", shards=1),
            plan_kwargs={"workers": addrs, "model_id": "t", "version": 1},
        )
        eng.predict_scores(shuttle_small[2][:8].astype(np.float32))
        eng.close()
        time.sleep(0.2)  # the worker flushes per line; give it a beat
        files = list(tmp_path.glob("worker_*.jsonl"))
        assert files
        recs = [json.loads(ln) for f in files
                for ln in f.read_text().splitlines()]
        assert recs
        assert all("spans" in r and r["model"] == "t" for r in recs)
        names = {sp["name"] for r in recs for sp in r["spans"]}
        assert "predict" in names
    finally:
        _kill_all(procs)

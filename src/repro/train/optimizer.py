"""AdamW with ZeRO-sharded state (no optax dependency — substrate built here).

Moment tensors inherit the parameter shardings (FSDP over ``data`` + TP over
``model``), i.e. ZeRO: optimizer memory scales with 1/(data*model).  The
update is fully elementwise, so no extra collectives are introduced by the
optimizer itself.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cosine = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cosine


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}

"""Jitted public wrapper around the tree-traversal Pallas kernel.

Handles padding (batch to ``block_b`` multiples, trees to ``block_t``
multiples with inert self-looping zero-probability trees), VMEM budgeting,
and exposes an ensemble-level entry point.

Layout contract (ForestIR): the kernel consumes dense ``(T, N)`` node tables
— the IR's ``padded`` or ``leaf_major`` materializations (the paper's codegen
step re-targeted at tensors).  ``packed_predict_integer`` accepts a
``ForestIR`` directly and materializes the layout its resolved impl walks
(``leaf_major`` for the linear-scan kernel, ``padded`` otherwise); the
``ragged`` layout has no VMEM-tileable shape and belongs to the table-walk C
backend instead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flint import float_to_key
from repro.kernels.tree_traverse import tree_traverse_leaf_major, tree_traverse_pallas

_VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # stay well under ~16 MiB v5e VMEM

# below this many rows a full-forest grid cell pays the whole per-cell scan
# for a handful of rows; block_t is scaled down proportionally instead
_TINY_BATCH_ROWS = 64


def _block_words(block_b, block_t, n, f, c):
    """int32/uint32 words resident per grid cell: the x block, the four node
    tables, the leaf table, the per-tree internal-count vector (leaf_major
    working set), and the output block."""
    return (
        block_b * f
        + block_t * n * 4
        + block_t * n * c
        + block_t
        + block_b * c
    )


def pick_blocks(b, t, n, f, c, block_b=256):
    """Choose (block_b, block_t) so the working set fits the VMEM budget.

    The tree dimension shrinks first; when even ``block_t == 1`` is over
    budget (wide leaf tables — ``c`` large relative to ``n`` — make the
    ``block_b * c`` output block and the ``n * c`` leaf rows dominate), the
    row block halves and the search repeats.  The floor is (1, 1): a single
    row against a single tree, the smallest working set any tiling can have.

    Tiny batches (``b < 64``) additionally clamp ``block_t`` proportionally
    to the rows that amortize it: a cell's tree scan costs the same whether
    2 rows ride it or 256, so a full-forest tile against a handful of rows
    is the pathological BENCH_7 ``b32`` case — all of the per-cell cost,
    almost none of the row throughput.  VMEM fit is preserved (the clamp
    only ever shrinks).
    """
    block_b = min(block_b, b)
    while True:
        for block_t in range(t, 0, -1):
            if _block_words(block_b, block_t, n, f, c) * 4 <= _VMEM_BUDGET_BYTES:
                if b < _TINY_BATCH_ROWS:
                    block_t = min(
                        block_t, max(1, (t * b) // _TINY_BATCH_ROWS)
                    )
                return block_b, block_t
        if block_b == 1:
            return 1, 1  # model-fixed minimum; nothing left to shrink
        block_b //= 2


def pick_blocks_candidates(b, t, n, f, c, block_b=256):
    """The measured-autotune grid around the heuristic: the ``pick_blocks``
    choice plus its VMEM-feasible half/double neighbours along each axis.

    The heuristic optimizes a *budget*, not a runtime; ``TreeEngine.warm``'s
    autotuner times these candidates on the live host and pins the winner.
    Deduplicated, heuristic first (ties resolve to it), every entry fits the
    VMEM budget, so any candidate is safe to pin.
    """
    auto_b, auto_t = pick_blocks(b, t, n, f, c, block_b)
    cands = [(auto_b, auto_t)]
    for bb, bt in (
        (auto_b, max(1, auto_t // 2)),
        (max(1, auto_b // 2), auto_t),
        (auto_b, min(t, auto_t * 2)),
    ):
        if (bb, bt) not in cands and \
                _block_words(bb, bt, n, f, c) * 4 <= _VMEM_BUDGET_BYTES:
            cands.append((bb, bt))
    return cands


@partial(jax.jit, static_argnames=("depth", "block_b", "block_t", "impl", "interpret"))
def _traverse_padded(x_keys, feature, key, left, right, leaf, *, depth, block_b, block_t, impl, interpret):
    return tree_traverse_pallas(
        x_keys, feature, key, left, right, leaf,
        depth=depth, block_b=block_b, block_t=block_t, impl=impl, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("block_b", "block_t", "interpret"))
def _traverse_leaf_major(x_keys, feature, key, left, right, nint, leaf, *, block_b, block_t, interpret):
    return tree_traverse_leaf_major(
        x_keys, feature, key, left, right, nint, leaf,
        block_b=block_b, block_t=block_t, interpret=interpret,
    )


def tree_predict_integer(
    x_keys,
    feature,
    threshold_key,
    left,
    right,
    leaf_fixed,
    *,
    depth: int,
    block_b: int = 256,
    block_t: int | None = None,
    impl: str = "gather",
    interpret: bool = True,
    internal_counts=None,
):
    """Integer ensemble inference via the Pallas kernel, any B/T.

    ``impl="leaf_major"`` selects the linear-scan kernel and requires
    ``internal_counts`` (the leaf_major layout's per-tree internal-prefix
    lengths); the other impls walk any node-table ordering.  Returns (B, C)
    uint32 scores, bit-identical to ``ref.tree_predict_integer_ref``.
    """
    if impl == "leaf_major" and internal_counts is None:
        raise ValueError(
            "impl='leaf_major' needs the layout's internal_counts; "
            "materialize the forest as leaf_major (see repro.ir.layouts)"
        )
    x_keys = jnp.asarray(x_keys, jnp.int32)
    b, f = x_keys.shape
    t, n = feature.shape
    c = leaf_fixed.shape[-1]
    auto_b, auto_t = pick_blocks(b, t, n, f, c, block_b)
    block_b = min(block_b, auto_b)
    block_t = block_t or auto_t

    pad_b = (-b) % block_b
    pad_t = (-t) % block_t
    if pad_b:
        x_keys = jnp.pad(x_keys, ((0, pad_b), (0, 0)))
    if pad_t:
        # inert trees: all nodes are self-looping leaves with zero mass
        feature = jnp.pad(feature, ((0, pad_t), (0, 0)), constant_values=-1)
        threshold_key = jnp.pad(threshold_key, ((0, pad_t), (0, 0)))
        selfloop = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (pad_t, n))
        left = jnp.concatenate([left, selfloop], axis=0)
        right = jnp.concatenate([right, selfloop], axis=0)
        leaf_fixed = jnp.pad(leaf_fixed, ((0, pad_t), (0, 0), (0, 0)))

    if impl == "leaf_major":
        nint = jnp.asarray(internal_counts, jnp.int32)
        if pad_t:  # inert trees have no internal prefix to scan
            nint = jnp.pad(nint, (0, pad_t))
        out = _traverse_leaf_major(
            x_keys, feature, threshold_key, left, right, nint, leaf_fixed,
            block_b=block_b, block_t=block_t, interpret=interpret,
        )
    else:
        out = _traverse_padded(
            x_keys, feature, threshold_key, left, right, leaf_fixed,
            depth=depth, block_b=block_b, block_t=block_t, impl=impl,
            interpret=interpret,
        )
    return out[:b]


def packed_predict_integer(packed, X, impl: str = "auto", **kw):
    """Node-table entry point: float features in, (scores, preds) out.

    ``packed``: a node-table artifact (``PackedEnsemble`` in ``padded`` or
    ``leaf_major`` layout) or a ``ForestIR``.  ``impl="auto"`` resolves per
    layout — the linear-scan kernel on ``leaf_major`` tables, ``gather`` on
    ``padded`` — and a ForestIR is materialized into whichever layout the
    resolved impl walks (``leaf_major`` for the scan, ``padded`` otherwise).
    Pinning ``impl="leaf_major"`` on a padded artifact re-materializes it as
    leaf_major through the IR back-reference.
    """
    if hasattr(packed, "materialize"):  # a ForestIR: take the kernel's layout
        packed = packed.materialize(
            "leaf_major" if impl in ("auto", "leaf_major") else "padded"
        )
    layout = getattr(packed, "layout", "padded")
    if layout not in ("padded", "leaf_major"):
        raise ValueError(
            f"the Pallas kernel walks (T, N) node tables, not the {layout!r} "
            "layout; ragged belongs to the table-walk C backend"
        )
    if impl == "auto":
        # the scan needs the leaf_major internal prefix and its children-
        # after-parents order (internal_counts is None when an imported
        # forest violates it); any node order gather-walks fine
        impl = ("leaf_major"
                if layout == "leaf_major"
                and getattr(packed, "internal_counts", None) is not None
                else "gather")
    if impl == "leaf_major" and layout != "leaf_major":
        from repro.ir import resolve_artifact

        packed = resolve_artifact(packed, "leaf_major")
    keys = float_to_key(jnp.asarray(X, jnp.float32))
    acc = tree_predict_integer(
        keys,
        jnp.asarray(packed.feature),
        jnp.asarray(packed.threshold_key),
        jnp.asarray(packed.left),
        jnp.asarray(packed.right),
        jnp.asarray(packed.leaf_fixed),
        depth=packed.max_depth,
        impl=impl,
        internal_counts=packed.internal_counts if impl == "leaf_major" else None,
        **kw,
    )
    return acc, jnp.argmax(acc, axis=1).astype(jnp.int32)

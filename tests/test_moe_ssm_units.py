"""Unit tests for the MoE router (FlInt top-k) and the Mamba2 SSD layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import ssm as ssm_mod
from repro.models.moe import flint_topk, moe_block, moe_params


# --------------------------------------------------------------------- MoE

@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=30, deadline=None)
def test_flint_topk_matches_float_topk(seed):
    """Integer-key top-k selects exactly the same experts as float top-k."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(scale=5, size=(32, 64)), jnp.float32)
    _, ids_int = flint_topk(logits, 8)
    _, ids_float = jax.lax.top_k(logits, 8)
    np.testing.assert_array_equal(np.asarray(ids_int), np.asarray(ids_float))


def test_flint_topk_weights_normalized():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w, _ = flint_topk(logits, 4)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


def test_moe_block_dropless_equals_dense_mixture():
    """With capacity E/k (dropless) the block equals the explicit mixture."""
    rng = np.random.default_rng(3)
    d, e, k, ff = 32, 8, 2, 48
    params = moe_params(jax.random.PRNGKey(0), d, e, ff)
    x = jnp.asarray(rng.normal(size=(2, 16, d)), jnp.bfloat16)
    y, aux = moe_block(params, x, n_experts=e, k=k, capacity_factor=float(e) / k)

    # explicit reference: route every token through its top-k experts
    xt = x.reshape(-1, d)
    logits = xt @ params["w_router"].astype(x.dtype)
    w, ids = flint_topk(logits, k)
    ref = np.zeros((xt.shape[0], d), np.float32)
    for t in range(xt.shape[0]):
        for j in range(k):
            eidx = int(ids[t, j])
            gate = jax.nn.silu(xt[t] @ params["w_gate_e"][eidx].astype(x.dtype))
            up = xt[t] @ params["w_up_e"][eidx].astype(x.dtype)
            out = (gate * up) @ params["w_down_e"][eidx].astype(x.dtype)
            ref[t] += float(w[t, j]) * np.asarray(out, np.float32)
    got = np.asarray(y.reshape(-1, d), np.float32)
    np.testing.assert_allclose(got, ref, atol=0.15, rtol=0.15)  # bf16 tolerance
    assert float(aux) > 0


def test_moe_capacity_drops_are_masked():
    """Overflow tokens contribute exactly zero (not garbage)."""
    rng = np.random.default_rng(0)
    d, e, k, ff = 16, 4, 1, 16
    params = moe_params(jax.random.PRNGKey(1), d, e, ff)
    x = jnp.asarray(rng.normal(size=(1, 32, d)), jnp.bfloat16)
    y, _ = moe_block(params, x, n_experts=e, k=k, capacity_factor=0.25)
    capacity = int(32 * k * 0.25) // e  # = 2 slots per expert
    # expected kept rows = sum_e min(count_e, capacity), rest exactly zero
    logits = x.reshape(-1, d) @ params["w_router"].astype(x.dtype)
    ids = np.asarray(flint_topk(logits, k)[1])[:, 0]
    counts = np.bincount(ids, minlength=e)
    expected_kept = int(np.minimum(counts, capacity).sum())
    zero_rows = int((np.abs(np.asarray(y[0], np.float32)).sum(-1) < 1e-6).sum())
    assert zero_rows == 32 - expected_kept
    assert zero_rows >= 32 - e * capacity  # at most e*capacity survive


# --------------------------------------------------------------------- SSD

def _ssd_naive(params, x, d_model, expand, state):
    """O(S^2)-free sequential reference: literal recurrence per step."""
    d_inner, h, conv_dim = ssm_mod.ssm_dims(d_model, expand, state)
    cache = ssm_mod.ssm_init_cache(x.shape[0], d_model, expand, state, x.dtype)
    outs = []
    for t in range(x.shape[1]):
        y, cache = ssm_mod.ssd_decode_step(
            params, x[:, t : t + 1], cache, d_model=d_model, expand=expand, state=state
        )
        outs.append(y)
    return jnp.concatenate(outs, axis=1), cache


@pytest.mark.parametrize("seq,chunk", [(16, 8), (24, 8), (32, 32), (17, 8)])
def test_ssd_chunked_matches_sequential(seq, chunk):
    """The chunked SSD algorithm == the literal recurrence (paper 2405.21060
    equivalence), including non-divisible sequence lengths."""
    d_model, expand, state = 64, 2, 16
    params = ssm_mod.ssm_params(jax.random.PRNGKey(0), d_model, expand, state)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, seq, d_model)) * 0.3, jnp.bfloat16)
    y_chunk, state_chunk = ssm_mod.ssd_forward(
        params, x, d_model=d_model, expand=expand, state=state, chunk=chunk,
        return_final_state=True,
    )
    y_seq, cache_seq = _ssd_naive(params, x, d_model, expand, state)
    np.testing.assert_allclose(
        np.asarray(y_chunk, np.float32), np.asarray(y_seq, np.float32), atol=0.15, rtol=0.2
    )
    np.testing.assert_allclose(
        np.asarray(state_chunk["ssm"]), np.asarray(cache_seq["ssm"]), atol=0.05, rtol=0.1
    )
    np.testing.assert_allclose(
        np.asarray(state_chunk["conv"], np.float32),
        np.asarray(cache_seq["conv"], np.float32),
        atol=1e-2,
    )


def test_ssd_state_carries_context():
    """A perturbed early token shifts the state within the decay horizon
    (default init decays ~e^-0.7/step, so use a short window)."""
    d_model, expand, state = 32, 2, 8
    params = ssm_mod.ssm_params(jax.random.PRNGKey(1), d_model, expand, state)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, d_model)) * 0.3, jnp.bfloat16)
    x2 = x.at[0, 0].add(5.0)
    y1, s1 = ssm_mod.ssd_forward(params, x, d_model=d_model, expand=expand, state=state,
                                 chunk=4, return_final_state=True)
    y2, s2 = ssm_mod.ssd_forward(params, x2, d_model=d_model, expand=expand, state=state,
                                 chunk=4, return_final_state=True)
    assert float(jnp.abs(s1["ssm"] - s2["ssm"]).max()) > 1e-4
    # and the perturbation propagates to later outputs (cross-chunk)
    assert float(jnp.abs(y1[:, 6:] - y2[:, 6:]).astype(jnp.float32).max()) > 1e-3

"""Render the roofline tables (EXPERIMENTS.md §Roofline) from dry-run
artifacts.  Baseline artifacts live in ``artifacts/dryrun_baseline`` (frozen
before the §Perf iterations), the current code's numbers in
``artifacts/dryrun``.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent / "artifacts"


def load(dirname: str):
    recs = {}
    for p in sorted((ART / dirname).glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_table(recs, mesh="16x16", baseline=None) -> str:
    rows = []
    header = (
        "| arch | shape | dominant | compute s | memory s | collective s | "
        "step LB s | useful | MFU bound |" + (" vs baseline |" if baseline else "")
    )
    sep = "|---" * (10 if baseline else 9) + "|"
    rows.append(header)
    rows.append(sep)
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or not r.get("ok"):
            continue
        t = r["roofline"]
        row = (
            f"| {arch} | {shape} | {t['dominant']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
            f"{t['step_time_lb_s']:.2e} | {t['useful_ratio']:.2f} | {t['mfu_bound']:.3f} |"
        )
        if baseline:
            b = baseline.get((arch, shape, m))
            if b and b.get("ok"):
                speed = b["roofline"]["step_time_lb_s"] / max(t["step_time_lb_s"], 1e-30)
                row += f" {speed:,.1f}x |"
            else:
                row += " - |"
        rows.append(row)
    return "\n".join(rows)


def memory_fit_table(recs, mesh="16x16") -> str:
    rows = ["| arch | shape | args GB/dev | temp GB/dev | fits 16 GB |", "|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or not r.get("ok"):
            continue
        mem = r["memory"]
        args = (mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
                - mem["alias_size_in_bytes"]) / 1e9
        temp = mem["temp_size_in_bytes"] / 1e9
        fits = "yes" if (mem["argument_size_in_bytes"] - mem["alias_size_in_bytes"]
                         + mem["temp_size_in_bytes"]) / 1e9 < 16 else "NO"
        rows.append(f"| {arch} | {shape} | {args:.2f} | {temp:.2f} | {fits} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun")
    ap.add_argument("--baseline", default="dryrun_baseline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    base = load(args.baseline) if (ART / args.baseline).exists() else None
    n_ok = sum(1 for r in recs.values() if r.get("ok"))
    print(f"## Roofline ({args.mesh}) — {n_ok}/{len(recs)} cells ok\n")
    print(fmt_table(recs, args.mesh, base))
    print("\n## Memory fit\n")
    print(memory_fit_table(recs, args.mesh))


if __name__ == "__main__":
    main()

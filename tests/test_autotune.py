"""The warm-time measured autotuner (``repro.serve.autotune``).

What must hold:
  * determinism — an injected constant-time measure resolves ties to the
    static default, and an injected ranking picks the same winner every run;
  * bit-identity — serving on any tuned config equals the untuned scores
    (the knobs only re-tile work; conformance crosses them independently);
  * caching — the winner lands in the owning ``ModelVersion``'s store, a
    hot-swapped version inherits it and serves tuned *without* re-measuring;
  * escape hatches — ``REPRO_AUTOTUNE=0`` kills tuning globally, caller-
    pinned ``backend_kwargs`` knobs are never overridden, and non-single
    plans / non-tunable backends never arm the tuner;
  * accounting — the measuring cost drains through ``drain_compile_timings``
    under the ``"tune"`` key and flows into the metrics ``tuned`` column and
    the compile ledger without breaking the int-keyed bucket sort.
"""
import numpy as np
import pytest

from repro.serve import autotune as at
from repro.serve.engine import TreeEngine
from repro.serve.registry import ModelRegistry

pytestmark = pytest.mark.requires_gcc


@pytest.fixture()
def probe(shuttle_small):
    _, _, Xte, _ = shuttle_small
    return Xte[:48]


def _fake_measure(winner_key, winner_val):
    """A deterministic measure: the candidate whose kwargs contain
    ``winner_key == winner_val`` is fastest, everything else ties slower."""
    def measure(backend, X):
        kw = {"interleave": getattr(backend, "interleave", None),
              "block_rows": getattr(backend, "block_rows", None)}
        return 1.0 if kw.get(winner_key) == winner_val else 2.0
    return measure


def test_candidate_grids_default_first(small_packed):
    ir = small_packed.to_ir()
    tbl = at.candidate_grid("native_c_table", ir.materialize("ragged"))
    assert tbl[0] == {"block_rows": 8}  # the static default leads
    assert {c["block_rows"] for c in tbl} == {1, 4, 8, 16}
    bv = at.candidate_grid("native_c_bitvector", ir.materialize("bitvector"))
    assert bv[0] == {"interleave": 8}
    assert {c["interleave"] for c in bv} == {1, 4, 8}
    pal = at.candidate_grid("pallas", ir.materialize("leaf_major"))
    assert pal and all({"block_b", "block_t"} == set(c) for c in pal)
    from repro.kernels.ops import pick_blocks

    t, n = ir.materialize("leaf_major").feature.shape
    auto = pick_blocks(at._TUNE_ROWS, t, n, ir.n_features, ir.n_classes)
    assert (pal[0]["block_b"], pal[0]["block_t"]) == auto  # heuristic leads
    assert at.candidate_grid("reference", small_packed) == []


def test_tune_is_deterministic_and_ties_go_to_default(small_packed):
    ir = small_packed.to_ir()
    art = ir.materialize("bitvector")
    # constant timer: every candidate ties -> the default (grid[0]) wins
    const = lambda backend, X: 1.0
    winners = {at.tune_backend("native_c_bitvector", art, "integer",
                               measure=const)[0]["interleave"]
               for _ in range(3)}
    assert winners == {8}
    # a ranked timer picks the same non-default winner every run
    for _ in range(2):
        w, wb, report = at.tune_backend(
            "native_c_bitvector", art, "integer",
            measure=_fake_measure("interleave", 4))
        assert w == {"interleave": 4} and wb.interleave == 4
        assert [kw["interleave"] for kw, _ in report] == [8, 1, 4]


def test_warm_tunes_and_stays_bit_identical(small_packed, probe, monkeypatch):
    ref = TreeEngine(small_packed, mode="integer").predict_scores(probe)
    monkeypatch.setattr(at, "measure_backend", _fake_measure("interleave", 1))
    store = {}
    eng = TreeEngine(small_packed, mode="integer",
                     backend="native_c_bitvector", autotune=True,
                     tuned_store=store)
    assert eng._pending_tune and eng.tuned_config is None
    eng.warm(32)
    assert eng.tuned_config == "interleave=1"
    assert eng.backend.interleave == 1
    assert store == {("native_c_bitvector", None, "integer"):
                     {"interleave": 1}}
    tune_ms = eng.drain_compile_timings()["tune"]
    assert tune_ms >= 0
    s, p = eng.predict_scores(probe)
    np.testing.assert_array_equal(s, ref[0])
    np.testing.assert_array_equal(p, ref[1])


def test_cached_winner_reused_without_measuring(small_packed, monkeypatch):
    calls = []

    def spy(*a, **kw):
        calls.append(a)
        return None, None, []

    monkeypatch.setattr(at, "tune_backend", spy)
    store = {("native_c_table", None, "integer"): {"block_rows": 4}}
    eng = TreeEngine(small_packed, mode="integer", backend="native_c_table",
                     autotune=True, tuned_store=store)
    # the cached winner applies at construction; warm() must not re-measure
    assert not eng._pending_tune
    assert eng.tuned_config == "block_rows=4"
    assert eng.backend.block_rows == 4
    eng.warm(16)
    assert calls == []


def test_env_kill_switch_and_ineligible_routes(small_packed, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    eng = TreeEngine(small_packed, mode="integer",
                     backend="native_c_bitvector", autotune=True)
    assert not eng._pending_tune and eng.tuned_config is None
    eng.warm(16)
    assert eng.backend.interleave == 8  # the static default, untouched
    monkeypatch.delenv("REPRO_AUTOTUNE")
    # non-tunable backend: never armed
    assert not TreeEngine(small_packed, mode="integer",
                          autotune=True)._pending_tune
    # multi-shard plans are not tuned (per-shard artifacts differ)
    assert not TreeEngine(small_packed, mode="integer",
                          backend="native_c_bitvector", plan="tree_parallel",
                          shards=2, autotune=True)._pending_tune


def test_caller_pinned_knob_is_never_overridden(small_packed, monkeypatch):
    monkeypatch.setattr(at, "measure_backend", _fake_measure("interleave", 1))
    eng = TreeEngine(small_packed, mode="integer",
                     backend="native_c_bitvector", autotune=True,
                     backend_kwargs={"interleave": 4})
    eng.warm(16)
    assert eng.backend.interleave == 4  # the pin survives warm
    assert eng.tuned_config is None     # and no winner is reported


def test_hot_swap_inherits_tuned_winner(small_forest, probe, monkeypatch):
    monkeypatch.setattr(at, "measure_backend", _fake_measure("interleave", 4))
    reg = ModelRegistry()
    mv1 = reg.register_forest("m", small_forest)
    eng1 = mv1.engine("integer", backend="native_c_bitvector", autotune=True)
    eng1.warm(32)
    assert eng1.tuned_config == "interleave=4"
    # hot-swap: the new version must inherit the measurement and serve tuned
    # from construction, without tune_backend running again
    calls = []
    real = at.tune_backend

    def spy(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(at, "tune_backend", spy)
    mv2 = reg.register_forest("m", small_forest)
    assert mv2.version == mv1.version + 1
    eng2 = mv2.engine("integer", backend="native_c_bitvector", autotune=True)
    assert not eng2._pending_tune
    assert eng2.tuned_config == "interleave=4"
    assert eng2.backend.interleave == 4
    eng2.warm(32)
    assert calls == []
    s1 = eng1.predict_scores(probe)
    s2 = eng2.predict_scores(probe)
    np.testing.assert_array_equal(s1[0], s2[0])
    np.testing.assert_array_equal(s1[1], s2[1])


def test_gateway_surfaces_tuned_column(small_forest, shuttle_small,
                                       monkeypatch):
    import asyncio

    from repro.serve.gateway import Gateway

    monkeypatch.setattr(at, "measure_backend", _fake_measure("block_rows", 1))
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", backend="native_c_table",
                 autotune=True, max_delay_ms=1.0)
    reg.get("m").engine("integer", backend="native_c_table",
                        autotune=True).warm(16)
    asyncio.run(gw.submit("m", Xte[:8]))
    asyncio.run(gw.close())
    st = gw.stats()["per_model"]["m"]
    assert st["tuned"] == "block_rows=1"
    assert st["compile_ms_by_bucket"]["tune"] >= 0
    # the mixed int/str bucket keys must survive every exposition surface
    gw.render_table()
    from repro.obs.export import render_prometheus

    assert 'bucket="tune"' in render_prometheus(gw.stats()["per_model"])

"""Gateway subsystem: bucketed engine, micro-batcher, registry, cache,
end-to-end bit-identity of gateway outputs vs direct engine calls."""
import asyncio
import time

import numpy as np
import pytest

from repro.serve.cache import QuantizedKeyCache, row_keys
from repro.serve.engine import TreeEngine, bucket_rows
from repro.serve.gateway import Gateway
from repro.serve.queue import AdmissionError, MicroBatcher
from repro.serve.registry import ModelRegistry


# ------------------------------------------------------------------ engine

def test_bucket_rows():
    assert [bucket_rows(b) for b in (1, 2, 3, 5, 64, 65, 1000)] == [
        1, 2, 4, 8, 64, 128, 1024
    ]
    assert bucket_rows(4097, max_bucket=4096) == 8192
    assert bucket_rows(5000, max_bucket=4096) == 8192
    with pytest.raises(ValueError):
        bucket_rows(0)


def test_engine_bucketing_bit_identical(small_packed, shuttle_small):
    """Padded-bucket execution must not perturb real rows."""
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer")
    s_full, p_full = eng.predict_scores(Xte[:64])
    for b in (1, 5, 37, 64):
        s, p = eng.predict_scores(Xte[:b])
        np.testing.assert_array_equal(s, s_full[:b])
        np.testing.assert_array_equal(p, p_full[:b])
    # 1, 5->8, 37->64, 64: three compiled buckets, not four shapes
    assert eng.compiled_buckets == {1, 8, 64}


# ------------------------------------------------------------------- cache

def test_cache_lru_and_counters():
    c = QuantizedKeyCache(capacity_rows=2)
    k = lambda i: c.key_for("m", 1, "integer", bytes([i]))
    assert c.get(k(0)) is None and c.misses == 1
    c.put(k(0), np.array([1, 2]), 0)
    c.put(k(1), np.array([3, 4]), 1)
    assert c.get(k(0))[1] == 0 and c.hits == 1
    c.put(k(2), np.array([5, 6]), 1)  # evicts k(1), the LRU entry
    assert len(c) == 2 and c.evictions == 1
    assert c.get(k(1)) is None
    assert c.get(k(0)) is not None and c.get(k(2)) is not None


def test_row_keys_quantized_exact_match():
    X = np.array([[0.5, -1.25], [0.5, -1.25], [0.5, -1.0]], np.float32)
    k = row_keys(X)
    assert k[0] == k[1] and k[0] != k[2]


# ---------------------------------------------------------------- batcher

def _fake_execute(model_id, X):
    # scores = row sums so results are easy to verify per row
    s = X.sum(axis=1, keepdims=True)
    return s, np.arange(len(X), dtype=np.int32) * 0, len(X), None


def test_micro_batcher_coalesces_and_scatters():
    batches = []

    async def run():
        mb = MicroBatcher(_fake_execute, max_batch_rows=64, max_delay_ms=100,
                          on_batch=lambda m, r, p: batches.append(r))
        reqs = [np.full((1, 3), float(i), np.float32) for i in range(8)]
        outs = await asyncio.gather(*[mb.submit("m", r) for r in reqs])
        await mb.close()
        return outs

    outs = asyncio.run(run())
    for i, (scores, preds, _meta) in enumerate(outs):
        assert scores.shape == (1, 1) and scores[0, 0] == 3.0 * i
    # 8 one-row submissions coalesced into far fewer engine dispatches
    assert sum(batches) == 8 and len(batches) < 8


def test_micro_batcher_admission_control():
    def slow_execute(model_id, X):
        time.sleep(0.15)
        return X.sum(axis=1, keepdims=True), np.zeros(len(X), np.int32), len(X), None

    async def run():
        mb = MicroBatcher(slow_execute, max_batch_rows=1, max_delay_ms=0.1,
                          max_queue_rows=4)
        first = asyncio.ensure_future(mb.submit("m", np.zeros((1, 2), np.float32)))
        await asyncio.sleep(0.05)  # worker is now busy executing `first`
        backlog = [asyncio.ensure_future(mb.submit("m", np.zeros((1, 2), np.float32)))
                   for _ in range(4)]
        await asyncio.sleep(0)  # let the submits enqueue
        with pytest.raises(AdmissionError):
            await mb.submit("m", np.zeros((1, 2), np.float32))
        await asyncio.gather(first, *backlog)
        await mb.close()

    asyncio.run(run())


def test_micro_batcher_close_drains_pending_submits():
    """close() drains: everything submitted before it resolves to a real
    result (never "batcher closed"), and nothing is stranded.  Submissions
    arriving after close() fail fast."""
    def slow_execute(model_id, X):
        time.sleep(0.2)
        return X.sum(axis=1, keepdims=True), np.zeros(len(X), np.int32), len(X), None

    async def run():
        mb = MicroBatcher(slow_execute, max_batch_rows=1, max_delay_ms=0.1)
        subs = [asyncio.ensure_future(mb.submit("m", np.zeros((1, 2), np.float32)))
                for _ in range(3)]
        await asyncio.sleep(0.05)  # first is executing, rest are queued
        await mb.close()
        done = await asyncio.wait_for(
            asyncio.gather(*subs, return_exceptions=True), timeout=2.0
        )
        with pytest.raises(RuntimeError):
            await mb.submit("m", np.zeros((1, 2), np.float32))
        return done

    done = asyncio.run(run())
    # every caller that submitted before close() got its real result
    assert all(isinstance(r, tuple) for r in done)


def test_micro_batcher_close_timeout_fails_stragglers():
    """A lane that overruns close_timeout_s is cancelled and its remaining
    callers failed — drain must not hang forever on a wedged executor."""
    def wedged_execute(model_id, X):
        time.sleep(1.2)  # >> close_timeout_s; asyncio.run reaps the thread
        return X.sum(axis=1, keepdims=True), np.zeros(len(X), np.int32), len(X), None

    async def run():
        mb = MicroBatcher(wedged_execute, max_batch_rows=1, max_delay_ms=0.1,
                          close_timeout_s=0.2)
        subs = [asyncio.ensure_future(mb.submit("m", np.zeros((1, 2), np.float32)))
                for _ in range(3)]
        await asyncio.sleep(0.05)
        await mb.close()
        return await asyncio.wait_for(
            asyncio.gather(*subs, return_exceptions=True), timeout=10.0
        )

    done = asyncio.run(run())
    assert all(isinstance(r, (tuple, RuntimeError)) for r in done)
    assert any(isinstance(r, RuntimeError) for r in done)


# --------------------------------------------------------------- registry

def test_registry_versioning_and_hot_swap(small_forest, small_packed):
    reg = ModelRegistry()
    v1 = reg.register_forest("m", small_forest)
    assert v1.version == 1 and reg.version("m") == 1
    v2 = reg.register_packed("m", small_packed)
    assert v2.version == 2 and reg.get("m") is v2
    # the old version object stays usable for in-flight batches
    assert v1.packed.n_trees == small_packed.n_trees
    with pytest.raises(KeyError):
        reg.get("nope")


def test_registry_json_load_path_bit_identical(small_forest, shuttle_small):
    from repro.trees.io import forest_to_json

    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("direct", small_forest)
    reg.register_json("via-json", forest_to_json(small_forest))
    s1, p1 = reg.get("direct").engine("integer").predict_scores(Xte[:40])
    s2, p2 = reg.get("via-json").engine("integer").predict_scores(Xte[:40])
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(p1, p2)


# ---------------------------------------------------------------- gateway

def test_gateway_bit_identical_with_cache_and_batching(small_forest, shuttle_small):
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m1", small_forest)
    gw = Gateway(reg, mode="integer", max_batch_rows=32, max_delay_ms=2.0)
    direct = reg.get("m1").engine("integer")

    async def run():
        rows = Xte[:24]
        # mixed-size concurrent submissions covering the same 24 rows
        parts = [rows[:1], rows[1:3], rows[3:10], rows[10:24]]
        outs = await asyncio.gather(*[gw.submit("m1", p) for p in parts])
        scores = np.concatenate([s for s, _ in outs])
        preds = np.concatenate([p for _, p in outs])
        # resubmit the same rows: every row must now be a cache hit
        s2, p2 = await gw.submit("m1", rows)
        await gw.close()
        return scores, preds, s2, p2

    scores, preds, s2, p2 = asyncio.run(run())
    d_scores, d_preds = direct.predict_scores(Xte[:24])
    np.testing.assert_array_equal(scores, d_scores)
    np.testing.assert_array_equal(preds, d_preds)
    np.testing.assert_array_equal(s2, d_scores)
    np.testing.assert_array_equal(p2, d_preds)
    assert gw.cache.hits >= 24  # the resubmission was served from cache
    st = gw.stats()["per_model"]["m1"]
    assert st["cache_hit_rate"] > 0
    assert st["batches"] >= 1 and st["batch_occupancy"] >= 1.0


def test_gateway_hot_swap_routes_new_version(small_forest, shuttle_small):
    Xtr, ytr, Xte, _ = shuttle_small
    from repro.trees.forest import RandomForestClassifier

    other = RandomForestClassifier(n_estimators=3, max_depth=4, seed=42).fit(
        Xtr[:1500], ytr[:1500]
    )
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", max_delay_ms=1.0)

    async def run():
        s_v1, _ = await gw.submit("m", Xte[:8])
        mv2 = reg.register_forest("m", other)  # hot-swap under the gateway
        s_v2, _ = await gw.submit("m", Xte[:8])
        await gw.close()
        return s_v1, s_v2, mv2

    s_v1, s_v2, mv2 = asyncio.run(run())
    d_v2, _ = mv2.engine("integer").predict_scores(Xte[:8])
    np.testing.assert_array_equal(s_v2, d_v2)  # new traffic hits v2
    assert mv2.version == 2
    # v1-keyed cache entries must not leak into v2 responses
    assert not np.array_equal(s_v1, s_v2)


def test_gateway_survives_event_loop_reuse(small_forest, shuttle_small):
    """asyncio.run tears down lane workers with its loop; a later loop must
    respawn them instead of hanging on a dead queue."""
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", max_delay_ms=1.0)
    s1, _ = asyncio.run(gw.submit("m", Xte[:4]))
    s2, _ = asyncio.run(gw.submit("m", Xte[4:8]))  # fresh loop, cache-cold rows
    direct = reg.get("m").engine("integer")
    np.testing.assert_array_equal(s2, direct.predict_scores(Xte[4:8])[0])
    np.testing.assert_array_equal(s1, direct.predict_scores(Xte[:4])[0])


def test_gateway_cache_hit_requests_record_latency(small_forest, shuttle_small):
    """Requests served entirely from cache must land in the per-model latency
    histogram and request counters (and the hit_requests counter) — pinned by
    test so the all-hit fast path can never silently start timing misses
    only, which would skew p50/p95 on high-hit-rate streams."""
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", max_delay_ms=1.0)

    async def run():
        s1, _ = await gw.submit("m", Xte[:6])
        s2, _ = await gw.submit("m", Xte[:6])  # every row now a cache hit
        await gw.close()
        return s1, s2

    s1, s2 = asyncio.run(run())
    np.testing.assert_array_equal(s1, s2)
    mm = gw.metrics.model("m")
    assert mm.hit_requests == 1
    assert mm.requests == 2
    assert mm.latency.count == 2  # the hit request was timed too
    st = gw.stats()["per_model"]["m"]
    assert st["hit_requests"] == 1 and st["requests"] == 2
    assert np.isfinite(st["p50_ms"]) and np.isfinite(st["p99_ms"])


def test_gateway_layout_routing_bit_identical(small_forest, shuttle_small):
    """A layout-pinned gateway serves bit-identically to the default route,
    and cache keys stay layout-agnostic (same key space, either fills it)."""
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw_default = Gateway(reg, mode="integer", max_delay_ms=1.0)
    gw_lm = Gateway(reg, mode="integer", layout="leaf_major", max_delay_ms=1.0)

    async def run(gw):
        out = await gw.submit("m", Xte[:12])
        await gw.close()
        return out

    s_d, p_d = asyncio.run(run(gw_default))
    s_l, p_l = asyncio.run(run(gw_lm))
    np.testing.assert_array_equal(s_d, s_l)
    np.testing.assert_array_equal(p_d, p_l)
    assert reg.get("m").engine("integer", layout="leaf_major").layout == "leaf_major"
    with pytest.raises(ValueError, match="layout"):
        Gateway(reg, mode="integer", backend="pallas", layout="ragged")


def test_gateway_float_mode_disables_cache(small_packed):
    reg = ModelRegistry()
    reg.register_packed("m", small_packed)
    gw = Gateway(reg, mode="float")
    assert gw.cache.capacity_rows == 0


def test_gateway_plan_routing_bit_identical(small_forest, shuttle_small):
    """A sharded-plan gateway serves bit-identically to the single-shard
    route — deterministic outputs are bit-identical across plans, which is
    exactly why cache keys can stay plan-agnostic."""
    _, _, Xte, _ = shuttle_small
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw_single = Gateway(reg, mode="integer", max_delay_ms=1.0)
    gw_tp = Gateway(reg, mode="integer", plan="tree_parallel", shards=3,
                    max_delay_ms=1.0)

    async def run(gw):
        out = await gw.submit("m", Xte[:12])
        await gw.close()
        return out

    s_s, p_s = asyncio.run(run(gw_single))
    s_t, p_t = asyncio.run(run(gw_tp))
    np.testing.assert_array_equal(s_s, s_t)
    np.testing.assert_array_equal(p_s, p_t)
    mv = reg.get("m")
    eng = mv.engine("integer", plan="tree_parallel", shards=3)
    from repro.plan import thread_shard_cap

    want = 3 if eng.plan.fused else min(3, thread_shard_cap())
    assert eng.plan_name == "tree_parallel" and eng.n_shards == want
    # the route is memoized separately from the single-shard engine
    assert eng is not mv.engine("integer")
    assert eng is mv.engine("integer", plan="tree_parallel", shards=3)
    with pytest.raises(KeyError, match="no-such"):
        Gateway(reg, mode="integer", plan="no-such-plan")


def test_gateway_hot_swap_with_multi_shard_plan_in_flight(small_forest,
                                                          shuttle_small):
    """Hot-swap while a tree-parallel plan is serving: the swapped-in version
    gets its *own* plan (its own shard carve — the new forest has a different
    tree count), responses never mix partials across versions, and the new
    traffic is bit-identical to a direct sharded engine on v2."""
    Xtr, ytr, Xte, _ = shuttle_small
    from repro.trees.forest import RandomForestClassifier

    other = RandomForestClassifier(n_estimators=5, max_depth=4, seed=77).fit(
        Xtr[:1500], ytr[:1500]
    )
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)
    gw = Gateway(reg, mode="integer", plan="tree_parallel", shards=3,
                 max_delay_ms=1.0)

    async def run():
        s_v1, _ = await gw.submit("m", Xte[:8])
        mv2 = reg.register_forest("m", other)  # hot-swap under the gateway
        s_v2, p_v2 = await gw.submit("m", Xte[:8])
        await gw.close()
        return s_v1, s_v2, p_v2, mv2

    s_v1, s_v2, p_v2, mv2 = asyncio.run(run())
    assert mv2.version == 2
    # v2 traffic == direct tree-parallel engine on v2 (5 trees -> 3 shards)
    eng2 = mv2.engine("integer", plan="tree_parallel", shards=3)
    d_s, d_p = eng2.predict_scores(Xte[:8])
    np.testing.assert_array_equal(s_v2, d_s)
    np.testing.assert_array_equal(p_v2, d_p)
    # ... and == the single-shard walk on v2 (no cross-version partial mixing:
    # a v1 shard summed into v2 could not reproduce this bit-exactly)
    d1_s, d1_p = mv2.engine("integer").predict_scores(Xte[:8])
    np.testing.assert_array_equal(s_v2, d1_s)
    assert not np.array_equal(s_v1, s_v2)  # v1 cache never leaks into v2

"""The paper's literal deliverable: integer-only if-else C.  When gcc is
available we compile the emitted file and diff argmax against the JAX path."""
import shutil
import subprocess
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.codegen.c_emitter import emit_c, emit_test_harness
from repro.core.ensemble import predict_integer
from repro.core.flint import float_to_key_np

HAS_GCC = shutil.which("gcc") is not None


def test_emit_integer_c_structure(small_packed):
    src = emit_c(small_packed, mode="integer")
    assert "#include <stdint.h>" in src
    assert "float" not in src  # integer-only: no float type anywhere
    assert "result[0] +=" in src
    assert "u;" in src  # uint32 literals
    assert src.count("if (") > small_packed.n_trees  # real branching structure


def test_emit_float_c_structure(small_packed):
    src = emit_c(small_packed, mode="float")
    assert "const float* data" in src
    assert "f;" in src


@pytest.mark.skipif(not HAS_GCC, reason="gcc not available")
def test_compiled_c_matches_jax(small_packed, shuttle_small):
    _, _, Xte, _ = shuttle_small
    Xte = Xte[:500]
    src = emit_c(small_packed, mode="integer") + emit_test_harness(small_packed, len(Xte))
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "model.c"
        binary = Path(d) / "model"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O2", "-o", str(binary), str(c_file)], check=True, capture_output=True
        )
        keys = float_to_key_np(Xte.astype(np.float32))
        out = subprocess.run(
            [str(binary)], input=keys.astype("<i4").tobytes(), capture_output=True, check=True
        )
        c_preds = np.array([int(v) for v in out.stdout.split()])
    _, jax_preds = predict_integer(small_packed, Xte)
    np.testing.assert_array_equal(c_preds, np.asarray(jax_preds))


@pytest.mark.skipif(not HAS_GCC, reason="gcc not available")
def test_c_binary_size_reported(small_packed):
    """Analog of the paper's Sec. IV-E memory-footprint measurement."""
    src = emit_c(small_packed, mode="integer")
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "model.c"
        obj = Path(d) / "model.o"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O2", "-c", "-o", str(obj), str(c_file)], check=True, capture_output=True
        )
        assert obj.stat().st_size > 0

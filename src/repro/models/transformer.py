"""Unified model definition for all assigned LM families.

One functional model covers dense / moe / ssm / hybrid / vlm / audio:
  * homogeneous families scan a stacked block (compile time independent of L,
    remat per block),
  * zamba2 hybrid runs 9 unrolled groups of (scan over 6 Mamba blocks) +
    one shared attention+MLP block (two alternating parameter sets),
  * vlm/audio prepend/replace inputs with stub frontend embeddings through a
    linear projector (the assignment stubs the modality encoder),
  * the LM loss never materializes (B, S, V) logits: cross-entropy is
    computed in sequence chunks inside a scan (vocab up to 262k).

Params are plain nested dicts; ``repro.sharding.rules`` maps leaf paths to
PartitionSpecs for the dry-run and production launch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    COMPUTE_DTYPE,
    AttnDims,
    attention,
    attn_params,
    dense_init,
    mlp,
    mlp_params,
    rms_norm,
)
from repro.models.moe import moe_block, moe_params


def _attn_dims(cfg: ModelConfig) -> AttnDims:
    return AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def window_schedule(cfg: ModelConfig):
    """Per-layer sliding-window size; 0 = full attention."""
    out = []
    for i in range(cfg.n_layers):
        if cfg.global_every:
            out.append(0 if (i + 1) % cfg.global_every == 0 else cfg.sliding_window)
        else:
            out.append(cfg.sliding_window)
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.family in ("ssm", "hybrid"):
        return {
            "ln1": jnp.zeros((d,), jnp.float32),
            "ssm": ssm_mod.ssm_params(ks[0], d, cfg.ssm_expand, cfg.ssm_state),
        }
    block = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        "attn": attn_params(ks[0], d, _attn_dims(cfg)),
    }
    if cfg.family == "moe":
        block["moe"] = moe_params(ks[1], d, cfg.n_experts, cfg.d_ff)
    else:
        block["mlp"] = mlp_params(ks[1], d, cfg.d_ff)
    return block


def _shared_block_init(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        "attn": attn_params(k1, d, _attn_dims(cfg)),
        "mlp": mlp_params(k2, d, cfg.d_ff),
    }


def init_params(cfg: ModelConfig, key):
    keys = jax.random.split(key, 8)
    params = {}
    if cfg.family != "audio":
        params["embed"] = dense_init(keys[0], (cfg.vocab_size, cfg.d_model), in_axis=1)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(keys[1], (cfg.frontend_dim, cfg.d_model))
    bkeys = jax.random.split(keys[2], cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _block_init(cfg, k))(bkeys)
    if cfg.hybrid_attn_every:
        skeys = jax.random.split(keys[3], cfg.hybrid_shared_sets)
        params["shared"] = jax.vmap(lambda k: _shared_block_init(cfg, k))(skeys)
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings or cfg.family == "audio":
        params["head"] = dense_init(keys[4], (cfg.d_model, cfg.vocab_size))
    return params


def param_shapes(cfg: ModelConfig):
    """Abstract params via eval_shape (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_attn_block(cfg, bp, x, positions, window, kv_cache=None, cache_pos=None,
                      causal=True, q_chunk=512):
    h, new_cache = attention(
        bp["attn"],
        rms_norm(x, bp["ln1"], cfg.norm_eps),
        _attn_dims(cfg),
        positions=positions,
        causal=causal,
        window=window,
        rope_theta=cfg.rope_theta,
        q_chunk=q_chunk,
        kv_cache=kv_cache,
        cache_pos=cache_pos,
    )
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        m, aux = moe_block(
            bp["moe"],
            rms_norm(x, bp["ln2"], cfg.norm_eps),
            n_experts=cfg.n_experts,
            k=cfg.experts_per_token,
            act=cfg.act,
            capacity_factor=cfg.moe_capacity_factor,
        )
    else:
        m = mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps), cfg.act)
    return x + m, new_cache, aux


def _apply_ssm_block(cfg, bp, x):
    h = ssm_mod.ssd_forward(
        bp["ssm"],
        rms_norm(x, bp["ln1"], cfg.norm_eps),
        d_model=cfg.d_model,
        expand=cfg.ssm_expand,
        state=cfg.ssm_state,
        chunk=cfg.ssm_chunk,
    )
    return x + h


def _select_shared(params_shared, idx: int):
    return jax.tree.map(lambda a: a[idx], params_shared)


# ---------------------------------------------------------------------------
# forward (train / encoder / prefill-logits)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, batch):
    """Token/frontend embedding.  Returns (x (B,S,D), label_offset)."""
    if cfg.family == "audio":
        x = batch["frames"].astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(COMPUTE_DTYPE)
        return x, 0
    tok = params["embed"][batch["tokens"]].astype(COMPUTE_DTYPE)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(COMPUTE_DTYPE) @ params["frontend_proj"].astype(
            COMPUTE_DTYPE
        )
        return jnp.concatenate([patches, tok], axis=1), cfg.vision_patches
    return tok, 0


@jax.custom_jvp
def _fence(x):
    """Block XLA from hoisting per-iteration converts of the scan carry out
    of the loop (measured: hoisting materialized the whole (L,B,S,D) saved
    stack in f32 — 2x activation memory on mamba2 train_4k).

    optimization_barrier has no differentiation rule, so we supply the
    obvious one: it is the identity.  The tangent passes through un-fenced —
    a fenced tangent would need a transpose rule for reverse mode, which
    the primitive also lacks; the measured hoisting hazard was on the
    primal carry, which stays fenced."""
    return jax.lax.optimization_barrier(x)


@_fence.defjvp
def _fence_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


def backbone(cfg: ModelConfig, params, x, *, remat: bool = True):
    """Run all blocks (no cache).  x: (B,S,D) -> (B,S,D), aux_loss."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    causal = not cfg.encoder_only

    if cfg.family in ("ssm",):

        def body(carry, bp):
            return _apply_ssm_block(cfg, bp, _fence(carry)), None

        body = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return x, jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        blocks = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["blocks"]
        )

        def body(carry, bp):
            return _apply_ssm_block(cfg, bp, _fence(carry)), None

        body = jax.checkpoint(body) if remat else body

        def shared_apply(x, sp):
            return _apply_attn_block(cfg, sp, x, positions, 0)[0]

        if remat:
            shared_apply = jax.checkpoint(shared_apply)
        for g in range(n_groups):
            gb = jax.tree.map(lambda a: a[g], blocks)
            x, _ = jax.lax.scan(body, x, gb)
            sp = _select_shared(params["shared"], g % cfg.hybrid_shared_sets)
            x = shared_apply(x, sp)
        return x, jnp.zeros((), jnp.float32)

    # dense / moe / vlm / audio: homogeneous scan with per-layer window
    windows = jnp.asarray(window_schedule(cfg), jnp.int32)

    def body(carry, xs):
        bp, w = xs
        h, _, aux = _apply_attn_block(cfg, bp, _fence(carry), positions, w, causal=causal)
        return h, aux

    body = jax.checkpoint(body) if remat else body
    x, auxs = jax.lax.scan(body, x, (params["blocks"], windows))
    return x, auxs.sum()


def head_weights(cfg: ModelConfig, params):
    if "head" in params:
        return params["head"]
    return params["embed"].T


def chunked_cross_entropy(cfg, params, x, labels, *, chunk: int = 512, label_offset: int = 0):
    """Mean CE over positions without materializing (B, S, V) logits."""
    if label_offset:
        x = x[:, label_offset:]
    b, s, d = x.shape
    w = head_weights(cfg, params).astype(COMPUTE_DTYPE)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    from repro.sharding.ops import constrain

    def one(carry, xs):
        xi, li = xs
        logits = constrain((xi @ w).astype(jnp.float32), "batch", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        valid = li >= 0
        loss = jnp.where(valid, logz - gold, 0.0)
        return (carry[0] + loss.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(one), (jnp.zeros(()), jnp.zeros((), jnp.int32)), (xc, lc))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01):
    x, label_offset = embed_inputs(cfg, params, batch)
    x, aux = backbone(cfg, params, x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    ce = chunked_cross_entropy(cfg, params, x, batch["labels"], label_offset=label_offset)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def forward_logits(cfg: ModelConfig, params, batch):
    """Full logits (smoke tests / small models only)."""
    x, label_offset = embed_inputs(cfg, params, batch)
    x, _ = backbone(cfg, params, x, remat=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# serving: prefill + decode with caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=COMPUTE_DTYPE):
    dims = _attn_dims(cfg)
    if cfg.family == "ssm":
        per = ssm_mod.ssm_init_cache(batch, cfg.d_model, cfg.ssm_expand, cfg.ssm_state, dtype)
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), per
            ),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        per = ssm_mod.ssm_init_cache(batch, cfg.d_model, cfg.ssm_expand, cfg.ssm_state, dtype)
        n_groups = cfg.n_layers // cfg.hybrid_attn_every
        return {
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), per
            ),
            "k": jnp.zeros((n_groups, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
            "v": jnp.zeros((n_groups, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, dims.n_kv_heads, dims.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One decode step.  tokens: (B, 1) -> (last-token logits (B, V), cache)."""
    pos = cache["pos"]
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(COMPUTE_DTYPE)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))

    if cfg.family == "ssm":

        def body(x, xs):
            bp, layer_cache = xs
            h, new_c = ssm_mod.ssd_decode_step(
                bp["ssm"],
                rms_norm(x, bp["ln1"], cfg.norm_eps),
                layer_cache,
                d_model=cfg.d_model,
                expand=cfg.ssm_expand,
                state=cfg.ssm_state,
            )
            return x + h, new_c

        x, new_ssm = jax.lax.scan(body, x, (params["blocks"], cache["ssm"]))
        new_cache = {"ssm": new_ssm, "pos": pos + 1}
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        blocks = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["blocks"]
        )
        ssm_cache = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), cache["ssm"]
        )

        def body(x, xs):
            bp, layer_cache = xs
            h, new_c = ssm_mod.ssd_decode_step(
                bp["ssm"],
                rms_norm(x, bp["ln1"], cfg.norm_eps),
                layer_cache,
                d_model=cfg.d_model,
                expand=cfg.ssm_expand,
                state=cfg.ssm_state,
            )
            return x + h, new_c

        new_ssm, new_k, new_v = [], [], []
        for g in range(n_groups):
            gb = jax.tree.map(lambda a: a[g], blocks)
            gc = jax.tree.map(lambda a: a[g], ssm_cache)
            x, nc = jax.lax.scan(body, x, (gb, gc))
            new_ssm.append(nc)
            sp = _select_shared(params["shared"], g % cfg.hybrid_shared_sets)
            x, akv, _ = _apply_attn_block(
                cfg, sp, x, positions, 0,
                kv_cache={"k": cache["k"][g], "v": cache["v"][g]}, cache_pos=pos,
            )
            new_k.append(akv["k"])
            new_v.append(akv["v"])
        new_cache = {
            "ssm": jax.tree.map(
                lambda *xs: jnp.stack(xs).reshape((cfg.n_layers,) + xs[0].shape[1:]), *new_ssm
            ),
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
            "pos": pos + 1,
        }
    else:
        windows = jnp.asarray(window_schedule(cfg), jnp.int32)

        # carry the whole cache and update layer slices in place — scanning
        # the cache as xs/ys double-buffers the full (L,B,S,K,Dh) tensors
        # (gemma3 decode_32k: +8.4 GB/device of temp)
        def body(carry, xs):
            x, ck_all, cv_all = carry
            bp, w, l = xs
            layer_cache = {
                "k": jax.lax.dynamic_index_in_dim(ck_all, l, 0, keepdims=False),
                "v": jax.lax.dynamic_index_in_dim(cv_all, l, 0, keepdims=False),
            }
            h, akv, _ = _apply_attn_block(
                cfg, bp, x, positions, w, kv_cache=layer_cache, cache_pos=pos
            )
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, akv["k"], l, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, akv["v"], l, 0)
            return (h, ck_all, cv_all), None

        (x, nk, nv), _ = jax.lax.scan(
            body,
            (x, cache["k"], cache["v"]),
            (params["blocks"], windows, jnp.arange(cfg.n_layers)),
        )
        new_cache = {"k": nk, "v": nv, "pos": pos + 1}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)
    return logits, new_cache


def _ssm_block_prefill(cfg, bp, x):
    h, state = ssm_mod.ssd_forward(
        bp["ssm"],
        rms_norm(x, bp["ln1"], cfg.norm_eps),
        d_model=cfg.d_model,
        expand=cfg.ssm_expand,
        state=cfg.ssm_state,
        chunk=cfg.ssm_chunk,
        return_final_state=True,
    )
    return x + h, state


def prefill(cfg: ModelConfig, params, batch, max_seq: Optional[int] = None):
    """Prefill: forward over the prompt, return (last-token logits, cache).

    Attention families: the per-layer K/V computed during the forward pass
    become the cache (padded to ``max_seq``).  SSM/hybrid: the chunked SSD
    scan returns the final (conv, state) pair per layer, handing off exactly
    to ``ssd_decode_step``.
    """
    x, label_offset = embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    max_seq = max_seq or s
    windows = jnp.asarray(window_schedule(cfg), jnp.int32)
    dims = _attn_dims(cfg)

    def pad_cache(kv):
        if max_seq == s:
            return kv
        return jnp.pad(kv, ((0, 0), (0, max_seq - s), (0, 0), (0, 0)))

    if cfg.family == "ssm":

        def body(x, bp):
            x, state = _ssm_block_prefill(cfg, bp, _fence(x))
            return x, state

        x, states = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x[:, -1] @ head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)
        return logits, {"ssm": states, "pos": jnp.full((), s, jnp.int32)}

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every
        blocks = jax.tree.map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["blocks"]
        )

        def body(x, bp):
            x, state = _ssm_block_prefill(cfg, bp, _fence(x))
            return x, state

        ssm_states, ks, vs = [], [], []
        for g in range(n_groups):
            gb = jax.tree.map(lambda a: a[g], blocks)
            x, states = jax.lax.scan(jax.checkpoint(body), x, gb)
            ssm_states.append(states)
            sp = _select_shared(params["shared"], g % cfg.hybrid_shared_sets)
            cache0 = {
                "k": jnp.zeros((b, max_seq, dims.n_kv_heads, dims.head_dim), COMPUTE_DTYPE),
                "v": jnp.zeros((b, max_seq, dims.n_kv_heads, dims.head_dim), COMPUTE_DTYPE),
            }
            x, akv, _ = _apply_attn_block(cfg, sp, x, positions, 0, kv_cache=cache0, cache_pos=0)
            ks.append(akv["k"])
            vs.append(akv["v"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x[:, -1] @ head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)
        cache = {
            # each group's scan yields leaves (every, ...); concat -> (L, ...)
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *ssm_states),
            "k": jnp.stack(ks),
            "v": jnp.stack(vs),
            "pos": jnp.full((), s, jnp.int32),
        }
        return logits, cache

    def body(carry, xs):
        carry = _fence(carry)
        bp, w = xs
        cache0 = {
            "k": jnp.zeros((b, max_seq, dims.n_kv_heads, dims.head_dim), COMPUTE_DTYPE),
            "v": jnp.zeros((b, max_seq, dims.n_kv_heads, dims.head_dim), COMPUTE_DTYPE),
        }
        h, akv, _ = _apply_attn_block(
            cfg, bp, carry, positions, w, kv_cache=cache0, cache_pos=0,
            causal=not cfg.encoder_only,
        )
        return h, (akv["k"], akv["v"])

    x, (nk, nv) = jax.lax.scan(jax.checkpoint(body), x, (params["blocks"], windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ head_weights(cfg, params).astype(x.dtype)).astype(jnp.float32)
    return logits, {"k": nk, "v": nv, "pos": jnp.full((), s, jnp.int32)}

"""Pluggable execution backends for packed tree ensembles.

One protocol (:class:`TreeBackend`: ``predict_scores(X) -> (scores, preds)``
plus declared :class:`BackendCapabilities`) behind three implementations:

  * ``reference`` — the jitted jnp node-table walk (all three modes),
  * ``pallas``    — the VMEM-tiled TPU kernel (integer mode),
  * ``native_c``  — the paper's emitted if-else C, compiled once per model
                    into a shared library and called via ctypes.

Backends register by name; the serving stack (``TreeEngine`` /
``ModelRegistry`` / ``Gateway``) routes per-(model, mode, backend) through
:func:`create_backend` and never special-cases an implementation.  For the
deterministic modes (flint/integer) all backends are bit-identical — see
``tests/test_backends.py`` / ``make conformance``.
"""
from repro.backends.base import (
    BackendCapabilities,
    BackendUnavailable,
    TreeBackend,
    available_backends,
    backend_class,
    create_backend,
    register_backend,
)
from repro.backends.native_c import NativeCBackend, have_c_toolchain
from repro.backends.pallas import PallasBackend
from repro.backends.reference import ReferenceBackend

__all__ = [
    "BackendCapabilities",
    "BackendUnavailable",
    "NativeCBackend",
    "PallasBackend",
    "ReferenceBackend",
    "TreeBackend",
    "available_backends",
    "backend_class",
    "create_backend",
    "have_c_toolchain",
    "register_backend",
]

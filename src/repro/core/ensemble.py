"""Ensemble inference paths: float baseline, FlInt, and integer-only.

Mirrors the paper's three evaluated implementations (Sec. IV):
  * ``float``   — float32 threshold compares, float32 probability adds
                  (the "naive" Listing 4 baseline),
  * ``flint``   — int32 key compares, exact uint32 fixed-point adds, float
                  probabilities recovered by one reciprocal multiply at
                  finalize (FlInt [26] keying; see the deviation note below),
  * ``integer`` — int32 key compares, uint32 fixed-point adds (InTreeger).

Partials vs finalize (the execution-plan split): inference is factored into
*accumulation* — walk every tree, sum its leaf contribution — and *finalize* —
turn the accumulator into scores (reciprocal-multiply averaging) and argmax
predictions.  For the deterministic modes the accumulator is a uint32
fixed-point partial sum, which is associative mod 2^32: a forest can be carved
into tree-contiguous sub-forests (``ForestIR.subset``), each shard's partials
computed on a different backend or device, and the merged sum is *bit-identical*
to the single-shard walk.  ``repro.plan`` builds on exactly this property.

Deviation (documented): the paper's FlInt variant accumulates float32
probabilities.  Float addition is not associative, so float partial sums
cannot be merged across shards without rounding drift.  Our ``flint`` mode
therefore accumulates the same exact uint32 fixed-point partials as
``integer`` and recovers float probabilities with a single precomputed
reciprocal multiply in finalize — int32 compares stay FlInt's, scores stay
float, and sharded execution stays bit-exact.  The float-accumulating FlInt C
is still emitted/benchmarked by ``codegen`` (``emit_c(mode="flint")``).

On TPU the if-else cascade becomes a breadth-batched node-table walk: every
example advances one level per step via vectorized gathers; leaves self-loop.
This module is the pure-jnp reference; ``repro.kernels.tree_traverse`` is the
Pallas VMEM-tiled version of the ``integer`` path and must match it exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import fixed_to_prob, scale_for
from repro.core.flint import float_to_key
from repro.core.packing import PackedEnsemble

MODES = ("float", "flint", "integer")


def flint_recip(n_trees: int, scale: int = None) -> np.float32:
    """The precomputed reciprocal that turns a uint32 fixed-point accumulator
    into ensemble-average probabilities: ``1 / (scale * n)`` as float32.
    Computed once in float64 (codegen-time division, paper Sec. III-A)."""
    s = scale_for(n_trees) if scale is None else int(scale)
    return np.float32(1.0 / (float(s) * float(n_trees)))


def _finalize_flint(acc, n_trees, scale=None):
    """uint32 partials -> float32 probabilities via one reciprocal multiply.

    Works on numpy and jnp accumulators alike; uint32 -> float32 conversion
    is IEEE round-to-nearest in both, so the two paths are bit-identical.
    """
    return acc.astype(np.float32) * flint_recip(n_trees, scale)


@dataclass(frozen=True)
class ModeSpec:
    """Everything that distinguishes one inference mode from another.

    The traversal itself (:func:`_predict`) is mode-oblivious; a mode is just
      * ``domain_transform`` — float32 features -> the threshold-compare
        domain (identity for ``float``, FlInt int32 keys otherwise),
      * ``acc_dtype``        — the accumulator dtype (uint32 fixed-point for
        the deterministic modes, float32 for ``float``),
      * ``leaf_field``       — which quantized leaf table accumulates
        (``leaf_fixed`` for uint32 partials, ``leaf_probs`` for float),
      * ``finalize``         — the standalone ``(acc, n_trees, scale) ->
        scores`` step (reciprocal-multiply averaging for ``flint``/``float``,
        identity for ``integer``); argmax over the finalized scores yields
        predictions,
      * ``deterministic``    — True when the accumulator is an exact integer
        partial sum (flint/integer): bit-deterministic given the row's FlInt
        keys, mergeable across tree shards with zero precision loss, and what
        makes gateway caching and cross-backend bit-identity sound.
    """

    name: str
    acc_dtype: Any
    leaf_field: str
    domain_transform: Callable
    finalize: Callable
    deterministic: bool


_MODE_SPECS = {
    "float": ModeSpec(
        name="float",
        acc_dtype=jnp.float32,
        leaf_field="leaf_probs",
        domain_transform=lambda x: x,
        finalize=lambda acc, n, scale=None: acc / n,
        deterministic=False,
    ),
    "flint": ModeSpec(
        name="flint",
        acc_dtype=jnp.uint32,
        leaf_field="leaf_fixed",
        domain_transform=float_to_key,
        finalize=_finalize_flint,
        deterministic=True,
    ),
    "integer": ModeSpec(
        name="integer",
        acc_dtype=jnp.uint32,
        leaf_field="leaf_fixed",
        domain_transform=float_to_key,
        finalize=lambda acc, n, scale=None: acc,
        deterministic=True,
    ),
}


def mode_spec(mode: str) -> ModeSpec:
    try:
        return _MODE_SPECS[mode]
    except KeyError:
        raise ValueError(f"unknown mode {mode!r}; have {MODES}") from None


def finalize_partials(mode: str, acc, n_trees: int, scale: int = None):
    """The standalone finalize step over integer partials, in numpy.

    ``acc`` is the (B, C) uint32 partial accumulator of a *full* forest;
    ``n_trees``/``scale`` are the full ensemble's (a sub-forest's partials
    must be merged before finalizing — see ``repro.plan``).  Returns
    ``(scores, preds)`` with the mode's score dtype.  Every backend and every
    execution plan funnels through this one implementation, so flint/integer
    scores cannot diverge across routes by construction.
    """
    spec = mode_spec(mode)
    if not spec.deterministic:
        raise ValueError(f"mode {mode!r} has no integer partials to finalize")
    acc = np.asarray(acc)
    scores = spec.finalize(acc, n_trees, scale)
    return scores, np.argmax(scores, axis=1).astype(np.int32)


def ensemble_device_arrays(packed: PackedEnsemble, mode: str) -> dict:
    """The deployment artifact for one mode, as a dict of jnp arrays."""
    spec = mode_spec(mode)
    base = dict(
        feature=jnp.asarray(packed.feature),
        left=jnp.asarray(packed.left),
        right=jnp.asarray(packed.right),
    )
    if mode == "float":
        base["threshold"] = jnp.asarray(packed.threshold)
    else:
        base["threshold"] = jnp.asarray(packed.threshold_key)
    base["leaf"] = jnp.asarray(getattr(packed, spec.leaf_field))
    return base


def _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth: int):
    """Walk one tree for a batch.  ``x``: (B, F) in the same domain as thr."""
    b = x.shape[0]
    node0 = jnp.zeros(b, jnp.int32)

    def body(_, node):
        feat = feature_t[node]  # (B,) gather
        thr = thr_t[node]
        xv = jnp.take_along_axis(x, jnp.clip(feat, 0)[:, None], axis=1)[:, 0]
        go_left = xv <= thr  # paper Listing 2 semantics
        # leaves have left == right == self, so they self-loop for free
        return jnp.where(go_left, left_t[node], right_t[node])

    return jax.lax.fori_loop(0, depth, body, node0)


@partial(jax.jit, static_argnames=("depth", "acc_dtype"))
def _predict(arrays, x, depth: int, acc_dtype):
    b = x.shape[0]
    c = arrays["leaf"].shape[-1]
    acc0 = jnp.zeros((b, c), acc_dtype)

    def per_tree(acc, tree):
        feature_t, thr_t, left_t, right_t, leaf_t = tree
        node = _traverse_tree(feature_t, thr_t, left_t, right_t, x, depth)
        return acc + leaf_t[node].astype(acc_dtype), None

    acc, _ = jax.lax.scan(
        per_tree,
        acc0,
        (
            arrays["feature"],
            arrays["threshold"],
            arrays["left"],
            arrays["right"],
            arrays["leaf"],
        ),
    )
    return acc


def predict_partials_mode(packed: PackedEnsemble, X, mode: str, arrays=None):
    """Accumulate only: (B, C) uint32 partials for a deterministic mode.

    This is the shard-level quantity — partials of tree-contiguous sub-forests
    sum (uint32, associative) to the full forest's partials bit-exactly.
    """
    spec = mode_spec(mode)
    if not spec.deterministic:
        raise ValueError(f"mode {mode!r} does not produce integer partials")
    if arrays is None:
        arrays = ensemble_device_arrays(packed, mode)
    dom = spec.domain_transform(jnp.asarray(X, jnp.float32))
    return _predict(arrays, dom, packed.max_depth, spec.acc_dtype)


def predict_mode(packed: PackedEnsemble, X, mode: str, arrays=None):
    """The one parametrized inference path: ``(scores, preds)`` for any mode.

    ``float``/``flint`` scores are float32 ensemble-average probabilities;
    ``integer`` scores are the raw uint32 fixed-point sums (overflow-free by
    construction: each tree contributes < scale = floor((2**32-1)/n) and
    there are n trees).
    """
    spec = mode_spec(mode)
    if arrays is None:
        arrays = ensemble_device_arrays(packed, mode)
    dom = spec.domain_transform(jnp.asarray(X, jnp.float32))
    acc = _predict(arrays, dom, packed.max_depth, spec.acc_dtype)
    scores = spec.finalize(acc, packed.n_trees, packed.scale)
    return scores, jnp.argmax(scores, axis=1).astype(jnp.int32)


def predict_float(packed: PackedEnsemble, X, arrays=None):
    """float32 path.  Returns (probs f32 (B,C), preds int32)."""
    return predict_mode(packed, X, "float", arrays)


def predict_flint(packed: PackedEnsemble, X, arrays=None):
    """FlInt-keyed path: integer compares, exact integer partials, float
    probabilities via the finalize reciprocal multiply."""
    return predict_mode(packed, X, "flint", arrays)


def predict_integer(packed: PackedEnsemble, X, arrays=None):
    """InTreeger path: integer compares + uint32 fixed-point accumulation."""
    return predict_mode(packed, X, "integer", arrays)


def integer_probs(packed: PackedEnsemble, acc):
    """Reconstruct ensemble-average probabilities from the uint32 scores."""
    return fixed_to_prob(acc, packed.n_trees)


def make_partials_fn(packed: PackedEnsemble, mode: str):
    """Close over device arrays; return a jitted ``X -> uint32 partials`` fn
    (deterministic modes only) — the backend-side half of the plan split."""
    spec = mode_spec(mode)
    if not spec.deterministic:
        raise ValueError(f"mode {mode!r} does not produce integer partials")
    arrays = ensemble_device_arrays(packed, mode)
    depth = packed.max_depth

    def fn(x):
        dom = spec.domain_transform(jnp.asarray(x, jnp.float32))
        return _predict(arrays, dom, depth, spec.acc_dtype)

    return jax.jit(fn)


def make_predict_fn(packed: PackedEnsemble, mode: str):
    """Close over device arrays; return a jitted X -> (scores, preds) fn."""
    spec = mode_spec(mode)
    arrays = ensemble_device_arrays(packed, mode)
    depth = packed.max_depth
    n = packed.n_trees
    scale = packed.scale

    def fn(x):
        dom = spec.domain_transform(jnp.asarray(x, jnp.float32))
        acc = _predict(arrays, dom, depth, spec.acc_dtype)
        scores = spec.finalize(acc, n, scale)
        return scores, jnp.argmax(scores, axis=1).astype(jnp.int32)

    return jax.jit(fn)

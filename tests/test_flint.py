"""FlInt key transform: order preservation (paper Sec. II-D / IV-C)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.flint import (
    float_to_key,
    float_to_key_np,
    key_to_float,
    key_to_float_np,
)

finite_f32 = st.floats(
    width=32, allow_nan=False, allow_infinity=False, allow_subnormal=True
)


@given(finite_f32, finite_f32)
@settings(max_examples=300)
def test_order_preserving(a, b):
    ka, kb = float_to_key_np(np.float32(a)), float_to_key_np(np.float32(b))
    if np.float32(a) < np.float32(b):
        assert ka < kb
    elif np.float32(a) > np.float32(b):
        assert ka > kb
    else:
        assert ka == kb  # includes -0.0 == +0.0


@given(finite_f32)
@settings(max_examples=300)
def test_roundtrip(a):
    a32 = np.float32(a)
    back = key_to_float_np(float_to_key_np(a32))
    # -0.0 maps through key 0 to +0.0; equality still holds
    assert back == a32


@given(st.floats(min_value=0.0, width=32, allow_nan=False, allow_infinity=False))
@settings(max_examples=200)
def test_nonnegative_keys_are_raw_bits(a):
    """For f >= 0 the key IS the IEEE-754 bit pattern — exactly the immediates
    the paper shows in Listing 2 (e.g. 87.5 -> 0x42af0000)."""
    a32 = np.float32(a)
    assert float_to_key_np(a32) == a32.view(np.int32)


def test_paper_listing2_value():
    assert int(float_to_key_np(np.float32(87.5))) == 0x42AF0000


def test_jax_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.normal(scale=100, size=4096).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(float_to_key(x)), float_to_key_np(x))
    np.testing.assert_array_equal(
        np.asarray(key_to_float(float_to_key(x))), key_to_float_np(float_to_key_np(x))
    )


def test_vector_order_random():
    rng = np.random.default_rng(1)
    x = rng.normal(scale=1e3, size=100_000).astype(np.float32)
    k = float_to_key_np(x)
    order_f = np.argsort(x, kind="stable")
    order_k = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(x[order_f], x[order_k])

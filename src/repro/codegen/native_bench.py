"""Native-C benchmarking of the emitted if-else trees — the paper's actual
experiment (Sec. IV-D): compile with -O3, run many inferences, read a
monotonic clock inside the binary.  x86 here; the paper also covers ARMv7 and
RISC-V (single-ISA container — noted in EXPERIMENTS.md)."""
from __future__ import annotations

import subprocess
import tempfile
from pathlib import Path

import numpy as np

from repro.codegen.c_emitter import emit_c
from repro.core.flint import float_to_key_np
from repro.core.packing import PackedEnsemble


def _timing_harness(packed: PackedEnsemble, n_rows: int, reps: int, mode: str) -> str:
    f = packed.n_features
    data_t = "float" if mode == "float" else "int32_t"
    return "\n".join(
        [
            "#include <stdio.h>",
            "#include <stdint.h>",
            "#include <time.h>",
            f"int predict_class(const {data_t}*);",
            "int main(void) {",
            f"  static {data_t} rows[{n_rows}][{f}];",
            f"  if (fread(rows, sizeof({data_t}), {n_rows * f}, stdin) != {n_rows * f}) return 2;",
            "  struct timespec t0, t1;",
            "  volatile long sink = 0;",
            "  clock_gettime(CLOCK_MONOTONIC, &t0);",
            f"  for (int r = 0; r < {reps}; ++r)",
            f"    for (int i = 0; i < {n_rows}; ++i) sink += predict_class(rows[i]);",
            "  clock_gettime(CLOCK_MONOTONIC, &t1);",
            "  long ns = (t1.tv_sec - t0.tv_sec) * 1000000000L + (t1.tv_nsec - t0.tv_nsec);",
            '  printf("%ld %ld\\n", ns, (long)sink);',
            "  return 0;",
            "}",
            "",
        ]
    )


def compile_and_time(packed: PackedEnsemble, X: np.ndarray, mode: str, *,
                     reps: int = 200) -> dict:
    """Returns {ns_per_row, checksum, binary_bytes} for one implementation."""
    n_rows = X.shape[0]
    src = emit_c(packed, mode=mode) + _timing_harness(packed, n_rows, reps, mode)
    if mode == "float":
        payload = X.astype("<f4").tobytes()
    else:
        payload = float_to_key_np(X.astype(np.float32)).astype("<i4").tobytes()
    with tempfile.TemporaryDirectory() as d:
        c_file = Path(d) / "m.c"
        binary = Path(d) / "m"
        c_file.write_text(src)
        subprocess.run(
            ["gcc", "-O3", "-o", str(binary), str(c_file)],
            check=True, capture_output=True,
        )
        size = binary.stat().st_size
        out = subprocess.run([str(binary)], input=payload, capture_output=True, check=True)
    ns, checksum = (int(v) for v in out.stdout.split())
    return {
        "ns_per_row": ns / (reps * n_rows),
        "checksum": checksum,
        "binary_bytes": size,
    }

"""Assigned input-shape sets and per-cell applicability (DESIGN.md Sec. 4).

LM shapes are (seq_len, global_batch).  ``decode_*``/``long_*`` lower
``serve_step`` (one token against a seq_len KV cache), not ``train_step``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}

# the paper's own architecture serves batched tabular rows
TREE_SHAPES = {
    "serve_1m": dict(rows=1_048_576, mode="trees"),
    "serve_64k": dict(rows=65_536, mode="trees"),
}


def cell_applicable(cfg: ModelConfig, shape_name: str):
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if cfg.family == "trees":
        return (shape_name in TREE_SHAPES), "tree arch uses TREE_SHAPES"
    if shape_name not in SHAPES:
        return False, f"unknown shape {shape_name}"
    mode = SHAPES[shape_name]["mode"]
    if mode == "decode" and not cfg.has_decode:
        return False, "encoder-only arch has no autoregressive step"
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""


def applicable_shapes(cfg: ModelConfig):
    src = TREE_SHAPES if cfg.family == "trees" else SHAPES
    return [s for s in src if cell_applicable(cfg, s)[0]]

"""Fixed log-scale bucket histograms for serving telemetry.

The serving metrics used to keep every latency sample in a bounded-but-large
reservoir and run ``np.percentile`` over it at read time — O(n) memory per
model and O(n log n) per stats call, and two reservoirs can't be combined
without concatenating their samples.  :class:`LogHistogram` replaces that
with exact counters over a fixed log2-spaced bucket grid:

  * **O(1) record** — one ``log2`` and one list increment per sample, no
    allocation, no lock (int increments are GIL-atomic enough for metrics;
    a torn read costs at most one sample).
  * **Bounded memory** — ``sub`` buckets per octave between ``lo`` and
    ``hi`` (defaults: 1 µs .. 1000 s in ms units, 8 per octave ≈ 9 %
    relative bucket width), plus one underflow and one overflow bucket.
  * **Mergeable** — two histograms over the same grid add counter-wise
    (:meth:`merge`), so per-shard and per-model distributions roll up into
    gateway- or fleet-level ones exactly, something percentile reservoirs
    fundamentally cannot do.
  * **Quantiles within one bucket width** — :meth:`percentile` walks the
    cumulative counts and returns the geometric midpoint of the target
    bucket, clamped to the observed [min, max]; the estimate is within half
    a bucket (≈ 4.5 % at ``sub=8``) of the true sample quantile.
"""
from __future__ import annotations

import math

__all__ = ["LogHistogram"]


class LogHistogram:
    """Exact counters over log2-spaced buckets; values are unitless (the
    serving metrics record milliseconds)."""

    __slots__ = ("lo", "hi", "sub", "counts", "count", "total",
                 "vmin", "vmax", "_log_lo", "_n")

    def __init__(self, lo: float = 1e-3, hi: float = 1e6, sub: int = 8):
        if not (0 < lo < hi) or sub < 1:
            raise ValueError(f"need 0 < lo < hi and sub >= 1, got {lo}, {hi}, {sub}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.sub = int(sub)
        self._log_lo = math.log2(lo)
        # bucket i in 1..n covers (edge(i-1), edge(i)] with
        # edge(i) = lo * 2**(i / sub); counts[0] is underflow (< lo, incl.
        # zero/negative), counts[n + 1] overflow (>= hi)
        self._n = int(math.ceil((math.log2(hi) - self._log_lo) * sub))
        self.counts = [0] * (self._n + 2)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    # ------------------------------------------------------------- recording
    def record(self, v) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v < self.lo:
            i = 0
        else:
            i = 1 + int((math.log2(v) - self._log_lo) * self.sub)
            if i > self._n:
                i = self._n + 1
        self.counts[i] += 1

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other``'s counters into this histogram (same grid only)."""
        if (self.lo, self.hi, self.sub) != (other.lo, other.hi, other.sub):
            raise ValueError(
                f"cannot merge histograms over different grids: "
                f"{(self.lo, self.hi, self.sub)} vs {(other.lo, other.hi, other.sub)}"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    # --------------------------------------------------------------- reading
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def upper_edge(self, i: int) -> float:
        """Inclusive upper bound of bucket ``i`` (1..n); underflow reports
        ``lo``, overflow ``inf``."""
        if i <= 0:
            return self.lo
        if i > self._n:
            return math.inf
        return self.lo * 2.0 ** (i / self.sub)

    def percentile(self, q: float) -> float:
        """The ``q``-th percentile, within one bucket width of the true
        sample quantile (exact when all mass sits in one bucket, because the
        estimate is clamped to the observed [min, max])."""
        if self.count == 0:
            return float("nan")
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if c and cum >= target:
                if i == 0:
                    v = self.lo
                elif i > self._n:
                    v = self.vmax
                else:
                    # geometric midpoint: halves the worst-case log error
                    v = self.lo * 2.0 ** ((i - 0.5) / self.sub)
                return float(min(max(v, self.vmin), self.vmax))
        return float(self.vmax)

    def snapshot(self) -> dict:
        """A JSON-friendly view: scalar stats + the non-empty buckets as
        ``[upper_edge, count]`` pairs (``None`` edge = overflow/+Inf) — the
        exposition layer renders Prometheus cumulative buckets from this."""
        buckets = []
        for i, c in enumerate(self.counts):
            if c:
                le = self.upper_edge(i)
                buckets.append([None if math.isinf(le) else le, c])
        return {
            "count": self.count,
            "sum": self.total,
            "mean": self.mean,
            "min": self.vmin if self.count else float("nan"),
            "max": self.vmax if self.count else float("nan"),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "buckets": buckets,
        }

    def __repr__(self) -> str:
        return (f"LogHistogram(n={self.count}, mean={self.mean:.4g}, "
                f"p50={self.percentile(50):.4g}, p99={self.percentile(99):.4g})")

"""Activation sharding constraints with logical axis names.

Model code calls ``constrain(x, "batch", None, "tp")`` — mesh-agnostic logical
names resolved against the ambient mesh (set by ``use_mesh``):

  * "batch" -> ("pod", "data") (whichever exist; divisibility-checked),
  * "tp"    -> "model",
  * "seq"   -> "data" (sequence parallelism),
  * None    -> replicated.

Outside a ``use_mesh`` context (CPU smoke tests) this is a no-op, so the same
model code runs everywhere.  GSPMD without these constraints reshards the 5-D
SSD/MoE intermediates pathologically (measured: 1.0 TB of collective-permute
per step on mamba2 train_4k — EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_ACTIVE: list = []

_LOGICAL = {
    "batch": ("pod", "data"),
    "tp": ("model",),
    "seq": ("data",),
    "expert": ("model",),
    "rows": ("pod", "data", "model"),  # tabular serving: rows over everything
}


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    _ACTIVE.append(mesh)
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE[-1] if _ACTIVE else None


def compat_shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """shard_map across jax versions: >= 0.5 exposes ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with the same
    knob named ``check_rep``.  Feature-detect instead of version-parsing."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


def constrain(x, *logical):
    """Apply with_sharding_constraint with logical names; no-op without mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = []
    for dim, item in zip(x.shape, logical):
        if item is None:
            spec.append(None)
            continue
        axes = []
        total = 1
        for ax in _LOGICAL.get(item, (item,)):
            size = mesh.shape.get(ax, 1)
            if size > 1 and dim % (total * size) == 0:
                axes.append(ax)
                total *= size
        spec.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    # trailing unlisted dims replicate
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

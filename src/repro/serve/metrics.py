"""Per-model serving metrics: throughput, latency percentiles, batch
occupancy, cache hit rate, per-shard execution timings.

Recorded by the gateway on every request/batch; surfaced as a plain stats
dict (``MetricsRegistry.stats``) and a human table (``render_table``) so the
CLI, tests, and benchmarks all read the same numbers.  Latencies are kept in
a bounded reservoir (newest-wins) so long-running gateways don't grow
without bound.  Shard timings come from the execution plan
(``TreeEngine.drain_shard_timings``): one labeled row per shard of the
active plan (e.g. ``s0:reference[0:5]``, ``fused:reference[x8]``,
``r1/4``), cumulative wall-ms and call counts — the observable that shows
whether a tree-/row-parallel plan actually balances its shards.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

_RESERVOIR = 100_000  # latency samples kept per model


@dataclass
class ModelMetrics:
    requests: int = 0
    hit_requests: int = 0  # requests served entirely from the response cache
    rows: int = 0
    rejected: int = 0
    batches: int = 0
    batched_rows: int = 0     # real rows sent through the engine
    padded_rows: int = 0      # rows after bucket padding
    cache_hits: int = 0
    cache_misses: int = 0
    latencies_ms: list = field(default_factory=list)
    # per-shard execution time: label -> [ms_total, calls]
    shard_ms: dict = field(default_factory=dict)
    t_first: float = 0.0
    t_last: float = 0.0

    def record_request(self, n_rows: int, latency_ms: float) -> None:
        now = time.perf_counter()
        if self.requests == 0:
            self.t_first = now
        self.t_last = now
        self.requests += 1
        self.rows += n_rows
        self.latencies_ms.append(latency_ms)
        if len(self.latencies_ms) > _RESERVOIR:
            del self.latencies_ms[: -_RESERVOIR // 2]

    def record_batch(self, real_rows: int, padded_rows: int) -> None:
        self.batches += 1
        self.batched_rows += real_rows
        self.padded_rows += padded_rows

    def record_cache(self, hits: int, misses: int) -> None:
        self.cache_hits += hits
        self.cache_misses += misses

    def record_shards(self, timings: dict) -> None:
        """Fold one plan drain (``{label: (ms, calls)}``) into the totals."""
        for label, (ms, calls) in timings.items():
            tot = self.shard_ms.setdefault(label, [0.0, 0])
            tot[0] += ms
            tot[1] += calls

    def stats(self) -> dict:
        lat = np.asarray(self.latencies_ms, np.float64)
        span = max(self.t_last - self.t_first, 1e-9)
        probed = self.cache_hits + self.cache_misses
        return {
            "requests": self.requests,
            # fully-cached requests: they flow through the same latency
            # histogram (a hit still costs key hashing + stitch), this just
            # makes their share observable
            "hit_requests": self.hit_requests,
            "rows": self.rows,
            "rejected": self.rejected,
            # a single request gives no usable time span; report 0, not a
            # fabricated rate
            "rows_per_s": self.rows / span if self.requests > 1 else 0.0,
            "p50_ms": float(np.percentile(lat, 50)) if lat.size else float("nan"),
            "p95_ms": float(np.percentile(lat, 95)) if lat.size else float("nan"),
            "p99_ms": float(np.percentile(lat, 99)) if lat.size else float("nan"),
            "batches": self.batches,
            # requests coalesced per engine dispatch; > 1 means batching won
            "batch_occupancy": self.batched_rows / self.batches if self.batches else 0.0,
            # real rows / padded rows: how much bucket padding cost
            "pad_efficiency": self.batched_rows / self.padded_rows if self.padded_rows else 0.0,
            "cache_hit_rate": self.cache_hits / probed if probed else 0.0,
            "cache_hits": self.cache_hits,
            # per-shard execution time of the serving plan: mean ms per call
            # exposes shard imbalance, total ms the parallel overlap
            "shards": {
                label: {
                    "ms_total": ms,
                    "calls": calls,
                    "ms_per_call": ms / calls if calls else 0.0,
                }
                for label, (ms, calls) in sorted(self.shard_ms.items())
            },
        }


class MetricsRegistry:
    def __init__(self):
        self._models: dict[str, ModelMetrics] = {}

    def model(self, model_id: str) -> ModelMetrics:
        return self._models.setdefault(model_id, ModelMetrics())

    def stats(self) -> dict:
        return {mid: m.stats() for mid, m in sorted(self._models.items())}

    def render_table(self) -> str:
        cols = ("requests", "rows", "rejected", "rows_per_s", "p50_ms", "p95_ms",
                "p99_ms", "batch_occupancy", "pad_efficiency", "cache_hit_rate")
        head = f"{'model':14s} " + " ".join(f"{c:>15s}" for c in cols)
        lines = [head, "-" * len(head)]
        for mid, s in self.stats().items():
            cells = []
            for c in cols:
                v = s[c]
                cells.append(f"{v:15.3f}" if isinstance(v, float) else f"{v:15d}")
            lines.append(f"{mid:14s} " + " ".join(cells))
        return "\n".join(lines)

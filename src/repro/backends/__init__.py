"""Pluggable execution backends for materialized tree ensembles.

One protocol (:class:`TreeBackend`: ``predict_partials(X) -> uint32
accumulators`` — the shardable half of inference — with ``predict_scores(X)
-> (scores, preds)`` as the finalize-wrapping compatibility surface, plus
declared :class:`BackendCapabilities`) behind six implementations:

  * ``reference``          — the jitted jnp node-table walk (all three modes),
  * ``pallas``             — the VMEM-tiled TPU kernel (flint + integer: one
                             integer accumulation, two finalizes),
  * ``native_c``           — the paper's emitted if-else C, compiled once per
                             model into a shared library, called via ctypes,
  * ``native_c_table``     — the ragged-layout table-walk C (data-as-arrays,
                             SIMD row-blocked), same shared-library contract,
  * ``bitvector``          — QuickScorer-style traversal-free scoring over
                             the bitvector layout, data-parallel in jnp,
  * ``native_c_bitvector`` — the same tables as emitted C, streaming each
                             feature's sorted threshold list with early exit.

Backends register by name and declare which ForestIR layouts they walk
(``supported_layouts``/``preferred_layout``); the serving stack (``TreeEngine``
/ ``ExecutionPlan`` / ``ModelRegistry`` / ``Gateway``) resolves the layout
through the IR and routes per-(model, mode, plan, backend, layout) via
:func:`create_backend`, never special-casing an implementation.  For the
deterministic modes (flint/integer) all backends are bit-identical across
all supported layouts AND all execution plans — see ``tests/test_backends.py``
/ ``tests/test_plans.py`` / ``make conformance``.
"""
from repro.backends.base import (
    BackendCapabilities,
    BackendUnavailable,
    TreeBackend,
    available_backends,
    backend_class,
    create_backend,
    register_backend,
)
from repro.backends.bitvector import BitvectorBackend
from repro.backends.native_c import CompiledCBackend, NativeCBackend, have_c_toolchain
from repro.backends.native_c_bitvector import NativeCBitvectorBackend
from repro.backends.native_c_table import NativeCTableBackend
from repro.backends.pallas import PallasBackend
from repro.backends.reference import ReferenceBackend

__all__ = [
    "BackendCapabilities",
    "BackendUnavailable",
    "BitvectorBackend",
    "CompiledCBackend",
    "NativeCBackend",
    "NativeCBitvectorBackend",
    "NativeCTableBackend",
    "PallasBackend",
    "ReferenceBackend",
    "TreeBackend",
    "available_backends",
    "backend_class",
    "create_backend",
    "have_c_toolchain",
    "register_backend",
]

"""Treelite-style JSON model exchange.

The paper's pipeline converts sklearn/XGBoost/LightGBM models into a common
Treelite representation before codegen (Sec. III-B).  This module provides
the equivalent boundary for this framework: export/import a trained forest as
a JSON document with the same information content (per-node feature,
threshold, children, leaf distribution), so externally-trained models can be
packed and served through the integer-only path.
"""
from __future__ import annotations

import json
from typing import List

import numpy as np

from repro.trees.cart import TreeArrays
from repro.trees.forest import RandomForestClassifier


def forest_to_json(forest: RandomForestClassifier) -> str:
    doc = {
        "model_type": "random_forest_classifier",
        "n_classes": forest.n_classes_,
        "n_features": forest.n_features_,
        "trees": [
            {
                "feature": t.feature.tolist(),
                "threshold": [float(x) for x in t.threshold],
                "left": t.left.tolist(),
                "right": t.right.tolist(),
                "leaf_probs": t.leaf_probs.tolist(),
                "depth": t.depth,
            }
            for t in forest.trees_
        ],
    }
    return json.dumps(doc)


def forest_from_json(payload: str) -> RandomForestClassifier:
    doc = json.loads(payload)
    assert doc["model_type"] == "random_forest_classifier"
    forest = RandomForestClassifier(n_estimators=len(doc["trees"]))
    forest.n_classes_ = int(doc["n_classes"])
    forest.n_features_ = int(doc["n_features"])
    forest.trees_ = [
        TreeArrays(
            feature=np.asarray(t["feature"], np.int32),
            threshold=np.asarray(t["threshold"], np.float32),
            left=np.asarray(t["left"], np.int32),
            right=np.asarray(t["right"], np.int32),
            leaf_probs=np.asarray(t["leaf_probs"], np.float64),
            depth=int(t["depth"]),
        )
        for t in doc["trees"]
    ]
    return forest

"""Vectorized histogram-based CART trainer (training substrate, numpy).

The paper delegates training to sklearn/XGBoost/LightGBM; none are installed
here, so the training substrate is built from scratch: a level-synchronous
histogram CART (the same algorithmic family as LightGBM/XGBoost-hist [29]).

All per-level work is vectorized:
  * features are quantile-binned once per dataset (uint8 codes),
  * per-(node, feature, bin, class) counts come from one ``np.bincount`` over a
    fused integer index,
  * best splits are chosen from cumulative histograms with Gini impurity.

Leaves store the class distribution (counts / n), matching sklearn's
``predict_proba`` semantics that the paper's pipeline consumes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class TreeArrays:
    """A trained tree as flat arrays (BFS order; node 0 is the root).

    Internal nodes: ``feature >= 0`` and the decision is
    ``x[feature] <= threshold -> left`` (paper Listing 2 semantics).
    Leaves: ``feature == -1`` and ``left == right == self`` (self-loop), with
    ``leaf_probs`` the class distribution.
    """

    feature: np.ndarray  # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray  # (n_nodes,) float32
    left: np.ndarray  # (n_nodes,) int32
    right: np.ndarray  # (n_nodes,) int32
    leaf_probs: np.ndarray  # (n_nodes, n_classes) float64 (exact counts ratio)
    depth: int

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        """Reference traversal (numpy, per-sample loopless level walk)."""
        node = np.zeros(X.shape[0], np.int32)
        for _ in range(self.depth + 1):
            feat = self.feature[node]
            is_leaf = feat < 0
            x = X[np.arange(X.shape[0]), np.clip(feat, 0, None)]
            go_left = x <= self.threshold[node]
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(is_leaf, node, nxt).astype(np.int32)
        return self.leaf_probs[node]


@dataclass
class _GrowState:
    feature: list = field(default_factory=list)
    threshold: list = field(default_factory=list)
    left: list = field(default_factory=list)
    right: list = field(default_factory=list)
    probs: list = field(default_factory=list)

    def add(self, feature=-1, threshold=0.0, probs=None) -> int:
        nid = len(self.feature)
        self.feature.append(feature)
        self.threshold.append(threshold)
        self.left.append(nid)
        self.right.append(nid)
        self.probs.append(probs)
        return nid


def _quantile_bins(X: np.ndarray, n_bins: int, rng: np.random.Generator):
    """Per-feature bin edges from quantiles; returns (codes uint8, edges list).

    ``edges[f]`` has shape (n_edges_f,) and code b means
    ``edges[f][b-1] < x <= edges[f][b]`` with code 0 the leftmost bucket.
    A split at bin b uses threshold ``edges[f][b]`` and sends codes <= b left.
    """
    n, f = X.shape
    sub = X if n <= 200_000 else X[rng.choice(n, 200_000, replace=False)]
    edges = []
    codes = np.empty((n, f), np.uint8)
    for j in range(f):
        qs = np.quantile(sub[:, j], np.linspace(0, 1, n_bins + 1)[1:-1])
        e = np.unique(qs.astype(np.float32))
        edges.append(e)
        codes[:, j] = np.searchsorted(e, X[:, j].astype(np.float32), side="left").astype(
            np.uint8
        )
    return codes, edges


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    *,
    max_depth: int = 6,
    min_samples_leaf: int = 1,
    min_samples_split: int = 2,
    max_features: Optional[int] = None,
    n_bins: int = 64,
    extra_random: bool = False,
    rng: Optional[np.random.Generator] = None,
    _binned: Optional[tuple] = None,
) -> TreeArrays:
    """Grow one CART tree level-synchronously with histogram splits."""
    rng = rng or np.random.default_rng(0)
    n, F = X.shape
    if _binned is None:
        codes, edges = _quantile_bins(X, n_bins, rng)
    else:
        codes, edges = _binned
    B = max(len(e) + 1 for e in edges) if edges else 1
    B = max(B, 2)
    y = y.astype(np.int64)
    C = n_classes

    st = _GrowState()
    root = st.add()
    sample_node = np.zeros(n, np.int32)
    # nodes still growing at current level
    frontier = {root: np.int32(root)}
    depth_of = {root: 0}
    tree_depth = 0

    for level in range(max_depth + 1):
        if not frontier:
            break
        active = sorted(frontier)
        slot_of = {nid: i for i, nid in enumerate(active)}
        S = len(active)
        # map each sample's node -> active slot (or -1 when finished)
        slot_map = np.full(len(st.feature), -1, np.int64)
        for nid, i in slot_of.items():
            slot_map[nid] = i
        sslot = slot_map[sample_node]
        live = sslot >= 0
        idx_live = np.nonzero(live)[0]
        if idx_live.size == 0:
            break
        sl = sslot[idx_live]
        yb = y[idx_live]
        cb = codes[idx_live]  # (m, F)

        # fused histogram: counts[slot, f, bin, class]
        fuse = ((sl[:, None] * F + np.arange(F)[None, :]) * B + cb.astype(np.int64)) * C + yb[
            :, None
        ]
        counts = np.bincount(fuse.ravel(), minlength=S * F * B * C).reshape(S, F, B, C)

        node_counts = counts[:, 0].sum(axis=1)  # (S, C) — same for every f
        node_total = node_counts.sum(axis=1)  # (S,)

        # candidate: split after bin b (codes <= b go left); last bin invalid
        left_counts = np.cumsum(counts, axis=2)  # (S, F, B, C)
        left_tot = left_counts.sum(axis=3)  # (S, F, B)
        right_counts = node_counts[:, None, None, :] - left_counts
        right_tot = node_total[:, None, None] - left_tot

        def gini_sum(cnt, tot):
            # tot * gini = tot - sum_c cnt_c^2 / tot  (0 when tot == 0)
            with np.errstate(divide="ignore", invalid="ignore"):
                g = tot - np.where(tot > 0, (cnt.astype(np.float64) ** 2).sum(-1) / tot, 0.0)
            return np.where(tot > 0, g, 0.0)

        impurity = gini_sum(left_counts, left_tot) + gini_sum(right_counts, right_tot)
        valid = (left_tot >= min_samples_leaf) & (right_tot >= min_samples_leaf)
        # bins past the last edge of a feature can never split
        for j in range(F):
            valid[:, j, len(edges[j]) :] = False
        if max_features is not None and max_features < F:
            # per-node random feature subset (RF-style)
            for i in range(S):
                keep = rng.choice(F, max_features, replace=False)
                mask = np.ones(F, bool)
                mask[keep] = False
                valid[i, mask, :] = False
        if extra_random:
            # ExtraTrees: one random candidate bin per (node, feature)
            keep_bin = rng.integers(0, B, size=(S, F))
            m = np.zeros_like(valid)
            m[np.arange(S)[:, None], np.arange(F)[None, :], keep_bin] = True
            valid &= m

        impurity = np.where(valid, impurity, np.inf)
        flat = impurity.reshape(S, F * B)
        best = flat.argmin(axis=1)
        best_f, best_b = best // B, best % B
        best_imp = flat[np.arange(S), best]
        parent_imp = gini_sum(node_counts, node_total)
        improves = best_imp < parent_imp - 1e-12

        # decide each active node: leaf or split
        child_assign = {}
        for i, nid in enumerate(active):
            probs = node_counts[i] / max(node_total[i], 1)
            pure = (node_counts[i] > 0).sum() <= 1
            if (
                level == max_depth
                or node_total[i] < min_samples_split
                or pure
                or not np.isfinite(best_imp[i])
                or not improves[i]
            ):
                st.feature[nid] = -1
                st.probs[nid] = probs
                continue
            f, b = int(best_f[i]), int(best_b[i])
            st.feature[nid] = f
            st.threshold[nid] = float(edges[f][b])
            lid = st.add()
            rid = st.add()
            st.left[nid], st.right[nid] = lid, rid
            depth_of[lid] = depth_of[rid] = level + 1
            tree_depth = max(tree_depth, level + 1)
            child_assign[nid] = (f, b, lid, rid)

        # route samples of split nodes to children
        new_frontier = {}
        if child_assign:
            for nid, (f, b, lid, rid) in child_assign.items():
                m = sample_node == nid
                go_left = codes[m, f] <= b
                ids = np.nonzero(m)[0]
                sample_node[ids[go_left]] = lid
                sample_node[ids[~go_left]] = rid
                new_frontier[lid] = lid
                new_frontier[rid] = rid
        frontier = new_frontier

    # finalize any frontier leftovers as leaves (shouldn't happen, guard)
    for nid in frontier:
        if st.probs[nid] is None:
            st.feature[nid] = -1
            st.probs[nid] = np.full(C, 1.0 / C)

    probs = np.stack(
        [p if p is not None else np.zeros(C) for p in st.probs]
    ).astype(np.float64)
    return TreeArrays(
        feature=np.asarray(st.feature, np.int32),
        threshold=np.asarray(st.threshold, np.float32),
        left=np.asarray(st.left, np.int32),
        right=np.asarray(st.right, np.int32),
        leaf_probs=probs,
        depth=tree_depth,
    )


# convenience alias used by forest.py
DecisionTree = TreeArrays

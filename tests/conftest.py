import os
import shutil
import sys
import types

import numpy as np
import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py uses 512 placeholders.
# Tests that need a few devices spawn subprocesses (see test_distributed.py).

# The whole suite is host-CPU-only (accelerator paths run in interpret mode
# or on forced host devices).  On images that bundle libtpu, leaving the
# platform unpinned makes every fresh jax process — this one, the
# test_distributed subprocesses, the remote shard workers — probe the cloud
# metadata service for a TPU, which stalls for minutes when that endpoint
# blackholes instead of refusing.  Pin before anything imports jax; spawned
# children inherit it.  setdefault so a caller pinning a real platform wins.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# ---------------------------------------------------------------------------
# hypothesis fallback shim: the property tests import `given`/`settings`/
# `strategies` at module scope, so a missing hypothesis breaks *collection*
# of four whole modules.  When it is absent, install a stub whose `given`
# marks the test skipped; all non-property tests in those modules still run.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        """Inert stand-in: any strategy combinator returns another stub."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def _settings(*a, **k):
        if a and callable(a[0]):  # bare @settings usage
            return a[0]
        return lambda fn: fn

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ---------------------------------------------------------------------------
# shared `requires_gcc` marker: codegen / native-backend tests need a C
# toolchain; on toolchain-less hosts they must *skip*, not error.  Usage:
#     @pytest.mark.requires_gcc
# ---------------------------------------------------------------------------

def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "requires_gcc: test compiles emitted C; skipped when gcc is absent",
    )
    config.addinivalue_line(
        "markers",
        "slow: long end-to-end runs (training drivers, Poisson gateway "
        'workloads); CI deselects them with -m "not slow", `make check` '
        "still runs everything",
    )


def pytest_collection_modifyitems(config, items):
    if shutil.which("gcc") is not None:
        return
    skip_gcc = pytest.mark.skip(reason="gcc not available")
    for item in items:
        if "requires_gcc" in item.keywords:
            item.add_marker(skip_gcc)


@pytest.fixture(scope="session")
def shuttle_small():
    from repro.data.tabular import make_shuttle_like, train_test_split

    X, y = make_shuttle_like(n=4000, seed=7)
    return train_test_split(X, y, seed=7)


@pytest.fixture(scope="session")
def small_forest(shuttle_small):
    from repro.trees.forest import RandomForestClassifier

    Xtr, ytr, _, _ = shuttle_small
    return RandomForestClassifier(n_estimators=9, max_depth=6, seed=1).fit(Xtr, ytr)


@pytest.fixture(scope="session")
def small_packed(small_forest):
    from repro.core.packing import pack_forest

    return pack_forest(small_forest)

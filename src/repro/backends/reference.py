"""ReferenceBackend: the pure-jnp breadth-batched node-table walk.

This is the semantic oracle: one jitted accumulate per (model, mode), built
from the shared mode spec in ``repro.core.ensemble``.  Every other backend's
flint/integer output is defined as "bit-identical to this".  Deterministic
modes run through the partials/finalize split (jitted uint32 accumulation,
shared numpy finalize); the float mode keeps its fused jitted predict.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.backends.base import BackendCapabilities, TreeBackend, register_backend
from repro.core.ensemble import MODES, make_partials_fn, make_predict_fn
from repro.core.packing import PackedEnsemble


@register_backend
class ReferenceBackend(TreeBackend):
    name = "reference"
    capabilities = BackendCapabilities(
        modes=MODES,
        deterministic_modes=("flint", "integer"),
        preferred_block_rows=None,  # any padded shape is fine
        compiles_per_shape=True,
        # the jnp walk gathers by node index over (T, N) tables, so any
        # node-table layout works; node order cannot perturb scores.
        # packed_leaf is served by decoding its exact group-quantized leaf
        # payload into dense tables at construction (deterministic modes
        # only — the packed payload is fixed-point)
        supported_layouts=("padded", "leaf_major", "packed_leaf"),
        preferred_layout="padded",
    )

    def __init__(self, packed: PackedEnsemble, mode: str = "integer"):
        super().__init__(packed, mode)
        walk = packed
        if getattr(packed, "layout", "padded") == "packed_leaf":
            if not self.deterministic:
                raise ValueError(
                    "layout 'packed_leaf' stores fixed-point leaves only; "
                    "serve it in a deterministic mode (flint/integer)"
                )
            walk = packed.decoded_tables()
        if self.deterministic:
            self._partials_fn = make_partials_fn(walk, mode)
        else:
            self._fn = make_predict_fn(walk, mode)

    def predict_partials(self, X):
        if not self.deterministic:
            return super().predict_partials(X)  # raises with the shared message
        return np.asarray(self._partials_fn(jnp.asarray(X, jnp.float32)))

    def predict_scores(self, X):
        if self.deterministic:
            return super().predict_scores(X)  # finalize(partials)
        return self._fn(jnp.asarray(X, jnp.float32))

"""Paper-faithful C code generation: integer-only if-else trees.

This reproduces InTreeger's literal deliverable (Sec. III-B): a standalone,
freestanding-C, architecture-agnostic if-else implementation of the trained
ensemble where

  * branch thresholds are FlInt int32 immediates (``data`` is the feature
    vector reinterpreted as int32 keys, cf. paper Listing 2),
  * leaf probabilities are uint32 fixed-point immediates at scale
    ``floor((2**32-1)/n_trees)`` (Sec. III-A),

plus the float baseline (paper Listing 4 flavor) for comparison.  The emitted
file needs only <stdint.h> — no libm, no FPU.
"""
from __future__ import annotations

import numpy as np

from repro.core.packing import PackedEnsemble


def _c_float(v: float) -> str:
    s = f"{float(v):.9g}"
    if "." not in s and "e" not in s and "inf" not in s and "nan" not in s:
        s += ".0"
    return s + "f"


# indentation is capped so pathologically deep trees (depth in the thousands)
# don't blow the emitted file up with megabytes of leading spaces
_MAX_INDENT = 64


def _emit_node(lines, packed, t, node, indent, mode):
    """Emit the if-else cascade for one tree, iteratively.

    The recursive formulation nests two Python calls per tree level, so any
    tree deeper than ~¼ of ``sys.getrecursionlimit()`` would crash codegen.
    An explicit work stack makes emission depth-independent; items are either
    a node to expand or a literal line (the ``} else {`` / ``}`` scaffolding),
    pushed in reverse so they pop in source order.
    """
    stack = [("node", node, indent)]
    while stack:
        kind, payload, ind = stack.pop()
        pad = "  " * min(ind, _MAX_INDENT)
        if kind == "line":
            lines.append(f"{pad}{payload}")
            continue
        feat = int(packed.feature[t, payload])
        if feat < 0:  # leaf
            if mode == "integer":
                row = packed.leaf_fixed[t, payload]
                for c, v in enumerate(row):
                    if int(v):
                        lines.append(f"{pad}result[{c}] += {int(v)}u;")
            else:
                row = packed.leaf_probs[t, payload]
                for c, v in enumerate(row):
                    if float(v):
                        lines.append(f"{pad}result[{c}] += {_c_float(v)};")
            continue
        if mode in ("integer", "flint"):
            key = int(packed.threshold_key[t, payload]) & 0xFFFFFFFF
            cond = f"data[{feat}] <= (int32_t)0x{key:08x}"
        else:
            cond = f"data[{feat}] <= {_c_float(packed.threshold[t, payload])}"
        lines.append(f"{pad}if ({cond}) {{")
        stack.append(("line", "}", ind))
        stack.append(("node", int(packed.right[t, payload]), ind + 1))
        stack.append(("line", "} else {", ind))
        stack.append(("node", int(packed.left[t, payload]), ind + 1))


def emit_c(packed: PackedEnsemble, mode: str = "integer") -> str:
    """Emit a standalone C file for the packed ensemble.

    mode == "integer": void predict(const int32_t* data, uint32_t* result)
        ``data`` holds FlInt keys of the float features (for non-negative
        features these are the raw IEEE-754 bit patterns, exactly as in the
        paper); ``result`` accumulates fixed-point class scores.
    mode == "flint":   FlInt baseline — int32 threshold compares, float
        probability accumulation (the paper's Sec. II-D comparison point)
    mode == "float":   void predict(const float* data, float* result)
    """
    assert mode in ("integer", "flint", "float")
    c, t = packed.n_classes, packed.n_trees
    lines = ["#include <stdint.h>", ""]
    if mode == "integer":
        lines.append(
            f"/* InTreeger: integer-only if-else ensemble. trees={t} classes={c}\n"
            f"   scale = floor((2^32-1)/{t}) = {packed.scale}; scores/2^32 ~= avg prob. */"
        )
        sig = "void predict(const int32_t* data, uint32_t* result)"
    elif mode == "flint":
        lines.append(f"/* FlInt if-else ensemble: int compares, float probs. */")
        sig = "void predict(const int32_t* data, float* result)"
    else:
        lines.append(f"/* float baseline if-else ensemble. trees={t} classes={c} */")
        sig = "void predict(const float* data, float* result)"
    lines.append(sig + " {")
    for i in range(c):
        lines.append(f"  result[{i}] = 0;")
    for tree in range(t):
        lines.append(f"  /* tree {tree} */")
        _emit_node(lines, packed, tree, 0, 1, mode)
    if mode in ("float", "flint"):
        # ensemble-average by the precomputed float32 reciprocal: XLA lowers
        # the reference path's ``acc / n`` to exactly this multiply, so the
        # emitted C stays bit-identical to the reference backend's scores
        rcp = np.float32(1.0) / np.float32(t)
        for i in range(c):
            lines.append(f"  result[{i}] *= {_c_float(rcp)};")
    lines.append("}")
    lines.append("")
    ty = "uint32_t" if mode == "integer" else "float"
    data_t = "float" if mode == "float" else "int32_t"
    lines += emit_predict_class(c, ty, data_t)
    return "\n".join(lines)


def emit_predict_class(n_classes: int, acc_t: str, data_t: str) -> list:
    """The argmax helper shared by every C emitter (comparisons only).

    Cross-backend prediction bit-identity depends on the tie-breaking rule
    (strict ``>``: first maximum wins, matching ``jnp.argmax``) being the
    SAME in every emitted artifact — keep this the single source of it.
    """
    return [
        f"int predict_class(const {data_t}* data) {{",
        f"  {acc_t} result[{n_classes}];",
        "  predict(data, result);",
        "  int best = 0;",
        f"  for (int i = 1; i < {n_classes}; ++i)"
        " if (result[i] > result[best]) best = i;",
        "  return best;",
        "}",
        "",
    ]


def emit_test_harness(packed: PackedEnsemble, n_samples: int,
                      mode: str = "integer") -> str:
    """A main() that reads raw feature rows from stdin and prints argmax —
    used by tests to diff gcc-compiled output against the JAX paths.

    ``mode == "float"`` reads float32 rows; flint/integer read the FlInt
    int32 keys, matching the ``predict_class`` prototype :func:`emit_c`
    produced for that mode.
    """
    assert mode in ("integer", "flint", "float")
    f = packed.n_features
    data_t = "float" if mode == "float" else "int32_t"
    return "\n".join(
        [
            "#include <stdio.h>",
            "#include <stdint.h>",
            f"int predict_class(const {data_t}* data);",
            "int main(void) {",
            f"  static {data_t} row[{f}];",
            f"  for (int s = 0; s < {n_samples}; ++s) {{",
            f"    fread(row, sizeof({data_t}), {f}, stdin);",
            '    printf("%d\\n", predict_class(row));',
            "  }",
            "  return 0;",
            "}",
            "",
        ]
    )


def emit_batch_entry(packed: PackedEnsemble, mode: str = "integer") -> str:
    """A batched entry point for shared-library serving (``NativeCBackend``).

    ``predict_batch(data, n_rows, scores, preds)`` runs the single-row
    ``predict`` over ``n_rows`` contiguous rows, filling a (n_rows, C) score
    matrix and an argmax vector — the C-side mirror of the JAX backends'
    ``predict_scores`` contract, callable from ctypes with any row count.
    """
    assert mode in ("integer", "flint", "float")
    f, c = packed.n_features, packed.n_classes
    data_t = "float" if mode == "float" else "int32_t"
    acc_t = "uint32_t" if mode == "integer" else "float"
    return "\n".join(
        [
            f"void predict_batch(const {data_t}* data, long n_rows,",
            f"                   {acc_t}* scores, int32_t* preds) {{",
            "  for (long r = 0; r < n_rows; ++r) {",
            f"    const {data_t}* row = data + r * {f};",
            f"    {acc_t}* out = scores + r * {c};",
            "    predict(row, out);",
            "    int best = 0;",
            f"    for (int i = 1; i < {c}; ++i) if (out[i] > out[best]) best = i;",
            "    preds[r] = best;",
            "  }",
            "}",
            "",
        ]
    )

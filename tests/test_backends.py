"""Cross-backend conformance: the backend layer's anchor suite.

InTreeger's claim — one trained ensemble, bit-identical integer-only
inference on any hardware — becomes testable through the TreeBackend
protocol: for the deterministic modes (flint/integer), every registered
backend must produce *bit-identical* scores and predictions on randomized
forests.  Plus: registry lookup/error behavior, capability validation,
TreeEngine bucketing edge cases, and the deep-tree C emitter guard.

Run standalone via ``make conformance``.
"""
import numpy as np
import pytest

from repro.backends import (
    BackendCapabilities,
    TreeBackend,
    available_backends,
    backend_class,
    create_backend,
)
from repro.serve.engine import TreeEngine, bucket_rows


@pytest.fixture(scope="module", params=[(3, 7, 5), (11, 16, 7)],
                ids=["t7d5", "t16d7"])
def random_case(request):
    """(packed, rows): a randomized forest + probe rows, per param seed."""
    from repro.core.packing import pack_forest
    from repro.data.tabular import make_shuttle_like, train_test_split
    from repro.trees.forest import RandomForestClassifier

    seed, n_trees, depth = request.param
    X, y = make_shuttle_like(n=3000, seed=seed)
    Xtr, ytr, Xte, _ = train_test_split(X, y, seed=seed)
    rf = RandomForestClassifier(
        n_estimators=n_trees, max_depth=depth, seed=seed
    ).fit(Xtr, ytr)
    return pack_forest(rf), Xte[:97]  # odd row count: exercises padding


def _scores(backend, rows):
    s, p = backend.predict_scores(rows)
    return np.asarray(s), np.asarray(p)


# ------------------------------------------------------------------ registry

def test_registry_has_all_three_backends():
    assert {"reference", "pallas", "native_c"} <= set(available_backends())


def test_registry_unknown_name_lists_available(small_packed):
    with pytest.raises(KeyError, match="reference"):
        backend_class("no-such-backend")
    with pytest.raises(KeyError, match="no-such-backend"):
        create_backend("no-such-backend", small_packed)


def test_backend_rejects_unsupported_mode(small_packed):
    # pallas implements only the paper's integer path
    assert backend_class("pallas").capabilities.modes == ("integer",)
    with pytest.raises(ValueError, match="pallas"):
        create_backend("pallas", small_packed, mode="float")


def test_capability_flags():
    ref = backend_class("reference").capabilities
    nat = backend_class("native_c").capabilities
    pal = backend_class("pallas").capabilities
    assert set(ref.modes) == {"float", "flint", "integer"}
    assert ref.deterministic_modes == ("flint", "integer")
    assert ref.compiles_per_shape and pal.compiles_per_shape
    assert not nat.compiles_per_shape  # the C loop takes any row count
    assert pal.preferred_block_rows == 256  # aligns buckets with kernel tiles


# --------------------------------------------------- cross-backend identity

def test_reference_vs_pallas_integer_bit_identical(random_case):
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode="integer"), rows)
    s_pal, p_pal = _scores(create_backend("pallas", packed, mode="integer"), rows)
    np.testing.assert_array_equal(s_ref, s_pal)
    np.testing.assert_array_equal(p_ref, p_pal)


@pytest.mark.requires_gcc
@pytest.mark.parametrize("mode", ["flint", "integer"])
def test_reference_vs_native_c_bit_identical(random_case, mode):
    packed, rows = random_case
    s_ref, p_ref = _scores(create_backend("reference", packed, mode=mode), rows)
    s_nat, p_nat = _scores(create_backend("native_c", packed, mode=mode), rows)
    assert s_nat.dtype == s_ref.dtype
    np.testing.assert_array_equal(s_ref, s_nat)
    np.testing.assert_array_equal(p_ref, p_nat)


@pytest.mark.requires_gcc
def test_all_backends_identical_through_engine(small_packed, shuttle_small):
    """The acceptance property, at the TreeEngine level: same model, three
    backends, bit-identical integer scores through the bucketed path."""
    _, _, Xte, _ = shuttle_small
    rows = Xte[:50]
    outs = {
        name: TreeEngine(small_packed, mode="integer", backend=name).predict_scores(rows)
        for name in ("reference", "pallas", "native_c")
    }
    s_ref, p_ref = outs["reference"]
    for name in ("pallas", "native_c"):
        np.testing.assert_array_equal(outs[name][0], s_ref)
        np.testing.assert_array_equal(outs[name][1], p_ref)


@pytest.mark.requires_gcc
def test_gateway_serves_same_model_through_every_backend(small_forest, shuttle_small):
    """Gateway/ModelRegistry route per-(model, mode, backend) and all
    deterministic-mode responses are bit-identical across backends."""
    import asyncio

    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    _, _, Xte, _ = shuttle_small
    rows = Xte[:16]
    reg = ModelRegistry()
    reg.register_forest("m", small_forest)

    results = {}
    for name in ("reference", "pallas", "native_c"):
        gw = Gateway(reg, mode="integer", backend=name, max_delay_ms=1.0)
        s, p = asyncio.run(gw.submit("m", rows))
        asyncio.run(gw.close())
        results[name] = (s, p)
    s_ref, p_ref = results["reference"]
    for name in ("pallas", "native_c"):
        np.testing.assert_array_equal(results[name][0], s_ref)
        np.testing.assert_array_equal(results[name][1], p_ref)
    # one engine per (mode, backend) route, memoized on the version
    mv = reg.get("m")
    assert mv.engine("integer", backend="pallas") is mv.engine("integer", backend="pallas")
    assert mv.engine("integer", backend="pallas") is not mv.engine("integer")


# -------------------------------------------------------- engine bucketing

def test_bucket_rows_at_and_past_the_cap():
    assert bucket_rows(4096, max_bucket=4096) == 4096
    assert bucket_rows(4097, max_bucket=4096) == 8192
    assert bucket_rows(8, max_bucket=8) == 8
    assert bucket_rows(9, max_bucket=8) == 16
    assert bucket_rows(17, max_bucket=8) == 24


class _RaisingBackend(TreeBackend):
    name = "raising-stub"
    capabilities = BackendCapabilities(
        modes=("integer",), deterministic_modes=("integer",)
    )

    def predict_scores(self, X):
        raise RuntimeError("backend exploded")


def test_failed_predict_does_not_mark_bucket_compiled(small_packed):
    eng = TreeEngine(backend=_RaisingBackend(small_packed, "integer"))
    with pytest.raises(RuntimeError, match="exploded"):
        eng.predict(np.zeros((5, small_packed.n_features), np.float32))
    assert eng.compiled_buckets == set()  # a raising predict compiled nothing


def test_warm_covers_max_bucket_multiples(small_packed, shuttle_small):
    """warm() must pre-compile the max_bucket-multiple shapes that batches
    with b >= max_bucket are padded to, not just the power-of-two buckets."""
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", max_bucket=8)
    eng.warm(20)
    assert eng.compiled_buckets == {1, 2, 4, 8, 16, 24}
    # every batch size the warm range promises is now a known bucket
    pre = set(eng.compiled_buckets)
    for b in (3, 8, 9, 20):
        eng.predict_scores(Xte[:b])
    assert eng.compiled_buckets == pre


def test_warm_covers_rounded_up_power_of_two(small_packed, shuttle_small):
    """A non-power-of-two max_rows must still warm the bucket its largest
    batches round UP to (warm(20) serves 17..20-row batches from bucket 32)."""
    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(small_packed, mode="integer", max_bucket=64)
    eng.warm(20)
    assert eng.compiled_buckets == {1, 2, 4, 8, 16, 32}
    pre = set(eng.compiled_buckets)
    eng.predict_scores(Xte[:17])
    assert eng.compiled_buckets == pre


def test_engine_skips_padding_for_shape_oblivious_backends(small_packed, shuttle_small):
    class Probe(TreeBackend):
        name = "probe"
        capabilities = BackendCapabilities(
            modes=("integer",), deterministic_modes=("integer",),
            compiles_per_shape=False,
        )
        seen = []

        def predict_scores(self, X):
            self.seen.append(X.shape[0])
            c = self.packed.n_classes
            return (np.zeros((X.shape[0], c), np.uint32),
                    np.zeros(X.shape[0], np.int32))

    _, _, Xte, _ = shuttle_small
    eng = TreeEngine(backend=Probe(small_packed, "integer"))
    eng.predict_scores(Xte[:5])
    assert eng.backend.seen == [5]  # not padded to 8
    eng.warm(64)
    assert eng.backend.seen == [5, 1]  # warm = one artifact-building call

"""packed_leaf: the group-quantized / bit-packed leaf payload layout.

The fifth registered layout.  Node structure stays CSR (same arrays as the
IR: tree-local children, per-tree offsets), but the fixed-point leaf table —
the size-dominant array on deep forests, ``n_leaves * C * 4`` bytes dense —
is stored group-quantized in the style of Jacob et al. (arXiv:1712.05877)
and distributed-llama's Q40 tensor export: the flattened leaf values are cut
into fixed-size groups, each group stores a ``uint32`` base (its minimum)
and a per-group bit width, and every value is encoded as ``value - base`` in
exactly ``width`` bits.

Unlike lossy weight quantization, the encoding here is **exact**: the width
is chosen as the bit length of the largest in-group delta, so decode
recovers every uint32 leaf bit-for-bit and flint/integer conformance is
preserved structurally, not approximately.  On top of the group codec sits
an optional dictionary stage (:func:`pack_leaf_payload`): fixed-point
leaves are ``floor(p * scale)`` and trained leaves are heavily repetitive —
a pure leaf's row is one-hot at ``scale``, impure leaves repeat the same
small-denominator count ratios — so the distinct-value table is typically
tiny and the groups pack ``log2(D)``-bit *indices* instead of ~30-bit raw
values.  The writer keeps whichever encoding is smaller per forest.

Internal-node rows of ``leaf_fixed`` are zero by IR construction, so only
actual leaf rows are encoded; decode scatters them back against the
``feature < 0`` mask.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.fixedpoint import scale_for
from repro.ir.layouts import register_layout

GROUP_SIZE = 64


# ---------------------------------------------------------------------------
# the exact group codec
# ---------------------------------------------------------------------------

def pack_groups(values: np.ndarray, group: int = GROUP_SIZE):
    """Encode a flat uint32 array into (base, bits, payload) — losslessly.

    Per group of ``group`` consecutive values: ``base`` is the group minimum,
    ``bits`` the bit length of the largest delta, and the payload packs each
    delta LSB-first in exactly ``bits`` bits (``np.packbits`` bit order
    within bytes; groups are byte-aligned so they decode independently).
    """
    values = np.ascontiguousarray(values, np.uint32).ravel()
    n = values.size
    n_groups = -(-n // group) if n else 0
    base = np.zeros(n_groups, np.uint32)
    bits = np.zeros(n_groups, np.uint8)
    chunks = []
    for g in range(n_groups):
        v = values[g * group:(g + 1) * group]
        b = v.min()
        delta = (v - b).astype(np.uint64)
        w = int(int(delta.max()).bit_length())
        base[g], bits[g] = b, w
        if w:
            lanes = ((delta[:, None] >> np.arange(w, dtype=np.uint64)) & 1)
            chunks.append(np.packbits(lanes.astype(np.uint8).ravel()))
    payload = np.concatenate(chunks) if chunks else np.zeros(0, np.uint8)
    return base, bits, payload


def unpack_groups(base: np.ndarray, bits: np.ndarray, payload: np.ndarray,
                  n_values: int, group: int = GROUP_SIZE) -> np.ndarray:
    """Exact inverse of :func:`pack_groups` -> (n_values,) uint32."""
    out = np.empty(n_values, np.uint32)
    off = 0
    for g in range(len(base)):
        count = min(group, n_values - g * group)
        w = int(bits[g])
        sl = slice(g * group, g * group + count)
        if w == 0:
            out[sl] = base[g]
            continue
        nbytes = -(-count * w // 8)
        lanes = np.unpackbits(payload[off:off + nbytes])[:count * w]
        lanes = lanes.reshape(count, w).astype(np.uint64)
        delta = (lanes << np.arange(w, dtype=np.uint64)).sum(axis=1)
        out[sl] = base[g] + delta.astype(np.uint32)
        off += nbytes
    return out


def pack_leaf_payload(values: np.ndarray, group: int = GROUP_SIZE):
    """Encode leaf values as (dictionary, base, bits, payload) — lossless.

    Two modes, whichever is smaller:

    * **dictionary** — trained leaves are heavily repetitive (a pure leaf's
      fixed row is one-hot at ``scale``; impure leaves repeat the same
      small-denominator count ratios), so the distinct-value table is tiny
      and the group codec packs *indices* at ~``log2(D)`` bits instead of
      raw ~``log2(scale)``-bit values.
    * **raw** — ``dictionary`` comes back empty and the groups pack the
      values themselves (the fallback when a forest's leaves are near-unique
      and a value table would cost more than it saves).
    """
    values = np.ascontiguousarray(values, np.uint32).ravel()
    uniq, inv = np.unique(values, return_inverse=True)
    d_base, d_bits, d_payload = pack_groups(inv.astype(np.uint32), group)
    r_base, r_bits, r_payload = pack_groups(values, group)
    dict_cost = uniq.nbytes + d_payload.nbytes
    if dict_cost < r_payload.nbytes:
        return uniq, d_base, d_bits, d_payload
    return np.zeros(0, np.uint32), r_base, r_bits, r_payload


def unpack_leaf_payload(dictionary: np.ndarray, base: np.ndarray,
                        bits: np.ndarray, payload: np.ndarray,
                        n_values: int, group: int = GROUP_SIZE) -> np.ndarray:
    """Exact inverse of :func:`pack_leaf_payload` -> (n_values,) uint32."""
    decoded = unpack_groups(base, bits, payload, n_values, group)
    if dictionary.size:
        return np.asarray(dictionary, np.uint32)[decoded]
    return decoded


# ---------------------------------------------------------------------------
# the layout artifact
# ---------------------------------------------------------------------------

@dataclass
class PackedLeafEnsemble:
    """CSR node arrays + group-quantized leaf payload.

    Node arrays mirror the IR exactly (tree-local children, leaves
    self-loop); the leaf table exists only in packed form.  Backends that
    walk node tables call :meth:`decoded_tables` to recover the dense padded
    tables — an explicit, lazy copy, which is what lets the packed artifact
    (and the mmap pages under it, when ITRF-loaded) stay shared and
    read-only.  Exposes the ``PackedEnsemble`` metadata surface so engines
    stay layout-polymorphic.
    """

    feature: np.ndarray  # (total,) int32, -1 for leaf
    threshold: np.ndarray  # (total,) float32 (reporting only)
    threshold_key: np.ndarray  # (total,) int32
    left: np.ndarray  # (total,) int32, tree-local
    right: np.ndarray  # (total,) int32, tree-local
    node_offsets: np.ndarray  # (T+1,) int64
    tree_depths: np.ndarray  # (T,) int32
    pack_dict: np.ndarray  # (D,) uint32 value table; empty = raw mode
    pack_base: np.ndarray  # (n_groups,) uint32
    pack_bits: np.ndarray  # (n_groups,) uint8
    pack_payload: np.ndarray  # (nbytes,) uint8
    n_leaf_values: int  # n_leaves * n_classes
    n_trees: int
    n_classes: int
    n_features: int
    max_depth: int
    group_size: int = GROUP_SIZE
    layout: str = "packed_leaf"
    quant_scale: int = field(default=None, repr=False)
    ir: object = field(default=None, repr=False, compare=False)
    _tables: object = field(default=None, repr=False, compare=False)

    @property
    def scale(self) -> int:
        return self.quant_scale if self.quant_scale is not None \
            else scale_for(self.n_trees)

    @property
    def total_nodes(self) -> int:
        return int(self.node_offsets[-1])

    def decode_leaf_fixed(self) -> np.ndarray:
        """The exact dense (total, C) uint32 leaf table — a fresh copy."""
        values = unpack_leaf_payload(self.pack_dict, self.pack_base,
                                     self.pack_bits, self.pack_payload,
                                     self.n_leaf_values, self.group_size)
        dense = np.zeros((self.total_nodes, self.n_classes), np.uint32)
        dense[self.feature < 0] = values.reshape(-1, self.n_classes)
        return dense

    def decoded_tables(self):
        """Dense padded node tables reconstructed *from the packed payload*
        (not from any IR back-reference), memoized.  This is the serving
        path: a backend built on packed_leaf walks exactly what the codec
        decodes, so conformance gates the codec itself."""
        if self._tables is None:
            from repro.ir.forest_ir import ForestIR

            leaf_fixed = self.decode_leaf_fixed()
            ir = ForestIR(
                feature=self.feature,
                threshold=self.threshold,
                threshold_key=self.threshold_key,
                left=self.left,
                right=self.right,
                leaf_probs=np.zeros(leaf_fixed.shape, np.float64),
                leaf_fixed=leaf_fixed,
                node_offsets=self.node_offsets,
                tree_depths=self.tree_depths,
                n_trees=self.n_trees,
                n_classes=self.n_classes,
                n_features=self.n_features,
                quant_scale=self.quant_scale,
            )
            self._tables = ir.materialize("padded")
        return self._tables

    def nbytes_integer(self) -> int:
        """Bytes of the integer-only packed-leaf deployment artifact."""
        return (
            self.feature.nbytes
            + self.threshold_key.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.node_offsets.nbytes
            + self.tree_depths.nbytes
            + self.pack_dict.nbytes
            + self.pack_base.nbytes
            + self.pack_bits.nbytes
            + self.pack_payload.nbytes
        )

    def nbytes_float(self) -> int:
        """Float deployments ship dense float32 leaves (the codec targets
        fixed-point payloads only) — reported for the size table's float
        column, not a servable artifact."""
        return (
            self.feature.nbytes
            + self.threshold.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.node_offsets.nbytes
            + self.tree_depths.nbytes
            + self.n_leaf_values * 4
        )


@register_layout("packed_leaf")
def packed_leaf_layout(ir, group: int = GROUP_SIZE) -> PackedLeafEnsemble:
    leaf_values = ir.leaf_fixed[ir.feature < 0].ravel()
    dictionary, base, bits, payload = pack_leaf_payload(leaf_values, group)
    return PackedLeafEnsemble(
        feature=ir.feature,
        threshold=ir.threshold,
        threshold_key=ir.threshold_key,
        left=ir.left,
        right=ir.right,
        node_offsets=ir.node_offsets,
        tree_depths=ir.tree_depths,
        pack_dict=dictionary,
        pack_base=base,
        pack_bits=bits,
        pack_payload=payload,
        n_leaf_values=int(leaf_values.size),
        n_trees=ir.n_trees,
        n_classes=ir.n_classes,
        n_features=ir.n_features,
        max_depth=ir.max_depth,
        group_size=group,
        quant_scale=ir.quant_scale,
        ir=ir,
    )

"""TreeParallelPlan: carve the forest into tree-contiguous shards and merge
exact integer partial sums.

The plan the paper's arithmetic earns: because every tree's contribution is a
uint32 fixed-point addend at a fixed per-ensemble scale, the ensemble sum is
associative — shard partials merge with *zero* precision loss, something a
float-accumulating ensemble cannot promise.  Two execution strategies behind
one plan:

  * **Device-parallel (fused)** — all shards on the jnp reference walk: the
    per-shard padded sub-forest tables are stacked into one ``(S, T', N)``
    array, laid over an ``S``-device mesh, and a single jitted
    ``shard_map`` call computes every shard's partials concurrently (each
    device scans only its trees) and merges them with a uint32 sum.  This is
    the path ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exercises
    in CI without real accelerators, and the scaling the ``plan_scaling``
    bench measures.
  * **Backend-parallel (threaded)** — one backend per shard, each built on
    ``ForestIR.subset``'s bit-identical sub-forest artifact, executed
    concurrently on a thread pool (jitted JAX and ctypes C both release the
    GIL) and merged on the host.  Shards may run *different* backends — a
    heterogeneous plan can put half the forest on compiled C and half on the
    Pallas kernel and still be bit-identical to single-shard execution.

Deterministic modes only: float accumulation is not associative, so a float
forest cannot be tree-sharded losslessly (use ``row_parallel``, which shards
the batch instead).
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from functools import reduce
from itertools import cycle, islice
from typing import Optional

import numpy as np

from repro.plan.base import ExecutionPlan, as_ir, build_backend, register_plan

_DEFAULT_SHARDS = 2


def thread_shard_cap() -> int:
    """The threaded path's shard ceiling: one in-flight shard per core, floor
    2.  BENCH_7 measured the cost of ignoring this — s4/s8 ran 1.4–1.8x
    *slower* than single-shard on the 1-core CI host, pure contention with no
    parallelism to buy.  The floor keeps two shards even on one core: the
    second shard overlaps the first's dispatch/merge gap (s2 measurably beat
    single there), and it preserves real multi-shard coverage everywhere.
    Fused (shard_map) plans are never capped — device counts are not core
    counts."""
    return max(os.cpu_count() or 1, 2)


def tree_ranges(n_trees: int, shards: int) -> list:
    """Contiguous, near-equal ``[start, stop)`` tree ranges, empties dropped
    (a 3-tree forest asked for 8 shards runs 3 single-tree shards)."""
    bounds = np.linspace(0, n_trees, min(int(shards), n_trees) + 1).astype(int)
    return [(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:]) if b > a]


@register_plan
class TreeParallelPlan(ExecutionPlan):
    name = "tree_parallel"
    deterministic_only = True

    def __init__(self, model, *, mode: str = "integer", backend="reference",
                 shards=None, layout: Optional[str] = None,
                 backend_kwargs: Optional[dict] = None,
                 device_parallel="auto", clamp_shards: bool = True):
        ir = as_ir(model)
        super().__init__(ir, mode=mode)
        if not self._spec.deterministic:
            raise ValueError(
                f"tree_parallel needs exact integer partials; mode {mode!r} "
                "accumulates floats — shard the batch (row_parallel) instead"
            )
        if isinstance(backend, str):
            names = [backend] * int(shards or _DEFAULT_SHARDS)
        else:  # heterogeneous: a sequence of backend names, cycled over shards
            names = list(islice(cycle(backend), int(shards or len(backend))))
        if not names:
            raise ValueError("tree_parallel needs at least one shard")
        self.ir = ir
        self.ranges = tree_ranges(ir.n_trees, len(names))
        names = names[: len(self.ranges)]
        self._names = names
        self._fused = None
        self._shard_backends: tuple = ()
        if self._can_fuse(names, layout, backend_kwargs, device_parallel):
            self._build_fused()
        else:
            if device_parallel is True:
                raise ValueError(
                    "device_parallel=True needs a homogeneous 'reference' "
                    "plan (default layout, no backend kwargs) and at least "
                    f"{len(self.ranges)} jax devices"
                )
            # oversubscription cap (threaded path only): shards beyond the
            # core budget cannot run concurrently, they just contend.  An
            # explicit heterogeneous backend mix is an explicit fan-out
            # request and is honored as asked; clamp_shards=False opts a
            # homogeneous plan out (scaling benches measure the full sweep).
            cap = thread_shard_cap()
            if clamp_shards and isinstance(backend, str) \
                    and len(self.ranges) > cap:
                self.ranges = tree_ranges(ir.n_trees, cap)
                names = names[: len(self.ranges)]
                self._names = names
            self._shard_backends = tuple(
                build_backend(name, ir.subset(a, b), mode, layout, backend_kwargs)
                for name, (a, b) in zip(names, self.ranges)
            )
        self._pool = None  # threaded path: created lazily, released by close()

    # ----------------------------------------------------------- strategies
    def _can_fuse(self, names, layout, backend_kwargs, device_parallel) -> bool:
        if not device_parallel or len(self.ranges) < 2:
            return False
        if any(n != "reference" for n in names) or backend_kwargs:
            return False
        if layout not in (None, "padded"):
            return False
        import jax

        return len(jax.devices()) >= len(self.ranges)

    def _build_fused(self) -> None:
        """Stack per-shard padded tables and jit one shard_map'd accumulate.

        Shards are padded to a common (T', N) with inert trees/nodes
        (self-looping zero-mass leaves), which contribute exactly 0 to the
        uint32 accumulator — the same trick the Pallas wrapper and the padded
        layout already rely on, so fusing cannot perturb partials.
        """
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.core.ensemble import _predict
        from repro.sharding.ops import compat_shard_map

        subs = [self.ir.subset(a, b).materialize("padded") for a, b in self.ranges]
        S = len(subs)
        C = self.ir.n_classes
        Tp = max(s.n_trees for s in subs)
        N = max(s.feature.shape[1] for s in subs)
        selfloop = np.tile(np.arange(N, dtype=np.int32), (Tp, 1))
        feats, keys, lefts, rights, leaves = [], [], [], [], []
        for s in subs:
            T0, N0 = s.feature.shape
            f = np.full((Tp, N), -1, np.int32)
            k = np.zeros((Tp, N), np.int32)
            l, r = selfloop.copy(), selfloop.copy()
            lf = np.zeros((Tp, N, C), np.uint32)
            f[:T0, :N0] = s.feature
            k[:T0, :N0] = s.threshold_key
            l[:T0, :N0] = s.left
            r[:T0, :N0] = s.right
            lf[:T0, :N0] = s.leaf_fixed
            feats.append(f); keys.append(k); lefts.append(l); rights.append(r)
            leaves.append(lf)
        stacked = tuple(jnp.asarray(np.stack(a))
                        for a in (feats, keys, lefts, rights, leaves))
        depth = int(self.ir.max_depth)
        mesh = Mesh(np.asarray(jax.devices()[:S]), ("s",))

        def shard_fn(feature, key, left, right, leaf, xk):
            # per-device view: the (1, T', N) block of this shard's trees
            arrays = dict(feature=feature[0], threshold=key[0], left=left[0],
                          right=right[0], leaf=leaf[0])
            return _predict(arrays, xk, depth, jnp.uint32)[None]

        sm = compat_shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P("s"), P("s"), P("s"), P("s"), P("s"), P()),
            out_specs=P("s"),
        )

        @jax.jit
        def fused(xk):
            # uint32 merge on device: associative, so the (S, B, C) shard
            # partials collapse to the single-shard accumulator bit-exactly
            return jnp.sum(sm(*stacked, xk), axis=0, dtype=jnp.uint32)

        self._fused = fused
        self._fused_label = f"fused:reference[x{S}]"

    # ------------------------------------------------------------ execution
    def predict_partials(self, X):
        X = np.asarray(X, np.float32)
        # capture the parent span on the dispatching thread: the shard pool
        # threads get it via submit args, not via the thread-local
        parent = self.trace_parent
        if self._fused is not None:
            from repro.core.flint import float_to_key_np

            # materialize inside the timed region: the jitted call dispatches
            # asynchronously, so timing it alone would record ~0ms.  The
            # device-side uint32 merge rides inside this span too.
            run = lambda xk: np.asarray(self._fused(xk))
            return self._timed(self._fused_label, run, float_to_key_np(X),
                               span_parent=parent)
        labels = [
            f"s{i}:{b.name}[{a}:{e}]"
            for i, (b, (a, e)) in enumerate(zip(self._shard_backends, self.ranges))
        ]
        pool = self._ensure_pool()
        futs = [
            pool.submit(self._timed, lab, b.predict_partials, X,
                        span_parent=parent)
            for lab, b in zip(labels, self._shard_backends)
        ]
        partials = [np.asarray(f.result()) for f in futs]
        # uint32 adds wrap mod 2^32 — the exact merge the IR's scale bound
        # guarantees never actually wraps for a full forest
        t0 = time.perf_counter_ns()
        merged = reduce(np.add, partials)
        t1 = time.perf_counter_ns()
        self._record_stage("merge", (t1 - t0) / 1e9)
        self._span("merge", t0, t1, parent, shards=len(partials))
        return merged

    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=len(self._shard_backends),
                thread_name_prefix="tree-shard",
            )
        return self._pool

    def close(self) -> None:
        """Drain in-flight shard dispatches and release the pool.  The plan
        stays usable — the next ``predict_partials`` lazily re-creates the
        pool — because registry-memoized engines outlive one gateway."""
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # -------------------------------------------------------------- metadata
    @property
    def fused(self) -> bool:
        """True when shards run as one shard_map'd device computation."""
        return self._fused is not None

    @property
    def backends(self) -> tuple:
        return self._shard_backends

    @property
    def packed(self):
        return self.ir

    @property
    def n_shards(self) -> int:
        return len(self.ranges)

    @property
    def compiles_per_shape(self) -> bool:
        if self._fused is not None:
            return True  # one jit compile per padded batch shape
        return super().compiles_per_shape

    @property
    def backend_name(self) -> str:
        if self._fused is not None:
            return "reference"
        return super().backend_name

    def describe(self) -> dict:
        d = super().describe()
        d.update(shards=self.n_shards, tree_ranges=self.ranges, fused=self.fused)
        return d

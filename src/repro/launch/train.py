"""Runnable training driver (CPU smoke-scale to multi-pod, same code path).

Composes the full stack: config -> mesh -> sharded params/opt state -> data
pipeline -> jitted train step (microbatching, optional integer DP reduce) ->
checkpoint manager + watchdog + restartable loop.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke \
      --steps 20 --integer-allreduce
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.fault_tolerance import RestartableLoop, StepWatchdog
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_config, smoke_config
from repro.data.tokens import pipeline_for
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as tfm
from repro.sharding import rules
from repro.sharding.ops import use_mesh
from repro.train import optimizer as opt
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    opt_cfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg)

    with mesh, use_mesh(mesh):
        params = tfm.init_params(cfg, jax.random.PRNGKey(0))
        shardings = rules.params_shardings(params, mesh)
        params = jax.tree.map(jax.device_put, params, shardings)
        opt_state = opt.init_opt_state(params)
        pipe = pipeline_for(cfg, args.batch, args.seq)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        watchdog = StepWatchdog()
        losses = []
        t_start = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            t0 = time.time()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            dt = time.time() - t0
            watchdog.observe(dt)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step:4d} loss {losses[-1]:.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f} "
                    f"dt {dt*1e3:.0f}ms",
                    flush=True,
                )
            if manager and (step + 1) % args.ckpt_every == 0:
                manager.save(step + 1, {"params": params, "opt": opt_state}, blocking=False)
        if manager:
            manager.wait()
        print(
            f"done: {args.steps} steps in {time.time()-t_start:.1f}s; "
            f"loss {losses[0]:.4f} -> {losses[-1]:.4f}; stragglers={watchdog.stragglers}"
        )
        return losses


if __name__ == "__main__":
    main()

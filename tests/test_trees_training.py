"""CART/RF training substrate correctness."""
import numpy as np
import pytest

from repro.data.tabular import make_esa_like, make_shuttle_like, train_test_split
from repro.trees.cart import train_tree
from repro.trees.forest import RandomForestClassifier


def test_single_tree_learns_axis_split():
    rng = np.random.default_rng(0)
    X = rng.uniform(-1, 1, size=(2000, 3)).astype(np.float32)
    y = (X[:, 1] > 0.25).astype(np.int64)
    tree = train_tree(X, y, 2, max_depth=3)
    preds = tree.predict_proba(X).argmax(1)
    assert (preds == y).mean() > 0.98
    assert tree.feature[0] == 1  # root splits on the informative feature
    assert abs(tree.threshold[0] - 0.25) < 0.1


def test_forest_beats_prior(shuttle_small):
    Xtr, ytr, Xte, yte = shuttle_small
    rf = RandomForestClassifier(n_estimators=10, max_depth=7, seed=0).fit(Xtr, ytr)
    acc = (rf.predict(Xte) == yte).mean()
    prior = max(np.bincount(yte)) / len(yte)
    assert acc > prior + 0.05
    assert acc > 0.9


def test_forest_probabilities_are_distributions(small_forest, shuttle_small):
    _, _, Xte, _ = shuttle_small
    probs = small_forest.predict_proba(Xte[:256])
    np.testing.assert_allclose(probs.sum(axis=1), 1.0, atol=1e-9)
    assert (probs >= 0).all()


def test_extra_trees_variant(shuttle_small):
    Xtr, ytr, Xte, yte = shuttle_small
    et = RandomForestClassifier(
        n_estimators=10, max_depth=7, seed=0, extra_random=True, bootstrap=False
    ).fit(Xtr, ytr)
    assert (et.predict(Xte) == yte).mean() > 0.85


def test_esa_like_binary():
    X, y = make_esa_like(n=8000, seed=3)
    Xtr, ytr, Xte, yte = train_test_split(X, y, seed=3)
    rf = RandomForestClassifier(n_estimators=8, max_depth=6, seed=0).fit(Xtr, ytr)
    preds = rf.predict(Xte)
    # anomalies are rare; require real recall, not majority voting
    recall = (preds[yte == 1] == 1).mean()
    assert recall > 0.5


def test_min_samples_leaf_respected():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(500, 4)).astype(np.float32)
    y = rng.integers(0, 2, 500)
    tree = train_tree(X, y, 2, max_depth=8, min_samples_leaf=20)
    # every leaf's training mass >= min_samples_leaf -> no leaf prob from
    # fewer than 20 samples => granularity of probs >= 1/500... sanity only:
    assert tree.n_nodes >= 1
    assert (tree.feature < 4).all()

"""Data pipeline determinism/sharding + serving engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import smoke_config
from repro.data.tokens import TokenPipeline, pipeline_for
from repro.models import transformer as tfm
from repro.serve.engine import LMEngine, TreeEngine


def test_pipeline_deterministic_across_restarts():
    p1 = TokenPipeline(256, 8, 32, seed=5)
    p2 = TokenPipeline(256, 8, 32, seed=5)
    for step in (0, 3, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    full = TokenPipeline(256, 8, 32, seed=5)
    shards = [TokenPipeline(256, 8, 32, seed=5, n_shards=4, shard=i) for i in range(4)]
    sizes = [s.batch_at(0)["tokens"].shape[0] for s in shards]
    assert sizes == [2, 2, 2, 2]
    # shards differ from each other
    a, b = shards[0].batch_at(0)["tokens"], shards[1].batch_at(0)["tokens"]
    assert not np.array_equal(a, b)


def test_pipeline_labels_are_shifted():
    p = TokenPipeline(256, 4, 16, seed=0)
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_pipeline_has_learnable_structure():
    """Markov blending: successor pairs appear far above chance."""
    p = TokenPipeline(512, 8, 256, seed=1)
    b = p.batch_at(0)["tokens"]
    succ = p._successor
    match = (b[:, 1:] == succ[b[:, :-1]]).mean()
    assert match > 0.3  # ~0.5 by construction; chance ~1/512


def test_lm_engine_greedy_deterministic():
    cfg = smoke_config("granite-3-2b")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = LMEngine(cfg, params, max_seq=48)
    pipe = pipeline_for(cfg, 2, 16)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items() if k != "labels"}
    out1 = np.asarray(eng.generate(batch, 8))
    out2 = np.asarray(eng.generate(batch, 8))
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)
    assert (out1 >= 0).all() and (out1 < cfg.vocab_size).all()


def test_tree_engine_all_paths_agree(small_packed, shuttle_small):
    _, _, Xte, yte = shuttle_small
    engines = {m: TreeEngine(small_packed, mode=m) for m in ("float", "flint", "integer")}
    engines["kernel"] = TreeEngine(small_packed, mode="integer", backend="pallas")
    preds = {name: e.predict(Xte[:256]) for name, e in engines.items()}
    for name in ("flint", "integer", "kernel"):
        np.testing.assert_array_equal(preds["float"], preds[name])

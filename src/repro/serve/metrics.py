"""Per-model serving metrics: throughput, latency + per-stage histograms,
batch occupancy, cache hit rate, per-shard execution timings, per-bucket
compile/warm times.

Recorded by the gateway on every request/batch; surfaced as a plain stats
dict (``MetricsRegistry.stats``), a human table (``render_table``), and the
Prometheus/JSON exposition renderers in ``repro.obs.export``.  Latencies
live in fixed log-scale bucket histograms (:class:`repro.obs.LogHistogram`):
exact counters, O(1) per record, bounded memory, p50/p95/p99 within one
bucket width of the old unbounded reservoir — and mergeable, so per-model
distributions roll up into gateway-level ones (:meth:`MetricsRegistry.
aggregate`) without keeping samples.

Stage histograms attribute where a request's time went: ``queue`` (micro-
batch wait), ``cache`` (probe), ``pad`` (bucket padding), ``shard`` (per-
shard execute), ``merge`` (partial sum), ``finalize`` (reciprocal-multiply +
argmax), ``stitch`` (response reassembly) — drained from the execution plan
after every batch (``TreeEngine.drain_stage_timings``) and surfaced as the
``*_ms`` columns.  Shard timings come per label (e.g. ``s0:reference[0:5]``,
``fused:reference[x8]``, ``r1/4``): cumulative wall-ms and call counts — the
observable that shows whether a tree-/row-parallel plan balances its shards.
``compile_ms_by_bucket`` tracks the one-time compile/warm cost of each
padded row bucket (``TreeEngine.drain_compile_timings``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.obs.histogram import LogHistogram

# stage means surfaced as first-class stats columns (and table columns)
_STAGE_COLUMNS = ("queue", "pad", "shard", "merge", "finalize")


@dataclass
class ModelMetrics:
    requests: int = 0
    hit_requests: int = 0  # requests served entirely from the response cache
    rows: int = 0
    rejected: int = 0
    batches: int = 0
    batched_rows: int = 0     # real rows sent through the engine
    padded_rows: int = 0      # rows after bucket padding
    cache_hits: int = 0
    cache_misses: int = 0
    latency: LogHistogram = field(default_factory=LogHistogram)
    # per-stage wall-ms histograms: stage name -> LogHistogram
    stages: dict = field(default_factory=dict)
    # per-shard execution time: label -> [ms_total, calls]
    shard_ms: dict = field(default_factory=dict)
    # one-time compile/warm wall-ms per padded row bucket (max wins: a
    # bucket recompiles after a hot-swap, keep the worst cold-start)
    compile_ms: dict = field(default_factory=dict)
    # SIMD ISA the serving backend dispatches to ("avx2-k8"/"neon"/"scalar"
    # for C backends; "-" before the first batch and for backends without
    # the surface) — recorded by the gateway after each dispatch
    isa: str = "-"
    # the warm-time autotuner's chosen backend config (e.g. "interleave=4");
    # "-" when the route is untuned or tuning hasn't run yet
    tuned: str = "-"
    # the canonical EngineSpec string the gateway serves this model on
    # (e.g. "integer:reference@padded+tree_parallel:2"); "-" pre-dispatch
    spec: str = "-"
    t_first: float = 0.0
    t_last: float = 0.0

    def _touch(self) -> None:
        """Extend the throughput span to now.  Called for *every* admitted or
        rejected request: a gateway under admission pressure keeps serving
        time even while shedding load, and excluding rejections from the
        span inflated ``rows_per_s`` exactly when it mattered most."""
        now = time.perf_counter()
        if self.t_first == 0.0:
            self.t_first = now
        self.t_last = now

    def record_request(self, n_rows: int, latency_ms: float) -> None:
        self._touch()
        self.requests += 1
        self.rows += n_rows
        self.latency.record(latency_ms)

    def record_rejected(self) -> None:
        self._touch()
        self.rejected += 1

    def record_batch(self, real_rows: int, padded_rows: int) -> None:
        self.batches += 1
        self.batched_rows += real_rows
        self.padded_rows += padded_rows

    def record_cache(self, hits: int, misses: int) -> None:
        self.cache_hits += hits
        self.cache_misses += misses

    def record_stage(self, stage: str, ms: float) -> None:
        """One wall-ms sample for a pipeline stage."""
        h = self.stages.get(stage)
        if h is None:
            h = self.stages.setdefault(stage, LogHistogram())
        h.record(ms)

    def record_stages(self, timings: dict) -> None:
        """Fold one drained ``{stage: (ms_total, calls)}`` batch (from
        ``TreeEngine.drain_stage_timings``) into the stage histograms —
        one mean-per-call sample per stage per drain."""
        for stage, (ms, calls) in timings.items():
            if calls:
                self.record_stage(stage, ms / calls)

    def record_shards(self, timings: dict) -> None:
        """Fold one plan drain (``{label: (ms, calls)}``) into the totals
        and the aggregate ``shard`` stage histogram."""
        for label, (ms, calls) in timings.items():
            tot = self.shard_ms.setdefault(label, [0.0, 0])
            tot[0] += ms
            tot[1] += calls
            if calls:
                self.record_stage("shard", ms / calls)

    def record_compiles(self, timings: dict) -> None:
        """Fold drained per-bucket compile/warm times (``{bucket: ms}``)."""
        for bucket, ms in timings.items():
            self.compile_ms[bucket] = max(self.compile_ms.get(bucket, 0.0), ms)

    def record_isa(self, isa) -> None:
        """Record the backend's dispatched SIMD ISA (None keeps "-")."""
        if isa:
            self.isa = str(isa)

    def record_tuned(self, config) -> None:
        """Record the engine's autotuned config string (None keeps "-")."""
        if config:
            self.tuned = str(config)

    def record_spec(self, spec) -> None:
        """Record the canonical serving-route spec string (None keeps "-")."""
        if spec:
            self.spec = str(spec)

    def _stage_mean(self, stage: str) -> float:
        h = self.stages.get(stage)
        return h.mean if h is not None and h.count else float("nan")

    def stats(self) -> dict:
        span = max(self.t_last - self.t_first, 1e-9)
        probed = self.cache_hits + self.cache_misses
        events = self.requests + self.rejected
        out = {
            "requests": self.requests,
            # fully-cached requests: they flow through the same latency
            # histogram (a hit still costs key hashing + stitch), this just
            # makes their share observable
            "hit_requests": self.hit_requests,
            "rows": self.rows,
            "rejected": self.rejected,
            # a single event gives no usable time span; report 0, not a
            # fabricated rate.  Rejections extend the span (_touch), so an
            # admission-pressured gateway reports its true serving rate.
            "rows_per_s": self.rows / span if events > 1 else 0.0,
            "p50_ms": self.latency.percentile(50),
            "p95_ms": self.latency.percentile(95),
            "p99_ms": self.latency.percentile(99),
            "batches": self.batches,
            # requests coalesced per engine dispatch; > 1 means batching won
            "batch_occupancy": self.batched_rows / self.batches if self.batches else 0.0,
            # real rows / padded rows: how much bucket padding cost
            "pad_efficiency": self.batched_rows / self.padded_rows if self.padded_rows else 0.0,
            "cache_hit_rate": self.cache_hits / probed if probed else 0.0,
            "cache_hits": self.cache_hits,
            "isa": self.isa,
            "tuned": self.tuned,
            "spec": self.spec,
            # the per-stage attribution columns: mean wall ms per stage
            # sample — where a request's latency actually went
            **{f"{stage}_ms": self._stage_mean(stage) for stage in _STAGE_COLUMNS},
            "latency": self.latency.snapshot(),
            "stages": {name: h.snapshot() for name, h in sorted(self.stages.items())},
            # keys are int row buckets plus the autotuner's "tune" entry —
            # sort on the string form so the mix stays orderable
            "compile_ms_by_bucket": dict(
                sorted(self.compile_ms.items(), key=lambda kv: str(kv[0]))
            ),
            # per-shard execution time of the serving plan: mean ms per call
            # exposes shard imbalance, total ms the parallel overlap
            "shards": {
                label: {
                    "ms_total": ms,
                    "calls": calls,
                    "ms_per_call": ms / calls if calls else 0.0,
                }
                for label, (ms, calls) in sorted(self.shard_ms.items())
            },
        }
        return out


# (header, stats key) pairs; "shards" renders the shard-label count and
# "isa" is the one string-valued cell (the C backends' dispatched SIMD ISA)
_TABLE_COLS = (
    ("requests", "requests"), ("hit_req", "hit_requests"), ("rows", "rows"),
    ("rejected", "rejected"), ("rows_per_s", "rows_per_s"),
    ("p50_ms", "p50_ms"), ("p95_ms", "p95_ms"), ("p99_ms", "p99_ms"),
    ("queue_ms", "queue_ms"), ("pad_ms", "pad_ms"), ("shard_ms", "shard_ms"),
    ("final_ms", "finalize_ms"), ("occup", "batch_occupancy"),
    ("pad_eff", "pad_efficiency"), ("hit_rate", "cache_hit_rate"),
    ("isa", "isa"), ("tuned", "tuned"), ("shards", "shards"),
    # last column on purpose: the canonical spec string is long and would
    # misalign everything to its right
    ("spec", "spec"),
)


class MetricsRegistry:
    def __init__(self):
        self._models: dict[str, ModelMetrics] = {}

    def model(self, model_id: str) -> ModelMetrics:
        return self._models.setdefault(model_id, ModelMetrics())

    def stats(self) -> dict:
        return {mid: m.stats() for mid, m in sorted(self._models.items())}

    def aggregate(self) -> dict:
        """Cross-model rollup: the latency and stage histograms of every
        model merged counter-wise (exact — the histogram property the old
        percentile reservoir could not offer)."""
        latency = LogHistogram()
        stages: dict = {}
        for m in self._models.values():
            latency.merge(m.latency)
            for name, h in m.stages.items():
                stages.setdefault(name, LogHistogram()).merge(h)
        return {
            "models": len(self._models),
            "requests": sum(m.requests for m in self._models.values()),
            "rejected": sum(m.rejected for m in self._models.values()),
            "latency": latency.snapshot(),
            "stages": {name: h.snapshot() for name, h in sorted(stages.items())},
        }

    def render_table(self) -> str:
        head = f"{'model':14s} " + " ".join(f"{h:>10s}" for h, _ in _TABLE_COLS)
        lines = [head, "-" * len(head)]
        for mid, s in self.stats().items():
            cells = []
            for _, key in _TABLE_COLS:
                v = len(s["shards"]) if key == "shards" else s[key]
                if isinstance(v, float):
                    # zero-sample stages and empty latency histograms are
                    # NaN: render an empty cell, not a bare "nan"
                    cells.append(f"{v:10.3f}" if v == v else f"{'-':>10s}")
                elif isinstance(v, str):
                    cells.append(f"{v:>10s}")
                else:
                    cells.append(f"{v:10d}")
            lines.append(f"{mid:14s} " + " ".join(cells))
        return "\n".join(lines)

"""Multi-model registry: versioned packed ensembles behind stable model ids.

Models enter through any boundary the repo supports:
  * a trained forest object (``register_forest``),
  * the Treelite-style JSON artifact (``register_json``), i.e. the
    ``trees/io`` exchange format — the path externally-trained models take, or
  * the ITRF binary artifact (``register_artifact``) — the deployment
    boundary: the file is mmap-ed read-only and the version serves zero-copy
    views over the shared pages, so load cost is O(1) in forest size and no
    JSON is parsed.  Re-registering the same (unchanged) artifact file —
    the hot-swap-back case — reuses the already-parsed IR *object*, layouts
    and all, so a swap costs microseconds.  The measured load wall-ms rides
    the compile/warm ledger as the ``"load"`` bucket of the version's first
    engine, next to the existing ``"tune"``/``"remote"`` entries.

Each ``register_*`` call creates a new immutable :class:`ModelVersion` and
atomically repoints the model id at it (hot-swap).  In-flight batches formed
against the previous version keep their reference and finish on it; new
requests route to the new version.  Engines are built lazily per (version,
mode, backend, layout) and memoized, so a registry fronts every route —
reference jnp, Pallas kernel, either compiled-C flavor, over any ForestIR
layout the backend walks — with one compile set per version.  The version's
padded tables carry the canonical IR, so every layout materializes from one
quantization.

Retention: superseded versions used to stay resident forever (engines,
compiled C libraries, tuned caches).  The registry now keeps the newest
``retain`` versions per model id (default 2: current + previous, so
in-flight batches on the just-swapped-out version still finish) and
releases anything older — :meth:`ModelVersion.release` closes and drops
every engine.  ``release(model_id, version)`` frees a retained non-current
version explicitly.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field

from repro.core.packing import PackedEnsemble, pack_forest
from repro.serve.engine import TreeEngine
from repro.trees.io import forest_from_json


def _freeze(obj):
    """Nested dict/list -> hashable tuples (the plan_kwargs memo-key leg)."""
    if isinstance(obj, dict):
        return tuple(sorted(((k, _freeze(v)) for k, v in obj.items()),
                            key=lambda kv: kv[0]))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


@dataclass
class ModelVersion:
    model_id: str
    version: int
    packed: PackedEnsemble  # or a ForestIR (register_artifact)
    source: str  # "forest" | "json" | "packed" | "artifact"
    _engines: dict = field(default_factory=dict, repr=False)
    # register_artifact's measured load wall-ms, charged once to the first
    # engine's compile ledger under the "load" bucket
    _load_ms: float = field(default=None, repr=False)
    released: bool = field(default=False, repr=False)
    # wall-ms spent constructing each route's engine (backend builds, native
    # compiles) — the cold-start cost ``describe()`` surfaces per model
    _build_ms: dict = field(default_factory=dict, repr=False)
    # measured autotune winners per (backend, layout, mode) route — written
    # by TreeEngine warm-time tuning, copied forward across hot-swaps by the
    # registry so a swapped-in version reuses the measurement
    _tuned: dict = field(default_factory=dict, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def engine(self, spec=None, *, mode: str = None, backend=None,
               layout: str = None, backend_kwargs: dict = None,
               plan: str = None, shards: int = None,
               autotune=None, plan_kwargs: dict = None) -> TreeEngine:
        """The memoized TreeEngine for one route.

        The route is an :class:`~repro.serve.spec.EngineSpec` (object, dict,
        or spec string) — ``engine("integer:bitvector+tree_parallel:4")``;
        a bare mode name (``engine("integer")``) and the loose keyword
        arguments remain as the deprecation-shimmed pre-spec API.

        Within the spec: ``layout=None`` resolves to the backend's
        ``preferred_layout`` (and memoizes under the resolved name, so a
        later explicit request for that layout reuses the same engine); a
        sequence of backend names (heterogeneous tree-parallel) memoizes
        under the tuple.  ``backend_kwargs`` only apply on the call that
        first builds the engine; later lookups for the same route return it
        as-is.  ``autotune`` arms warm-time measured tuning (memoized
        separately, so tuned and untuned routes never alias); winners land
        in this version's ``_tuned`` cache and survive hot-swaps.
        ``plan_kwargs`` carries plan deployment knobs (e.g. the remote
        plan's ``workers``) and participates in the memo key; the remote
        plan additionally receives this version's identity so its handshake
        carries the model id + version.
        """
        from repro.backends import backend_class
        from repro.plan import select_plan
        from repro.serve.spec import MODES, EngineSpec

        if isinstance(spec, str) and spec in MODES and mode is None:
            # a bare mode name is valid under both APIs: alone it is simply
            # the spec string "integer" (no deprecation); combined with loose
            # route kwargs it is the pre-spec positional call
            # engine("integer", backend=...) and goes through the shim
            loose = (backend, layout, plan, shards, backend_kwargs)
            if any(v is not None for v in loose) or autotune is not None:
                mode, spec = spec, None
        spec = EngineSpec.coerce(spec, caller="ModelVersion.engine",
                                 mode=mode, backend=backend, layout=layout,
                                 plan=plan, shards=shards,
                                 backend_kwargs=backend_kwargs,
                                 autotune=autotune)
        if isinstance(spec.backend, str):
            resolved = spec.layout or \
                backend_class(spec.backend).capabilities.preferred_layout
            backend_key = spec.backend
        else:  # heterogeneous shard spec: memoize under the name tuple
            resolved = spec.layout
            backend_key = tuple(spec.backend) \
                if isinstance(spec.backend, tuple) else spec.backend
        # memoize under the *resolved* plan so plan=None / "auto" / "single"
        # (and their equivalent shard counts) share one engine instead of
        # rebuilding — and recompiling — the same route per alias
        resolved_plan = select_plan(spec.plan, mode=spec.mode,
                                    backend=spec.backend, shards=spec.shards,
                                    model=self.packed)
        key = (spec.mode, backend_key, resolved, resolved_plan,
               None if resolved_plan == "single" else spec.shards,
               bool(spec.autotune), _freeze(plan_kwargs))
        with self._lock:
            if self.released:
                raise RuntimeError(
                    f"model {self.model_id!r} v{self.version} was released; "
                    f"route new requests through the registry's current "
                    f"version"
                )
            if key not in self._engines:
                t0 = time.perf_counter()
                pk = dict(plan_kwargs or {})
                if resolved_plan == "remote_tree_parallel":
                    # the wire handshake carries the model identity
                    pk.setdefault("model_id", self.model_id)
                    pk.setdefault("version", self.version)
                eng = TreeEngine(
                    self.packed, spec.replace(layout=resolved),
                    plan_kwargs=pk or None, tuned_store=self._tuned,
                )
                if self._load_ms is not None:
                    # the artifact load cost surfaces once, through the same
                    # ledger compile/tune/remote costs already ride
                    eng._compile_ms["load"] = self._load_ms
                    self._load_ms = None
                self._engines[key] = eng
                route = "/".join(
                    str(p) for p in (spec.mode, backend_key, resolved,
                                     resolved_plan)
                )
                self._build_ms[route] = (time.perf_counter() - t0) * 1e3
            return self._engines[key]

    def release(self) -> None:
        """Close and drop every engine this version built (thread pools,
        remote workers, native libraries become collectable).  Idempotent;
        an engine handle obtained before the release stops serving."""
        with self._lock:
            engines = list(self._engines.values())
            self._engines.clear()
            self.released = True
        for eng in engines:
            eng.close()


class ModelRegistry:
    def __init__(self, *, retain: int = 2):
        if retain < 1:
            raise ValueError("retain must keep at least the current version")
        self.retain = retain
        self._models: dict[str, ModelVersion] = {}
        self._history: dict[str, int] = {}  # model_id -> latest version number
        # model_id -> {version: ModelVersion} for the retained window
        self._versions: dict[str, dict[int, ModelVersion]] = {}
        # (realpath, mtime_ns, size) -> ForestIR: hot-swapping back to an
        # already-mapped, unchanged artifact file reuses the parsed IR and
        # its materialized layouts — the pages were never duplicated
        self._artifact_cache: dict = {}
        self._lock = threading.Lock()

    # ---------------------------------------------------------- registration
    def _install(self, model_id: str, packed, source: str) -> ModelVersion:
        with self._lock:
            version = self._history.get(model_id, 0) + 1
            mv = ModelVersion(model_id=model_id, version=version, packed=packed,
                              source=source)
            prev = self._models.get(model_id)
            if prev is not None:
                # carry measured autotune winners across the hot-swap: the
                # host didn't change, so the new version serves on the tuned
                # config immediately instead of re-measuring during warm
                mv._tuned.update(prev._tuned)
            self._history[model_id] = version
            self._models[model_id] = mv  # atomic repoint = hot-swap
            window = self._versions.setdefault(model_id, {})
            window[version] = mv
            evict = sorted(window)[:-self.retain]
            evicted = [window.pop(v) for v in evict]
        for old in evicted:  # outside the lock: close() may drain executors
            old.release()
        return mv

    def register_packed(self, model_id: str, packed: PackedEnsemble) -> ModelVersion:
        return self._install(model_id, packed, "packed")

    def register_forest(self, model_id: str, forest) -> ModelVersion:
        return self._install(model_id, pack_forest(forest), "forest")

    def register_json(self, model_id: str, payload: str) -> ModelVersion:
        """Load from the trees/io JSON artifact boundary."""
        return self._install(model_id, pack_forest(forest_from_json(payload)), "json")

    def register_artifact(self, model_id: str, path, *,
                          mmap: bool = True) -> ModelVersion:
        """Load an ITRF binary artifact — no JSON parse, no re-quantization.

        With ``mmap=True`` the version's ForestIR is zero-copy read-only
        views over the file mapping; every process registering the same file
        shares one page cache.  The measured load wall-ms lands in the first
        engine's compile ledger under ``"load"``.  If the artifact carries a
        ``tune_db`` entry for this host's ISA (see
        :func:`repro.ir.artifact.host_isa_key`), the autotune winners seed
        the version's ``_tuned`` cache, so warm-time tuning is skipped;
        entries recorded on hosts with different CPU flags are ignored.
        """
        from repro.ir.artifact import deserialize_tuned, host_isa_key, \
            read_itrf

        t0 = time.perf_counter()
        cache_key = None
        ir = None
        if mmap:
            try:
                st = os.stat(path)
                cache_key = (os.path.realpath(path), st.st_mtime_ns,
                             st.st_size)
            except OSError:
                cache_key = None
            with self._lock:
                ir = self._artifact_cache.get(cache_key)
        if ir is None:
            ir = read_itrf(path, mmap_arrays=mmap)
            if cache_key is not None:
                with self._lock:
                    self._artifact_cache[cache_key] = ir
        load_ms = (time.perf_counter() - t0) * 1e3
        mv = self._install(model_id, ir, "artifact")
        mv._load_ms = load_ms
        for route, kwargs in deserialize_tuned(
                getattr(ir, "itrf_tuned", {}).get(host_isa_key(), {})).items():
            # live measurements carried across the swap still win
            mv._tuned.setdefault(route, kwargs)
        return mv

    def export_tuned(self, model_id: str, path) -> None:
        """Persist the current version's measured autotune winners into an
        existing ITRF file's ``tune_db`` section (keyed by this host's ISA),
        so the next process to ``register_artifact`` it starts warm-tuned."""
        from repro.ir.artifact import update_tuned

        mv = self.get(model_id)
        with mv._lock:
            tuned = dict(mv._tuned)
        if tuned:
            update_tuned(path, tuned)

    def release(self, model_id: str, version: int) -> None:
        """Free a retained, non-current version explicitly (its engines
        close; compiled artifacts become collectable)."""
        with self._lock:
            if self._models.get(model_id) is not None \
                    and self._models[model_id].version == version:
                raise ValueError(
                    f"version {version} is the current version of "
                    f"{model_id!r}; register a replacement before releasing"
                )
            mv = self._versions.get(model_id, {}).pop(version, None)
        if mv is None:
            raise KeyError(f"no retained version {version} for {model_id!r}")
        mv.release()

    # ---------------------------------------------------------------- lookup
    def get(self, model_id: str) -> ModelVersion:
        try:
            return self._models[model_id]
        except KeyError:
            raise KeyError(f"unknown model id {model_id!r}; have {sorted(self._models)}")

    def version(self, model_id: str) -> int:
        return self.get(model_id).version

    def ids(self) -> list:
        return sorted(self._models)

    def describe(self) -> dict:
        out = {}
        for mid, mv in sorted(self._models.items()):
            d = {
                "version": mv.version,
                "source": mv.source,
                "n_trees": mv.packed.n_trees,
                "n_classes": mv.packed.n_classes,
                "n_features": mv.packed.n_features,
                "artifact_kb": mv.packed.nbytes_integer() / 1e3,
            }
            # bytes per layout, for the layouts serving routes have actually
            # materialized (reporting must not force builds of the others)
            from repro.ir import ForestIR

            ir = mv.packed if isinstance(mv.packed, ForestIR) \
                else getattr(mv.packed, "ir", None)
            if ir is not None:
                d["layout_kb"] = {
                    name: ir.materialize(name).nbytes_integer() / 1e3
                    for name in ir.materialized_layouts()
                }
            if mv._build_ms:
                d["engine_builds"] = dict(sorted(mv._build_ms.items()))
            out[mid] = d
        return out

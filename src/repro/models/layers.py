"""Shared transformer building blocks (pure-functional, params as dicts).

Conventions:
  * activations are bf16 in compute, params f32 (cast at use),
  * weights are dicts of jnp arrays; every leaf name is matched by
    ``repro.sharding.rules`` to a PartitionSpec,
  * attention is exact chunked ("lazy flash"): queries processed in chunks,
    scores per chunk are (q_chunk, S) — bounded memory at 32k prefill without
    an online-softmax inner loop (simpler HLO, same FLOPs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0):
    fan_in = shape[in_axis]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(jnp.float32)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh), positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)  # (Dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attn_params(key, d_model: int, dims: AttnDims):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, k, dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": dense_init(kq, (d_model, h * dh)),
        "wk": dense_init(kk, (d_model, k * dh)),
        "wv": dense_init(kv, (d_model, k * dh)),
        "wo": dense_init(ko, (h * dh, d_model)),
    }


def _chunked_softmax_attn(q, k, v, *, causal: bool, window: int, q_chunk: int,
                          q_offset=0, kv_len: Optional[int] = None):
    """Exact attention, queries chunked.  q: (B,Sq,K,G,Dh) k/v: (B,Skv,K,Dh).

    ``window`` > 0 masks keys older than ``window`` positions (sliding
    window); 0 means full attention.  ``q_offset`` is the absolute position of
    q[0] (decode with cache).  ``kv_len`` masks out cache tail beyond the
    valid length (traced scalar ok).
    """
    b, sq, kh, g, dh = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    pad = (-sq) % q_chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = q.shape[1] // q_chunk
    qc = q.reshape(b, nq, q_chunk, kh, g, dh)
    scale = 1.0 / np.sqrt(dh)
    kpos = jnp.arange(skv)

    def one_chunk(i, qi):
        # qi: (B, qc, K, G, Dh)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32), k.astype(jnp.float32))
        scores *= scale
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, skv), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if not (isinstance(window, int) and window == 0):
            # window may be a traced per-layer scalar (gemma3 local:global);
            # window == 0 means full attention.
            w = jnp.asarray(window, jnp.int32)
            mask &= (kpos[None, :] > qpos[:, None] - w) | (w == 0)
        if kv_len is not None:
            mask &= (kpos < kv_len)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)

    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(nq), qc.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(b, nq * q_chunk, kh, g, dh)
    return out[:, :sq]


def _flash_decode_partial(q, k, v, window, q_offset, kv_len, seq_axis, seq_shards,
                          head_axes=()):
    """Exact attention over a sequence-sharded KV cache (distributed flash
    decode): each shard computes unnormalized (m, l, o) over its local keys;
    a pmax/psum pair over ``seq_axis`` combines them.  q: (B,Sq,KH,G,Dh),
    k/v local: (B,S_loc,KH,Dh)."""
    b, sq, kh, g, dh = q.shape
    s_loc = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    offset = jax.lax.axis_index(seq_axis) * s_loc
    kpos = offset + jnp.arange(s_loc)
    qpos = q_offset + jnp.arange(sq)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32))
    scores *= scale
    mask = kpos[None, :] <= qpos[:, None]
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window, jnp.int32)
        mask &= (kpos[None, :] > qpos[:, None] - w) | (w == 0)
    mask &= (kpos < kv_len)[None, :]
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    # softmax is shift-invariant: the max is numerical stabilization only, so
    # stopping its gradient is exact (and pmax has no AD rule).
    m_loc = jax.lax.stop_gradient(jnp.max(scores, axis=-1))  # (B,KH,G,Sq)
    p = jnp.exp(scores - m_loc[..., None])
    p = jnp.where(jnp.isfinite(scores), p, 0.0)  # all-masked shard -> zeros
    l_loc = p.sum(axis=-1)
    o_loc = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    m = jax.lax.stop_gradient(jax.lax.pmax(m_loc, seq_axis))
    corr = jnp.where(jnp.isfinite(m_loc), jnp.exp(m_loc - m), 0.0)
    l = jax.lax.psum(l_loc * corr, seq_axis)
    o = jax.lax.psum(o_loc * corr[..., None], seq_axis)
    out = o / jnp.maximum(l[..., None], 1e-30)
    # (B,KH,G,Sq,Dh) -> (B,Sq,KH,G,Dh)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(v.dtype)


def _attn_specs(mesh, b, kh, g, skv, sq):
    """(batch_axes, kv_sharded, seq_axis) placement decisions shared with
    ``repro.sharding.rules.cache_shardings`` — keep the two in sync.

    Sequence sharding (partial-softmax combine) pays a psum of the (.., Sq,
    Dh) output per call — profitable only for decode (sq == 1, giant cache);
    for training it regressed granite-34b train_4k 24.7 -> 44.0 s of
    collective (EXPERIMENTS.md §Perf iteration log), hence the sq gate."""
    tp = mesh.shape.get("model", 1)
    batch_axes = []
    total = 1
    for a in ("pod", "data"):
        size = mesh.shape.get(a, 1)
        if size > 1 and b % (total * size) == 0:
            batch_axes.append(a)
            total *= size
    kv_sharded = tp > 1 and kh % tp == 0
    seq_axis = None
    if sq == 1:
        if not kv_sharded and tp > 1 and skv is not None and skv % tp == 0:
            seq_axis = "model"
        if skv is not None and "data" not in batch_axes and mesh.shape.get("data", 1) > 1 \
                and skv % (mesh.shape["data"] * (tp if seq_axis else 1)) == 0 and b == 1:
            # long-context decode at batch 1: shard the cache seq over data
            seq_axis = seq_axis or "data"
    return tuple(batch_axes), kv_sharded, seq_axis


def attention(params, x, dims: AttnDims, *, positions, causal=True, window=0,
              rope_theta=10000.0, q_chunk=512, kv_cache=None, cache_pos=None):
    """Full attention layer.  x: (B, S, D).

    If ``kv_cache`` is given (dict with k/v of shape (B, Smax, K, Dh)), new
    K/V are written at ``cache_pos`` and attention runs over the cache
    (decode / incremental prefill).  Returns (out, new_cache_or_None).

    Under an active mesh the score/softmax core runs inside ``shard_map``
    (batch x heads manual; partial-softmax combine when the KV sequence is
    sharded) — GSPMD replicates the chunked-attention loop state otherwise
    (measured f32 (B,S,H*Dh) all-gathers per layer, EXPERIMENTS.md §Perf).
    """
    from repro.sharding.ops import constrain, current_mesh

    b, s, _ = x.shape
    h, kh, dh = dims.n_heads, dims.n_kv_heads, dims.head_dim
    g = h // kh
    mesh = current_mesh()
    tp = mesh.shape.get("model", 1) if mesh else 1
    # Keep q in MERGED-head layout (B,S,H,Dh) as long as possible: H usually
    # divides the model axis even when kh/g individually don't, and an early
    # (kh, g) reshape forces GSPMD to all-gather the whole (B,S,H*Dh) tensor
    # (measured 103 GB/device/step on qwen3 train_4k — §Perf).
    xq_m = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    xk = (x @ params["wk"].astype(x.dtype)).reshape(b, s, kh, dh)
    xv = (x @ params["wv"].astype(x.dtype)).reshape(b, s, kh, dh)
    xq_m = constrain(xq_m, "batch", None, "tp", None)
    if kh % tp == 0:
        xk = constrain(xk, "batch", None, "tp", None)
        xv = constrain(xv, "batch", None, "tp", None)
    else:
        # kv heads not shardable: pin K/V replicated over `model` — otherwise
        # GSPMD shards head_dim and all-reduces the (B,KH,G,Sq,Skv) score
        # partials (llava prefill_32k: 3.6 TB/device/step, §Perf).
        xk = constrain(xk, "batch", None, None, None)
        xv = constrain(xv, "batch", None, None, None)
    xq_m = apply_rope(xq_m, positions, rope_theta)
    xk = apply_rope(xk, positions, rope_theta)
    merged_tp = tp > 1 and h % tp == 0 and kh % tp != 0

    new_cache = None
    if kv_cache is not None:
        xq = xq_m.reshape(b, s, kh, g, dh)
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], xk.astype(kv_cache["k"].dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], xv.astype(kv_cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = _attn_core(
            xq, ck, cv, causal=causal, window=window, q_chunk=q_chunk,
            q_offset=cache_pos, kv_len=cache_pos + s, mesh=mesh,
        )
    else:
        if merged_tp and s > 1:
            # repeat KV to one head per query head; merged heads shard cleanly
            xk = jnp.repeat(xk, g, axis=2)
            xv = jnp.repeat(xv, g, axis=2)
            xq = xq_m.reshape(b, s, h, 1, dh)
        else:
            xq = xq_m.reshape(b, s, kh, g, dh)
        out = _attn_core(xq, xk, xv, causal=causal, window=window, q_chunk=q_chunk,
                         mesh=mesh)
    out = out.reshape(b, s, h * dh)
    return out @ params["wo"].astype(x.dtype), new_cache


def _attn_core(xq, xk, xv, *, causal=True, window=0, q_chunk=512, q_offset=0,
               kv_len=None, mesh=None):
    """Dispatch: local chunked attention, or shard_map'ed (batch x heads
    manual; seq-sharded partial-softmax flash decode when applicable)."""
    from jax.sharding import PartitionSpec as P

    b, sq, kh, g, dh = xq.shape
    skv = xk.shape[1]
    if mesh is None or all(v <= 1 for v in mesh.shape.values()):
        return _chunked_softmax_attn(
            xq, xk, xv, causal=causal, window=window, q_chunk=q_chunk,
            q_offset=q_offset, kv_len=kv_len,
        )
    batch_axes, kv_sharded, seq_axis = _attn_specs(mesh, b, kh, g, skv, sq)
    tp = mesh.shape.get("model", 1)
    if sq > 1 and not kv_sharded:
        if tp > 1 and (kh * g) % tp == 0:
            # merged-head TP: kh doesn't divide the model axis but H = kh*g
            # does — repeat KV to one head per query head and shard merged
            # heads.  Removes the (B,S,H*Dh) q/k/v all-gathers GSPMD emits
            # for this layout (qwen3 train_4k: 103 GB/device/step).
            xk = jnp.repeat(xk, g, axis=2)
            xv = jnp.repeat(xv, g, axis=2)
            xq = xq.reshape(b, sq, kh * g, 1, dh)
            out = _attn_core(
                xq, xk, xv, causal=causal, window=window, q_chunk=q_chunk,
                q_offset=q_offset, kv_len=kv_len, mesh=mesh,
            )
            return out.reshape(b, sq, kh, g, dh)
        # MQA/small-GQA fallback: GSPMD with the g-dim constraint
        return _chunked_softmax_attn(
            xq, xk, xv, causal=causal, window=window, q_chunk=q_chunk,
            q_offset=q_offset, kv_len=kv_len,
        )
    bax = tuple(batch_axes) if batch_axes else None
    head_kh = "model" if kv_sharded else None
    head_g = "model" if (not kv_sharded and g % mesh.shape.get("model", 1) == 0
                         and mesh.shape.get("model", 1) > 1 and seq_axis != "model") else None
    q_spec = P(bax, None, head_kh, head_g, None)
    kv_spec = P(bax, seq_axis, head_kh, None)
    # traced scalars enter as replicated operands
    w_arr = jnp.asarray(window, jnp.int32)
    off_arr = jnp.asarray(q_offset, jnp.int32)
    len_arr = jnp.asarray(skv if kv_len is None else kv_len, jnp.int32)

    static_window = window if isinstance(window, int) else None

    if seq_axis is None:

        def body(q, k, v, w, off, klen):
            win = static_window if static_window is not None else w
            return _chunked_softmax_attn(
                q, k, v, causal=causal, window=win, q_chunk=q_chunk,
                q_offset=off, kv_len=klen,
            )

    else:
        seq_shards = mesh.shape[seq_axis]

        def body(q, k, v, w, off, klen):
            win = static_window if static_window is not None else w
            return _flash_decode_partial(q, k, v, win, off, klen, seq_axis, seq_shards)

    from repro.sharding.ops import compat_shard_map

    fn = compat_shard_map(
        body,
        mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec, P(), P(), P()),
        out_specs=q_spec,
    )
    return fn(xq, xk, xv, w_arr, off_arr, len_arr)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }


def mlp(params, x, act: str = "silu"):
    a = act_fn(act)
    gate = a(x @ params["w_gate"].astype(x.dtype))
    up = x @ params["w_up"].astype(x.dtype)
    return (gate * up) @ params["w_down"].astype(x.dtype)

"""Pallas tree-traversal kernel vs the pure-jnp oracle: shape/dtype sweeps,
both gather strategies, padding paths — bit-identical uint32 scores."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.flint import float_to_key
from repro.core.packing import pack_forest
from repro.kernels.ops import packed_predict_integer, pick_blocks, tree_predict_integer
from repro.kernels.ref import tree_predict_integer_ref
from repro.trees.forest import RandomForestClassifier


def _forest(n_trees, depth, n_features, n_classes, seed=0, n=1500):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, n_features)).astype(np.float32)
    y = rng.integers(0, n_classes, n)
    # inject signal so trees are non-trivial
    y = np.where(X[:, 0] > 0.5, (y + 1) % n_classes, y)
    rf = RandomForestClassifier(n_estimators=n_trees, max_depth=depth, seed=seed).fit(X, y)
    return pack_forest(rf), X


def _args(packed):
    return (
        jnp.asarray(packed.feature),
        jnp.asarray(packed.threshold_key),
        jnp.asarray(packed.left),
        jnp.asarray(packed.right),
        jnp.asarray(packed.leaf_fixed),
    )


@pytest.mark.parametrize("impl", ["gather", "onehot"])
@pytest.mark.parametrize(
    "n_trees,depth,n_features,n_classes",
    [(3, 3, 4, 2), (7, 5, 7, 7), (12, 6, 11, 3), (5, 4, 87, 2)],
)
def test_kernel_matches_ref_sweep(impl, n_trees, depth, n_features, n_classes):
    packed, X = _forest(n_trees, depth, n_features, n_classes)
    keys = float_to_key(jnp.asarray(X[:300]))
    feature, tkey, left, right, leaf = _args(packed)
    ref = tree_predict_integer_ref(keys, feature, tkey, left, right, leaf, packed.max_depth)
    out = tree_predict_integer(
        keys, feature, tkey, left, right, leaf,
        depth=packed.max_depth, block_b=64, impl=impl,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert out.dtype == jnp.uint32


@given(
    bb=st.sampled_from([16, 64, 128]),
    bt=st.integers(min_value=1, max_value=7),
    rows=st.integers(min_value=1, max_value=200),
)
@settings(max_examples=12, deadline=None)
def test_kernel_block_shapes_property(bb, bt, rows):
    """Any (block_b, block_t, n_rows) combination is bit-identical to ref."""
    packed, X = _forest(7, 4, 5, 3, seed=2)
    keys = float_to_key(jnp.asarray(X[:rows]))
    feature, tkey, left, right, leaf = _args(packed)
    ref = tree_predict_integer_ref(keys, feature, tkey, left, right, leaf, packed.max_depth)
    out = tree_predict_integer(
        keys, feature, tkey, left, right, leaf,
        depth=packed.max_depth, block_b=bb, block_t=min(bt, packed.n_trees),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_packed_entry_point(small_packed, shuttle_small):
    from repro.core.ensemble import predict_integer

    _, _, Xte, _ = shuttle_small
    acc_ref, pred_ref = predict_integer(small_packed, Xte[:200])
    acc_k, pred_k = packed_predict_integer(small_packed, Xte[:200], block_b=32)
    np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_ref))
    np.testing.assert_array_equal(np.asarray(pred_k), np.asarray(pred_ref))


def test_vmem_budget_picker():
    bb, bt = pick_blocks(b=4096, t=128, n=2047, f=87, c=8)
    words = bb * 87 + bt * 2047 * 4 + bt * 2047 * 8 + bb * 8
    assert words * 4 <= 8 * 1024 * 1024
    assert bb >= 1 and bt >= 1

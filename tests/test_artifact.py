"""ITRF binary artifact suite: the deployment boundary's guarantees.

Round-trip bit-identity (every IR array, dtype and value, including the
degenerate forests and a multi-word-bitvector chain), loud refusal of
newer-major artifacts (mirroring the trees/io schema gating), mmap read-only
safety, the packed-leaf group/dictionary codec's exactness at its edges,
registry retention + hot-swap page reuse, tune-DB persistence across
process-like reloads, the worker HELLO artifact-bytes fast path, and the
converter CLI.
"""
import gc
import json
import os
import weakref

import numpy as np
import pytest

from repro.ir import ForestIR
from repro.ir.artifact import (
    FLAG_FLOAT,
    FLAG_PACKED_LEAVES,
    FLAG_TUNED,
    ITRF_VERSION,
    host_isa_key,
    inspect_itrf,
    read_itrf,
    read_itrf_bytes,
    update_tuned,
    write_itrf,
)
from repro.ir.packed_leaf import (
    pack_groups,
    pack_leaf_payload,
    unpack_groups,
    unpack_leaf_payload,
)

from forest_cases import DEGENERATE_FORESTS, chain_tree, forest_from_trees

IR_ARRAYS = ("feature", "threshold", "threshold_key", "left", "right",
             "leaf_probs", "leaf_fixed", "node_offsets", "tree_depths")


def _assert_ir_equal(a: ForestIR, b: ForestIR, *, msg=""):
    for name in IR_ARRAYS:
        x, y = getattr(a, name), getattr(b, name)
        assert x.dtype == y.dtype, f"{msg}{name} dtype {x.dtype} != {y.dtype}"
        np.testing.assert_array_equal(x, y, err_msg=f"{msg}{name}")
    assert (a.n_trees, a.n_classes, a.n_features, a.quant_scale) == \
           (b.n_trees, b.n_classes, b.n_features, b.quant_scale)


@pytest.fixture(scope="module")
def trained_ir(small_forest):
    return ForestIR.from_forest(small_forest)


# ------------------------------------------------------------- round trips

@pytest.mark.parametrize("mmap_arrays", [True, False], ids=["mmap", "eager"])
@pytest.mark.parametrize("kwargs", [
    {},
    {"include_float": False},
    {"pack_leaves": True},
    {"include_float": False, "pack_leaves": True},
], ids=["full", "stripped", "packed", "stripped+packed"])
def test_round_trip_trained(trained_ir, tmp_path, kwargs, mmap_arrays):
    path = tmp_path / "m.itrf"
    info = trained_ir.to_itrf(str(path), **kwargs)
    assert info["file_bytes"] == os.path.getsize(path)
    out = ForestIR.from_itrf(str(path), mmap=mmap_arrays)
    if kwargs.get("include_float", True):
        _assert_ir_equal(trained_ir, out)
    else:
        # deterministic-only artifact: float tables load as zeros, every
        # integer-side array still round-trips exactly
        for name in IR_ARRAYS:
            if name in ("threshold", "leaf_probs"):
                assert not np.asarray(getattr(out, name)).any()
            else:
                np.testing.assert_array_equal(getattr(trained_ir, name),
                                              getattr(out, name),
                                              err_msg=name)
    assert out.itrf_version == ITRF_VERSION
    assert bool(out.itrf_flags & FLAG_PACKED_LEAVES) == \
           bool(kwargs.get("pack_leaves"))


@pytest.mark.parametrize("case", sorted(DEGENERATE_FORESTS))
@pytest.mark.parametrize("pack_leaves", [False, True], ids=["raw", "packed"])
def test_round_trip_degenerate(case, pack_leaves, tmp_path):
    """Stumps (T trees of one node), T == 1, and depth-skewed forests
    survive the binary boundary bit-for-bit."""
    ir = ForestIR.from_forest(DEGENERATE_FORESTS[case]())
    path = tmp_path / f"{case}.itrf"
    ir.to_itrf(str(path), pack_leaves=pack_leaves)
    _assert_ir_equal(ir, ForestIR.from_itrf(str(path)), msg=f"{case}: ")


def test_round_trip_multiword_bitvector_chain(tmp_path):
    """A depth-70 chain yields > 64 leaves per tree, so the bitvector layout
    needs multiple mask words; the artifact round trip must preserve the
    bit-identical serve through that layout too."""
    from repro.serve.engine import TreeEngine

    ir = ForestIR.from_forest(
        forest_from_trees([chain_tree(70, 3)], 3, 4))
    path = tmp_path / "chain.itrf"
    ir.to_itrf(str(path), pack_leaves=True)
    out = ForestIR.from_itrf(str(path))
    _assert_ir_equal(ir, out)
    assert out.materialize("bitvector").words > 1
    rows = np.random.default_rng(5).normal(0, 40, (33, 4)).astype(np.float32)
    s_ref, _ = TreeEngine(ir, "integer").predict_scores(rows)
    s_bv, _ = TreeEngine(out, "integer:bitvector").predict_scores(rows)
    np.testing.assert_array_equal(np.asarray(s_bv), np.asarray(s_ref))


def test_round_trip_single_stump(tmp_path):
    """The smallest possible artifact: one tree, one node."""
    ir = ForestIR.from_forest(forest_from_trees(
        [DEGENERATE_FORESTS["stumps"]().trees_[0]], 3, 4))
    path = tmp_path / "stump.itrf"
    ir.to_itrf(str(path), pack_leaves=True)
    _assert_ir_equal(ir, ForestIR.from_itrf(str(path)))


def test_inspect_reports_header_and_sections(trained_ir, tmp_path):
    path = tmp_path / "m.itrf"
    trained_ir.to_itrf(str(path), pack_leaves=True)
    info = inspect_itrf(str(path))
    assert info["version"] == list(ITRF_VERSION) or \
           info["version"] == ITRF_VERSION
    assert info["n_trees"] == trained_ir.n_trees
    assert info["total_nodes"] == trained_ir.total_nodes
    assert set(info["sections"]) >= {"feature", "threshold_key", "left",
                                     "right", "node_offsets", "tree_depths",
                                     "leaf_pack_data", "meta"}
    for ent in info["sections"].values():
        assert ent["offset"] % 64 == 0  # every section is 64-byte aligned


# --------------------------------------------------------- format gating

def _patch_header(path, **over):
    """Rewrite header fields in-place (test-only corruption helper)."""
    from repro.ir.artifact import _HEADER

    raw = bytearray(path.read_bytes())
    fields = list(_HEADER.unpack_from(raw))
    names = ["magic", "vmaj", "vmin", "flags", "n_trees", "n_classes",
             "n_features", "total_nodes", "quant_scale", "n_sections"]
    for k, v in over.items():
        fields[names.index(k)] = v
    raw[:_HEADER.size] = _HEADER.pack(*fields)
    path.write_bytes(bytes(raw))


def test_refuses_newer_major_version(trained_ir, tmp_path):
    """Mirror of trees/io schema gating: a future-major artifact is refused
    loudly, never half-parsed.  A newer *minor* still loads."""
    path = tmp_path / "m.itrf"
    trained_ir.to_itrf(str(path))
    _patch_header(path, vmaj=ITRF_VERSION[0] + 1)
    with pytest.raises(ValueError, match="format version"):
        read_itrf(str(path))
    with pytest.raises(ValueError, match="format version"):
        inspect_itrf(str(path))
    _patch_header(path, vmaj=ITRF_VERSION[0], vmin=ITRF_VERSION[1] + 7)
    out = read_itrf(str(path))
    _assert_ir_equal(trained_ir, out)
    assert out.itrf_version == (ITRF_VERSION[0], ITRF_VERSION[1] + 7)


def test_refuses_bad_magic_and_truncation(trained_ir, tmp_path):
    path = tmp_path / "m.itrf"
    trained_ir.to_itrf(str(path))
    _patch_header(path, magic=b"NOPE")
    with pytest.raises(ValueError, match="magic"):
        read_itrf(str(path))
    with pytest.raises(ValueError, match="not an ITRF"):
        read_itrf_bytes(b"IT")


def test_unknown_sections_are_skipped(trained_ir, tmp_path):
    """Minor versions may append sections; this reader must ignore names it
    does not know instead of failing."""
    from repro.ir.artifact import _parse_header, _parse_sections, \
        _section_array, _write_raw

    path = tmp_path / "m.itrf"
    trained_ir.to_itrf(str(path))
    ir = read_itrf(str(path), mmap_arrays=False)
    buf = path.read_bytes()
    head = _parse_header(buf)
    table = _parse_sections(buf, head["n_sections"])
    sections = [(n, _section_array(buf, e, copy=False))
                for n, e in table.items()]
    sections.append(("future_thing", np.arange(9, dtype=np.uint8)))
    _write_raw(str(path), (*head["version"], head["flags"], head["n_trees"],
                           head["n_classes"], head["n_features"],
                           head["total_nodes"],
                           int(head["quant_scale"] or 0)), sections)
    _assert_ir_equal(ir, read_itrf(str(path)))


# ------------------------------------------------------- mmap safety

def test_mmap_views_are_read_only_and_file_unchanged(trained_ir, tmp_path):
    from repro.serve.engine import TreeEngine

    path = tmp_path / "m.itrf"
    trained_ir.to_itrf(str(path))
    before = path.read_bytes()
    ir = ForestIR.from_itrf(str(path), mmap=True)
    for name in IR_ARRAYS:
        a = getattr(ir, name)
        assert not a.flags.writeable, f"{name} must be a read-only view"
        with pytest.raises((ValueError, RuntimeError)):
            a[...] = 0
    # serving goes through layout materializers, which copy — predicts must
    # neither fail on the read-only canon nor write back through the map
    rows = np.random.default_rng(0).normal(
        0, 4, (17, ir.n_features)).astype(np.float32)
    for mode in ("flint", "integer"):
        TreeEngine(ir, mode).predict_scores(rows)
    TreeEngine(ir, "integer:reference@packed_leaf").predict_scores(rows)
    assert path.read_bytes() == before
    # eager load is the opposite contract: private writable copies
    eager = ForestIR.from_itrf(str(path), mmap=False)
    assert eager.feature.flags.writeable
    eager.feature[0] = -1  # must not raise


# ------------------------------------------------- packed-leaf codec edges

def test_pack_groups_round_trip_edges():
    for values in (
        np.zeros(0, np.uint32),  # empty
        np.zeros(64, np.uint32),  # constant group, width 0
        np.full(7, 2**32 - 1, np.uint32),  # max values, partial group
        np.arange(200, dtype=np.uint32),  # multiple groups + tail
        np.array([0, 2**32 - 1] * 65, np.uint32),  # full-width deltas
    ):
        base, bits, payload = pack_groups(values, 64)
        out = unpack_groups(base, bits, payload, len(values), 64)
        np.testing.assert_array_equal(out, values)
        assert out.dtype == np.uint32


def test_pack_leaf_payload_picks_dictionary_for_near_one_hot():
    """Trained leaves are near-one-hot fixed-point rows: few distinct
    values, so the dictionary stage must win over raw group packing."""
    rng = np.random.default_rng(0)
    scale = (2**32 - 1) // 16
    values = rng.choice(
        np.array([0, scale // 2, scale], np.uint32), 4096).astype(np.uint32)
    dictionary, base, bits, payload = pack_leaf_payload(values, 64)
    assert dictionary.size == 3  # dict mode engaged
    out = unpack_leaf_payload(dictionary, base, bits, payload,
                              len(values), 64)
    np.testing.assert_array_equal(out, values)


def test_pack_leaf_payload_falls_back_to_raw_for_high_entropy():
    rng = np.random.default_rng(1)
    values = rng.integers(0, 2**32, 4096, dtype=np.uint32)
    dictionary, base, bits, payload = pack_leaf_payload(values, 64)
    assert dictionary.size == 0  # raw mode: a 4096-entry dict cannot win
    out = unpack_leaf_payload(dictionary, base, bits, payload,
                              len(values), 64)
    np.testing.assert_array_equal(out, values)


def test_packed_leaf_layout_registered_and_smaller(trained_ir):
    sizes = trained_ir.nbytes_by_layout(mode="integer")
    assert "packed_leaf" in sizes
    assert sizes["packed_leaf"] < sizes["padded"]


def test_packed_leaf_rejects_float_mode(trained_ir):
    from repro.backends import create_backend

    art = trained_ir.materialize("packed_leaf")
    with pytest.raises(ValueError, match="deterministic"):
        create_backend("reference", art, mode="float")


# ----------------------------------------------------- registry integration

@pytest.fixture()
def artifact_path(trained_ir, tmp_path):
    path = tmp_path / "reg.itrf"
    trained_ir.to_itrf(str(path))
    return str(path)


def test_register_artifact_serves_identically_to_json(
        small_forest, artifact_path, shuttle_small):
    from repro.serve.registry import ModelRegistry
    from repro.trees.io import forest_to_json

    _, _, Xte, _ = shuttle_small
    rows = Xte[:64]
    reg = ModelRegistry()
    mv_j = reg.register_json("j", forest_to_json(small_forest))
    mv_a = reg.register_artifact("a", artifact_path)
    assert mv_a.source == "artifact"
    for mode in ("flint", "integer"):
        np.testing.assert_array_equal(
            np.asarray(mv_a.engine(mode).predict(rows)),
            np.asarray(mv_j.engine(mode).predict(rows)))


def test_register_artifact_load_ms_lands_in_engine_ledger(artifact_path):
    from repro.serve.registry import ModelRegistry

    mv = ModelRegistry().register_artifact("m", artifact_path)
    eng = mv.engine("integer")
    assert "load" in eng.drain_compile_timings()
    # charged once: a second engine on the same version pays nothing
    assert "load" not in mv.engine("flint").drain_compile_timings()


def test_hot_swap_reuses_mapped_artifact(artifact_path):
    from repro.serve.registry import ModelRegistry

    reg = ModelRegistry()
    mv1 = reg.register_artifact("m", artifact_path)
    mv2 = reg.register_artifact("m", artifact_path)
    assert mv2.version == mv1.version + 1
    assert mv2.packed is mv1.packed  # the mapped IR object, pages shared
    # rewriting the file (mtime/size change) invalidates the cache entry
    ir = read_itrf(artifact_path, mmap_arrays=False)
    os.utime(artifact_path, ns=(1, 1))
    mv3 = reg.register_artifact("m", artifact_path)
    assert mv3.packed is not mv1.packed
    del ir


def test_retention_releases_swapped_out_versions(artifact_path):
    """The regression the retention policy exists for: versions beyond the
    keep-window must close their engines and become garbage-collectable."""
    from repro.serve.registry import ModelRegistry

    reg = ModelRegistry(retain=2)
    mv1 = reg.register_artifact("m", artifact_path)
    eng1 = mv1.engine("integer")
    ref = weakref.ref(eng1)
    mv2 = reg.register_artifact("m", artifact_path)
    assert not mv1.released  # still inside the window (current + previous)
    mv3 = reg.register_artifact("m", artifact_path)
    assert mv1.released and eng1.closed
    assert not mv2.released
    with pytest.raises(RuntimeError, match="released"):
        mv1.engine("integer")
    del eng1, mv1
    gc.collect()
    assert ref() is None, "released engine still referenced"
    # explicit release of the retained previous version
    reg.release("m", mv2.version)
    assert mv2.released
    with pytest.raises(ValueError, match="current"):
        reg.release("m", mv3.version)
    with pytest.raises(KeyError):
        reg.release("m", mv2.version)  # already gone from the window
    assert reg.get("m") is mv3  # current version untouched throughout


def test_registry_retain_validation():
    from repro.serve.registry import ModelRegistry

    with pytest.raises(ValueError, match="retain"):
        ModelRegistry(retain=0)


def test_gateway_prunes_closed_engines(artifact_path, shuttle_small):
    import asyncio

    from repro.serve.gateway import Gateway
    from repro.serve.registry import ModelRegistry

    _, _, Xte, _ = shuttle_small
    rows = Xte[:8]
    reg = ModelRegistry(retain=1)
    gw = Gateway(reg, "integer", max_delay_ms=0.5)
    reg.register_artifact("m", artifact_path)
    asyncio.run(gw.submit("m", rows))
    assert len(gw._engines) == 1
    reg.register_artifact("m", artifact_path)  # retain=1: v1 released now
    s2, _ = asyncio.run(gw.submit("m", rows))
    assert all(not e.closed for e in gw._engines.values())
    assert len(gw._engines) == 1  # the closed v1 engine was pruned
    asyncio.run(gw.close())


# --------------------------------------------------------- tune-db sidecar

def test_tune_db_persists_and_foreign_hosts_ignore(trained_ir, tmp_path):
    from repro.serve.registry import ModelRegistry

    path = tmp_path / "tuned.itrf"
    winners = {("native_c_table", None, "integer"): {"block_rows": 8}}
    trained_ir.to_itrf(str(path), tuned=winners)
    info = inspect_itrf(str(path))
    assert info["flags"] & FLAG_TUNED
    assert info["tuned_hosts"] == [host_isa_key()]
    # this host's entry seeds the version's tuned cache on load
    mv = ModelRegistry().register_artifact("m", str(path))
    assert mv._tuned == winners
    # a foreign host's winners are carried but never applied here
    update_tuned(str(path), {("bitvector", None, "flint"): {"interleave": 4}},
                 host_key="riscv64+vext")
    assert sorted(inspect_itrf(str(path))["tuned_hosts"]) == \
           sorted([host_isa_key(), "riscv64+vext"])
    mv2 = ModelRegistry().register_artifact("m", str(path))
    assert mv2._tuned == winners  # unchanged: foreign flags, host re-tunes


def test_export_tuned_round_trips_through_registry(artifact_path):
    from repro.serve.registry import ModelRegistry

    reg = ModelRegistry()
    mv = reg.register_artifact("m", artifact_path)
    mv._tuned[("native_c_bitvector", None, "integer")] = {"interleave": 8}
    reg.export_tuned("m", artifact_path)
    # a "fresh process": a new registry mapping the updated file starts warm
    mv2 = ModelRegistry().register_artifact("m", artifact_path)
    assert mv2._tuned == {("native_c_bitvector", None, "integer"):
                          {"interleave": 8}}


# ------------------------------------------------- worker HELLO fast path

def test_worker_session_decodes_itrf_hello(trained_ir, tmp_path,
                                           shuttle_small):
    """The artifact-bytes fast path: a HELLO whose payload is one raw ITRF
    image (not the per-array directory) rebuilds the forest and serves the
    bit-identical shard partials."""
    from repro.serve import wire
    from repro.serve.worker import _Session
    from repro.backends import create_backend

    path = tmp_path / "w.itrf"
    trained_ir.to_itrf(str(path), include_float=False)
    ir = ForestIR.from_itrf(str(path))
    meta = {"artifact_format": "itrf", "mode": "integer",
            "model_id": "m", "version": 1,
            "shards": [{"shard": 0, "start": 0, "stop": ir.n_trees,
                        "backend": "reference"}]}
    payload = wire.encode_hello(meta, {"itrf": ir.itrf_bytes})
    session = _Session(payload)
    _assert_ir_equal(ir, session.ir)
    _, _, Xte, _ = shuttle_small
    rows = Xte[:19]
    backend, built = session.backend(0)
    assert built
    ref = create_backend("reference", trained_ir.materialize("padded"),
                         mode="integer")
    np.testing.assert_array_equal(
        np.asarray(backend.predict_partials(rows)),
        np.asarray(ref.predict_partials(rows)))


def test_remote_plan_prefers_artifact_bytes_when_smaller(trained_ir,
                                                         tmp_path):
    """The HELLO fast path is size-guarded: a stripped artifact image beats
    the per-array payload and ships whole; a full-float image (2x, thanks to
    f64 leaf_probs) must fall back to the array directory."""
    from repro.serve import wire

    stripped = tmp_path / "s.itrf"
    full = tmp_path / "f.itrf"
    trained_ir.to_itrf(str(stripped), include_float=False)
    trained_ir.to_itrf(str(full), include_float=True)
    wire_arrays_nbytes = sum(
        getattr(trained_ir, n).nbytes
        for n in ("feature", "threshold", "threshold_key", "left", "right",
                  "leaf_fixed", "node_offsets", "tree_depths"))
    assert ForestIR.from_itrf(str(stripped)).itrf_bytes.nbytes \
        <= wire_arrays_nbytes
    assert ForestIR.from_itrf(str(full)).itrf_bytes.nbytes \
        > wire_arrays_nbytes


# ------------------------------------------------------------ converter CLI

def test_convert_cli_and_inspect(small_forest, tmp_path, capsys):
    from repro.trees.convert import main
    from repro.trees.io import forest_to_json

    src = tmp_path / "model.json"
    dst = tmp_path / "model.itrf"
    src.write_text(forest_to_json(small_forest))
    assert main([str(src), str(dst), "--strip-float", "--pack-leaves"]) == 0
    out = capsys.readouterr().out
    assert "packed_leaf=" in out and "bitvector=" in out
    ir = ForestIR.from_itrf(str(dst))
    assert ir.itrf_flags & FLAG_PACKED_LEAVES
    assert not ir.itrf_flags & FLAG_FLOAT
    ref = ForestIR.from_forest(small_forest)
    for name in ("feature", "threshold_key", "left", "right", "leaf_fixed",
                 "node_offsets", "tree_depths"):
        np.testing.assert_array_equal(getattr(ref, name), getattr(ir, name),
                                      err_msg=name)
    assert main(["--inspect", str(dst)]) == 0
    info = json.loads(capsys.readouterr().out)
    assert info["n_trees"] == small_forest.n_estimators


def test_convert_cli_requires_paths(capsys):
    from repro.trees.convert import main

    with pytest.raises(SystemExit):
        main([])

"""Sharded execution plans: engine -> plan -> backend partials -> merge ->
finalize.

The paper's integer-only accumulation makes ensemble aggregation an exact,
associative uint32 sum, so a forest can be split across devices or across
*different backends* and the partial scores merged with zero precision loss —
something float ensembles cannot guarantee.  This package is that property as
an architecture layer: plans carve the forest (``ForestIR.subset`` tree
shards) or the batch (row shards), drive ``TreeBackend.predict_partials`` on
each piece, merge, and run the standalone finalize step exactly once.  Every
plan is bit-identical to single-shard execution in the deterministic modes —
``make conformance`` (``tests/test_plans.py``) enforces it across the full
(plan, backend, layout) cross.
"""
from repro.plan.base import (
    ExecutionPlan,
    available_plans,
    build_backend,
    create_plan,
    plan_class,
    register_plan,
    select_plan,
)
from repro.plan.remote import RemoteTreeParallelPlan
from repro.plan.row_parallel import RowParallelPlan
from repro.plan.single import SingleShardPlan
from repro.plan.tree_parallel import (
    TreeParallelPlan,
    thread_shard_cap,
    tree_ranges,
)

__all__ = [
    "ExecutionPlan",
    "RemoteTreeParallelPlan",
    "RowParallelPlan",
    "SingleShardPlan",
    "TreeParallelPlan",
    "available_plans",
    "build_backend",
    "create_plan",
    "plan_class",
    "register_plan",
    "select_plan",
    "thread_shard_cap",
    "tree_ranges",
]

"""The padded node-table artifact (the IR's ``padded``/``leaf_major`` layouts).

This is the TPU analogue of the paper's codegen step: instead of emitting
if-else C, we emit *tensors*.  All per-node quantities are padded to the max
node count across trees; padding nodes are self-looping leaves with zero
probability mass, so they are semantically inert.

Since the ForestIR refactor, ``PackedEnsemble`` is no longer the canonical
representation — it is one *materialization* of :class:`repro.ir.ForestIR`
(``layout == "padded"``, or ``"leaf_major"`` for the internal-first node
ordering).  :func:`pack_forest` keeps its historical signature and produces
bit-identical tables to the pre-IR implementation; the quantized artifacts it
carries are exactly the paper's:
  * ``threshold_key``: FlInt int32 keys of the float thresholds,
  * ``leaf_fixed``:  uint32 fixed-point leaf probabilities at scale
    ``floor((2**32-1)/n_trees)`` (Sec. III-A), overflow-free by construction,
and both are quantized once, in the IR — never re-derived per layout.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.fixedpoint import scale_for


@dataclass
class PackedEnsemble:
    feature: np.ndarray  # (T, N) int32, -1 for leaf
    threshold: np.ndarray  # (T, N) float32
    threshold_key: np.ndarray  # (T, N) int32 (FlInt keys)
    left: np.ndarray  # (T, N) int32
    right: np.ndarray  # (T, N) int32
    leaf_probs: np.ndarray  # (T, N, C) float32 (zeros on internal/pad nodes)
    leaf_fixed: np.ndarray  # (T, N, C) uint32
    n_trees: int
    n_classes: int
    n_features: int
    max_depth: int  # walk length that guarantees leaf arrival
    # layout metadata (ForestIR refactor): which materialization these tables
    # are, the per-tree real node counts padding erased, and a back-reference
    # to the canonical IR so other layouts can be materialized on demand.
    layout: str = "padded"
    # sub-forest artifacts (ForestIR.subset): the scale the leaves were
    # quantized at — the parent ensemble's, not scale_for(n_trees)
    quant_scale: Optional[int] = field(default=None, repr=False)
    node_counts: Optional[np.ndarray] = field(default=None, repr=False)
    # leaf_major only: per-tree internal-node counts (T,).  In that layout a
    # tree's nodes are permuted internal-first, so indices [0, internal_counts
    # [t]) are exactly tree t's split nodes — the prefix the linear-scan
    # Pallas kernel walks front-to-back instead of gathering per depth level.
    internal_counts: Optional[np.ndarray] = field(default=None, repr=False)
    ir: Optional[object] = field(default=None, repr=False, compare=False)

    @property
    def scale(self) -> int:
        return self.quant_scale if self.quant_scale is not None \
            else scale_for(self.n_trees)

    def to_ir(self):
        """The canonical IR behind these tables (recovered if not attached)."""
        if self.ir is None:
            from repro.ir.forest_ir import ForestIR

            self.ir = ForestIR.from_packed(self)
        return self.ir

    def nbytes_integer(self) -> int:
        """Bytes of the integer-only deployment artifact *in this layout*.

        Padded tables pay O(T * max(n_nodes)); use
        ``ForestIR.nbytes_by_layout`` to compare against the ragged layout's
        O(sum(n_nodes)) footprint.
        """
        return (
            self.feature.nbytes
            + self.threshold_key.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_fixed.nbytes
        )

    def nbytes_float(self) -> int:
        """Bytes of the float deployment artifact in this layout."""
        return (
            self.feature.nbytes
            + self.threshold.nbytes
            + self.left.nbytes
            + self.right.nbytes
            + self.leaf_probs.nbytes
        )


def pack_forest(forest) -> PackedEnsemble:
    """Quantize ``forest`` into the IR and materialize the padded layout.

    Kept as the one-call path from a trained forest to servable node tables;
    the returned artifact carries ``.ir``, so every other registered layout
    (``ragged``, ``leaf_major``) is one ``materialize`` away with no
    re-quantization.
    """
    from repro.ir.forest_ir import ForestIR

    return ForestIR.from_forest(forest).materialize("padded")

"""Paper-faithful C code generation: integer-only if-else trees.

This reproduces InTreeger's literal deliverable (Sec. III-B): a standalone,
freestanding-C, architecture-agnostic if-else implementation of the trained
ensemble where

  * branch thresholds are FlInt int32 immediates (``data`` is the feature
    vector reinterpreted as int32 keys, cf. paper Listing 2),
  * leaf probabilities are uint32 fixed-point immediates at scale
    ``floor((2**32-1)/n_trees)`` (Sec. III-A),

plus the float baseline (paper Listing 4 flavor) for comparison.  The emitted
file needs only <stdint.h> — no libm, no FPU.
"""
from __future__ import annotations

from repro.core.packing import PackedEnsemble


def _c_float(v: float) -> str:
    s = f"{float(v):.9g}"
    if "." not in s and "e" not in s and "inf" not in s and "nan" not in s:
        s += ".0"
    return s + "f"


def _emit_node(lines, packed, t, node, indent, mode):
    pad = "  " * indent
    feat = int(packed.feature[t, node])
    if feat < 0:  # leaf
        if mode == "integer":
            row = packed.leaf_fixed[t, node]
            for c, v in enumerate(row):
                if int(v):
                    lines.append(f"{pad}result[{c}] += {int(v)}u;")
        else:
            row = packed.leaf_probs[t, node]
            for c, v in enumerate(row):
                if float(v):
                    lines.append(f"{pad}result[{c}] += {_c_float(v)};")
        return
    if mode in ("integer", "flint"):
        key = int(packed.threshold_key[t, node]) & 0xFFFFFFFF
        cond = f"data[{feat}] <= (int32_t)0x{key:08x}"
    else:
        cond = f"data[{feat}] <= {_c_float(packed.threshold[t, node])}"
    lines.append(f"{pad}if ({cond}) {{")
    _emit_node(lines, packed, t, int(packed.left[t, node]), indent + 1, mode)
    lines.append(f"{pad}}} else {{")
    _emit_node(lines, packed, t, int(packed.right[t, node]), indent + 1, mode)
    lines.append(f"{pad}}}")


def emit_c(packed: PackedEnsemble, mode: str = "integer") -> str:
    """Emit a standalone C file for the packed ensemble.

    mode == "integer": void predict(const int32_t* data, uint32_t* result)
        ``data`` holds FlInt keys of the float features (for non-negative
        features these are the raw IEEE-754 bit patterns, exactly as in the
        paper); ``result`` accumulates fixed-point class scores.
    mode == "flint":   FlInt baseline — int32 threshold compares, float
        probability accumulation (the paper's Sec. II-D comparison point)
    mode == "float":   void predict(const float* data, float* result)
    """
    assert mode in ("integer", "flint", "float")
    c, t = packed.n_classes, packed.n_trees
    lines = ["#include <stdint.h>", ""]
    if mode == "integer":
        lines.append(
            f"/* InTreeger: integer-only if-else ensemble. trees={t} classes={c}\n"
            f"   scale = floor((2^32-1)/{t}) = {packed.scale}; scores/2^32 ~= avg prob. */"
        )
        sig = "void predict(const int32_t* data, uint32_t* result)"
    elif mode == "flint":
        lines.append(f"/* FlInt if-else ensemble: int compares, float probs. */")
        sig = "void predict(const int32_t* data, float* result)"
    else:
        lines.append(f"/* float baseline if-else ensemble. trees={t} classes={c} */")
        sig = "void predict(const float* data, float* result)"
    lines.append(sig + " {")
    for i in range(c):
        lines.append(f"  result[{i}] = 0;")
    for tree in range(t):
        lines.append(f"  /* tree {tree} */")
        _emit_node(lines, packed, tree, 0, 1, mode)
    if mode in ("float", "flint"):
        for i in range(c):
            lines.append(f"  result[{i}] /= {t}.0f;")
    lines.append("}")
    lines.append("")
    # argmax helper (comparisons only)
    ty = "uint32_t" if mode == "integer" else "float"
    data_t = "float" if mode == "float" else "int32_t"
    lines += [
        f"int predict_class(const {data_t}* data) {{",
        f"  {ty} result[{c}];",
        "  predict(data, result);",
        "  int best = 0;",
        f"  for (int i = 1; i < {c}; ++i) if (result[i] > result[best]) best = i;",
        "  return best;",
        "}",
        "",
    ]
    return "\n".join(lines)


def emit_test_harness(packed: PackedEnsemble, n_samples: int) -> str:
    """A main() that reads raw feature rows from stdin and prints argmax —
    used by tests to diff gcc-compiled output against the JAX paths."""
    f = packed.n_features
    return "\n".join(
        [
            "#include <stdio.h>",
            "#include <stdint.h>",
            "int predict_class(const int32_t* data);",
            "int main(void) {",
            f"  static int32_t row[{f}];",
            f"  for (int s = 0; s < {n_samples}; ++s) {{",
            f"    fread(row, sizeof(int32_t), {f}, stdin);",
            '    printf("%d\\n", predict_class(row));',
            "  }",
            "  return 0;",
            "}",
            "",
        ]
    )

"""Fixed-point integer cross-replica accumulation — the paper's Sec. III-A
math applied to distributed reductions (beyond-paper feature, DESIGN.md §4.2).

The paper sums n bounded per-tree values in uint32 by pre-scaling each with
``2**32/n`` so the total provably fits.  A data-parallel gradient all-reduce
is the same problem: n replicas each contribute a bounded value.  We pre-scale
each replica's contribution into int32 fixed point with

    scale = (2**31 - 1) / (n_replicas * bound)

so ``|sum| <= n * bound * scale <= 2**31 - 1`` — overflow-free by the same
argument.  The integer psum is **deterministic and order-independent**
(integer addition is associative), unlike float psum whose result depends on
the reduction order — a real reproducibility win at 1000+ nodes.

Quantization error per element is <= n/(2*scale) = n^2 * bound / 2**32 in the
worst case; tests assert the bound.  ``bound`` comes from a preliminary
``psum(max|x|)`` (one cheap extra collective) unless given statically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_I32_MAX = 2**31 - 1


def integer_psum(x, axis_name: str, n_shards: int, bound=None):
    """Deterministic fixed-point all-reduce of ``x`` over ``axis_name``.

    Must be called inside shard_map/pmap with ``axis_name`` bound.
    """
    xf = x.astype(jnp.float32)
    if bound is None:
        local_max = jnp.max(jnp.abs(xf))
        bound = jax.lax.pmax(local_max, axis_name)
    # Power-of-two scale <= (2^31-1)/(n*bound): the f32 multiply is then an
    # exact exponent shift (an arbitrary scale would itself round at ~2^28
    # magnitude and dominate the quantization error).
    scale = (_I32_MAX / n_shards) / jnp.maximum(bound, 1e-30)
    scale = jnp.exp2(jnp.floor(jnp.log2(scale)))
    xi = jnp.round(xf * scale).astype(jnp.int32)
    total = jax.lax.psum(xi, axis_name)
    # int32 -> float exactly: f32 has 24 mantissa bits, totals reach 2^31;
    # split into (total >> 16) * 2^16 + low16, both exactly representable.
    hi = (total >> 16).astype(jnp.float32) * 65536.0
    lo = (total - ((total >> 16) << 16)).astype(jnp.float32)
    return (hi + lo) / scale


def integer_pmean(x, axis_name: str, n_shards: int, bound=None):
    return integer_psum(x, axis_name, n_shards, bound) / n_shards


def integer_psum_tree(tree, axis_name: str, n_shards: int):
    return jax.tree.map(lambda x: integer_psum(x, axis_name, n_shards), tree)


def quantization_error_bound(n_shards: int, bound: float) -> float:
    """Worst-case |integer_psum - exact_sum| per element.

    The power-of-two floor loses at most 2x vs the ideal scale; each shard
    contributes <= 0.5 rounding units; one final f32 add/divide rounds at
    2^-24 relative.
    """
    scale = (_I32_MAX / n_shards) / max(bound, 1e-30)
    scale_p2 = 2.0 ** np.floor(np.log2(scale))
    return n_shards / (2.0 * scale_p2) + n_shards * bound * 2.0**-23

"""ForestIR: the layout-aware forest representation layer.

The paper compiles a trained forest straight into one fixed artifact (if-else
C, Sec. III-B); memory layout is an *implicit* consequence of that choice.
This package makes the layout a first-class axis instead:

    forest  --quantize once-->  ForestIR  --materialize-->  layout artifact
                                (canonical,                  (padded | ragged |
                                 unpadded)                    leaf_major |
                                                              bitvector |
                                                              packed_leaf)

``ForestIR`` also round-trips through the ITRF binary artifact
(``artifact.py``): ``ir.to_itrf(path)`` / ``ForestIR.from_itrf(path)``,
with ``mmap=True`` loads returning zero-copy read-only views over the file.

``ForestIR`` (``forest_ir.py``) holds the canonical quantized forest — FlInt
int32 threshold keys, uint32 fixed-point leaves, per-tree node counts, all
unpadded — and ``layouts.py`` holds the registry of materializers that turn it
into the concrete memory layouts the execution backends consume.  Every
materialization of one IR is score-bit-identical in the deterministic modes
(flint/integer); ``tests/test_backends.py`` / ``make conformance`` enforce
this across all (layout, backend) pairs.
"""
from repro.ir.forest_ir import ForestIR, resolve_artifact
from repro.ir.layouts import (
    RaggedEnsemble,
    available_layouts,
    materialize,
    register_layout,
)
from repro.ir.bitvector import BitvectorEnsemble  # registers "bitvector"
from repro.ir.packed_leaf import PackedLeafEnsemble  # registers "packed_leaf"

__all__ = [
    "BitvectorEnsemble",
    "ForestIR",
    "PackedLeafEnsemble",
    "RaggedEnsemble",
    "available_layouts",
    "materialize",
    "register_layout",
    "resolve_artifact",
]

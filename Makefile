# One-step entry points for the repo's standard workflows.

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test conformance check bench serve-trees serve-gateway

# tier-1 verify (see ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# cross-(backend, layout) bit-identity suite
# (reference / pallas / native_c / native_c_table x padded / ragged / leaf_major)
conformance:
	$(PY) -m pytest -q tests/test_backends.py

# the full gate: tier-1 tests, then the conformance suite standalone
check: test conformance

bench:
	$(PY) benchmarks/run.py

serve-trees:
	$(PY) -m repro.launch.serve --trees

serve-gateway:
	$(PY) -m repro.launch.serve --trees --gateway

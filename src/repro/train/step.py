"""Train step factory: loss -> grads -> (clip, optional integer DP reduce) ->
AdamW -> new state.  One function serves smoke tests (1 CPU device), the
multi-pod dry-run (abstract lowering), and the runnable examples.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.train import optimizer as opt
from repro.train.intreeger_allreduce import integer_pmean


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[opt.AdamWConfig] = None):
    """Single-step factory with microbatched gradient accumulation.

    ``cfg.microbatches > 1`` splits the global batch on the leading axis and
    scans value_and_grad over the slices, accumulating f32 grads — activation
    stacks shrink by the microbatch factor while arithmetic is unchanged
    (standard virtual-batch training at scale).
    """
    opt_cfg = opt_cfg or opt.AdamWConfig()

    def grads_of(params, batch):
        def lf(p):
            return tfm.loss_fn(cfg, p, batch)

        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        from repro.sharding.ops import current_mesh

        # each microbatch must still fill every batch shard: cap the count
        # so B/n_micro stays divisible by the (pod x data) extent
        b = jax.tree.leaves(batch)[0].shape[0]
        mesh = current_mesh()
        dp = 1
        if mesh is not None:
            for a in ("pod", "data"):
                dp *= mesh.shape.get(a, 1)
        n_micro = max(1, min(cfg.microbatches, b // max(dp, 1)))
        while b % (n_micro * dp) and n_micro > 1:
            n_micro -= 1
        if n_micro == 1:
            (loss, parts), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro) + a.shape[1:]), batch
            )

            def acc_fn(carry, mb):
                (loss, parts), grads = grads_of(params, mb)
                gsum, lsum, psum_ = carry
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss, jax.tree.map(jnp.add, psum_, parts)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            p0 = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
            (gsum, lsum, psum_), _ = jax.lax.scan(acc_fn, (g0, jnp.zeros(()), p0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
            parts = jax.tree.map(lambda x: x / n_micro, psum_)
        params, opt_state, om = opt.adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_integer_dp_train_step(cfg: ModelConfig, mesh, opt_cfg: Optional[opt.AdamWConfig] = None):
    """Variant with the paper-math integer all-reduce over the data axis.

    Gradients are computed per data shard (batch split via shard_map), then
    combined with the deterministic int32 fixed-point psum
    (``intreeger_allreduce``).  Params/opt state are replicated over ``data``
    in this mode (pure DP; for FSDP the integer reduce applies to the
    reduce-scatter equivalently).
    """
    opt_cfg = opt_cfg or opt.AdamWConfig()
    n = mesh.shape["data"]

    from jax.sharding import PartitionSpec as P

    from repro.sharding.ops import compat_shard_map

    def grad_fn(params, batch):
        def lf(p):
            return tfm.loss_fn(cfg, p, batch)

        (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads = jax.tree.map(lambda g: integer_pmean(g, "data", n), grads)
        loss = jax.lax.pmean(loss, "data")
        return loss, grads

    sharded_grad = compat_shard_map(
        grad_fn,
        mesh=mesh,
        in_specs=(P(), P("data")),
        out_specs=(P(), P()),
    )

    def train_step(params, opt_state, batch):
        loss, grads = sharded_grad(params, batch)
        params, opt_state, om = opt.adamw_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **om}

    return train_step

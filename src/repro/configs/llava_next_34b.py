"""llava-next-34b [vlm]: decoder backbone + stubbed vision frontend.

[hf:llava-hf/llava-v1.6-mistral-7b-hf]  60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000.  Per the assignment the modality frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (anyres tiling not
implemented); a linear projector (the only trained frontend piece in LLaVA)
maps them to d_model and they are prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    frontend="vision_stub",
    frontend_dim=1024,
    vision_patches=576,
    microbatches=16,  # keep layer-boundary remat stacks under HBM (EXPERIMENTS §Dry-run)
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    frontend="vision_stub",
    frontend_dim=32,
    vision_patches=16,
)

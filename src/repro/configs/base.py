"""Model configuration schema + registry of the assigned architectures.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced same-family
config for CPU smoke tests).  ``get_config(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | trees
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25  # E/k makes dispatch provably dropless
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # attention pattern
    sliding_window: int = 0  # 0 = full attention
    global_every: int = 0  # gemma3: 1 global layer per N (others local)
    # hybrid (zamba2): shared attention block applied every k mamba blocks,
    # alternating between `hybrid_shared_sets` parameter sets
    hybrid_attn_every: int = 0
    hybrid_shared_sets: int = 2
    encoder_only: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    frontend: str = "none"  # none | audio_stub | vision_stub
    frontend_dim: int = 0  # stub embedding dim fed to the projector
    vision_patches: int = 576  # vlm: patch tokens prepended to the sequence
    act: str = "silu"
    norm_eps: float = 1e-6
    # training
    microbatches: int = 1  # gradient accumulation (activation-memory control)
    # trees family (the paper's own architecture)
    n_trees: int = 0
    tree_depth: int = 0
    n_tab_features: int = 0
    n_classes: int = 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid/local-global attention)."""
        return self.family in ("ssm", "hybrid") or self.global_every > 0

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only and self.family != "trees"

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        d, l, v = self.d_model, self.n_layers, self.vocab_size
        dh = self.resolved_head_dim
        n = v * d  # embed (tied head)
        if not self.tie_embeddings:
            n += v * d
        for i in range(l):
            kind = block_kind(self, i)
            if kind in ("attn_mlp", "attn_moe"):
                n += d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d
                if kind == "attn_mlp":
                    n += 3 * d * self.d_ff
                else:
                    n += d * self.n_experts + self.n_experts * 3 * d * self.d_ff
            if kind == "ssm":
                from repro.models.ssm import ssm_dims

                d_inner, h, conv_dim = ssm_dims(d, self.ssm_expand, self.ssm_state)
                n += d * (2 * d_inner + 2 * self.ssm_state + h)
                n += conv_dim * 4 + 3 * h + d_inner + d_inner * d
        if self.hybrid_attn_every:
            # shared attention+mlp sets
            per = d * dh * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * dh * d + 3 * d * self.d_ff
            n += self.hybrid_shared_sets * per
        if self.frontend != "none":
            n += self.frontend_dim * d
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * self.d_ff
        return dense + self.n_layers * self.experts_per_token * 3 * d * self.d_ff


def block_kind(cfg: ModelConfig, layer: int) -> str:
    if cfg.family == "moe":
        return "attn_moe"
    if cfg.family in ("ssm", "hybrid"):
        return "ssm"
    return "attn_mlp"


ARCHS: Tuple[str, ...] = (
    "zamba2-2.7b",
    "olmoe-1b-7b",
    "qwen3-moe-30b-a3b",
    "mamba2-370m",
    "llava-next-34b",
    "starcoder2-3b",
    "granite-3-2b",
    "gemma3-27b",
    "granite-34b",
    "hubert-xlarge",
    "intreeger-rf",  # the paper's own architecture (tree ensemble serving)
)


def _module(name: str):
    return importlib.import_module("repro.configs." + name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE

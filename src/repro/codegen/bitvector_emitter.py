"""QuickScorer bitvector C: sorted threshold streams compiled as static data.

The emitted scorer is the sequential form the bitvector layout is built for
(the jnp backend evaluates the same tables data-parallel instead):

    for each feature f:
      for each entry e in f's ASCENDING threshold list:
        if (x[f] <= key[e]) break;        /* every later test is true too */
        v[tree[e]] &= mask[e];            /* clear the false node's left leaves */
    for each tree: exit leaf = lowest set bit of v[tree]

No per-row pointer chasing: the hot loop is a linear stream over sorted keys
with one well-predicted break per feature, and the per-tree state is
``words`` uint64 accumulators (multi-word for trees beyond 64 leaves).  The
lowest-set-bit scan uses ``__builtin_ctzll`` under GCC/Clang and a portable
shift loop otherwise — build with ``-DREPRO_NO_BUILTINS`` to force the
portable path (the CI degradation job does exactly that).

At batch, the per-row scorer is memory-bound: every row re-streams the whole
threshold table (~24 B/entry — hundreds of KB per row on large forests).  So
``predict_batch`` walks blocks of 8 rows through one shared pass over the
stream, amortizing every table load 8x.  The block keeps the early exit —
ascending keys make ``x > key`` monotone decreasing per row, so an 8-bit
``act`` bitset recomputed per entry only ever loses bits and ``act == 0``
ends the feature for the whole block — and applies masks branch-free:
``m[k] | (((uint64_t)((act >> r) & 1)) - 1)`` is the mask when row ``r`` is
active and all-ones (a no-op AND) when it is not.  Live-leaf state is
row-minor (``v[(t*words + k)*8 + r]``) so one (tree, word) touch lands the
whole block's lane on a single cache line.

On x86 the blocked apply is lifted to AVX2 (same runtime-cpuid dispatch and
``simd_isa()`` export as the table-walk unit): one broadcast compare per
entry yields the 8-row active set, sign-extension widens it to 64-bit lane
masks, and ``v &= mk | ~act`` folds to two ``andnot`` ops per half-block per
word — ~3x fewer instructions than the scalar 8-lane apply, which stays in
the unit as the mandatory fallback (and the whole story on aarch64, where
this scorer has no NEON block: ``simd_isa()`` honestly reports "scalar").

Integer translation unit only: like the other deterministic C backends, both
flint and integer modes run the uint32-partials unit and diverge only in the
shared numpy finalize, so the emitter refuses anything else.  The scalar
paths need only <stdint.h>.
"""
from __future__ import annotations

from repro.codegen.table_emitter import _array_lines, _i32, _simd_prelude

_CTZ64 = [
    "static int ctz64(uint64_t x) {",
    "#if defined(__GNUC__) && !defined(REPRO_NO_BUILTINS)",
    "  return __builtin_ctzll(x);",
    "#else",
    "  int n = 0;",
    "  while (!(x & 1u)) { x >>= 1; ++n; }",
    "  return n;",
    "#endif",
    "}",
]


def _u64(v: int) -> str:
    return f"0x{int(v) & 0xFFFFFFFFFFFFFFFF:016x}ull"


def _i64(v: int) -> str:
    return f"{int(v)}ll"


_BLOCK_ROWS = 8  # rows sharing one pass over the threshold stream


def emit_bitvector_c(bv, mode: str = "integer") -> str:
    """Emit the standalone bitvector scorer for a ``BitvectorEnsemble``.

    Single-row ``predict(data, result)`` over FlInt int32 keys filling uint32
    partials (the block tail path, and the contract every other emitter
    shares), the row-blocked ``predict_block8``, the shared ``predict_class``,
    and a ``predict_batch`` entry that runs full blocks through the blocked
    scorer and the remainder through ``predict`` — a complete translation
    unit; nothing from ``c_emitter`` needs appending.
    """
    assert mode == "integer", (
        "the bitvector scorer is emitted once as the integer translation "
        "unit; flint reuses it and diverges only in the shared finalize"
    )
    from repro.codegen.c_emitter import emit_predict_class

    t, c, f, w = bv.n_trees, bv.n_classes, bv.n_features, bv.words
    lines = ["#include <stdint.h>", ""]
    lines += _simd_prelude()
    lines.append("")
    lines.append(
        f"/* InTreeger bitvector (QuickScorer-family) ensemble: per-feature\n"
        f"   ascending threshold streams + false-node leaf masks. trees={t}\n"
        f"   classes={c} entries={bv.total_entries} words={w} "
        f"scale={bv.scale} */"
    )
    lines += _array_lines("feat_off", "int64_t", bv.feat_offsets, _i64)
    lines += _array_lines("thr_key", "int32_t", bv.thr_key, _i32)
    lines += _array_lines("thr_tree", "int32_t", bv.thr_tree, _i32)
    lines += _array_lines("thr_mask", "uint64_t", bv.thr_mask.reshape(-1), _u64)
    lines += _array_lines("init_mask", "uint64_t", bv.init_mask.reshape(-1), _u64)
    lines += _array_lines("leaf_off", "int64_t", bv.leaf_offsets[:-1], _i64)
    lines += _array_lines(
        "leaf_fixed", "uint32_t", bv.leaf_fixed.reshape(-1),
        lambda v: f"{int(v)}u",
    )
    lines.append("")
    lines += _CTZ64
    lines += [
        "",
        "void predict(const int32_t* data, uint32_t* result) {",
        f"  uint64_t v[{t * w}];",
        f"  for (int i = 0; i < {t * w}; ++i) v[i] = init_mask[i];",
        f"  for (int f = 0; f < {f}; ++f) {{",
        "    const int32_t xf = data[f];",
        "    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; ++e) {",
        "      if (xf <= thr_key[e]) break;  /* ascending: rest true too */",
        f"      uint64_t* vt = v + (int64_t)thr_tree[e] * {w};",
        f"      const uint64_t* m = thr_mask + e * {w};",
        f"      for (int k = 0; k < {w}; ++k) vt[k] &= m[k];",
        "    }",
        "  }",
        f"  for (int i = 0; i < {c}; ++i) result[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        "    int leaf = 0;",
        f"    for (int k = 0; k < {w}; ++k) {{",
        f"      const uint64_t word = v[t * {w} + k];",
        "      if (word) { leaf = k * 64 + ctz64(word); break; }",
        "    }",
        f"    const uint32_t* lf = leaf_fixed + (leaf_off[t] + leaf) * {c};",
        f"    for (int i = 0; i < {c}; ++i) result[i] += lf[i];",
        "  }",
        "}",
        "",
    ]
    lines += emit_predict_class(c, "uint32_t", "int32_t")
    r = _BLOCK_ROWS
    # leaf extraction + class adds shared by the scalar and AVX2 blocks
    # (identical add order per tree -> bit-identical partials everywhere)
    block_tail = [
        f"  for (long i = 0; i < {r * c}; ++i) scores[i] = 0;",
        f"  for (int t = 0; t < {t}; ++t) {{",
        f"    for (int rr = 0; rr < {r}; ++rr) {{",
        "      int leaf = 0;",
        f"      for (int k = 0; k < {w}; ++k) {{",
        f"        const uint64_t word = v[(t * {w} + k) * {r} + rr];",
        "        if (word) { leaf = k * 64 + ctz64(word); break; }",
        "      }",
        f"      const uint32_t* lf = leaf_fixed + (leaf_off[t] + leaf) * {c};",
        f"      uint32_t* out = scores + rr * {c};",
        f"      for (int i = 0; i < {c}; ++i) out[i] += lf[i];",
        "    }",
        "  }",
        "}",
    ]
    lines += [
        "",
        f"/* {r} rows share ONE pass over the threshold stream (the per-row",
        "   scorer re-streams the whole table per row and is memory-bound at",
        "   batch).  act = the block's still-active rows for this entry,",
        "   recomputed branch-free each entry: ascending keys make x > key",
        "   monotone decreasing, so act only loses bits and act == 0 ends",
        "   the feature for everyone.  Inactive rows AND with all-ones. */",
        f"static void predict_block{r}(const int32_t* data, uint32_t* scores) {{",
        f"  uint64_t v[{t * w * r}];  /* row-minor: v[(t*{w} + k)*{r} + rr] */",
        f"  for (int i = 0; i < {t * w}; ++i) {{",
        "    const uint64_t iv = init_mask[i];",
        f"    for (int rr = 0; rr < {r}; ++rr) v[i * {r} + rr] = iv;",
        "  }",
        f"  for (int f = 0; f < {f}; ++f) {{",
        f"    int32_t xf[{r}];",
        f"    for (int rr = 0; rr < {r}; ++rr) xf[rr] = data[rr * {f} + f];",
        "    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; ++e) {",
        "      const int32_t key = thr_key[e];",
        "      uint32_t act = 0;",
        f"      for (int rr = 0; rr < {r}; ++rr)",
        "        act |= (uint32_t)(xf[rr] > key) << rr;",
        "      if (!act) break;  /* ascending: rest true for no row either */",
        f"      uint64_t* vt = v + (int64_t)thr_tree[e] * {w * r};",
        f"      const uint64_t* m = thr_mask + e * {w};",
        f"      for (int k = 0; k < {w}; ++k) {{",
        "        const uint64_t mk = m[k];",
        f"        uint64_t* vp = vt + k * {r};",
        f"        for (int rr = 0; rr < {r}; ++rr)",
        "          vp[rr] &= mk | (((uint64_t)((act >> rr) & 1u)) - 1u);",
        "      }",
        "    }",
        "  }",
    ] + block_tail + [
        "",
        "#if defined(REPRO_HAVE_AVX2)",
        "/* The same block, mask application lifted to AVX2: one broadcast",
        "   compare per entry gives the 8-row active set; sign-extending the",
        "   32-bit compare lanes yields 64-bit all-ones/zero row masks, and",
        "   v &= mk | ~act folds to andnot(andnot(mk, act), v) — two ops per",
        "   half-block per word instead of the scalar 8-lane or/and chain. */",
        '__attribute__((target("avx2")))',
        f"static void predict_block{r}_avx2(const int32_t* data, uint32_t* scores) {{",
        f"  uint64_t v[{t * w * r}];",
        f"  for (int i = 0; i < {t * w}; ++i) {{",
        "    const __m256i iv = _mm256_set1_epi64x((long long)init_mask[i]);",
        f"    _mm256_storeu_si256((__m256i*)(v + i * {r}), iv);",
        f"    _mm256_storeu_si256((__m256i*)(v + i * {r} + 4), iv);",
        "  }",
        "  const __m256i vstride = _mm256_setr_epi32("
        + ", ".join(str(k * f) for k in range(r)) + ");",
        f"  for (int f = 0; f < {f}; ++f) {{",
        "    const __m256i xv = _mm256_i32gather_epi32(data + f, vstride, 4);",
        "    for (int64_t e = feat_off[f]; e < feat_off[f + 1]; ++e) {",
        "      const __m256i cmp = _mm256_cmpgt_epi32(",
        "          xv, _mm256_set1_epi32(thr_key[e]));",
        "      if (!_mm256_movemask_epi8(cmp)) break;  /* no active rows */",
        "      const __m256i alo = _mm256_cvtepi32_epi64("
        "_mm256_castsi256_si128(cmp));",
        "      const __m256i ahi = _mm256_cvtepi32_epi64("
        "_mm256_extracti128_si256(cmp, 1));",
        f"      uint64_t* vt = v + (int64_t)thr_tree[e] * {w * r};",
        f"      const uint64_t* m = thr_mask + e * {w};",
        f"      for (int k = 0; k < {w}; ++k) {{",
        "        const __m256i mk = _mm256_set1_epi64x((long long)m[k]);",
        f"        uint64_t* vp = vt + k * {r};",
        "        __m256i lo = _mm256_loadu_si256((const __m256i*)vp);",
        "        __m256i hi = _mm256_loadu_si256((const __m256i*)(vp + 4));",
        "        lo = _mm256_andnot_si256(_mm256_andnot_si256(mk, alo), lo);",
        "        hi = _mm256_andnot_si256(_mm256_andnot_si256(mk, ahi), hi);",
        "        _mm256_storeu_si256((__m256i*)vp, lo);",
        "        _mm256_storeu_si256((__m256i*)(vp + 4), hi);",
        "      }",
        "    }",
        "  }",
    ] + block_tail + [
        "#endif  /* REPRO_HAVE_AVX2 */",
        "",
        "/* runtime dispatch mirrors the table-walk unit, but this scorer has",
        "   no NEON block: scalar is the honest answer off x86-with-AVX2. */",
        "static const char* g_simd_isa = 0;",
        "",
        "static void pick_simd(void) {",
        "#if defined(REPRO_HAVE_AVX2)",
        '  if (__builtin_cpu_supports("avx2")) { g_simd_isa = "avx2"; return; }',
        "#endif",
        '  g_simd_isa = "scalar";',
        "}",
        "",
        "const char* simd_isa(void) {",
        "  if (!g_simd_isa) pick_simd();",
        "  return g_simd_isa;",
        "}",
        "",
        "void predict_batch(const int32_t* data, long n_rows,",
        "                   uint32_t* scores, int32_t* preds) {",
        "  if (!g_simd_isa) pick_simd();",
        "  long r0 = 0;",
        "#if defined(REPRO_HAVE_AVX2)",
        "  if (g_simd_isa[0] == 'a')",
        f"    for (; r0 + {r} <= n_rows; r0 += {r})",
        f"      predict_block{r}_avx2(data + r0 * {f}, scores + r0 * {c});",
        "#endif",
        f"  for (; r0 + {r} <= n_rows; r0 += {r})",
        f"    predict_block{r}(data + r0 * {f}, scores + r0 * {c});",
        "  for (; r0 < n_rows; ++r0)",
        f"    predict(data + r0 * {f}, scores + r0 * {c});",
        "  for (long rr = 0; rr < n_rows; ++rr) {",
        f"    const uint32_t* out = scores + rr * {c};",
        "    int best = 0;",
        f"    for (int i = 1; i < {c}; ++i) if (out[i] > out[best]) best = i;",
        "    preds[rr] = best;",
        "  }",
        "}",
        "",
    ]
    return "\n".join(lines)
